//! Bench for paper Table 2: end-to-end cost of the six deployment
//! strategies on both datasets.  Uses real engines for trace recording
//! (when artifacts are present; mock engines otherwise) and times the
//! DES replay of each strategy.
//!
//!     cargo bench --bench table2_deployments [-- --prompts 10]

use ce_collm::config::AblationFlags;
use ce_collm::harness::des::{simulate, SimConfig, Strategy};
use ce_collm::harness::runner::{record_main_experiments, ExperimentConfig, PolicyKey};
use ce_collm::harness::tables;
use ce_collm::net::profiles::LinkProfile;
use ce_collm::util::bench::bench;
use ce_collm::util::cli::Args;

mod common;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let cfg = ExperimentConfig {
        n_prompts: args.get_parse("prompts", 10),
        repeats: args.get_parse("repeats", 3),
        max_new_tokens: args.get_parse("max-new", 64),
        seed: 42,
    };
    let link = LinkProfile::paper_scaled();
    let (mut edge, mut cloud, dims) = common::engines();

    eprintln!("recording traces ({} prompts x 2 datasets x 4 policies)...", cfg.n_prompts);
    let rec = record_main_experiments(edge.as_mut(), cloud.as_mut(), &cfg).unwrap();

    println!("== DES replay cost per strategy (Alpaca traces) ==");
    for (name, traces, strategy) in [
        ("cloud-only", &rec.alpaca.t10[..], Strategy::CloudOnly),
        ("naive-split", &rec.alpaca.t10[..], Strategy::NaiveSplit),
        ("standalone", &rec.alpaca.standalone[..], Strategy::Standalone),
        ("ce-collm θ=0.8", &rec.alpaca.t08[..], Strategy::CeCollm(AblationFlags::default())),
        ("ce-collm θ=0.9", rec.alpaca.for_policy(PolicyKey::T09), Strategy::CeCollm(AblationFlags::default())),
        ("ce-collm θ=1.0", &rec.alpaca.t10[..], Strategy::CeCollm(AblationFlags::default())),
    ] {
        let per_client = vec![traces.to_vec()];
        bench(&format!("table2 replay: {name}"), 0.3, || {
            simulate(
                &per_client,
                &dims,
                &rec.cost,
                &SimConfig {
                    strategy,
                    link,
                    seed: 1,
                    workers: 1,
                    cross_device_batch: true,
                    ..Default::default()
                },
            )
        });
    }

    println!("\n== Table 2 ==");
    println!("{}", tables::table2(&rec, &dims, link, &cfg));
}
