//! Shared bench setup: real PJRT engines when `artifacts/` exists,
//! deterministic mocks otherwise (so `cargo bench` is green either way).

use ce_collm::model::manifest::{test_manifest, ModelDims};
use ce_collm::runtime::mock::{MockCloud, MockEdge, MockOracle};
use ce_collm::runtime::stack::LocalStack;
use ce_collm::runtime::traits::{CloudEngine, EdgeEngine};

pub fn engines() -> (Box<dyn EdgeEngine>, Box<dyn CloudEngine>, ModelDims) {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        // leak the stack: benches live for the process lifetime and the
        // sessions borrow its Rc'd artifacts
        let stack = Box::leak(Box::new(LocalStack::load("artifacts").unwrap()));
        let dims = stack.manifest.model.clone();
        eprintln!("using REAL PJRT engines");
        (Box::new(stack.edge_session()), Box::new(stack.cloud_session()), dims)
    } else {
        let dims = test_manifest().model;
        let o = MockOracle::new(7);
        eprintln!("artifacts/ missing: using mock engines");
        (
            Box::new(MockEdge::new(o, dims.clone())),
            Box::new(MockCloud::new(o, dims.clone())),
            dims,
        )
    }
}
