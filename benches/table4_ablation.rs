//! Bench for paper Table 4 (ablation study): replay cost of each ablated
//! configuration and the rendered table.
//!
//!     cargo bench --bench table4_ablation [-- --prompts 10]

use ce_collm::config::AblationFlags;
use ce_collm::harness::des::{simulate, SimConfig, Strategy};
use ce_collm::harness::runner::{record_main_experiments, ExperimentConfig};
use ce_collm::harness::tables;
use ce_collm::net::profiles::LinkProfile;
use ce_collm::util::bench::bench;
use ce_collm::util::cli::Args;

mod common;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let cfg = ExperimentConfig {
        n_prompts: args.get_parse("prompts", 10),
        repeats: args.get_parse("repeats", 3),
        max_new_tokens: args.get_parse("max-new", 64),
        seed: 42,
    };
    let link = LinkProfile::paper_scaled();
    let (mut edge, mut cloud, dims) = common::engines();

    eprintln!("recording traces...");
    let rec = record_main_experiments(edge.as_mut(), cloud.as_mut(), &cfg).unwrap();

    println!("== DES replay cost per ablation (XSum traces) ==");
    for (name, traces, flags) in [
        ("full CE-CoLLM θ=0.8", &rec.xsum.t08, AblationFlags::default()),
        ("− half precision", &rec.xsum.t08, AblationFlags::without_half_precision()),
        ("− early exit", &rec.xsum.t10, AblationFlags::without_early_exit()),
        ("− CM & parallel upload", &rec.xsum.t08, AblationFlags::without_cm_and_parallel_upload()),
    ] {
        let per_client = vec![traces.to_vec()];
        bench(&format!("table4 replay: {name}"), 0.3, || {
            simulate(
                &per_client,
                &dims,
                &rec.cost,
                &SimConfig {
                    strategy: Strategy::CeCollm(flags),
                    link,
                    seed: 1,
                    workers: 1,
                    cross_device_batch: true,
                    ..Default::default()
                },
            )
        });
    }

    println!("\n== Table 4 ==");
    println!("{}", tables::table4(&rec, &dims, link, &cfg));
}
