//! Bench for paper Figure 4: multi-client scaling (1..5 edge devices),
//! CE-CoLLM vs cloud-based deployment, plus Fig 4(c)'s request-rate and
//! transmitted-data comparison.
//!
//!     cargo bench --bench fig4_scaling [-- --prompts 10 --clients 5]

use ce_collm::config::AblationFlags;
use ce_collm::harness::des::{simulate, SimConfig, Strategy};
use ce_collm::harness::runner::{record_main_experiments, ExperimentConfig};
use ce_collm::harness::tables;
use ce_collm::harness::trace::Trace;
use ce_collm::net::profiles::LinkProfile;
use ce_collm::util::bench::bench;
use ce_collm::util::cli::Args;

mod common;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let cfg = ExperimentConfig {
        n_prompts: args.get_parse("prompts", 10),
        repeats: args.get_parse("repeats", 3),
        max_new_tokens: args.get_parse("max-new", 64),
        seed: 42,
    };
    let max_clients: usize = args.get_parse("clients", 5);
    let link = LinkProfile::paper_scaled();
    let (mut edge, mut cloud, dims) = common::engines();

    eprintln!("recording traces...");
    let rec = record_main_experiments(edge.as_mut(), cloud.as_mut(), &cfg).unwrap();

    println!("== DES scaling replay cost (Alpaca, θ=0.8) ==");
    for n in [1usize, max_clients] {
        let per_client: Vec<Vec<Trace>> = (0..n).map(|_| rec.alpaca.t08.clone()).collect();
        bench(&format!("fig4 replay: {n} clients"), 0.3, || {
            simulate(
                &per_client,
                &dims,
                &rec.cost,
                &SimConfig {
                    strategy: Strategy::CeCollm(AblationFlags::default()),
                    link,
                    seed: 1,
                    workers: 1,
                    cross_device_batch: true,
                    ..Default::default()
                },
            )
        });
    }

    println!("\n== Figure 4 ==");
    println!("{}", tables::fig4(&rec, &dims, link, &cfg, max_clients));
}
