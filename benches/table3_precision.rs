//! Bench for paper Table 3: accuracy across thresholds × transmission
//! precision (f16 vs f32) on TruthfulQA/XSum/CNN-DM-like sets.
//!
//!     cargo bench --bench table3_precision [-- --prompts 8]

use ce_collm::harness::runner::ExperimentConfig;
use ce_collm::harness::tables;
use ce_collm::util::bench::bench;
use ce_collm::util::cli::Args;

mod common;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let cfg = ExperimentConfig {
        n_prompts: args.get_parse("prompts", 8),
        repeats: 1,
        max_new_tokens: args.get_parse("max-new", 48),
        seed: 42,
    };
    let (mut edge, mut cloud, _dims) = common::engines();

    let mut table = String::new();
    bench("table3 full pipeline (record + score)", 0.0, || {
        table = tables::table3(edge.as_mut(), cloud.as_mut(), &cfg).unwrap();
    });
    println!("\n== Table 3 ==\n{table}");
}
