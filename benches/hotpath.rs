//! Hot-path microbenchmarks: everything that runs per token on the
//! request path — quantization, protocol codec (owned vs borrowed),
//! frame ingest (scratch-copy `feed_all` vs single-copy `read_into`),
//! reactor wake cost per backend, content-manager ops, batched decode,
//! exit policy, DES replay — plus the real PJRT per-segment step costs
//! when artifacts are available.
//!
//!     cargo bench --bench hotpath [-- --smoke] [-- --json PATH]
//!
//! `--smoke` shrinks every budget for CI; results are always written to
//! `BENCH_hotpath.json` (override with `--json`) so the workflow can
//! upload them as the perf-trajectory artifact.

use ce_collm::config::{AblationFlags, CloudConfig, ExitPolicy};
use ce_collm::coordinator::content_manager::{ContentManager, PlanReq};
use ce_collm::coordinator::context_store::ContextStore;
use ce_collm::coordinator::policy::TokenPolicy;
use ce_collm::coordinator::protocol::Message;
use ce_collm::coordinator::scheduler::{
    InferOutcome, Reply, SchedMsg, Scheduler, SessionFactory, UploadPayload,
};
use ce_collm::eval::rouge::rouge_l;
use ce_collm::harness::cost::CostModel;
use ce_collm::harness::des::{simulate, SimConfig, Strategy};
use ce_collm::harness::trace::{record, CallTimings};
use ce_collm::model::manifest::test_manifest;
use ce_collm::net::codec::FrameCodec;
use ce_collm::net::profiles::LinkProfile;
use ce_collm::net::transport::{TcpTransport, Transport};
use ce_collm::quant::{self, Precision};
use ce_collm::runtime::mock::{MockCloud, MockEdge, MockOracle};
use ce_collm::runtime::traits::{BatchItem, CloudEngine, EdgeEngine};
use ce_collm::util::bench::{bench, bench_throughput, to_json, BenchResult};
use ce_collm::util::cli::Args;
use std::sync::Arc;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let smoke = args.has("smoke");
    let scale = if smoke { 0.15 } else { 1.0 };
    let json_path = args.get_or("json", "BENCH_hotpath.json");
    let mut results: Vec<BenchResult> = Vec::new();

    println!("== quantization (128-dim hidden state, the per-token upload) ==");
    let h: Vec<f32> = (0..128).map(|i| (i as f32 - 64.0) * 3.1).collect();
    results.push(bench_throughput("quant::pack f16 [128]", 256, 0.3 * scale, || {
        quant::pack(&h, Precision::F16)
    }));
    results.push(bench_throughput("quant::pack f32 [128]", 512, 0.3 * scale, || {
        quant::pack(&h, Precision::F32)
    }));
    let p16 = quant::pack(&h, Precision::F16);
    results.push(bench("quant::unpack f16 [128] (alloc)", 0.3 * scale, || {
        quant::unpack(&p16, Precision::F16).unwrap()
    }));
    let mut reuse = Vec::new();
    results.push(bench("quant::unpack_into f16 [128] (reused buf)", 0.3 * scale, || {
        quant::unpack_into(&p16, Precision::F16, &mut reuse).unwrap();
        reuse.len()
    }));
    // prompt-sized payload
    let hp: Vec<f32> = (0..256 * 128).map(|i| (i % 997) as f32).collect();
    results.push(bench_throughput(
        "quant::pack f16 [256x128] (prompt)",
        hp.len() * 2,
        0.3 * scale,
        || quant::pack(&hp, Precision::F16),
    ));

    println!("\n== wire protocol ==");
    let up = Message::UploadHidden {
        device_id: 3,
        req_id: 1,
        start_pos: 40,
        count: 1,
        prompt_len: 30,
        precision: Precision::F16,
        payload: p16.clone(),
    };
    results.push(bench("protocol encode UploadHidden[128]", 0.3 * scale, || up.encode()));
    let enc = up.encode();
    results.push(bench("protocol decode UploadHidden[128] (owned)", 0.3 * scale, || {
        Message::decode(&enc).unwrap()
    }));
    // the serve path's actual per-token upload codec: owned decode+unpack
    // vs the borrowed fast path feeding a reused buffer
    results.push(bench("upload codec: decode+unpack (owned)", 0.3 * scale, || {
        match Message::decode(&enc).unwrap() {
            Message::UploadHidden { payload, precision, .. } => {
                quant::unpack(&payload, precision).unwrap().len()
            }
            _ => unreachable!(),
        }
    }));
    let mut scratch = Vec::new();
    results.push(bench("upload codec: decode_upload+unpack_into", 0.3 * scale, || {
        let v = Message::decode_upload(&enc).unwrap().unwrap();
        quant::unpack_into(v.payload, v.precision, &mut scratch).unwrap();
        scratch.len()
    }));
    // the reactor's framing layer: a 4-frame chunk fed and drained
    let mut wire4 = Vec::new();
    for _ in 0..4 {
        wire4.extend_from_slice(&ce_collm::net::codec::encode_frame(&enc));
    }
    results.push(bench("codec feed 4-frame chunk + drain", 0.3 * scale, || {
        let mut c = FrameCodec::new();
        let mut got = 0usize;
        let mut next = c.feed(&wire4).unwrap();
        while let Some(f) = next {
            got += f.len();
            next = c.next_frame().unwrap();
        }
        got
    }));

    println!("\n== ingest: scratch copy vs single-copy read_into (64KiB upload frame) ==");
    {
        // A 64KiB upload body arriving through 16KiB socket reads (the
        // TcpTransport scratch size).  Baseline = the old path: every
        // chunk lands in scratch (the memcpy below stands in for the
        // kernel's copyout), then feed_all stages it through the codec
        // buffer into the frame — two user-space passes per payload
        // byte.  read_into = the reserve-then-fill path: once the
        // length prefix is visible the codec hands out the frame's own
        // tail and the "kernel" fills it directly — one pass.
        let payload = vec![42u8; 64 << 10];
        let wire = ce_collm::net::codec::encode_frame(&payload);
        const CHUNK: usize = 16 << 10;
        let mut scratch = vec![0u8; CHUNK];
        results.push(bench_throughput(
            "ingest feed_all 64KiB frame (scratch copy)",
            wire.len(),
            0.3 * scale,
            || {
                let mut c = FrameCodec::new();
                let mut out = Vec::new();
                let mut off = 0;
                while off < wire.len() {
                    let n = CHUNK.min(wire.len() - off);
                    scratch[..n].copy_from_slice(&wire[off..off + n]); // "kernel" copy
                    c.feed_all(&scratch[..n], &mut out).unwrap();
                    off += n;
                }
                assert_eq!(out.len(), 1);
                out
            },
        ));
        results.push(bench_throughput(
            "ingest read_into 64KiB frame (single copy)",
            wire.len(),
            0.3 * scale,
            || {
                let mut c = FrameCodec::new();
                let mut out = Vec::new();
                let mut off = 0;
                while off < wire.len() {
                    let n = if let Some(slot) = c.read_slot() {
                        let n = slot.len().min(CHUNK).min(wire.len() - off);
                        slot[..n].copy_from_slice(&wire[off..off + n]); // "kernel" copy
                        c.commit(n);
                        n
                    } else {
                        // header phase: stage through scratch like a
                        // real socket read would
                        let n = CHUNK.min(wire.len() - off);
                        scratch[..n].copy_from_slice(&wire[off..off + n]); // "kernel" copy
                        c.feed_all(&scratch[..n], &mut out).unwrap();
                        n
                    };
                    off += n;
                    while let Some(f) = c.next_frame().unwrap() {
                        out.push(f);
                    }
                }
                assert_eq!(out.len(), 1);
                out
            },
        ));
    }

    println!("\n== tcp frame send (localhost, drained by sink threads) ==");
    {
        use std::io::Write;
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let sink = std::thread::spawn(move || {
            let drainers: Vec<_> = listener
                .incoming()
                .take(2)
                .map(|s| {
                    let mut s = s.unwrap();
                    std::thread::spawn(move || {
                        use std::io::Read;
                        let mut buf = [0u8; 65536];
                        while matches!(s.read(&mut buf), Ok(n) if n > 0) {}
                    })
                })
                .collect();
            for d in drainers {
                let _ = d.join();
            }
        });
        // the pre-codec transport issued two write syscalls per frame
        // (prefix, then payload); the codec path queues them contiguous
        // and issues one — same ~286-byte UploadHidden frame on both
        let mut legacy = std::net::TcpStream::connect(addr).unwrap();
        legacy.set_nodelay(true).unwrap();
        let mut codec_path = TcpTransport::connect(&addr.to_string()).unwrap();
        results.push(bench("tcp send: prefix+payload (2 writes, legacy)", 0.3 * scale, || {
            legacy.write_all(&(enc.len() as u32).to_le_bytes()).unwrap();
            legacy.write_all(&enc).unwrap();
        }));
        results.push(bench("tcp send: codec single buffer (1 write)", 0.3 * scale, || {
            codec_path.send(&enc).unwrap();
        }));
        drop(legacy);
        drop(codec_path);
        let _ = sink.join();
    }

    println!("\n== reactor wake (stats round trip past 256 idle conns) ==");
    {
        // A stats() call forces exactly one wake: the poll backend
        // rebuilds a 256-entry pollfd array to serve it, epoll does
        // O(1) work.  The conns are handshaken (Active) so no reap
        // scan pollutes the wake path.
        use ce_collm::config::{ReactorBackend, ReactorConfig};
        use ce_collm::coordinator::protocol::Channel;
        use ce_collm::net::reactor::Reactor;
        let mut backends = vec![("poll", ReactorBackend::Poll)];
        if cfg!(target_os = "linux") {
            backends.push(("epoll", ReactorBackend::Epoll));
        }
        for (name, backend) in backends {
            // shards ∈ {1, 4}: the 1-shard labels match earlier runs
            // for bench_diff continuity; the 4-shard pair tracks the
            // fleet's fan-out cost (a stats round trip touches EVERY
            // shard, and the 256 conns spread round-robin across them)
            for shards in [1usize, 4] {
                let dims = test_manifest().model;
                let sdims = dims.clone();
                let sched = Scheduler::spawn(
                    dims.clone(),
                    CloudConfig::default(),
                    Arc::new(move || {
                        let sdims = sdims.clone();
                        let f: SessionFactory = Box::new(move |_| {
                            Ok(Box::new(MockCloud::new(MockOracle::new(1), sdims.clone())) as _)
                        });
                        Ok(f)
                    }),
                )
                .unwrap();
                let rcfg = ReactorConfig { backend, shards, ..ReactorConfig::default() };
                let reactor = Reactor::spawn(sched.router(), dims, rcfg, None).unwrap();
                let handle = reactor.handle();
                let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
                let addr = listener.local_addr().unwrap();
                let mut clients = Vec::with_capacity(256);
                for i in 0..256u64 {
                    let mut t = TcpTransport::connect(&addr.to_string()).unwrap();
                    let (server_end, _) = listener.accept().unwrap();
                    handle.register(server_end).unwrap();
                    t.send(
                        &Message::Hello {
                            device_id: i,
                            session: 1,
                            channel: Channel::Infer,
                            resume: false,
                            mirror: false,
                        }
                        .encode(),
                    )
                    .unwrap();
                    assert_eq!(t.recv().unwrap(), Message::Ack.encode());
                    clients.push(t);
                }
                let label = match (name, shards) {
                    ("epoll", 1) => "reactor wake round trip, 256 idle conns (epoll)",
                    ("epoll", _) => "reactor wake round trip, 256 idle conns (epoll, 4 shards)",
                    (_, 1) => "reactor wake round trip, 256 idle conns (poll)",
                    (_, _) => "reactor wake round trip, 256 idle conns (poll, 4 shards)",
                };
                results.push(bench(label, 0.2 * scale, || handle.stats().unwrap().wakes));
                drop(clients);
                reactor.shutdown();
                sched.shutdown();
            }
        }
    }

    println!("\n== reactor frame route: trace off vs on ==");
    {
        // A Ping answered in-reactor is the purest frame-route cycle
        // (no scheduler hop): the pair bounds what `CE_TRACE` costs per
        // frame when recording, and documents that the off path stays
        // a no-op (a None sink is two branch tests per frame).
        use ce_collm::config::ReactorConfig;
        use ce_collm::coordinator::protocol::Channel;
        use ce_collm::net::reactor::Reactor;
        use ce_collm::trace::TraceSink;
        let trace_path = std::env::temp_dir()
            .join(format!("ce_bench_trace_{}.jsonl", std::process::id()))
            .display()
            .to_string();
        for traced in [false, true] {
            let dims = test_manifest().model;
            let sdims = dims.clone();
            let sched = Scheduler::spawn(
                dims.clone(),
                CloudConfig::default(),
                Arc::new(move || {
                    let sdims = sdims.clone();
                    let f: SessionFactory = Box::new(move |_| {
                        Ok(Box::new(MockCloud::new(MockOracle::new(1), sdims.clone())) as _)
                    });
                    Ok(f)
                }),
            )
            .unwrap();
            let sink = if traced { Some(TraceSink::to_file(&trace_path).unwrap()) } else { None };
            let reactor = Reactor::spawn_traced(
                sched.router(),
                dims,
                ReactorConfig { shards: 1, ..ReactorConfig::default() },
                None,
                sink,
            )
            .unwrap();
            let handle = reactor.handle();
            let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let mut t = TcpTransport::connect(&addr.to_string()).unwrap();
            let (server_end, _) = listener.accept().unwrap();
            handle.register(server_end).unwrap();
            t.send(
                &Message::Hello {
                    device_id: 1,
                    session: 1,
                    channel: Channel::Infer,
                    resume: false,
                    mirror: false,
                }
                .encode(),
            )
            .unwrap();
            assert_eq!(t.recv().unwrap(), Message::Ack.encode());
            let label = if traced {
                "reactor frame route: ping round trip (trace on)"
            } else {
                "reactor frame route: ping round trip (trace off)"
            };
            let mut nonce = 0u64;
            results.push(bench(label, 0.2 * scale, || {
                nonce += 1;
                t.send(&Message::Ping { nonce }.encode()).unwrap();
                t.recv().unwrap()
            }));
            drop(t);
            reactor.shutdown();
            sched.shutdown();
        }
        let _ = std::fs::remove_file(&trace_path);
    }

    println!("\n== exit policy ==");
    let pol = TokenPolicy::new(ExitPolicy::Threshold(0.8), AblationFlags::default());
    results.push(bench("policy decide", 0.1 * scale, || pol.decide(0.7, 0.85)));

    println!("\n== content manager (per-token upload + plan) ==");
    results.push(bench("cm upload+plan cycle", 0.3 * scale, || {
        let mut cm = ContentManager::new(128);
        let h = vec![0.5f32; 30 * 128];
        cm.upload(1, 0, 0, 30, &h).unwrap();
        cm.plan(1, 0, 29, 30).unwrap();
        for pos in 30..60u32 {
            cm.upload(1, 0, pos, 30, &h[..128]).unwrap();
            cm.plan(1, 0, pos, 30).unwrap();
        }
        cm.end_session(1);
    }));
    results.push(bench("cm upload_owned+plan cycle (moved payloads)", 0.3 * scale, || {
        let mut cm = ContentManager::new(128);
        cm.upload_owned(1, 0, 0, 30, vec![0.5f32; 30 * 128]).unwrap();
        cm.plan(1, 0, 29, 30).unwrap();
        for pos in 30..60u32 {
            cm.upload_owned(1, 0, pos, 30, vec![0.5f32; 128]).unwrap();
            cm.plan(1, 0, pos, 30).unwrap();
        }
        cm.end_session(1);
    }));

    println!("\n== context store (budget metering + LRU on the serve path) ==");
    {
        use ce_collm::coordinator::context_store::SessionFactory as StoreFactory;
        let dims = test_manifest().model;
        let d = dims.d_model;
        let mut factory: StoreFactory = {
            let fdims = dims.clone();
            Box::new(move |_| Ok(Box::new(MockCloud::new(MockOracle::new(1), fdims.clone())) as _))
        };
        let settle = |store: &mut ContextStore, f: &mut StoreFactory, dev: u64| {
            store.upload_owned(dev, 1, 0, 8, vec![0.5; 8 * d]).unwrap();
            let req = PlanReq { device: dev, req_id: 1, pos: 7, prompt_len: 8 };
            let plan = store.plan_batch(&[req], usize::MAX).remove(0).unwrap();
            let s = store.session(dev, f).unwrap();
            s.reset();
            let (h, len) = plan.prefill.unwrap();
            s.prefill(&h, len).unwrap();
        };
        // the per-token store ops with 32 resident devices to scan past
        let mut store = ContextStore::new(&dims, Some(u64::MAX), None);
        for dev in 0..32u64 {
            settle(&mut store, &mut factory, dev);
        }
        let mut pos = 8u32;
        results.push(bench("store touch: upload+plan (32 resident devices)", 0.3 * scale, || {
            store.upload_owned(7, 1, pos, 8, vec![0.5; d]).unwrap();
            let req = PlanReq { device: 7, req_id: 1, pos, prompt_len: 8 };
            store.plan_batch(&[req], usize::MAX).remove(0).unwrap();
            pos += 1;
        }));
        results.push(bench("store budget sweep, under budget (32 devices)", 0.3 * scale, || {
            store.reap_ttl(std::time::Instant::now(), |_| false);
            store.enforce_budget(|_| false)
        }));
        // evict + replay-plan: the full recovery cycle of one device
        let kv8 = 8 * dims.cloud_kv_bytes_per_pos() as u64;
        let mut tight = ContextStore::new(&dims, Some(kv8 + kv8 / 2), None);
        settle(&mut tight, &mut factory, 1);
        settle(&mut tight, &mut factory, 2);
        results.push(bench("store evict + replay-plan cycle", 0.3 * scale, || {
            // over budget: the LRU of {1, 2} is evicted...
            tight.enforce_budget(|_| false);
            let victim = if tight.evicted_req(1).is_some() { 1u64 } else { 2 };
            // ...and replays its history from position 0
            tight.upload_owned(victim, 1, 0, 8, vec![0.5; 8 * d]).unwrap();
            let req = PlanReq { device: victim, req_id: 1, pos: 7, prompt_len: 8 };
            let plan = tight.plan_batch(&[req], usize::MAX).remove(0).unwrap();
            let s = tight.session(victim, &mut factory).unwrap();
            s.reset();
            let (h, len) = plan.prefill.unwrap();
            s.prefill(&h, len).unwrap();
        }));
    }

    println!("\n== batched decode (mock engine) ==");
    {
        let dims = test_manifest().model;
        let d = dims.d_model;
        let mk = || {
            let mut c = MockCloud::new(MockOracle::new(1), dims.clone());
            c.prefill(&vec![0.5; 4 * d], 4).unwrap();
            c
        };
        let items: Vec<BatchItem> =
            (4..12).map(|pos| BatchItem { h1: vec![0.5; d], pos }).collect();
        let mut fused = mk();
        results.push(bench("decode_batch fused (8-pos run)", 0.3 * scale, || {
            fused.decode_batch(&items).unwrap()
        }));
        let mut seq = mk();
        results.push(bench("decode sequential loop (8-pos run)", 0.3 * scale, || {
            items.iter().map(|b| seq.decode(&b.h1, b.pos).unwrap()).count()
        }));
    }

    println!("\n== scheduler (event-driven serving core, mock engine) ==");
    {
        let dims = test_manifest().model;
        let d = dims.d_model;
        let sdims = dims.clone();
        let sched = Scheduler::spawn(
            dims,
            CloudConfig::default(),
            Arc::new(move || {
                let sdims = sdims.clone();
                let f: SessionFactory = Box::new(move |_| {
                    Ok(Box::new(MockCloud::new(MockOracle::new(1), sdims.clone())) as _)
                });
                Ok(f)
            }),
        )
        .unwrap();
        let router = sched.router();
        let mut req = 0u32;
        results.push(bench("scheduler upload+infer round trip (8-pos prompt)", 0.3 * scale, || {
            req += 1;
            router
                .send(1, SchedMsg::Upload {
                    device: 1,
                    session: 0,
                    req_id: req,
                    start_pos: 0,
                    prompt_len: 8,
                    payload: UploadPayload::Floats(vec![0.5; 8 * d]),
                })
                .unwrap();
            let (tx, rx) = std::sync::mpsc::channel();
            router
                .send(1, SchedMsg::Infer {
                    device: 1,
                    session: 0,
                    req_id: req,
                    pos: 7,
                    prompt_len: 8,
                    deadline: None,
                    reply: Reply::channel(tx),
                })
                .unwrap();
            match rx.recv().unwrap().unwrap() {
                InferOutcome::Token(t) => t,
                InferOutcome::Evicted => unreachable!("no budget configured"),
            }
        }));
        // cross-device load: four devices' uploads + infers in flight at
        // once — the padded per-worker pass serves them together
        results.push(bench("scheduler 4-device cross-batch round trip", 0.3 * scale, || {
            req += 1;
            for dev in 0..4u64 {
                router
                    .send(dev, SchedMsg::Upload {
                        device: dev,
                        session: 0,
                        req_id: req,
                        start_pos: 0,
                        prompt_len: 8,
                        payload: UploadPayload::Floats(vec![0.5; 8 * d]),
                    })
                    .unwrap();
            }
            let rxs: Vec<_> = (0..4u64)
                .map(|dev| {
                    let (tx, rx) = std::sync::mpsc::channel();
                    router
                        .send(dev, SchedMsg::Infer {
                            device: dev,
                            session: 0,
                            req_id: req,
                            pos: 7,
                            prompt_len: 8,
                            deadline: None,
                            reply: Reply::channel(tx),
                        })
                        .unwrap();
                    rx
                })
                .collect();
            for rx in rxs {
                let _ = rx.recv().unwrap().unwrap();
            }
        }));
        let stats = sched.shutdown();
        println!(
            "    ({} engine passes over {} served requests, widest pass {} devices)",
            stats.engine_passes, stats.requests_served, stats.batch_devices_max
        );
    }

    println!("\n== metrics: hist record + scheduler token path, off vs on ==");
    {
        // Budget: one enabled record is a bucket index plus three relaxed
        // atomic RMWs — it must stay within ~20ns on commodity cores, and
        // the disabled path is an Option test that folds to nothing.  Off
        // legs run FIRST: resolving an enabled registry latches the
        // process-global switch, and both pairs share this process.
        use ce_collm::metrics::LatencyHist;
        use std::hint::black_box;
        let off: Option<Arc<LatencyHist>> = None;
        let mut i = 0u64;
        results.push(bench("hist record (off: None handle)", 0.2 * scale, || {
            i = i.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            if let Some(h) = black_box(&off) {
                h.record(i >> 34);
            }
            i
        }));
        let mk_sched = |metrics: bool| {
            let dims = test_manifest().model;
            let sdims = dims.clone();
            let cfg = CloudConfig { metrics, ..CloudConfig::default() };
            Scheduler::spawn(
                dims,
                cfg,
                Arc::new(move || {
                    let sdims = sdims.clone();
                    let f: SessionFactory = Box::new(move |_| {
                        Ok(Box::new(MockCloud::new(MockOracle::new(1), sdims.clone())) as _)
                    });
                    Ok(f)
                }),
            )
            .unwrap()
        };
        let token_trip = |router: &ce_collm::coordinator::scheduler::Router,
                          d: usize,
                          req: u32| {
            router
                .send(1, SchedMsg::Upload {
                    device: 1,
                    session: 0,
                    req_id: req,
                    start_pos: 0,
                    prompt_len: 8,
                    payload: UploadPayload::Floats(vec![0.5; 8 * d]),
                })
                .unwrap();
            let (tx, rx) = std::sync::mpsc::channel();
            router
                .send(1, SchedMsg::Infer {
                    device: 1,
                    session: 0,
                    req_id: req,
                    pos: 7,
                    prompt_len: 8,
                    deadline: None,
                    reply: Reply::channel(tx),
                })
                .unwrap();
            rx.recv().unwrap().unwrap()
        };
        let d = test_manifest().model.d_model;
        // off leg before any enabled registry exists in the process
        let sched_off = mk_sched(false);
        let router_off = sched_off.router();
        let mut req = 0u32;
        results.push(bench("scheduler token path (metrics off)", 0.2 * scale, || {
            req += 1;
            token_trip(&router_off, d, req)
        }));
        sched_off.shutdown();
        // the enabled legs: from here on the process-global latch is set
        let on = Some(Arc::new(LatencyHist::new()));
        let mut j = 0u64;
        results.push(bench("hist record (on)", 0.2 * scale, || {
            j = j.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            if let Some(h) = black_box(&on) {
                h.record(j >> 34);
            }
            j
        }));
        let sched_on = mk_sched(true);
        let router_on = sched_on.router();
        let mut req = 0u32;
        results.push(bench("scheduler token path (metrics on)", 0.2 * scale, || {
            req += 1;
            token_trip(&router_on, d, req)
        }));
        sched_on.shutdown();
    }

    println!("\n== eval ==");
    let a = "the machine is a test of a system's ability to exhibit intelligent behaviour";
    let b = "the machine is a test of a network's ability to produce intelligent behaviour";
    results.push(bench("rouge_l (2x ~80 chars)", 0.3 * scale, || rouge_l(a, b)));

    println!("\n== DES replay (mock trace, 1 client) ==");
    let dims = test_manifest().model;
    let o = MockOracle::new(1);
    let mut edge = MockEdge::new(o, dims.clone());
    let mut cloud = MockCloud::new(o, dims.clone());
    let mut t = CallTimings::default();
    let tr = record(&mut edge, &mut cloud, ExitPolicy::Threshold(0.8), Precision::F16,
                    "a benchmark prompt for des replay", 48, &mut t).unwrap();
    let cost = CostModel::synthetic(&dims);
    let traces = vec![vec![tr; 10]];
    results.push(bench("DES replay 10 requests (batched law)", 0.3 * scale, || {
        simulate(
            &traces,
            &dims,
            &cost,
            &SimConfig {
                strategy: Strategy::CeCollm(AblationFlags::default()),
                link: LinkProfile::paper_scaled(),
                seed: 0,
                workers: 1,
                cross_device_batch: true,
                ..Default::default()
            },
        )
    }));

    // real PJRT segment costs — the actual compute hot path (skipped in
    // smoke mode: CI has no artifacts and the budgets are long)
    if !smoke && std::path::Path::new("artifacts/manifest.json").exists() {
        println!("\n== real PJRT segment steps (artifacts) ==");
        let stack = ce_collm::runtime::stack::LocalStack::load("artifacts").unwrap();
        let tokzr = stack.tokenizer();
        let ids = tokzr.encode("the machine is a benchmark");
        let mut edge = stack.edge_session();
        let mut cloud = stack.cloud_session();

        results.push(bench("edge_prefill (short prompt -> P=64 bucket)", 2.0, || {
            edge.prefill(&ids).unwrap()
        }));
        let pre = edge.prefill(&ids).unwrap();
        let mut pos = ids.len();
        results.push(bench("edge seg1 decode (layers 0..3 + exit head)", 2.0, || {
            let out = edge.seg1(97, pos).unwrap();
            pos += 1;
            if pos >= stack.manifest.model.max_seq - 1 {
                edge.reset();
                edge.prefill(&ids).unwrap();
                pos = ids.len();
            }
            out
        }));
        edge.reset();
        let pre2 = edge.prefill(&ids).unwrap();
        let h1 = pre2.h1[(ids.len() - 1) * 128..].to_vec();
        let mut pos2 = ids.len();
        results.push(bench("edge seg2 decode (layers 3..5 + exit head)", 2.0, || {
            let out = edge.seg2(&h1, pos2).unwrap();
            pos2 += 1;
            if pos2 >= stack.manifest.model.max_seq - 1 {
                edge.reset();
                edge.prefill(&ids).unwrap();
                pos2 = ids.len();
            }
            out
        }));
        cloud.prefill(&pre.h1, ids.len()).unwrap();
        let mut pos3 = ids.len();
        results.push(bench("cloud decode (layers 3..8 + final head)", 2.0, || {
            let out = cloud.decode(&h1, pos3).unwrap();
            pos3 += 1;
            if pos3 >= stack.manifest.model.max_seq - 1 {
                cloud.reset();
                cloud.prefill(&pre.h1, ids.len()).unwrap();
                pos3 = ids.len();
            }
            out
        }));
        results.push(bench("cloud_prefill (short prompt -> P=64 bucket)", 2.0, || {
            cloud.reset();
            cloud.prefill(&pre.h1, ids.len()).unwrap()
        }));

        println!("\n== PJRT copy overhead (seg1 KV cache = 2 x [3,4,384,32] f32) ==");
        let n = 3 * 4 * 384 * 32;
        let data = vec![0.5f32; n];
        let lit = ce_collm::runtime::literal::f32_literal(&data, &[3, 4, 384, 32]).unwrap();
        results.push(bench("literal -> device buffer (589KB)", 0.5, || {
            stack.client.buffer_from_host_literal(None, &lit).unwrap()
        }));
        let buf = stack.client.buffer_from_host_literal(None, &lit).unwrap();
        results.push(bench("device buffer -> host literal (589KB)", 0.5, || {
            buf.to_literal_sync().unwrap()
        }));
        results.push(bench("host vec -> literal (589KB)", 0.5, || {
            ce_collm::runtime::literal::f32_literal(&data, &[3, 4, 384, 32]).unwrap()
        }));
    } else if !smoke {
        println!("\n(artifacts/ missing — skipping real PJRT step benches)");
    }

    std::fs::write(&json_path, to_json(&results)).expect("write bench json");
    println!("\nwrote {} results to {json_path}", results.len());
}
