//! End-to-end tests over the REAL artifacts (PJRT CPU execution of the
//! AOT-lowered Pallas/jax segments).  Requires `make artifacts` to have
//! run; a single #[test] loads the stack once (PJRT client startup is
//! expensive) and drives every sub-check sequentially.

use ce_collm::config::ExitPolicy;
use ce_collm::baselines::cloud_only::CloudOnlyRunner;
use ce_collm::baselines::naive_split::NaiveSplitRunner;
use ce_collm::harness::trace::{record, CallTimings};
use ce_collm::quant::Precision;
use ce_collm::runtime::stack::LocalStack;
use ce_collm::runtime::traits::EdgeEngine;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn e2e_real_artifacts() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        // CI and fresh clones have no artifacts (and the default build has
        // no PJRT); run `make artifacts` and build with `--features pjrt`
        // to enable this end-to-end check
        eprintln!("skipping e2e_real_artifacts: no artifacts at {}", dir.display());
        return;
    }
    let stack = LocalStack::load(&dir).expect("loading artifact stack");
    let dims = stack.manifest.model.clone();
    assert_eq!(dims.d_model, dims.n_heads * dims.head_dim);

    check_confidence_is_probability(&stack);
    check_theta_one_matches_cloud_only(&stack);
    check_standalone_stays_on_edge(&stack);
    check_threshold_monotonicity(&stack);
    check_f16_transport_token_divergence(&stack);
    check_naive_matches_cloud_only_tokens(&stack);
    check_kv_session_reset(&stack);
    check_exit_confidences_have_structure(&stack);
}

/// Fused exit-head confidence is a probability and consistent with logits.
fn check_confidence_is_probability(stack: &LocalStack) {
    let mut edge = stack.edge_session();
    let tok = stack.tokenizer();
    let ids = tok.encode("the machine can compute");
    let pre = edge.prefill(&ids).unwrap();
    for exit in [&pre.exit1, &pre.exit2] {
        assert!(exit.conf > 0.0 && exit.conf <= 1.0 + 1e-5, "conf {}", exit.conf);
        // conf equals max softmax prob of the returned logits
        let mut logits = exit.logits.clone();
        let maxp = ce_collm::model::sampling::softmax(&mut logits);
        assert!((maxp - exit.conf).abs() < 1e-4, "{maxp} vs {}", exit.conf);
        // argmax token agrees
        assert_eq!(
            exit.token,
            ce_collm::model::sampling::argmax(&exit.logits),
            "fused kernel argmax disagrees with logits"
        );
    }
}

/// Paper Table 2, θ=1.0 row: ROUGE-L 1.0 vs the cloud deployment —
/// i.e. *identical greedy tokens*, because the composed partitions ARE
/// the full model.
fn check_theta_one_matches_cloud_only(stack: &LocalStack) {
    let prompt = "every efficient system must";
    let mut timings = CallTimings::default();
    let mut edge = stack.edge_session();
    let mut cloud = stack.cloud_session();
    let tr = record(
        &mut edge,
        &mut cloud,
        ExitPolicy::Threshold(1.0),
        Precision::F32,
        prompt,
        32,
        &mut timings,
    )
    .unwrap();
    assert!(tr.cloud_rate() > 0.999, "θ=1.0 must defer every token");

    let mut runner = CloudOnlyRunner::new(stack.edge_session(), stack.cloud_session());
    let cl = runner.generate(prompt, 32).unwrap();
    assert_eq!(tr.tokens, cl.tokens, "θ=1.0 != cloud-only: partition composition broken");
    assert_eq!(
        ce_collm::eval::rouge_l(&tr.text, &cl.text),
        1.0,
        "paper invariant: ROUGE-L at θ=1.0 is exactly 1.0"
    );
}

fn check_standalone_stays_on_edge(stack: &LocalStack) {
    let mut timings = CallTimings::default();
    let mut edge = stack.edge_session();
    let mut cloud = stack.cloud_session();
    let tr = record(
        &mut edge,
        &mut cloud,
        ExitPolicy::Standalone { threshold: 0.8 },
        Precision::F16,
        "a fast local response",
        24,
        &mut timings,
    )
    .unwrap();
    assert_eq!(tr.cloud_rate(), 0.0);
    assert!(timings.cloud_decode.is_empty() && timings.cloud_prefill.is_empty());
    assert!(!tr.text.is_empty());
}

/// Lower threshold ⇒ request-cloud rate can only drop (paper Table 2).
fn check_threshold_monotonicity(stack: &LocalStack) {
    let prompt = "the cloud and the edge process together";
    let mut rates = Vec::new();
    for theta in [0.8f32, 0.9, 1.0] {
        let mut timings = CallTimings::default();
        let mut edge = stack.edge_session();
        let mut cloud = stack.cloud_session();
        let tr = record(
            &mut edge,
            &mut cloud,
            ExitPolicy::Threshold(theta),
            Precision::F16,
            prompt,
            24,
            &mut timings,
        )
        .unwrap();
        rates.push(tr.cloud_rate());
    }
    assert!(rates[0] <= rates[1] + 1e-9, "rates {rates:?}");
    assert!(rates[1] <= rates[2] + 1e-9, "rates {rates:?}");
    assert!(rates[2] > 0.999);
    // θ=0.8 must actually exit early on a meaningful share (paper: >40%)
    assert!(rates[0] < 0.8, "almost nothing exits early at θ=0.8: {rates:?}");
}

/// f16 hidden transport changes at most a small fraction of greedy
/// tokens (Table 3 shows no metric change).
fn check_f16_transport_token_divergence(stack: &LocalStack) {
    let prompt = "what is the network? it is";
    let run = |precision| {
        let mut timings = CallTimings::default();
        let mut edge = stack.edge_session();
        let mut cloud = stack.cloud_session();
        record(
            &mut edge,
            &mut cloud,
            ExitPolicy::Threshold(0.9),
            precision,
            prompt,
            32,
            &mut timings,
        )
        .unwrap()
    };
    let a = run(Precision::F32);
    let b = run(Precision::F16);
    let n = a.tokens.len().min(b.tokens.len());
    let diff = a.tokens[..n].iter().zip(&b.tokens[..n]).filter(|(x, y)| x != y).count();
    assert!(
        diff * 100 <= n * 15,
        "f16 transport changed {diff}/{n} tokens — quantization harms accuracy"
    );
}

fn check_naive_matches_cloud_only_tokens(stack: &LocalStack) {
    let prompt = "this adaptive model can";
    let mut naive = NaiveSplitRunner::new(stack.edge_session(), stack.cloud_session());
    let nv = naive.generate(prompt, 20).unwrap();
    let mut cloud = CloudOnlyRunner::new(stack.edge_session(), stack.cloud_session());
    let cl = cloud.generate(prompt, 20).unwrap();
    assert_eq!(nv.tokens, cl.tokens);
    assert_eq!(nv.counters.request_cloud_rate(), 1.0);
    // naive transmits orders of magnitude more than the prompt text
    assert!(nv.counters.bytes_up > 100 * cl.bytes_up);
}

/// Reusing a session across requests must behave like a fresh session
/// (paper §4.4 step 6: caches cleared between prompts).
fn check_kv_session_reset(stack: &LocalStack) {
    let mut edge = stack.edge_session();
    let mut cloud = stack.cloud_session();
    let prompt = "the cache must reset";
    let mut timings = CallTimings::default();
    let first = record(
        &mut edge, &mut cloud,
        ExitPolicy::Threshold(0.9), Precision::F16, prompt, 16, &mut timings,
    )
    .unwrap();
    // poison with a different generation, then repeat the original
    let _ = record(
        &mut edge, &mut cloud,
        ExitPolicy::Threshold(0.9), Precision::F16, "something quite different", 16,
        &mut timings,
    )
    .unwrap();
    let again = record(
        &mut edge, &mut cloud,
        ExitPolicy::Threshold(0.9), Precision::F16, prompt, 16, &mut timings,
    )
    .unwrap();
    assert_eq!(first.tokens, again.tokens, "stale KV state leaked across requests");
}

/// The trained model exhibits the paper's Table 1 confidence structure:
/// confidences spread across the (0, 1) range rather than collapsing.
fn check_exit_confidences_have_structure(stack: &LocalStack) {
    let mut timings = CallTimings::default();
    let mut edge = stack.edge_session();
    let mut cloud = stack.cloud_session();
    let tr = record(
        &mut edge,
        &mut cloud,
        ExitPolicy::Threshold(1.0),
        Precision::F16,
        "the turing test is",
        24,
        &mut timings,
    )
    .unwrap();
    let confs: Vec<f32> = tr.steps.iter().map(|s| s.conf1).collect();
    let hi = confs.iter().filter(|&&c| c >= 0.8).count();
    let lo = confs.iter().filter(|&&c| c < 0.8).count();
    assert!(hi > 0, "no high-confidence tokens — early exits would never fire");
    assert!(lo > 0, "no low-confidence tokens — the cloud would never be used");
}
