//! Integration tests for the real TCP serving path (cloud server + edge
//! client over sockets) using mock engines — fast, artifact-free, and
//! exercising the full dual-channel protocol, content manager, and
//! single-token response loop.

use std::net::TcpListener;

use ce_collm::config::{CloudConfig, DeploymentConfig, ReactorBackend};
use ce_collm::coordinator::cloud::{CloudServer, SessionFactory};
use ce_collm::coordinator::edge::{CloudLink, EdgeClient};
use ce_collm::coordinator::protocol::{Channel, Message};
use ce_collm::model::manifest::test_manifest;
use ce_collm::net::transport::{TcpTransport, Transport};
use ce_collm::runtime::mock::{MockCloud, MockEdge, MockOracle};

/// The non-default readiness backend for this platform, so the flow-
/// control tests cover both event loops: Linux defaults to epoll and
/// cross-checks poll; elsewhere the default IS poll, so the "other"
/// run is redundant but harmless.
const OTHER_BACKEND: ReactorBackend = ReactorBackend::Poll;

fn spawn_mock_server_cfg(seed: u64, cfg: CloudConfig) -> CloudServer {
    // the preferred entry point: binds the reactor fleet's own
    // listeners (per-shard SO_REUSEPORT on Linux when shards > 1, which
    // the CE_REACTOR_SHARDS=4 CI leg exercises across this whole file)
    let dims = test_manifest().model;
    let sdims = dims.clone();
    CloudServer::bind("127.0.0.1:0", dims, cfg, move || {
        let f: SessionFactory = Box::new(move |_device| {
            Ok(Box::new(MockCloud::new(MockOracle::new(seed), sdims.clone())) as _)
        });
        Ok(f)
    })
    .unwrap()
}

fn spawn_mock_server_with(seed: u64, workers: usize) -> CloudServer {
    spawn_mock_server_cfg(seed, CloudConfig::with_workers(workers))
}

fn spawn_mock_server(seed: u64) -> CloudServer {
    spawn_mock_server_with(seed, 1)
}

fn connect_client(
    server: &CloudServer,
    device_id: u64,
    seed: u64,
    threshold: f32,
) -> EdgeClient<MockEdge> {
    let dims = test_manifest().model;
    let mut cfg = DeploymentConfig::with_threshold(threshold);
    cfg.device_id = device_id;
    cfg.max_new_tokens = 20;
    let addr = server.addr.to_string();
    let upload = Box::new(TcpTransport::connect(&addr).unwrap());
    let infer = Box::new(TcpTransport::connect(&addr).unwrap());
    let link = CloudLink::new(device_id, upload, infer).unwrap();
    EdgeClient::with_cloud(MockEdge::new(MockOracle::new(seed), dims), cfg, link)
}

#[test]
fn tcp_generation_matches_local_trace() {
    let seed = 17;
    let server = spawn_mock_server(seed);
    let mut client = connect_client(&server, 1, seed, 0.8);
    let out = client.generate("a tcp test prompt").unwrap();
    assert!(!out.tokens.is_empty());
    assert_eq!(out.counters.tokens_generated, out.tokens.len());

    // the same request recorded locally must produce identical tokens
    let dims = test_manifest().model;
    let o = MockOracle::new(seed);
    let mut edge = MockEdge::new(o, dims.clone());
    let mut cloud = MockCloud::new(o, dims);
    let mut timings = ce_collm::harness::trace::CallTimings::default();
    let tr = ce_collm::harness::trace::record(
        &mut edge,
        &mut cloud,
        ce_collm::config::ExitPolicy::Threshold(0.8),
        ce_collm::quant::Precision::F16,
        "a tcp test prompt",
        20,
        &mut timings,
    )
    .unwrap();
    assert_eq!(out.tokens, tr.tokens, "wire path and local path disagree");

    let stats = server.shutdown();
    assert!(stats.uploads > 0, "parallel uploads should have arrived");
    assert_eq!(stats.requests_served as usize, out.counters.cloud_requests);
}

#[test]
fn tcp_multiple_sequential_requests_reuse_session() {
    let server = spawn_mock_server(3);
    let mut client = connect_client(&server, 9, 3, 0.9);
    let a = client.generate("first prompt").unwrap();
    let b = client.generate("second prompt, longer than the first").unwrap();
    assert!(!a.tokens.is_empty() && !b.tokens.is_empty());
    server.shutdown();
}

#[test]
fn tcp_concurrent_clients_are_isolated() {
    // two scheduler workers: devices shard across them and are served
    // concurrently over the real TCP path
    let server = spawn_mock_server_with(11, 2);
    let addr = server.addr;
    let mut handles = Vec::new();
    for device in 0..4u64 {
        let addr = addr.to_string();
        handles.push(std::thread::spawn(move || {
            let dims = test_manifest().model;
            let mut cfg = DeploymentConfig::with_threshold(0.85);
            cfg.device_id = device;
            cfg.max_new_tokens = 12;
            let upload = Box::new(TcpTransport::connect(&addr).unwrap());
            let infer = Box::new(TcpTransport::connect(&addr).unwrap());
            let link = CloudLink::new(device, upload, infer).unwrap();
            // different oracle per device -> different token streams
            let mut client = EdgeClient::with_cloud(
                MockEdge::new(MockOracle::new(100 + device), dims),
                cfg,
                link,
            );
            client.generate("concurrent prompt").unwrap().tokens
        }));
    }
    let results: Vec<Vec<i32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    // server-produced tokens come from each device's own session: at
    // least two streams must differ (different seeds)
    assert!(results.windows(2).any(|w| w[0] != w[1]));
    let stats = server.shutdown();
    assert!(stats.requests_served > 0);
}

#[test]
fn tcp_end_session_releases_content_manager_state() {
    let server = spawn_mock_server(7);
    let mut client = connect_client(&server, 2, 7, 0.8);
    let _ = client.generate("release my state").unwrap();
    // EndSession is fire-and-forget: give the worker a moment
    for _ in 0..50 {
        std::thread::sleep(std::time::Duration::from_millis(20));
        let stats = server.stats().unwrap();
        if stats.active_devices == 0 {
            assert_eq!(stats.pending_floats, 0);
            server.shutdown();
            return;
        }
    }
    panic!("content manager still holds device state after EndSession");
}

fn hello_timeout_reaps_silent_connection(backend: ReactorBackend) {
    // a socket that connects and never says Hello must not squat on a
    // max_conns slot forever
    let mut cfg = CloudConfig::with_workers(1);
    cfg.reactor.hello_timeout_s = 0.05;
    cfg.reactor.backend = backend;
    let server = spawn_mock_server_cfg(1, cfg);

    let silent = std::net::TcpStream::connect(server.addr).unwrap();
    for _ in 0..100 {
        std::thread::sleep(std::time::Duration::from_millis(20));
        let rs = server.reactor_stats().unwrap();
        if rs.hello_timeouts >= 1 && rs.open_conns == 0 {
            drop(silent);
            server.shutdown();
            return;
        }
    }
    panic!("silent connection was never reaped by the hello timeout ({backend:?})");
}

#[test]
fn silent_connection_is_reaped_by_hello_timeout() {
    hello_timeout_reaps_silent_connection(ReactorBackend::Auto);
}

#[test]
fn silent_connection_is_reaped_by_hello_timeout_other_backend() {
    hello_timeout_reaps_silent_connection(OTHER_BACKEND);
}

fn idle_timeout_reaps_established_connection(backend: ReactorBackend) {
    // an established (post-Hello) connection whose peer goes silent —
    // the NAT-expiry shape — must release its slot via the idle reap
    let mut cfg = CloudConfig::with_workers(1);
    cfg.reactor.idle_timeout_s = 0.05;
    cfg.reactor.backend = backend;
    let server = spawn_mock_server_cfg(1, cfg);

    let mut conn = TcpTransport::connect(&server.addr.to_string()).unwrap();
    conn.send(
        &Message::Hello {
            device_id: 77,
            session: 1,
            channel: Channel::Upload,
            resume: false,
            mirror: false,
        }
        .encode(),
    )
        .unwrap();
    assert_eq!(conn.recv().unwrap(), Message::Ack.encode(), "handshake completes");
    // ... and then the peer says nothing, forever
    for _ in 0..100 {
        std::thread::sleep(std::time::Duration::from_millis(20));
        let rs = server.reactor_stats().unwrap();
        if rs.idle_timeouts >= 1 && rs.open_conns == 0 {
            server.shutdown();
            return;
        }
    }
    panic!("established idle connection was never reaped by the idle timeout ({backend:?})");
}

#[test]
fn established_idle_connection_is_reaped_by_idle_timeout() {
    idle_timeout_reaps_established_connection(ReactorBackend::Auto);
}

#[test]
fn established_idle_connection_is_reaped_by_idle_timeout_other_backend() {
    idle_timeout_reaps_established_connection(OTHER_BACKEND);
}

fn slow_reader_gets_evicted(backend: ReactorBackend) {
    // a client that requests responses and never reads them must be
    // evicted once the kernel stops absorbing writes and the reactor's
    // write queue crosses the cap — not allowed to buffer the server
    // into the ground
    let mut cfg = CloudConfig::with_workers(1);
    cfg.max_park_s = 0.02; // park → fast error responses
    cfg.reactor.write_queue_cap = 1024;
    cfg.reactor.backend = backend;
    let server = spawn_mock_server_cfg(2, cfg);

    let mut conn = TcpTransport::connect(&server.addr.to_string()).unwrap();
    conn.send(
        &Message::Hello {
            device_id: 3,
            session: 9,
            channel: Channel::Infer,
            resume: false,
            mirror: false,
        }
        .encode(),
    )
        .unwrap();
    assert_eq!(conn.recv().unwrap(), Message::Ack.encode(), "handshake completes");
    // each request parks (its uploads never come), expires after
    // max_park_s, and produces an Error frame this client never reads;
    // enough of them overflow the kernel buffers, then the cap
    for req in 0..10_000u32 {
        let msg = Message::InferRequest {
            device_id: 3,
            req_id: req,
            pos: 0,
            prompt_len: 1,
            deadline_ms: 0,
        };
        if conn.send(&msg.encode()).is_err() {
            break; // already evicted: the dead socket is the success path
        }
    }
    for _ in 0..500 {
        std::thread::sleep(std::time::Duration::from_millis(20));
        let rs = server.reactor_stats().unwrap();
        if rs.evicted_slow >= 1 {
            assert_eq!(rs.open_conns, 0, "evicted conn must be closed: {rs:?}");
            server.shutdown();
            return;
        }
    }
    panic!("slow reader was never evicted past write_queue_cap ({backend:?})");
}

#[test]
fn slow_reader_is_evicted() {
    slow_reader_gets_evicted(ReactorBackend::Auto);
}

#[test]
fn slow_reader_is_evicted_other_backend() {
    slow_reader_gets_evicted(OTHER_BACKEND);
}

fn backpressure_pauses_then_serves_identically(backend: ReactorBackend) {
    // worker_queue_cap = 0: any undrained scheduler message pauses
    // reads on that worker's connections.  The pause/resume cycling
    // must be invisible to the client — tokens still bit-identical to
    // the blocking path — and the pause counter must prove the
    // interest-toggling machinery actually engaged.
    let seed = 29;
    let mut cfg = CloudConfig::with_workers(1);
    cfg.reactor.worker_queue_cap = 0;
    cfg.reactor.backend = backend;
    let server = spawn_mock_server_cfg(seed, cfg);
    // θ = 1.0: every token defers, maximizing upload+infer traffic
    let mut client = connect_client(&server, 6, seed, 1.0);
    let out = client.generate("a backpressure prompt").unwrap();
    assert!(!out.tokens.is_empty());

    let dims = test_manifest().model;
    let o = MockOracle::new(seed);
    let mut edge = MockEdge::new(o, dims.clone());
    let mut cloud = MockCloud::new(o, dims);
    let mut timings = ce_collm::harness::trace::CallTimings::default();
    let tr = ce_collm::harness::trace::record(
        &mut edge,
        &mut cloud,
        ce_collm::config::ExitPolicy::Threshold(1.0),
        ce_collm::quant::Precision::F16,
        "a backpressure prompt",
        20,
        &mut timings,
    )
    .unwrap();
    assert_eq!(out.tokens, tr.tokens, "pause/resume must not corrupt the stream ({backend:?})");

    let rs = server.reactor_stats().unwrap();
    assert!(
        rs.read_pauses >= 1,
        "a zero worker-queue cap must pause reads at least once ({backend:?}): {rs:?}"
    );
    assert_eq!(rs.evicted_slow, 0, "backpressure must not evict ({backend:?}): {rs:?}");
    server.shutdown();
}

#[test]
fn backpressure_pause_resume_is_transparent() {
    backpressure_pauses_then_serves_identically(ReactorBackend::Auto);
}

#[test]
fn backpressure_pause_resume_is_transparent_other_backend() {
    backpressure_pauses_then_serves_identically(OTHER_BACKEND);
}

#[test]
fn tcp_eviction_replay_keeps_tokens_bit_identical() {
    // two concurrent clients against a 1-byte context budget: the store
    // ping-pongs evictions between their devices, every cloud deferral
    // risks a SessionEvicted round trip, and the token streams must
    // still match the local (never-evicted) reference exactly
    let dims = test_manifest().model;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let sdims = dims.clone();
    let mut cfg = CloudConfig::with_workers(1);
    cfg.memory_budget_bytes = Some(1);
    let server = CloudServer::spawn(listener, dims, cfg, move || {
        let sdims = sdims.clone();
        let f: SessionFactory = Box::new(move |device| {
            Ok(Box::new(MockCloud::new(MockOracle::new(200 + device), sdims.clone())) as _)
        });
        Ok(f)
    })
    .unwrap();

    let addr = server.addr;
    let gate = std::sync::Arc::new(std::sync::Barrier::new(2));
    let mut handles = Vec::new();
    for device in 0..2u64 {
        let addr = addr.to_string();
        let gate = std::sync::Arc::clone(&gate);
        handles.push(std::thread::spawn(move || {
            let dims = test_manifest().model;
            // θ = 1.0: every token defers to the cloud, so both devices
            // stay active for the whole run and keep evicting each other
            let mut cfg = DeploymentConfig::with_threshold(1.0);
            cfg.device_id = device;
            cfg.max_new_tokens = 16;
            let upload = Box::new(TcpTransport::connect(&addr).unwrap());
            let infer = Box::new(TcpTransport::connect(&addr).unwrap());
            let link = CloudLink::new(device, upload, infer).unwrap();
            let mut client = EdgeClient::with_cloud(
                MockEdge::new(MockOracle::new(200 + device), dims),
                cfg,
                link,
            );
            gate.wait();
            let out = client.generate("an eviction storm prompt").unwrap();
            (device, out)
        }));
    }
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    for (device, out) in &results {
        // local reference: same engines, no wire, no eviction
        let o = MockOracle::new(200 + device);
        let dims = test_manifest().model;
        let mut edge = MockEdge::new(o, dims.clone());
        let mut cloud = MockCloud::new(o, dims);
        let mut timings = ce_collm::harness::trace::CallTimings::default();
        let tr = ce_collm::harness::trace::record(
            &mut edge,
            &mut cloud,
            ce_collm::config::ExitPolicy::Threshold(1.0),
            ce_collm::quant::Precision::F16,
            "an eviction storm prompt",
            16,
            &mut timings,
        )
        .unwrap();
        assert_eq!(out.tokens, tr.tokens, "device {device}: replay must be bit-identical");
    }

    let stats = server.shutdown();
    // with a 1-byte budget and overlapping runs the store must have
    // evicted, and every eviction the clients hit was replayed through
    let replayed: usize = results.iter().map(|(_, o)| o.counters.context_replays).sum();
    assert!(stats.context.evictions > 0, "no eviction under a 1-byte budget? {stats:?}");
    assert!(replayed > 0, "clients never saw a SessionEvicted");
    assert_eq!(stats.context.replays as usize, replayed, "server/client replay counts agree");
}

#[test]
fn shutdown_closes_every_connection_with_no_stragglers() {
    // the pre-reactor server joined its acceptor but *detached* the
    // per-connection threads, which lingered holding their sockets; the
    // reactor must close every registered connection before shutdown()
    // returns, so a straggling request can never be answered
    let server = spawn_mock_server(19);
    let addr = server.addr.to_string();
    let mut conns: Vec<TcpTransport> = (0..3u64)
        .map(|i| {
            let mut t = TcpTransport::connect(&addr).unwrap();
            t.send(
                &Message::Hello {
                    device_id: 40 + i,
                    session: 7,
                    channel: Channel::Infer,
                    resume: false,
                    mirror: false,
                }
                .encode(),
            )
            .unwrap();
            assert_eq!(t.recv().unwrap(), Message::Ack.encode(), "handshake must complete");
            t
        })
        .collect();

    server.shutdown();

    for (i, t) in conns.iter_mut().enumerate() {
        // the send may still land in a dead socket's buffer; what must
        // never happen is a response coming back
        let _ = t.send(
            &Message::InferRequest {
                device_id: 40 + i as u64,
                req_id: 1,
                pos: 1,
                prompt_len: 2,
                deadline_ms: 0,
            }
            .encode(),
        );
        assert!(
            t.recv().is_err(),
            "connection {i} still answered after shutdown() returned"
        );
    }
}

/// One full e2e pass against a fleet of exactly `shards` reactor
/// shards: 8 devices, θ = 1.0 (every token defers), served streams
/// returned for cross-shard-count comparison.
fn serve_with_shards(shards: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut cfg = CloudConfig::with_workers(2);
    cfg.reactor.shards = shards; // explicit: wins over CE_REACTOR_SHARDS
    let server = spawn_mock_server_cfg(seed, cfg);
    assert_eq!(server.shards(), shards, "fleet must spawn exactly as configured");

    let devices = 8u64;
    let addr = server.addr.to_string();
    let mut handles = Vec::new();
    for device in 0..devices {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let dims = test_manifest().model;
            let mut cfg = DeploymentConfig::with_threshold(1.0);
            cfg.device_id = device;
            cfg.max_new_tokens = 10;
            let upload = Box::new(TcpTransport::connect(&addr).unwrap());
            let infer = Box::new(TcpTransport::connect(&addr).unwrap());
            let link = CloudLink::new(device, upload, infer).unwrap();
            let mut client = EdgeClient::with_cloud(
                MockEdge::new(MockOracle::new(seed), dims),
                cfg,
                link,
            );
            client.generate("a sharded fleet prompt").unwrap().tokens
        }));
    }
    let results: Vec<Vec<i32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    // the fleet-level invariants: per-shard stats retained next to the
    // aggregate, every accept attributed to exactly one shard
    let per_shard = server.reactor_shard_stats().unwrap();
    assert_eq!(per_shard.len(), shards);
    let accepted: u64 = per_shard.iter().map(|s| s.accepts).sum();
    assert_eq!(accepted, 2 * devices, "accepts summed across shards == sockets opened");
    #[cfg(target_os = "linux")]
    {
        let want = if shards > 1 { "reuseport" } else { "single" };
        for s in &per_shard {
            assert_eq!(s.accept_mode, want, "bound servers get per-shard listeners: {s:?}");
        }
    }
    let stats = server.shutdown();
    assert_eq!(stats.reactor_shards.len(), shards, "finals keep per-shard resolution");
    assert_eq!(stats.reactor.conns_opened, 2 * devices, "aggregate folds the fleet");
    results
}

#[test]
fn tcp_sharded_fleet_serves_bit_identical_streams() {
    let seed = 41;
    let single = serve_with_shards(1, seed);
    let fleet = serve_with_shards(4, seed);

    // blocking reference: same engines, no wire, no fleet
    let dims = test_manifest().model;
    let o = MockOracle::new(seed);
    let mut edge = MockEdge::new(o, dims.clone());
    let mut cloud = MockCloud::new(o, dims);
    let mut timings = ce_collm::harness::trace::CallTimings::default();
    let tr = ce_collm::harness::trace::record(
        &mut edge,
        &mut cloud,
        ce_collm::config::ExitPolicy::Threshold(1.0),
        ce_collm::quant::Precision::F16,
        "a sharded fleet prompt",
        10,
        &mut timings,
    )
    .unwrap();
    for (device, tokens) in single.iter().enumerate() {
        assert_eq!(tokens, &tr.tokens, "1-shard device {device} diverges from blocking path");
    }
    for (device, tokens) in fleet.iter().enumerate() {
        assert_eq!(tokens, &tr.tokens, "4-shard device {device} diverges from blocking path");
    }
    assert_eq!(single, fleet, "shard count must never change served bytes");
}

#[test]
fn dead_conn_completion_never_crosses_shards() {
    use ce_collm::config::ReactorConfig;
    use ce_collm::coordinator::cloud::Scheduler;
    use ce_collm::net::reactor::Reactor;
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    // two shards, hand-registered connections (round-robin: the i-th
    // register lands on shard i % 2), so conn placement is exact:
    // conn A (device 1) on shard 0, conn B (device 2) on shard 1.
    // A's infer request parks, A dies, the park expires — the error
    // completion must be FENCED on shard 0, not delivered anywhere,
    // and shard 1's live connection must stay untouched and healthy.
    let dims = test_manifest().model;
    let seed = 53u64;
    let mut cfg = CloudConfig::with_workers(1);
    cfg.max_park_s = 0.2; // A's request fails quickly
    let sdims = dims.clone();
    let scheduler = Scheduler::spawn(
        dims.clone(),
        cfg,
        Arc::new(move || {
            let sdims = sdims.clone();
            let f: SessionFactory = Box::new(move |_device| {
                Ok(Box::new(MockCloud::new(MockOracle::new(seed), sdims.clone())) as _)
            });
            Ok(f)
        }),
    )
    .unwrap();
    let rcfg = ReactorConfig { shards: 2, ..ReactorConfig::default() };
    let reactor = Reactor::spawn(scheduler.router(), dims.clone(), rcfg, None).unwrap();
    let handle = reactor.handle();
    assert_eq!(reactor.shards(), 2);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let register = |device: u64| -> TcpTransport {
        let mut t = TcpTransport::connect(&addr).unwrap();
        let (srv, _) = listener.accept().unwrap();
        handle.register(srv).unwrap();
        t.send(
            &Message::Hello {
                device_id: device,
                session: 0,
                channel: Channel::Infer,
                resume: false,
                mirror: false,
            }
            .encode(),
        )
        .unwrap();
        assert_eq!(t.recv().unwrap(), Message::Ack.encode(), "handshake completes");
        t
    };
    let mut conn_a = register(1); // shard 0
    let mut conn_b = register(2); // shard 1

    // A asks, then dies before the answer can exist
    conn_a
        .send(
            &Message::InferRequest { device_id: 1, req_id: 1, pos: 1, prompt_len: 2, deadline_ms: 0 }
                .encode(),
        )
        .unwrap();
    drop(conn_a);

    // shard 0 reaps A on EOF ...
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let open: usize = handle.shard_stats().unwrap().iter().map(|s| s.open_conns).sum();
        if open == 1 {
            break;
        }
        assert!(Instant::now() < deadline, "dead connection was never reaped");
        std::thread::sleep(Duration::from_millis(5));
    }
    // ... then the parked request expires on the worker
    loop {
        if scheduler.stats().unwrap().deadline_expired >= 1 {
            break;
        }
        assert!(Instant::now() < deadline, "parked request never expired");
        std::thread::sleep(Duration::from_millis(5));
    }

    // the completion must be dropped by shard 0's fence: B sees nothing
    assert_eq!(
        conn_b.recv_deadline(Instant::now() + Duration::from_millis(400)).unwrap(),
        None,
        "a dead conn's completion leaked to a live conn on another shard"
    );
    let per_shard = handle.shard_stats().unwrap();
    // each shard wrote exactly its own Hello ack — the fenced error
    // frame was never written anywhere
    assert_eq!(per_shard[0].frames_out, 1, "shard 0 must fence the dead conn: {per_shard:?}");
    assert_eq!(per_shard[1].frames_out, 1, "shard 1 must stay untouched: {per_shard:?}");
    assert_eq!(per_shard[0].conns_closed, 1, "shard 0 reaped exactly conn A: {per_shard:?}");

    // both shards still serve: a full client through freshly registered
    // connections (round-robin puts one on each shard) stays
    // bit-identical to the blocking path
    let mut dcfg = DeploymentConfig::with_threshold(1.0);
    dcfg.device_id = 5;
    dcfg.max_new_tokens = 6;
    let connect_raw = || -> TcpTransport {
        let t = TcpTransport::connect(&addr).unwrap();
        let (srv, _) = listener.accept().unwrap();
        handle.register(srv).unwrap();
        t
    };
    let upload = Box::new(connect_raw()); // shard 0
    let infer = Box::new(connect_raw()); // shard 1
    let link = CloudLink::new(5, upload, infer).unwrap();
    let mut client =
        EdgeClient::with_cloud(MockEdge::new(MockOracle::new(seed), dims.clone()), dcfg, link);
    let out = client.generate("after the fence").unwrap();
    let o = MockOracle::new(seed);
    let mut edge = MockEdge::new(o, dims.clone());
    let mut cloud = MockCloud::new(o, dims);
    let mut timings = ce_collm::harness::trace::CallTimings::default();
    let tr = ce_collm::harness::trace::record(
        &mut edge,
        &mut cloud,
        ce_collm::config::ExitPolicy::Threshold(1.0),
        ce_collm::quant::Precision::F16,
        "after the fence",
        6,
        &mut timings,
    )
    .unwrap();
    assert_eq!(out.tokens, tr.tokens, "post-fence serving must stay bit-identical");

    drop(reactor);
    scheduler.shutdown();
}

/// Raw scrape of the reactor's in-band `/metrics` endpoint: plain TCP,
/// `GET ` sniffed on an un-Hello'd connection, one HTTP/1.0 response,
/// server closes.  Returns the body.
fn scrape(addr: &str) -> String {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    s.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).unwrap();
    let text = String::from_utf8(raw).unwrap();
    let (head, body) = text.split_once("\r\n\r\n").expect("http header/body split");
    assert!(head.starts_with("HTTP/1.0 200"), "unexpected response head: {head}");
    body.to_string()
}

fn metrics_scrape_under_load_is_consistent(backend: ReactorBackend) {
    use ce_collm::metrics::parse_exposition;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    let seed = 61;
    let devices = 4u64;
    let mut cfg = CloudConfig::with_workers(2);
    cfg.metrics = true;
    cfg.reactor.backend = backend;
    cfg.reactor.shards = 2;
    let server = spawn_mock_server_cfg(seed, cfg);
    assert_eq!(server.shards(), 2);
    let addr = server.addr.to_string();

    // a scraper hammers /metrics WHILE the clients generate.  The
    // registry is process-global (other tests in this binary share it),
    // so mid-load checks are structural only: the exposition must parse
    // (parse_exposition enforces monotone cumulative buckets, a +Inf
    // bucket equal to _count, and a _sum per family) — torn numbers or
    // broken framing under concurrent load would fail right here
    let stop = Arc::new(AtomicBool::new(false));
    let scraper = {
        let addr = addr.clone();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut scrapes = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let body = scrape(&addr);
                let exp = parse_exposition(&body)
                    .unwrap_or_else(|e| panic!("mid-load scrape unparseable: {e}\n{body}"));
                assert!(
                    exp.types.values().any(|t| t == "histogram"),
                    "scrape carries no histogram families"
                );
                assert!(
                    exp.value("ce_reactor_accepts", &[]).is_some(),
                    "scrape is missing the fleet load report"
                );
                scrapes += 1;
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            scrapes
        })
    };

    let mut handles = Vec::new();
    for device in 0..devices {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let dims = test_manifest().model;
            let mut cfg = DeploymentConfig::with_threshold(1.0);
            cfg.device_id = device;
            cfg.max_new_tokens = 10;
            let upload = Box::new(TcpTransport::connect(&addr).unwrap());
            let infer = Box::new(TcpTransport::connect(&addr).unwrap());
            let link = CloudLink::new(device, upload, infer).unwrap();
            let mut client = EdgeClient::with_cloud(
                MockEdge::new(MockOracle::new(seed), dims),
                cfg,
                link,
            );
            client.generate("a scrape under load prompt").unwrap().tokens
        }));
    }
    let results: Vec<Vec<i32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    stop.store(true, Ordering::Relaxed);
    let scrapes = scraper.join().unwrap();
    assert!(scrapes >= 1, "the scraper never completed a mid-load scrape");

    // scraping must be invisible to the protocol: every stream still
    // bit-identical to the blocking no-wire reference
    let dims = test_manifest().model;
    let o = MockOracle::new(seed);
    let mut edge = MockEdge::new(o, dims.clone());
    let mut cloud = MockCloud::new(o, dims);
    let mut timings = ce_collm::harness::trace::CallTimings::default();
    let tr = ce_collm::harness::trace::record(
        &mut edge,
        &mut cloud,
        ce_collm::config::ExitPolicy::Threshold(1.0),
        ce_collm::quant::Precision::F16,
        "a scrape under load prompt",
        10,
        &mut timings,
    )
    .unwrap();
    for (device, tokens) in results.iter().enumerate() {
        assert_eq!(
            tokens, &tr.tokens,
            "device {device} diverged with a scraper attached ({backend:?})"
        );
    }

    // at quiescence the fleet-local load report must balance: every
    // accept attributed to exactly one shard, summed == conns opened.
    // (Shards publish at each wake, so give the last disconnect a
    // moment to be observed.)  Scrape conns count too — each attempt
    // adds one accept and one open to some shard, so re-read until two
    // consecutive scrapes agree with each other's expectations.
    let mut ok = false;
    for _ in 0..100 {
        let body = scrape(&addr);
        let exp = parse_exposition(&body).unwrap();
        let per_shard: Vec<f64> = (0..2)
            .map(|i| {
                let shard = i.to_string();
                exp.value("ce_reactor_accepts", &[("shard", shard.as_str())])
                    .unwrap_or_else(|| panic!("no accepts for shard {shard}:\n{body}"))
            })
            .collect();
        let opened = exp.value("ce_reactor_conns_opened", &[]).unwrap_or(-1.0);
        let floor = (2 * devices) as f64; // client sockets, before scrape conns
        if per_shard.iter().sum::<f64>() == opened && opened >= floor {
            ok = true;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    assert!(ok, "per-shard accepts never reconciled with conns_opened ({backend:?})");

    // the worker-side spine is live too: the batch-pass family served
    // these requests, so its recorded count is non-zero by now
    let body = scrape(&addr);
    let exp = parse_exposition(&body).unwrap();
    let passes: f64 =
        exp.samples_named("ce_sched_batch_pass_ns_count").map(|s| s.value).sum();
    assert!(passes > 0.0, "no batch passes recorded in the scrape ({backend:?})");

    server.shutdown();
}

#[test]
fn metrics_scrape_under_load() {
    metrics_scrape_under_load_is_consistent(ReactorBackend::Auto);
}

#[test]
fn metrics_scrape_under_load_other_backend() {
    metrics_scrape_under_load_is_consistent(OTHER_BACKEND);
}

#[test]
fn tcp_standalone_policy_never_contacts_server() {
    let server = spawn_mock_server(5);
    let dims = test_manifest().model;
    let mut cfg = DeploymentConfig::standalone();
    cfg.max_new_tokens = 12;
    let mut client =
        EdgeClient::standalone(MockEdge::new(MockOracle::new(5), dims), cfg);
    let out = client.generate("standalone never uploads").unwrap();
    assert!(!out.tokens.is_empty());
    assert_eq!(out.counters.cloud_requests, 0);
    let stats = server.shutdown();
    assert_eq!(stats.uploads, 0);
    assert_eq!(stats.requests_served, 0);
}
