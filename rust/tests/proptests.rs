//! Property-based tests over coordinator invariants (routing, batching,
//! state management) using the in-tree deterministic PRNG — the offline
//! environment has no proptest crate, so shrinking is replaced by
//! printing the failing seed.

use ce_collm::config::{AblationFlags, DeploymentConfig, ExitPolicy, ReconnectPolicy};
use ce_collm::coordinator::content_manager::ContentManager;
use ce_collm::coordinator::edge::{CloudLink, DialFn, EdgeClient};
use ce_collm::coordinator::policy::{ExitPoint, TokenPolicy};
use ce_collm::coordinator::protocol::{Channel, Message};
use ce_collm::harness::cost::CostModel;
use ce_collm::harness::des::{simulate, SimConfig, Strategy};
use ce_collm::harness::trace::{record, CallTimings};
use ce_collm::model::manifest::test_manifest;
use ce_collm::net::profiles::LinkProfile;
use ce_collm::net::transport::{in_proc_pair, Transport};
use ce_collm::quant::{self, Precision};
use ce_collm::runtime::mock::{MockCloud, MockEdge, MockOracle};
use ce_collm::util::rng::Rng;

const CASES: usize = 64;

// ---------------------------------------------------------------------------
// protocol: encode∘decode = id for arbitrary messages
// ---------------------------------------------------------------------------

fn arb_message(rng: &mut Rng) -> Message {
    match rng.gen_range(7) {
        0 => Message::Hello {
            device_id: rng.next_u64(),
            session: rng.next_u64(),
            channel: if rng.gen_bool(0.5) { Channel::Upload } else { Channel::Infer },
            resume: rng.gen_bool(0.5),
            mirror: rng.gen_bool(0.5),
        },
        1 => {
            let precision = if rng.gen_bool(0.5) { Precision::F16 } else { Precision::F32 };
            let count = rng.gen_range(4) as u32 + 1;
            let n = count as usize * 8;
            let values: Vec<f32> =
                (0..n).map(|_| (rng.gen_f32() - 0.5) * 2000.0).collect();
            Message::UploadHidden {
                device_id: rng.next_u64(),
                req_id: rng.next_u64() as u32,
                start_pos: rng.gen_range(1000) as u32,
                count,
                prompt_len: rng.gen_range(256) as u32,
                precision,
                payload: quant::pack(&values, precision),
            }
        }
        2 => Message::InferRequest {
            device_id: rng.next_u64(),
            req_id: rng.next_u64() as u32,
            pos: rng.gen_range(4096) as u32,
            prompt_len: rng.gen_range(256) as u32,
            deadline_ms: rng.gen_range(5000) as u32,
        },
        3 => Message::TokenResponse {
            req_id: rng.next_u64() as u32,
            pos: rng.gen_range(4096) as u32,
            token: rng.gen_range(384) as i32,
            conf: rng.gen_f32(),
            compute_s: rng.gen_f32() * 0.1,
        },
        4 => Message::EndSession { device_id: rng.next_u64(), req_id: rng.next_u64() as u32 },
        5 => Message::Ack,
        _ => Message::Error {
            req_id: rng.next_u64() as u32,
            pos: rng.gen_range(4096) as u32,
            msg: (0..rng.gen_range(64)).map(|_| (rng.gen_range(94) as u8 + 32) as char).collect(),
        },
    }
}

#[test]
fn prop_protocol_roundtrip() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::seed_from_u64(seed);
        for _ in 0..8 {
            let msg = arb_message(&mut rng);
            let decoded = Message::decode(&msg.encode())
                .unwrap_or_else(|e| panic!("seed {seed}: decode failed: {e:#} for {msg:?}"));
            assert_eq!(decoded, msg, "seed {seed}");
        }
    }
}

#[test]
fn prop_protocol_rejects_random_mutation() {
    // flipping the tag byte to an invalid value must never decode
    for seed in 0..CASES as u64 {
        let mut rng = Rng::seed_from_u64(seed);
        let msg = arb_message(&mut rng);
        let mut enc = msg.encode();
        enc[0] = 200 + rng.gen_range(55) as u8;
        assert!(Message::decode(&enc).is_err(), "seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// quantization: f16 round trip error bound over random activations
// ---------------------------------------------------------------------------

#[test]
fn prop_f16_roundtrip_error_bounded() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::seed_from_u64(seed);
        // cover the paper's observed activation range ±6600
        let v: Vec<f32> = (0..256).map(|_| (rng.gen_f32() - 0.5) * 13200.0).collect();
        let back = quant::unpack(&quant::pack(&v, Precision::F16), Precision::F16).unwrap();
        for (a, b) in v.iter().zip(&back) {
            let rel = (a - b).abs() / a.abs().max(1e-3);
            assert!(rel <= 2.0f32.powi(-10), "seed {seed}: {a} -> {b} rel {rel}");
        }
    }
}

// ---------------------------------------------------------------------------
// content manager: random upload orders, duplication, interleaved devices
// ---------------------------------------------------------------------------

#[test]
fn prop_content_manager_consumes_each_position_once() {
    const D: usize = 8;
    for seed in 0..CASES as u64 {
        let mut rng = Rng::seed_from_u64(seed);
        let mut cm = ContentManager::new(D);
        let plen = 1 + rng.gen_range(8);
        let total = plen + 1 + rng.gen_range(12);

        // upload all positions in random order (decode positions one by
        // one, prompt as one batch), with random duplicates
        let mut order: Vec<usize> = (plen..total).collect();
        rng.shuffle(&mut order);
        let prompt: Vec<f32> = (0..plen).flat_map(|p| vec![p as f32; D]).collect();
        cm.upload(7, 1, 0, plen as u32, &prompt).unwrap();
        for &p in &order {
            cm.upload(7, 1, p as u32, plen as u32, &vec![p as f32; D]).unwrap();
            if rng.gen_bool(0.3) {
                cm.upload(7, 1, p as u32, plen as u32, &vec![p as f32; D]).unwrap();
            }
        }

        // request tokens at increasing positions; every position must be
        // delivered exactly once with the right payload
        let mut consumed = vec![false; total];
        let mut pos = plen - 1;
        while pos < total - 1 {
            pos = (pos + 1 + rng.gen_range(3)).min(total - 1);
            let plan = cm.plan(7, 1, pos as u32, plen as u32).unwrap();
            if let Some((h, len)) = &plan.prefill {
                assert_eq!(*len, plen, "seed {seed}");
                assert_eq!(h.len(), plen * D);
                for p in 0..plen {
                    assert!(!consumed[p]);
                    consumed[p] = true;
                    assert_eq!(h[p * D], p as f32, "seed {seed}");
                }
            }
            for (p, h) in &plan.decode {
                let p = *p as usize;
                assert!(!consumed[p], "seed {seed}: pos {p} delivered twice");
                consumed[p] = true;
                assert_eq!(h[0], p as f32, "seed {seed}");
            }
        }
        assert!(consumed[..pos + 1].iter().all(|&c| c), "seed {seed}");
        // release-on-complete leaves nothing resident beyond unconsumed tail
        cm.end_session(7);
        assert_eq!(cm.pending_floats(), 0, "seed {seed}");
        assert_eq!(cm.device_count(), 0);
    }
}

#[test]
fn prop_content_manager_device_isolation() {
    const D: usize = 4;
    for seed in 0..CASES as u64 {
        let mut rng = Rng::seed_from_u64(seed ^ 0xD15);
        let mut cm = ContentManager::new(D);
        let devices: Vec<u64> = (0..3).collect();
        for &dev in &devices {
            let marker = dev as f32 * 100.0;
            cm.upload(dev, 0, 0, 2, &[marker, 0.0, 0.0, 0.0, marker + 1.0, 0.0, 0.0, 0.0])
                .unwrap();
        }
        // consume in random device order; payloads must not cross devices
        let mut order = devices.clone();
        rng.shuffle(&mut order);
        for dev in order {
            let plan = cm.plan(dev, 0, 1, 2).unwrap();
            let (h, _) = plan.prefill.unwrap();
            assert_eq!(h[0], dev as f32 * 100.0, "seed {seed}");
            assert_eq!(h[D], dev as f32 * 100.0 + 1.0);
        }
    }
}

// ---------------------------------------------------------------------------
// batched decode: fused passes bit-identical to sequential per-device decode
// ---------------------------------------------------------------------------

#[test]
fn prop_decode_batch_identical_to_sequential_per_device() {
    use ce_collm::runtime::traits::{BatchItem, CloudEngine};

    let dims = test_manifest().model;
    let d = dims.d_model;
    for seed in 0..CASES as u64 {
        let mut rng = Rng::seed_from_u64(seed ^ 0xBA7C);
        // a random cross-device batch: each device gets its own session
        // pair and a random-length contiguous catch-up run
        let n_devices = 1 + rng.gen_range(4);
        for dev in 0..n_devices as u64 {
            let o = MockOracle::new(seed ^ dev);
            let mut fused = MockCloud::new(o, dims.clone());
            let mut seq = MockCloud::new(o, dims.clone());
            let plen = 1 + rng.gen_range(4);
            let prompt = vec![0.25; plen * d];
            fused.prefill(&prompt, plen).unwrap();
            seq.prefill(&prompt, plen).unwrap();

            let run = 1 + rng.gen_range(12);
            let items: Vec<BatchItem> = (0..run)
                .map(|i| BatchItem { h1: vec![rng.gen_f32(); d], pos: plen + i })
                .collect();
            let batched = fused.decode_batch(&items).unwrap();
            let sequential: Vec<_> =
                items.iter().map(|b| seq.decode(&b.h1, b.pos).unwrap()).collect();
            assert_eq!(batched.len(), sequential.len(), "seed {seed} dev {dev}");
            for (a, b) in batched.iter().zip(&sequential) {
                assert_eq!(a.exit.token, b.exit.token, "seed {seed} dev {dev}");
                assert_eq!(
                    a.exit.conf.to_bits(),
                    b.exit.conf.to_bits(),
                    "seed {seed} dev {dev}: confidence must be bit-identical"
                );
                assert_eq!(a.exit.logits, b.exit.logits, "seed {seed} dev {dev}");
            }
            assert_eq!(fused.batch_passes(), 1, "seed {seed}: one fused pass per run");
            assert_eq!(fused.decoded_positions, seq.decoded_positions, "seed {seed}");
        }
    }
}

// ---------------------------------------------------------------------------
// policy: monotonicity over random confidences
// ---------------------------------------------------------------------------

#[test]
fn prop_policy_monotone_in_threshold() {
    let rank = |e: ExitPoint| match e {
        ExitPoint::Exit1 => 0,
        ExitPoint::Exit2 => 1,
        ExitPoint::Cloud => 2,
    };
    for seed in 0..CASES as u64 {
        let mut rng = Rng::seed_from_u64(seed);
        for _ in 0..32 {
            let c1 = rng.gen_f32();
            let c2 = rng.gen_f32();
            let t_lo = rng.gen_f32();
            let t_hi = (t_lo + rng.gen_f32() * (1.0 - t_lo)).min(1.0);
            let lo = TokenPolicy::new(ExitPolicy::Threshold(t_lo), AblationFlags::default());
            let hi = TokenPolicy::new(ExitPolicy::Threshold(t_hi), AblationFlags::default());
            assert!(
                rank(lo.decide(c1, c2)) <= rank(hi.decide(c1, c2)),
                "seed {seed}: c=({c1},{c2}) t=({t_lo},{t_hi})"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// trace + DES: structural invariants over random mock models
// ---------------------------------------------------------------------------

#[test]
fn prop_trace_cloud_catchup_partitions_positions() {
    let dims = test_manifest().model;
    for seed in 0..32u64 {
        let o = MockOracle::new(seed);
        let mut edge = MockEdge::new(o, dims.clone());
        let mut cloud = MockCloud::new(o, dims.clone());
        let mut t = CallTimings::default();
        let tr = record(
            &mut edge,
            &mut cloud,
            ExitPolicy::Threshold(0.7),
            Precision::F16,
            "a property test prompt",
            24,
            &mut t,
        )
        .unwrap();
        // every cloud-decoded position is consumed exactly once and in order
        let decoded = &cloud.decoded_positions;
        let mut sorted = decoded.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(&sorted, decoded, "seed {seed}: out-of-order or duplicate decode");
        // catch-up sums equal the number of cloud decode calls
        let catchup: usize = tr.steps.iter().map(|s| s.cloud_catchup).sum();
        assert_eq!(catchup, decoded.len(), "seed {seed}");
    }
}

#[test]
fn prop_des_total_bounds_parts() {
    // makespan >= each client's edge time; comm and cloud non-negative
    let dims = test_manifest().model;
    let cost = CostModel::synthetic(&dims);
    for seed in 0..32u64 {
        let o = MockOracle::new(seed);
        let mut edge = MockEdge::new(o, dims.clone());
        let mut cloud = MockCloud::new(o, dims.clone());
        let mut t = CallTimings::default();
        let tr = record(
            &mut edge,
            &mut cloud,
            ExitPolicy::Threshold(0.8),
            Precision::F16,
            "bounds check prompt",
            16,
            &mut t,
        )
        .unwrap();
        for strategy in [
            Strategy::CeCollm(AblationFlags::default()),
            Strategy::CloudOnly,
            Strategy::NaiveSplit,
            Strategy::Standalone,
        ] {
            let traces = match strategy {
                Strategy::Standalone => {
                    let mut e2 = MockEdge::new(o, dims.clone());
                    let mut c2 = MockCloud::new(o, dims.clone());
                    let mut tt = CallTimings::default();
                    vec![vec![record(
                        &mut e2,
                        &mut c2,
                        ExitPolicy::Standalone { threshold: 0.8 },
                        Precision::F16,
                        "bounds check prompt",
                        16,
                        &mut tt,
                    )
                    .unwrap()]]
                }
                _ => vec![vec![tr.clone()]],
            };
            let out = simulate(
                &traces,
                &dims,
                &cost,
                &SimConfig {
                    strategy,
                    link: LinkProfile::wifi(),
                    seed,
                    workers: 1,
                    cross_device_batch: false,
                    ..Default::default()
                },
            );
            let (c, k) = out.summed();
            assert!(out.makespan_s >= c.edge_s - 1e-9, "seed {seed} {strategy:?}");
            assert!(c.cloud_s >= 0.0 && c.comm_s >= 0.0);
            assert!(k.tokens_generated > 0);
            assert_eq!(
                k.tokens_generated,
                k.tokens_exit1 + k.tokens_exit2 + k.tokens_cloud,
                "seed {seed} {strategy:?}: exit counts must partition tokens"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// hedge fencing: delayed loser echoes never double-bill or corrupt
// ---------------------------------------------------------------------------

/// The stale-response fence behind hedged failover, as a property: for
/// ANY storm of delayed loser echoes — re-sent `TokenResponse`s for
/// `(req_id, pos)` pairs the client has already resolved, answers for
/// positions it will never ask about, stale `Error`s for a neighboring
/// request — the client must (a) never re-issue a request the cloud
/// already served (the server-side `requests_served` double-bill), (b)
/// bill `cloud_requests` exactly once per accepted token, and (c) keep
/// the accepted token stream equal to the genuinely-served stream, in
/// order.  The fake cloud here speaks the real wire format over
/// in-process transports and fails the run from the inside if a
/// duplicate `(req_id, pos)` request ever arrives.
#[test]
fn prop_delayed_loser_echoes_are_fenced() {
    use std::sync::{Arc, Mutex};

    let dims = test_manifest().model;
    for seed in 0..16u64 {
        let (up_c, up_s) = in_proc_pair();
        let (inf_c, inf_s) = in_proc_pair();

        // upload-channel half of the fake cloud: Ack the Hello, Pong
        // any keepalive, drain the fan-out until the peer hangs up
        let upload_thread = std::thread::spawn(move || {
            let mut t = up_s;
            loop {
                let Ok(frame) = t.recv() else { return };
                match Message::decode(&frame).unwrap() {
                    Message::Hello { .. } => t.send(&Message::Ack.encode()).unwrap(),
                    Message::Ping { nonce } => {
                        t.send(&Message::Pong { nonce }.encode()).unwrap()
                    }
                    _ => {}
                }
            }
        });

        // infer-channel half: before every real answer, flood the wire
        // with loser echoes.  A loser can only ever echo the PAST (a
        // pair the race already resolved) or the never-asked — a real
        // standby cannot answer a position before the client asks.
        let served: Arc<Mutex<Vec<(u32, u32, i32)>>> = Arc::new(Mutex::new(Vec::new()));
        let served_srv = Arc::clone(&served);
        let infer_thread = std::thread::spawn(move || {
            let mut t = inf_s;
            let mut rng = Rng::seed_from_u64(seed ^ 0x10_5E2);
            loop {
                let Ok(frame) = t.recv() else { return };
                match Message::decode(&frame).unwrap() {
                    Message::Hello { .. } => t.send(&Message::Ack.encode()).unwrap(),
                    Message::InferRequest { req_id, pos, .. } => {
                        let mut sv = served_srv.lock().unwrap();
                        assert!(
                            !sv.iter().any(|&(r, p, _)| r == req_id && p == pos),
                            "seed {seed}: (req {req_id}, pos {pos}) requested twice — a \
                             fence miss would double-bill requests_served"
                        );
                        for _ in 0..rng.gen_range(3) {
                            let stale = if !sv.is_empty() && rng.gen_bool(0.6) {
                                let (r, p, tok) = sv[rng.gen_range(sv.len())];
                                Message::TokenResponse {
                                    req_id: r,
                                    pos: p,
                                    token: tok,
                                    conf: 0.5,
                                    compute_s: 0.0,
                                }
                            } else if rng.gen_bool(0.5) {
                                Message::TokenResponse {
                                    req_id,
                                    pos: pos + 1000,
                                    token: 7,
                                    conf: 0.5,
                                    compute_s: 0.0,
                                }
                            } else {
                                Message::Error {
                                    req_id: req_id + 1,
                                    pos,
                                    msg: "stale loser".into(),
                                }
                            };
                            t.send(&stale.encode()).unwrap();
                        }
                        let token = ((pos as u64 * 31 + seed) % 300) as i32 + 2;
                        sv.push((req_id, pos, token));
                        drop(sv);
                        let real = Message::TokenResponse {
                            req_id,
                            pos,
                            token,
                            conf: 0.99,
                            compute_s: 0.0,
                        };
                        t.send(&real.encode()).unwrap();
                    }
                    _ => {}
                }
            }
        });

        let mut halves = Some((up_c, inf_c));
        let dial: DialFn = Box::new(move |_addr: &str| {
            let (u, i) = halves.take().expect("the fake cloud accepts a single dial");
            Ok((Box::new(u) as Box<dyn Transport + Send>, Box::new(i) as Box<dyn Transport>))
        });
        let link =
            CloudLink::connect_via(9, vec!["inproc".into()], ReconnectPolicy::default(), dial)
                .unwrap();
        // θ = 1.0: every token defers, so every token crosses the fence
        let mut cfg = DeploymentConfig::with_threshold(1.0);
        cfg.device_id = 9;
        cfg.max_new_tokens = 6;
        let mut client =
            EdgeClient::with_cloud(MockEdge::new(MockOracle::new(seed), dims.clone()), cfg, link);
        let out = client.generate("a stale echo prompt").unwrap();
        drop(client);
        upload_thread.join().unwrap();
        infer_thread.join().unwrap();

        let served = served.lock().unwrap();
        let expected: Vec<i32> = served.iter().map(|&(_, _, t)| t).collect();
        assert_eq!(
            out.tokens, expected,
            "seed {seed}: accepted stream must be the served stream, in order"
        );
        assert_eq!(
            out.counters.cloud_requests,
            out.tokens.len(),
            "seed {seed}: exactly one billing per accepted token: {:?}",
            out.counters
        );
        assert_eq!(out.counters.context_replays, 0, "seed {seed}: no echo may trigger a replay");
    }
}

#[test]
fn prop_des_more_clients_never_faster() {
    let dims = test_manifest().model;
    let cost = CostModel::synthetic(&dims);
    let o = MockOracle::new(5);
    let mut edge = MockEdge::new(o, dims.clone());
    let mut cloud = MockCloud::new(o, dims.clone());
    let mut t = CallTimings::default();
    let tr = record(
        &mut edge,
        &mut cloud,
        ExitPolicy::Threshold(0.8),
        Precision::F16,
        "scaling prompt",
        16,
        &mut t,
    )
    .unwrap();
    for strategy in [Strategy::CeCollm(AblationFlags::default()), Strategy::CloudOnly] {
        let mut prev = 0.0;
        for n in 1..=5 {
            let traces: Vec<Vec<_>> = (0..n).map(|_| vec![tr.clone()]).collect();
            let out = simulate(
                &traces,
                &dims,
                &cost,
                &SimConfig {
                    strategy,
                    link: LinkProfile::wifi(),
                    seed: 0,
                    workers: 1,
                    cross_device_batch: false,
                    ..Default::default()
                },
            );
            assert!(
                out.makespan_s >= prev - 1e-9,
                "{strategy:?}: makespan shrank {prev} -> {}",
                out.makespan_s
            );
            prev = out.makespan_s;
        }
    }
}
