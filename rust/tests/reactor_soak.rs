//! Soak test for the event-driven connection reactor: 256 concurrent
//! edge devices (512 sockets via the dual API) served end-to-end by a
//! cloud using **workers + 2** threads total — one worker, one acceptor,
//! one reactor — with every device's token stream bit-identical to the
//! blocking single-client path.
//!
//! This file holds exactly one `#[test]` so the thread-count assertions
//! cannot race other tests in the same binary.

use std::net::TcpListener;
use std::sync::{Arc, Barrier};

use ce_collm::config::{CloudConfig, DeploymentConfig, ExitPolicy};
use ce_collm::coordinator::cloud::{CloudServer, SessionFactory};
use ce_collm::coordinator::edge::{CloudLink, EdgeClient};
use ce_collm::harness::trace::{record, CallTimings};
use ce_collm::model::manifest::test_manifest;
use ce_collm::net::transport::TcpTransport;
use ce_collm::quant::Precision;
use ce_collm::runtime::mock::{MockCloud, MockEdge, MockOracle};

const DEVICES: usize = 256;
const SEED: u64 = 33;
const PROMPT: &str = "soak test prompt for the reactor";
const MAX_NEW: usize = 8;
/// θ = 1.0 (the paper's high-accuracy row): confidences are < 1, so
/// EVERY token defers to the cloud — each device exercises the full
/// upload/park/wake/respond loop through the reactor for all
/// `MAX_NEW` positions, deterministically.
const THRESHOLD: f32 = 1.0;

/// Live thread count of this process (linux); `None` elsewhere.
fn thread_count() -> Option<usize> {
    std::fs::read_to_string("/proc/self/status")
        .ok()?
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
}

/// Both endpoints of all 512 dual-API connections live in this one test
/// process (~1024 sockets + listener + wake pair + harness fds), which
/// exceeds the common RLIMIT_NOFILE soft default of 1024 — raise the
/// soft limit toward the hard limit before fanning out.
#[cfg(target_os = "linux")]
fn ensure_fd_capacity(want: u64) -> bool {
    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
    }
    const RLIMIT_NOFILE: i32 = 7;
    unsafe {
        let mut r = RLimit { cur: 0, max: 0 };
        if getrlimit(RLIMIT_NOFILE, &mut r) != 0 {
            return false;
        }
        if r.cur >= want {
            return true;
        }
        let bumped = RLimit { cur: want.min(r.max), max: r.max };
        let _ = setrlimit(RLIMIT_NOFILE, &bumped);
        getrlimit(RLIMIT_NOFILE, &mut r) == 0 && r.cur >= want
    }
}

#[cfg(not(target_os = "linux"))]
fn ensure_fd_capacity(_want: u64) -> bool {
    true // no portable probe; a too-low limit will surface as EMFILE
}

#[test]
fn soak_256_devices_through_one_reactor_thread() {
    assert!(
        ensure_fd_capacity(4 * DEVICES as u64 + 64),
        "this soak needs ~{} file descriptors (both endpoints of 512 \
         connections live in-process) and the RLIMIT_NOFILE hard limit \
         is below that; raise `ulimit -n`",
        4 * DEVICES + 64
    );
    let dims = test_manifest().model;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let sdims = dims.clone();

    let baseline = thread_count();
    let server = CloudServer::spawn(
        listener,
        dims.clone(),
        CloudConfig::with_workers(1),
        move || {
            let sdims = sdims.clone();
            let f: SessionFactory = Box::new(move |_device| {
                Ok(Box::new(MockCloud::new(MockOracle::new(SEED), sdims.clone())) as _)
            });
            Ok(f)
        },
    )
    .unwrap();

    // thread budget at spawn: acceptor + reactor + one worker, nothing else
    if let (Some(b), Some(now)) = (baseline, thread_count()) {
        assert!(
            now <= b + 3,
            "cloud spawn must add at most workers+2 threads (added {})",
            now - b
        );
    }

    // every client thread connects its dual API, then all rendezvous so
    // the thread census sees all 512 sockets open simultaneously
    let barrier = Arc::new(Barrier::new(DEVICES + 1));
    let addr = server.addr.to_string();
    let mut handles = Vec::with_capacity(DEVICES);
    for device in 0..DEVICES as u64 {
        let addr = addr.clone();
        let barrier = Arc::clone(&barrier);
        let dims = dims.clone();
        handles.push(std::thread::spawn(move || {
            let upload = Box::new(TcpTransport::connect(&addr).unwrap());
            let infer = Box::new(TcpTransport::connect(&addr).unwrap());
            let link = CloudLink::new(device, upload, infer).unwrap();
            barrier.wait(); // (1) everyone connected
            barrier.wait(); // (2) census taken
            let mut cfg = DeploymentConfig::with_threshold(THRESHOLD);
            cfg.device_id = device;
            cfg.max_new_tokens = MAX_NEW;
            let mut client =
                EdgeClient::with_cloud(MockEdge::new(MockOracle::new(SEED), dims), cfg, link);
            let out = client.generate(PROMPT).unwrap();
            (out.tokens, out.counters.cloud_requests)
        }));
    }

    barrier.wait(); // (1) all 512 sockets are up
    // census: baseline + cloud (worker + acceptor + reactor) + per-client
    // threads (each client thread spawned one uploader).  The old
    // thread-per-connection server would add another 512 here.
    if let (Some(b), Some(now)) = (baseline, thread_count()) {
        assert!(
            now <= b + 3 + 2 * DEVICES,
            "server must not spawn per-connection threads \
             (baseline {b}, now {now}, clients account for {})",
            2 * DEVICES
        );
    }
    let rs = server.reactor_stats().unwrap();
    assert_eq!(rs.open_conns, 2 * DEVICES, "all dual-API sockets registered: {rs:?}");
    barrier.wait(); // (2) release the fleet

    let results: Vec<(Vec<i32>, usize)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();

    // the blocking reference path: one locally recorded trace with the
    // same seed/policy must match every device bit-for-bit
    let oracle = MockOracle::new(SEED);
    let mut edge = MockEdge::new(oracle, dims.clone());
    let mut cloud = MockCloud::new(oracle, dims);
    let mut timings = CallTimings::default();
    let reference = record(
        &mut edge,
        &mut cloud,
        ExitPolicy::Threshold(THRESHOLD),
        Precision::F16,
        PROMPT,
        MAX_NEW,
        &mut timings,
    )
    .unwrap();
    assert!(!reference.tokens.is_empty());
    let mut cloud_requests = 0usize;
    for (device, (tokens, reqs)) in results.iter().enumerate() {
        assert_eq!(
            tokens, &reference.tokens,
            "device {device}: reactor-served tokens diverge from the blocking path"
        );
        cloud_requests += reqs;
    }
    assert!(cloud_requests > 0, "the soak must actually exercise cloud deferrals");

    let stats = server.shutdown();
    assert_eq!(
        stats.requests_served as usize, cloud_requests,
        "every deferral answered exactly once: {stats:?}"
    );
    assert!(stats.uploads as usize >= DEVICES, "parallel uploads must have landed");

    // reactor + acceptor + worker are gone and every client (plus its
    // uploader) was joined; allow one thread of slack for runtime noise
    if let (Some(b), Some(now)) = (baseline, thread_count()) {
        assert!(
            now <= b + 1,
            "no cloud threads may outlive shutdown (baseline {b}, now {now})"
        );
    }
}
