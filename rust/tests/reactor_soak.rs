//! Soak test for the sharded reactor fleet, run across backends AND
//! shard counts: the portable `poll(2)` loop at 256 devices (512
//! sockets via the dual API, 1 shard), and on Linux the edge-triggered
//! `epoll` backend at 1024 devices on 1 shard plus a **multi-shard
//! leg** — 4 shards × 4096 devices (8192 sockets spread across
//! per-shard `SO_REUSEPORT` listeners by the kernel's 4-tuple hash),
//! fd-limit- and pid-limit-aware fallback to smaller scales.  Every
//! device is served end-to-end by a cloud using **workers + shards**
//! threads total — the thread census is asserted exactly at spawn,
//! mid-soak, and post-shutdown — with every device's token stream
//! bit-identical to the blocking single-client path AND bit-identical
//! across backends and shard counts.
//!
//! This file holds exactly one `#[test]` so the thread-count assertions
//! cannot race other tests in the same binary.

use std::sync::{Arc, Barrier};

use ce_collm::config::{CloudConfig, DeploymentConfig, ExitPolicy, ReactorBackend};
use ce_collm::coordinator::cloud::{CloudServer, SessionFactory};
use ce_collm::coordinator::edge::{CloudLink, EdgeClient};
use ce_collm::harness::trace::{record, CallTimings};
use ce_collm::model::manifest::test_manifest;
use ce_collm::net::transport::TcpTransport;
use ce_collm::quant::Precision;
use ce_collm::runtime::mock::{MockCloud, MockEdge, MockOracle};

const SEED: u64 = 33;
const PROMPT: &str = "soak test prompt for the reactor";
const MAX_NEW: usize = 8;
/// θ = 1.0 (the paper's high-accuracy row): confidences are < 1, so
/// EVERY token defers to the cloud — each device exercises the full
/// upload/park/wake/respond loop through the reactor for all
/// `MAX_NEW` positions, deterministically.
const THRESHOLD: f32 = 1.0;

/// Live thread count of this process (linux); `None` elsewhere.
fn thread_count() -> Option<usize> {
    std::fs::read_to_string("/proc/self/status")
        .ok()?
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
}

/// Both endpoints of all dual-API connections live in this one test
/// process (4 fds per device + listeners + wake pairs + harness fds),
/// which can exceed the common RLIMIT_NOFILE soft default of 1024 —
/// raise the soft limit toward the hard limit before fanning out.
#[cfg(target_os = "linux")]
fn ensure_fd_capacity(want: u64) -> bool {
    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
    }
    const RLIMIT_NOFILE: i32 = 7;
    unsafe {
        let mut r = RLimit { cur: 0, max: 0 };
        if getrlimit(RLIMIT_NOFILE, &mut r) != 0 {
            return false;
        }
        if r.cur >= want {
            return true;
        }
        let bumped = RLimit { cur: want.min(r.max), max: r.max };
        let _ = setrlimit(RLIMIT_NOFILE, &bumped);
        getrlimit(RLIMIT_NOFILE, &mut r) == 0 && r.cur >= want
    }
}

#[cfg(not(target_os = "linux"))]
fn ensure_fd_capacity(_want: u64) -> bool {
    true // no portable probe; a too-low limit will surface as EMFILE
}

/// The big fan-out also spawns 2 threads per device; respect a cgroup
/// pids ceiling where one is readable (the common container limit).
/// `pids.max` of "max" parses to `None` → unconstrained.
#[cfg(target_os = "linux")]
fn thread_capacity_allows(extra: usize) -> bool {
    let limit = std::fs::read_to_string("/sys/fs/cgroup/pids.max")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok());
    match limit {
        Some(l) => thread_count().unwrap_or(0) + extra + 64 <= l,
        None => true,
    }
}

/// One full soak: `devices` concurrent edge devices (2 sockets each)
/// against a fleet of exactly `shards` reactor shards, thread census
/// checked at spawn, mid-soak, and post-shutdown, tokens checked
/// against the blocking reference.  Returns the (single, shared)
/// per-device token stream so the caller can compare legs — across
/// backends AND shard counts — against each other.
fn run_soak(devices: usize, shards: usize, backend: ReactorBackend, expect: &str) -> Vec<i32> {
    let dims = test_manifest().model;
    let sdims = dims.clone();

    let mut cfg = CloudConfig::with_workers(1);
    cfg.reactor.backend = backend;
    cfg.reactor.shards = shards; // explicit: wins over CE_REACTOR_SHARDS
    // headroom over the per-shard max_conns share: the reuseport hash
    // is uniform-ish, not exact, so give each shard's share room for
    // the whole socket population and assert zero rejections below
    cfg.reactor.max_conns = (8 * devices).max(4096);

    let baseline = thread_count();
    let server = CloudServer::bind("127.0.0.1:0", dims.clone(), cfg, move || {
        let sdims = sdims.clone();
        let f: SessionFactory = Box::new(move |_device| {
            Ok(Box::new(MockCloud::new(MockOracle::new(SEED), sdims.clone())) as _)
        });
        Ok(f)
    })
    .unwrap();
    assert_eq!(server.shards(), shards, "fleet size must be exactly as configured");

    // thread budget at spawn: EXACTLY workers + shards — one worker plus
    // the reactor shards (each owns an accept path; no acceptor thread)
    if let (Some(b), Some(now)) = (baseline, thread_count()) {
        assert_eq!(
            now,
            b + 1 + shards,
            "{expect}/{shards}: cloud spawn must add exactly workers+shards threads \
             (baseline {b}, now {now})"
        );
    }

    // every client thread connects its dual API, then all rendezvous so
    // the thread census sees every socket open simultaneously
    let barrier = Arc::new(Barrier::new(devices + 1));
    let addr = server.addr.to_string();
    let mut handles = Vec::with_capacity(devices);
    for device in 0..devices as u64 {
        let addr = addr.clone();
        let barrier = Arc::clone(&barrier);
        let dims = dims.clone();
        // small stacks: the 4-shard leg runs thousands of client threads
        // in this one process, and the mock engines need very little
        handles.push(
            std::thread::Builder::new()
                .stack_size(192 * 1024)
                .spawn(move || {
                    let upload = Box::new(TcpTransport::connect(&addr).unwrap());
                    let infer = Box::new(TcpTransport::connect(&addr).unwrap());
                    let link = CloudLink::new(device, upload, infer).unwrap();
                    barrier.wait(); // (1) everyone connected
                    barrier.wait(); // (2) census taken
                    let mut cfg = DeploymentConfig::with_threshold(THRESHOLD);
                    cfg.device_id = device;
                    cfg.max_new_tokens = MAX_NEW;
                    let mut client = EdgeClient::with_cloud(
                        MockEdge::new(MockOracle::new(SEED), dims),
                        cfg,
                        link,
                    );
                    let out = client.generate(PROMPT).unwrap();
                    (out.tokens, out.counters.cloud_requests)
                })
                .unwrap(),
        );
    }

    barrier.wait(); // (1) all sockets are up
    // census: baseline + cloud (worker + shards) + per-device client
    // threads (each client thread spawned one uploader).  The old
    // design would add an acceptor here; thread-per-connection would
    // add 2×devices more.
    if let (Some(b), Some(now)) = (baseline, thread_count()) {
        assert_eq!(
            now,
            b + 1 + shards + 2 * devices,
            "{expect}/{shards}: cloud must stay at workers+shards threads mid-soak \
             (baseline {b}, clients account for {})",
            2 * devices
        );
    }
    // fleet-level invariants, per shard: every socket registered, every
    // accept attributed to exactly one shard, no admission rejections
    let per_shard = server.reactor_shard_stats().unwrap();
    assert_eq!(per_shard.len(), shards);
    let open: usize = per_shard.iter().map(|s| s.open_conns).sum();
    let accepts: u64 = per_shard.iter().map(|s| s.accepts).sum();
    let opened: u64 = per_shard.iter().map(|s| s.conns_opened).sum();
    let rejected: u64 = per_shard.iter().map(|s| s.conns_rejected).sum();
    assert_eq!(open, 2 * devices, "all dual-API sockets registered: {per_shard:?}");
    assert_eq!(
        accepts, 2 * devices as u64,
        "accepts summed across shards == connections opened: {per_shard:?}"
    );
    assert_eq!(opened, accepts, "every accept admitted: {per_shard:?}");
    assert_eq!(rejected, 0, "no admission rejections expected: {per_shard:?}");
    if cfg!(unix) {
        // non-unix targets run the probe fallback regardless of config
        for s in &per_shard {
            assert_eq!(s.backend, expect, "wrong readiness backend selected: {s:?}");
        }
    }
    #[cfg(target_os = "linux")]
    {
        if shards > 1 {
            for s in &per_shard {
                assert_eq!(
                    s.accept_mode, "reuseport",
                    "multi-shard bound fleets must get per-shard listeners: {s:?}"
                );
            }
        }
    }
    barrier.wait(); // (2) release the fleet

    let mut results: Vec<(Vec<i32>, usize)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();

    // the O(1)-readiness counters: measured, not just asserted — and the
    // per-shard accept histogram, so shard imbalance is observable
    let per_shard = server.reactor_shard_stats().unwrap();
    let hist: Vec<u64> = per_shard.iter().map(|s| s.accepts).collect();
    let wakes: u64 = per_shard.iter().map(|s| s.wakes).sum();
    let events: u64 = per_shard.iter().map(|s| s.events_seen).sum();
    assert!(wakes > 0 && events > 0, "wake accounting dead: {per_shard:?}");
    println!(
        "{expect}/{shards} shards: {} devices, {} wakes, {} events \
         ({:.1} events/wake), accept histogram {:?}",
        devices,
        wakes,
        events,
        events as f64 / wakes as f64,
        hist
    );

    // the blocking reference path: one locally recorded trace with the
    // same seed/policy must match every device bit-for-bit
    let oracle = MockOracle::new(SEED);
    let mut edge = MockEdge::new(oracle, dims.clone());
    let mut cloud = MockCloud::new(oracle, dims);
    let mut timings = CallTimings::default();
    let reference = record(
        &mut edge,
        &mut cloud,
        ExitPolicy::Threshold(THRESHOLD),
        Precision::F16,
        PROMPT,
        MAX_NEW,
        &mut timings,
    )
    .unwrap();
    assert!(!reference.tokens.is_empty());
    let mut cloud_requests = 0usize;
    for (device, (tokens, reqs)) in results.iter().enumerate() {
        assert_eq!(
            tokens, &reference.tokens,
            "{expect}/{shards}: device {device} diverges from the blocking path"
        );
        cloud_requests += reqs;
    }
    assert!(cloud_requests > 0, "the soak must actually exercise cloud deferrals");

    let stats = server.shutdown();
    assert_eq!(
        stats.requests_served as usize, cloud_requests,
        "every deferral answered exactly once: {stats:?}"
    );
    assert!(stats.uploads as usize >= devices, "parallel uploads must have landed");
    // shutdown folds the fleet's finals into CloudStats, per shard and
    // aggregated
    assert_eq!(stats.reactor_shards.len(), shards, "per-shard finals retained: {stats:?}");
    assert_eq!(
        stats.reactor.conns_opened, 2 * devices as u64,
        "aggregate reactor stats must fold every shard: {stats:?}"
    );

    // reactor shards + worker are gone and every client (plus its
    // uploader) was joined; the count must return EXACTLY to baseline
    // (a retry loop absorbs kernel task-reaping lag, and an exact
    // landing keeps the next leg's fresh baseline uncontaminated)
    if let Some(b) = baseline {
        let mut now = thread_count();
        for _ in 0..200 {
            if now == Some(b) {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
            now = thread_count();
        }
        assert_eq!(
            now,
            Some(b),
            "{expect}/{shards}: cloud threads outlive shutdown (baseline {b})"
        );
    }
    // the tokens the wire actually served (already proven equal to the
    // reference above) — returned so the caller's cross-leg bit-identity
    // asserts compare *served* streams, not two copies of the local
    // recomputation
    results.swap_remove(0).0
}

#[test]
fn soak_shard_fleet_exact_thread_budget() {
    // portable poll(2) fallback: 256 devices / 512 sockets, 1 shard
    assert!(
        ensure_fd_capacity(4 * 256 + 64),
        "this soak needs ~{} file descriptors and the RLIMIT_NOFILE hard \
         limit is below that; raise `ulimit -n`",
        4 * 256 + 64
    );
    let poll_tokens = run_soak(256, 1, ReactorBackend::Poll, "poll");

    #[cfg(target_os = "linux")]
    {
        // epoll, single shard: 2048 sockets if the fd budget allows,
        // else the same 256-device scale
        let devices = if ensure_fd_capacity(4 * 1024 + 128) {
            1024
        } else {
            eprintln!("RLIMIT_NOFILE too low for 2048 sockets; epoll leg at 256 devices");
            256
        };
        let epoll_tokens = run_soak(devices, 1, ReactorBackend::Epoll, "epoll");
        // cross-backend bit-identity: the same device script must yield
        // the same token stream whichever readiness backend served it
        assert_eq!(
            poll_tokens, epoll_tokens,
            "poll and epoll backends produced diverging token streams"
        );

        // the multi-shard leg: 4 SO_REUSEPORT shards at 8192 sockets
        // (4096 devices), laddering down where fd or pid limits bite
        let mut devices = 4096usize;
        while devices > 256
            && !(ensure_fd_capacity(4 * devices as u64 + 256)
                && thread_capacity_allows(2 * devices + 16))
        {
            devices /= 4;
        }
        if devices < 4096 {
            eprintln!("fd/pid limits too low for 8192 sockets; multi-shard leg at {devices}");
        }
        let fleet_tokens = run_soak(devices, 4, ReactorBackend::Epoll, "epoll");
        // cross-shard-count bit-identity: sharding the reactor must
        // never change the served bytes
        assert_eq!(
            poll_tokens, fleet_tokens,
            "1-shard and 4-shard fleets produced diverging token streams"
        );
    }
    #[cfg(not(target_os = "linux"))]
    let _ = poll_tokens;
}
