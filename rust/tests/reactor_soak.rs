//! Soak test for the event-driven connection reactor, run on BOTH
//! readiness backends: the portable `poll(2)` loop at 256 devices (512
//! sockets via the dual API) and, on Linux, the edge-triggered `epoll`
//! backend at 1024 devices (2048 sockets — the O(1)-readiness scale).
//! Every device is served end-to-end by a cloud using **workers + 1**
//! threads total — one worker plus one reactor that also owns the
//! listener; the acceptor thread is gone — with every device's token
//! stream bit-identical to the blocking single-client path AND
//! bit-identical across the two backends.
//!
//! This file holds exactly one `#[test]` so the thread-count assertions
//! cannot race other tests in the same binary.

use std::net::TcpListener;
use std::sync::{Arc, Barrier};

use ce_collm::config::{CloudConfig, DeploymentConfig, ExitPolicy, ReactorBackend};
use ce_collm::coordinator::cloud::{CloudServer, SessionFactory};
use ce_collm::coordinator::edge::{CloudLink, EdgeClient};
use ce_collm::harness::trace::{record, CallTimings};
use ce_collm::model::manifest::test_manifest;
use ce_collm::net::transport::TcpTransport;
use ce_collm::quant::Precision;
use ce_collm::runtime::mock::{MockCloud, MockEdge, MockOracle};

const SEED: u64 = 33;
const PROMPT: &str = "soak test prompt for the reactor";
const MAX_NEW: usize = 8;
/// θ = 1.0 (the paper's high-accuracy row): confidences are < 1, so
/// EVERY token defers to the cloud — each device exercises the full
/// upload/park/wake/respond loop through the reactor for all
/// `MAX_NEW` positions, deterministically.
const THRESHOLD: f32 = 1.0;

/// Live thread count of this process (linux); `None` elsewhere.
fn thread_count() -> Option<usize> {
    std::fs::read_to_string("/proc/self/status")
        .ok()?
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
}

/// Both endpoints of all dual-API connections live in this one test
/// process (4 fds per device + listener + wake pair + harness fds),
/// which can exceed the common RLIMIT_NOFILE soft default of 1024 —
/// raise the soft limit toward the hard limit before fanning out.
#[cfg(target_os = "linux")]
fn ensure_fd_capacity(want: u64) -> bool {
    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
    }
    const RLIMIT_NOFILE: i32 = 7;
    unsafe {
        let mut r = RLimit { cur: 0, max: 0 };
        if getrlimit(RLIMIT_NOFILE, &mut r) != 0 {
            return false;
        }
        if r.cur >= want {
            return true;
        }
        let bumped = RLimit { cur: want.min(r.max), max: r.max };
        let _ = setrlimit(RLIMIT_NOFILE, &bumped);
        getrlimit(RLIMIT_NOFILE, &mut r) == 0 && r.cur >= want
    }
}

#[cfg(not(target_os = "linux"))]
fn ensure_fd_capacity(_want: u64) -> bool {
    true // no portable probe; a too-low limit will surface as EMFILE
}

/// One full soak on the given backend: `devices` concurrent edge
/// devices (2 sockets each), thread census checked at spawn, mid-soak,
/// and post-shutdown, tokens checked against the blocking reference.
/// Returns the (single, shared) per-device token stream so the caller
/// can compare backends against each other.
fn run_soak(devices: usize, backend: ReactorBackend, expect_backend: &str) -> Vec<i32> {
    let dims = test_manifest().model;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let sdims = dims.clone();

    let mut cfg = CloudConfig::with_workers(1);
    cfg.reactor.backend = backend;

    let baseline = thread_count();
    let server = CloudServer::spawn(listener, dims.clone(), cfg, move || {
        let sdims = sdims.clone();
        let f: SessionFactory = Box::new(move |_device| {
            Ok(Box::new(MockCloud::new(MockOracle::new(SEED), sdims.clone())) as _)
        });
        Ok(f)
    })
    .unwrap();

    // thread budget at spawn: EXACTLY workers + 1 — one worker plus the
    // reactor (which owns the listener; no acceptor thread)
    if let (Some(b), Some(now)) = (baseline, thread_count()) {
        assert_eq!(
            now,
            b + 2,
            "{expect_backend}: cloud spawn must add exactly workers+1 threads \
             (baseline {b}, now {now})"
        );
    }

    // every client thread connects its dual API, then all rendezvous so
    // the thread census sees every socket open simultaneously
    let barrier = Arc::new(Barrier::new(devices + 1));
    let addr = server.addr.to_string();
    let mut handles = Vec::with_capacity(devices);
    for device in 0..devices as u64 {
        let addr = addr.clone();
        let barrier = Arc::clone(&barrier);
        let dims = dims.clone();
        handles.push(std::thread::spawn(move || {
            let upload = Box::new(TcpTransport::connect(&addr).unwrap());
            let infer = Box::new(TcpTransport::connect(&addr).unwrap());
            let link = CloudLink::new(device, upload, infer).unwrap();
            barrier.wait(); // (1) everyone connected
            barrier.wait(); // (2) census taken
            let mut cfg = DeploymentConfig::with_threshold(THRESHOLD);
            cfg.device_id = device;
            cfg.max_new_tokens = MAX_NEW;
            let mut client =
                EdgeClient::with_cloud(MockEdge::new(MockOracle::new(SEED), dims), cfg, link);
            let out = client.generate(PROMPT).unwrap();
            (out.tokens, out.counters.cloud_requests)
        }));
    }

    barrier.wait(); // (1) all sockets are up
    // census: baseline + cloud (worker + reactor) + per-device client
    // threads (each client thread spawned one uploader).  The old
    // design would add an acceptor here; thread-per-connection would
    // add 2×devices more.
    if let (Some(b), Some(now)) = (baseline, thread_count()) {
        assert_eq!(
            now,
            b + 2 + 2 * devices,
            "{expect_backend}: cloud must stay at workers+1 threads mid-soak \
             (baseline {b}, clients account for {})",
            2 * devices
        );
    }
    let rs = server.reactor_stats().unwrap();
    assert_eq!(rs.open_conns, 2 * devices, "all dual-API sockets registered: {rs:?}");
    if cfg!(unix) {
        // non-unix targets run the probe fallback regardless of config
        assert_eq!(rs.backend, expect_backend, "wrong readiness backend selected: {rs:?}");
    }
    assert_eq!(
        rs.accepts, 2 * devices as u64,
        "every socket must have been accepted in-reactor: {rs:?}"
    );
    assert_eq!(rs.conns_opened, rs.accepts, "no admission rejections expected: {rs:?}");
    barrier.wait(); // (2) release the fleet

    let mut results: Vec<(Vec<i32>, usize)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();

    // the O(1)-readiness counters: measured, not just asserted
    let rs = server.reactor_stats().unwrap();
    assert!(rs.wakes > 0 && rs.events_seen > 0, "wake accounting dead: {rs:?}");
    println!(
        "{expect_backend}: {} devices, {} wakes, {} events ({:.1} events/wake)",
        devices,
        rs.wakes,
        rs.events_seen,
        rs.events_seen as f64 / rs.wakes as f64
    );

    // the blocking reference path: one locally recorded trace with the
    // same seed/policy must match every device bit-for-bit
    let oracle = MockOracle::new(SEED);
    let mut edge = MockEdge::new(oracle, dims.clone());
    let mut cloud = MockCloud::new(oracle, dims);
    let mut timings = CallTimings::default();
    let reference = record(
        &mut edge,
        &mut cloud,
        ExitPolicy::Threshold(THRESHOLD),
        Precision::F16,
        PROMPT,
        MAX_NEW,
        &mut timings,
    )
    .unwrap();
    assert!(!reference.tokens.is_empty());
    let mut cloud_requests = 0usize;
    for (device, (tokens, reqs)) in results.iter().enumerate() {
        assert_eq!(
            tokens, &reference.tokens,
            "{expect_backend}: device {device} diverges from the blocking path"
        );
        cloud_requests += reqs;
    }
    assert!(cloud_requests > 0, "the soak must actually exercise cloud deferrals");

    let stats = server.shutdown();
    assert_eq!(
        stats.requests_served as usize, cloud_requests,
        "every deferral answered exactly once: {stats:?}"
    );
    assert!(stats.uploads as usize >= devices, "parallel uploads must have landed");

    // reactor + worker are gone and every client (plus its uploader)
    // was joined; the count must return EXACTLY to baseline (a retry
    // loop absorbs kernel task-reaping lag, and an exact landing keeps
    // the next leg's fresh baseline uncontaminated)
    if let Some(b) = baseline {
        let mut now = thread_count();
        for _ in 0..200 {
            if now == Some(b) {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
            now = thread_count();
        }
        assert_eq!(
            now,
            Some(b),
            "{expect_backend}: cloud threads outlive shutdown (baseline {b})"
        );
    }
    // the tokens the wire actually served (already proven equal to the
    // reference above) — returned so the caller's cross-backend
    // bit-identity assert compares two *served* streams, not two
    // copies of the local recomputation
    results.swap_remove(0).0
}

#[test]
fn soak_both_backends_one_reactor_thread() {
    // portable poll(2) fallback: 256 devices / 512 sockets
    assert!(
        ensure_fd_capacity(4 * 256 + 64),
        "this soak needs ~{} file descriptors and the RLIMIT_NOFILE hard \
         limit is below that; raise `ulimit -n`",
        4 * 256 + 64
    );
    let poll_tokens = run_soak(256, ReactorBackend::Poll, "poll");

    // epoll (linux): 2048 sockets if the fd budget allows, else the
    // same 256-device scale — the backend still gets full coverage
    #[cfg(target_os = "linux")]
    {
        let devices = if ensure_fd_capacity(4 * 1024 + 128) {
            1024
        } else {
            eprintln!("RLIMIT_NOFILE too low for 2048 sockets; epoll leg at 256 devices");
            256
        };
        let epoll_tokens = run_soak(devices, ReactorBackend::Epoll, "epoll");
        // cross-backend bit-identity: the same device script must yield
        // the same token stream whichever readiness backend served it
        assert_eq!(
            poll_tokens, epoll_tokens,
            "poll and epoll backends produced diverging token streams"
        );
    }
    #[cfg(not(target_os = "linux"))]
    let _ = poll_tokens;
}
