//! Integration tests for the cloud context store through the scheduler:
//! budget-pressure LRU eviction with bit-identical replay recovery, the
//! idle-TTL reaper, and the "never evict a device inside the batch pass
//! that serves it" protection — all with mock engines and deterministic
//! message ordering (no sleeps except where the TTL clock itself is the
//! thing under test).

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use ce_collm::config::CloudConfig;
use ce_collm::coordinator::scheduler::{
    InferOutcome, Reply, Router, SchedMsg, Scheduler, SessionFactory, UploadPayload,
};
use ce_collm::model::manifest::test_manifest;
use ce_collm::runtime::mock::{MockCloud, MockOracle};

const D: usize = 128; // test manifest d_model
const KV_POS: u64 = 5120; // test manifest cloud_kv_bytes_per_pos()

fn scheduler(seed: u64, cfg: CloudConfig, gate: Option<Arc<std::sync::Barrier>>) -> Scheduler {
    let dims = test_manifest().model;
    let sdims = dims.clone();
    Scheduler::spawn(
        dims,
        cfg,
        Arc::new(move || {
            if let Some(g) = &gate {
                g.wait();
            }
            let sdims = sdims.clone();
            let f: SessionFactory = Box::new(move |_device| {
                Ok(Box::new(MockCloud::new(MockOracle::new(seed), sdims.clone())) as _)
            });
            Ok(f)
        }),
    )
    .unwrap()
}

fn upload(router: &Router, device: u64, req_id: u32, start_pos: u32, count: usize, plen: u32) {
    router
        .send(
            device,
            SchedMsg::Upload {
                device,
                session: 0,
                req_id,
                start_pos,
                prompt_len: plen,
                payload: UploadPayload::Floats(vec![0.5; count * D]),
            },
        )
        .unwrap();
}

fn infer(
    router: &Router,
    device: u64,
    req_id: u32,
    pos: u32,
    plen: u32,
) -> mpsc::Receiver<anyhow::Result<InferOutcome>> {
    let (tx, rx) = mpsc::channel();
    router
        .send(
            device,
            SchedMsg::Infer {
                device,
                session: 0,
                req_id,
                pos,
                prompt_len: plen,
                deadline: None,
                reply: Reply::channel(tx),
            },
        )
        .unwrap();
    rx
}

fn expect_token(rx: mpsc::Receiver<anyhow::Result<InferOutcome>>) -> i32 {
    match rx.recv().unwrap().unwrap() {
        InferOutcome::Token(t) => t.token,
        InferOutcome::Evicted => panic!("expected a token, got an eviction notice"),
    }
}

fn expect_evicted(rx: mpsc::Receiver<anyhow::Result<InferOutcome>>) {
    match rx.recv().unwrap().unwrap() {
        InferOutcome::Evicted => {}
        InferOutcome::Token(t) => panic!("expected an eviction notice, got token {}", t.token),
    }
}

/// The driver loop of these tests, shared with the no-budget reference
/// run: device 1 serves positions 2..=4 of a 3-token prompt, with device
/// 2 wedged in between to create budget pressure, recovering from any
/// eviction notice by replaying the history from position 0 exactly as
/// the edge client does.  Returns device 1's tokens.
fn drive(sched: &Scheduler) -> (Vec<i32>, u64) {
    let router = sched.router();
    let mut tokens = Vec::new();
    let mut replays = 0u64;
    // device 1: prompt + first token
    upload(&router, 1, 1, 0, 3, 3);
    tokens.push(expect_token(infer(&router, 1, 1, 2, 3)));
    // device 2 becomes the most recent tenant (pressure on device 1)
    upload(&router, 2, 1, 0, 3, 3);
    expect_token(infer(&router, 2, 1, 2, 3));
    // device 1 continues at positions 3 and 4; on eviction, replay
    // 0..=pos under the same request id and ask again
    for pos in 3..=4u32 {
        upload(&router, 1, 1, pos, 1, 3);
        let mut rx = infer(&router, 1, 1, pos, 3);
        loop {
            match rx.recv().unwrap().unwrap() {
                InferOutcome::Token(t) => {
                    tokens.push(t.token);
                    break;
                }
                InferOutcome::Evicted => {
                    replays += 1;
                    assert!(replays <= 4, "replay loop must converge");
                    upload(&router, 1, 1, 0, pos as usize + 1, 3);
                    rx = infer(&router, 1, 1, pos, 3);
                }
            }
        }
    }
    router.send(1, SchedMsg::End { device: 1, session: 0, req_id: 1 }).unwrap();
    router.send(2, SchedMsg::End { device: 2, session: 0, req_id: 1 }).unwrap();
    (tokens, replays)
}

#[test]
fn unset_budget_is_behaviorally_identical_to_today() {
    let sched = scheduler(17, CloudConfig::default(), None);
    let (tokens, replays) = drive(&sched);
    assert_eq!(tokens.len(), 3);
    assert_eq!(replays, 0, "no budget -> no eviction notices");
    let stats = sched.shutdown();
    let c = stats.context;
    assert_eq!((c.evictions, c.ttl_reaps, c.replays), (0, 0, 0));
    assert_eq!(c.resident_bytes, 0, "everything released by EndSession");
}

#[test]
fn budget_pressure_evicts_lru_and_replay_is_bit_identical() {
    // budget above any single device's working set (5 positions = 25600)
    // but below two settled devices (>= 30720): pressure must evict, the
    // gauge must never exceed the budget, and the tokens must match the
    // unbudgeted reference exactly
    let budget = 28_000u64;
    let seed = 17;
    let reference = {
        let sched = scheduler(seed, CloudConfig::default(), None);
        drive(&sched).0
    };
    let cfg = CloudConfig { memory_budget_bytes: Some(budget), ..Default::default() };
    let sched = scheduler(seed, cfg, None);

    let router = sched.router();
    let mut tokens = Vec::new();
    upload(&router, 1, 1, 0, 3, 3);
    tokens.push(expect_token(infer(&router, 1, 1, 2, 3)));
    assert!(sched.stats().unwrap().context.resident_bytes <= budget);

    // device 2's pass pushes the pool over budget: idle device 1 (LRU)
    // is evicted, device 2 (just served, MRU) survives
    upload(&router, 2, 1, 0, 3, 3);
    expect_token(infer(&router, 2, 1, 2, 3));
    let stats = sched.stats().unwrap();
    assert_eq!(stats.context.evictions, 1);
    assert!(stats.context.resident_bytes <= budget, "{stats:?}");

    // device 1's next request hits the eviction notice...
    upload(&router, 1, 1, 3, 1, 3);
    expect_evicted(infer(&router, 1, 1, 3, 3));
    // ...and recovers by replaying positions 0..=3 under the same req id
    upload(&router, 1, 1, 0, 4, 3);
    tokens.push(expect_token(infer(&router, 1, 1, 3, 3)));
    // the continuation serves normally (device 1 is resident again)
    upload(&router, 1, 1, 4, 1, 3);
    tokens.push(expect_token(infer(&router, 1, 1, 4, 3)));

    assert_eq!(tokens, reference, "evict-then-replay must be bit-identical");
    let stats = sched.stats().unwrap();
    assert!(stats.context.resident_bytes <= budget, "{stats:?}");
    assert_eq!(stats.context.replays, 1, "one replayed context");
    assert!(stats.context.evictions >= 2, "device 2 evicted under device 1's replay pressure");
    assert_eq!(stats.context.ttl_reaps, 0);
    sched.shutdown();
}

#[test]
fn eviction_never_targets_a_device_in_the_current_batch_pass() {
    // absurd budget (1 byte) + a gated worker: three devices' uploads
    // and infers are queued before the worker drains anything, so one
    // batch pass serves all three.  Every request must resolve with a
    // TOKEN — eviction sweeps run only between passes — and only then
    // may the sweep evict the now-idle losers.
    let gate = Arc::new(std::sync::Barrier::new(2));
    let cfg = CloudConfig { memory_budget_bytes: Some(1), ..Default::default() };
    let sched = scheduler(5, cfg, Some(Arc::clone(&gate)));
    let router = sched.router();
    for dev in 1..=3u64 {
        upload(&router, dev, 1, 0, 3, 3);
    }
    let rxs: Vec<_> = (1..=3u64).map(|dev| infer(&router, dev, 1, 2, 3)).collect();
    gate.wait();
    let oracle = MockOracle::new(5);
    for rx in rxs {
        assert_eq!(expect_token(rx), oracle.cloud_token(2), "served, not evicted, mid-pass");
    }
    let stats = sched.stats().unwrap();
    assert_eq!(stats.engine_passes, 1, "one padded pass over all three devices: {stats:?}");
    // after the pass the sweep evicts everything but the MRU device
    assert_eq!(stats.context.evictions, 2, "{stats:?}");
    assert!(stats.context.resident_bytes <= 3 * KV_POS, "at most one settled device left");
    sched.shutdown();
}

#[test]
fn idle_ttl_reaps_and_the_session_recovers_by_replay() {
    let seed = 9;
    let cfg = CloudConfig { session_ttl_s: Some(0.05), ..Default::default() };
    let sched = scheduler(seed, cfg, None);
    let router = sched.router();
    let oracle = MockOracle::new(seed);

    upload(&router, 1, 1, 0, 3, 3);
    assert_eq!(expect_token(infer(&router, 1, 1, 2, 3)), oracle.cloud_token(2));
    assert!(sched.stats().unwrap().context.resident_bytes > 0);

    // idle past the TTL: the worker wakes itself at the deadline and
    // reaps the session with no traffic arriving at all
    std::thread::sleep(Duration::from_millis(200));
    let stats = sched.stats().unwrap();
    assert_eq!(stats.context.ttl_reaps, 1, "{stats:?}");
    assert_eq!(stats.context.resident_bytes, 0);

    // the device's next deferral is told to replay, then serves
    upload(&router, 1, 1, 3, 1, 3);
    expect_evicted(infer(&router, 1, 1, 3, 3));
    upload(&router, 1, 1, 0, 4, 3);
    assert_eq!(expect_token(infer(&router, 1, 1, 3, 3)), oracle.cloud_token(3));
    let stats = sched.shutdown();
    assert_eq!(stats.context.replays, 1);
}

#[test]
fn budget_splits_evenly_across_workers() {
    // two workers, budget 2 * (one settled device): each shard fits one
    // device, so two devices on DIFFERENT workers coexist while a second
    // device on the SAME worker evicts its shard-mate
    let budget = 2 * 3 * KV_POS + 2; // per-worker share: 3*KV_POS + 1
    let cfg = CloudConfig {
        workers: 2,
        memory_budget_bytes: Some(budget),
        ..Default::default()
    };
    let sched = scheduler(3, cfg, None);
    let router = sched.router();
    // devices 0 and 1 land on different workers and both stay resident
    for dev in [0u64, 1] {
        upload(&router, dev, 1, 0, 3, 3);
        expect_token(infer(&router, dev, 1, 2, 3));
    }
    let stats = sched.stats().unwrap();
    assert_eq!(stats.context.evictions, 0, "shards independent: {stats:?}");
    // device 2 shares worker 0 with device 0: its pass evicts device 0
    upload(&router, 2, 1, 0, 3, 3);
    expect_token(infer(&router, 2, 1, 2, 3));
    let stats = sched.stats().unwrap();
    assert_eq!(stats.context.evictions, 1, "{stats:?}");
    upload(&router, 0, 1, 3, 1, 3);
    expect_evicted(infer(&router, 0, 1, 3, 3));
    // device 1's shard was never pressured
    upload(&router, 1, 1, 3, 1, 3);
    expect_token(infer(&router, 1, 1, 3, 3));
    sched.shutdown();
}
