//! Edge-resilience integration tests: scripted link faults against the
//! real TCP serving path.  Every fault here is deterministic — either a
//! client-side [`FaultTransport`] schedule injected through the
//! `CloudLink` dialer seam, or a server-side [`ReactorFault`] severing
//! connections at a fixed inbound-frame ordinal — so reconnect, session
//! resume, and failover are exercised at exact protocol steps and the
//! recovered token streams can be compared bit-for-bit against the
//! local (never-severed) reference.
//!
//! The whole file also runs under the CI `CE_FAULT=sever_in:7` leg,
//! where every server connection additionally severs after its 7th
//! inbound frame.  Assertions are therefore lower bounds (`>=`) on
//! fault/recovery counters wherever the env schedule can add rounds.

use std::sync::{Arc, Barrier};

use ce_collm::config::{
    CloudConfig, DeploymentConfig, ExitPolicy, ReactorBackend, ReconnectPolicy,
};
use ce_collm::coordinator::cloud::{CloudServer, SessionFactory};
use ce_collm::coordinator::edge::{CloudLink, DialFn, EdgeClient};
use ce_collm::coordinator::protocol::{Channel, Message};
use ce_collm::model::manifest::test_manifest;
use ce_collm::net::fault::{FaultPlan, FaultTransport, ReactorFault};
use ce_collm::net::transport::{TcpTransport, Transport};
use ce_collm::runtime::mock::{MockCloud, MockEdge, MockOracle};

/// See `serve_tcp.rs`: the non-default readiness backend, so severs and
/// resumes are exercised under both event loops.
const OTHER_BACKEND: ReactorBackend = ReactorBackend::Poll;

/// Server config for fault runs: parks must expire fast, because a
/// sever can eat an upload and leave its infer request waiting for
/// state that will never arrive — the expiry error is what hands
/// control back to the client's reconnect loop.  The idle reap is
/// tightened for the same reason: a `drop_in`/`reorder_in` env
/// schedule can swallow an infer *request*, and a client blocked in a
/// deadline-less `recv` only recovers once the reactor reaps the
/// now-silent connection and the close reaches its reconnect loop.
fn fault_cloud_config(workers: usize) -> CloudConfig {
    let mut cfg = CloudConfig::with_workers(workers);
    cfg.max_park_s = 0.2;
    cfg.reactor.idle_timeout_s = 2.0;
    cfg
}

/// One mock engine per device, all seeded `seed_base + device`, so each
/// client thread has its own deterministic local reference.
fn spawn_server(seed_base: u64, cfg: CloudConfig) -> CloudServer {
    let dims = test_manifest().model;
    let sdims = dims.clone();
    CloudServer::bind("127.0.0.1:0", dims, cfg, move || {
        let sdims = sdims.clone();
        let f: SessionFactory = Box::new(move |device| {
            Ok(Box::new(MockCloud::new(MockOracle::new(seed_base + device), sdims.clone())) as _)
        });
        Ok(f)
    })
    .unwrap()
}

/// The local (in-process, never-severed) reference stream every
/// recovered wire run must match bit-for-bit.
fn local_trace(seed: u64, threshold: f32, prompt: &str, max_new: usize) -> Vec<i32> {
    let dims = test_manifest().model;
    let o = MockOracle::new(seed);
    let mut edge = MockEdge::new(o, dims.clone());
    let mut cloud = MockCloud::new(o, dims);
    let mut timings = ce_collm::harness::trace::CallTimings::default();
    ce_collm::harness::trace::record(
        &mut edge,
        &mut cloud,
        ExitPolicy::Threshold(threshold),
        ce_collm::quant::Precision::F16,
        prompt,
        max_new,
        &mut timings,
    )
    .unwrap()
    .tokens
}

/// Clean TCP `(upload, infer)` pair — the test twin of the default
/// dialer inside [`CloudLink::connect`].
fn tcp_pair(addr: &str) -> anyhow::Result<(Box<dyn Transport + Send>, Box<dyn Transport>)> {
    let upload = Box::new(TcpTransport::connect(addr)?);
    let infer = Box::new(TcpTransport::connect(addr)?);
    Ok((upload as Box<dyn Transport + Send>, infer as Box<dyn Transport>))
}

fn clean_dial() -> DialFn {
    Box::new(tcp_pair)
}

/// A dialer whose FIRST dial wraps the infer channel in `plan`; every
/// redial is clean TCP.  The scripted sever therefore fires exactly
/// once per run (the env-leg reactor schedule may add more).
fn faulty_first_dial(plan: FaultPlan) -> DialFn {
    let mut first = Some(plan);
    Box::new(move |addr: &str| match first.take() {
        Some(plan) => {
            let upload = Box::new(TcpTransport::connect(addr)?);
            let infer = FaultTransport::new(TcpTransport::connect(addr)?, plan);
            Ok((upload as Box<dyn Transport + Send>, Box::new(infer) as Box<dyn Transport>))
        }
        None => tcp_pair(addr),
    })
}

fn client_via(
    addr: &str,
    device: u64,
    seed: u64,
    threshold: f32,
    max_new: usize,
    policy: ReconnectPolicy,
    dial: DialFn,
) -> EdgeClient<MockEdge> {
    let dims = test_manifest().model;
    let mut cfg = DeploymentConfig::with_threshold(threshold);
    cfg.device_id = device;
    cfg.max_new_tokens = max_new;
    let link = CloudLink::connect_via(device, vec![addr.to_string()], policy, dial).unwrap();
    EdgeClient::with_cloud(MockEdge::new(MockOracle::new(seed), dims), cfg, link)
}

/// Sever the infer channel exactly when the first deferred token's
/// response is on the wire (recv ordinal 0 is the handshake `Ack`): the
/// cloud has served the token but the edge never hears it — the
/// "lost response" hole.  The reconnect must resume the session (same
/// nonce), replay the full exit-1 history, and re-derive the identical
/// token; nothing about the recovery may be billed as an eviction.
fn severed_link_resumes_bit_identical(backend: ReactorBackend) {
    let seed = 17;
    let mut cfg = fault_cloud_config(1);
    cfg.reactor.backend = backend;
    let server = spawn_server(seed, cfg);

    let dial = faulty_first_dial(FaultPlan::new().sever_recv_at(1));
    let mut client = client_via(
        &server.addr.to_string(),
        0,
        seed,
        0.8,
        20,
        ReconnectPolicy::default(),
        dial,
    );
    let out = client.generate("a tcp test prompt").unwrap();
    assert_eq!(
        out.tokens,
        local_trace(seed, 0.8, "a tcp test prompt", 20),
        "resumed stream diverges from the unsevered reference ({backend:?})"
    );
    assert!(out.counters.reconnects >= 1, "the sever must reconnect: {:?}", out.counters);
    assert_eq!(out.counters.failovers, 0, "one endpoint: rotation is impossible");
    assert_eq!(
        out.counters.context_replays, 0,
        "a resume replay must not be billed as an eviction replay"
    );

    let stats = server.shutdown();
    assert!(stats.sessions_resumed >= 1, "the resume Hello must be honored: {stats:?}");
    assert_eq!(stats.stale_resumes, 0, "the server never lost the session: {stats:?}");
}

#[test]
fn severed_link_resumes_bit_identically() {
    severed_link_resumes_bit_identical(ReactorBackend::Auto);
}

#[test]
fn severed_link_resumes_bit_identically_other_backend() {
    severed_link_resumes_bit_identical(OTHER_BACKEND);
}

/// Two devices ping-pong evictions under a 1-byte context budget while
/// device 0's infer channel is severed mid-churn (recv ordinal 4 lands
/// among `SessionEvicted` responses and replay acks).  Reconnect-resume
/// and eviction-replay recovery compose: both streams must still match
/// the never-evicted, never-severed local reference.
#[test]
fn sever_during_eviction_replay_stays_bit_identical() {
    let mut cfg = fault_cloud_config(1);
    cfg.memory_budget_bytes = Some(1);
    let server = spawn_server(500, cfg);

    let addr = server.addr.to_string();
    let gate = Arc::new(Barrier::new(2));
    let mut handles = Vec::new();
    for device in 0..2u64 {
        let addr = addr.clone();
        let gate = Arc::clone(&gate);
        handles.push(std::thread::spawn(move || {
            let dial = if device == 0 {
                faulty_first_dial(FaultPlan::new().sever_recv_at(4))
            } else {
                clean_dial()
            };
            // θ = 1.0: every token defers, keeping both devices active
            // so the budget keeps evicting whichever is idle
            let mut client = client_via(
                &addr,
                device,
                500 + device,
                1.0,
                16,
                ReconnectPolicy::default(),
                dial,
            );
            gate.wait();
            (device, client.generate("an eviction sever prompt").unwrap())
        }));
    }
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    for (device, out) in &results {
        assert_eq!(
            out.tokens,
            local_trace(500 + device, 1.0, "an eviction sever prompt", 16),
            "device {device}: recovery must be bit-identical"
        );
    }
    let severed = &results.iter().find(|(d, _)| *d == 0).unwrap().1;
    assert!(severed.counters.reconnects >= 1, "device 0 must reconnect: {:?}", severed.counters);

    let stats = server.shutdown();
    assert!(stats.context.evictions > 0, "no eviction under a 1-byte budget? {stats:?}");
    assert!(stats.sessions_resumed >= 1, "device 0's resume must be honored: {stats:?}");
}

/// Endpoint A dies mid-generation and refuses every redial — the
/// cloud-restart shape.  The link must exhaust A's attempt budget,
/// rotate to endpoint B, and present the session nonce there; B has
/// never seen it (stale resume → full reset + pin), so the edge replay
/// re-prefills B and the stream continues bit-identically.
#[test]
fn cloud_restart_fails_over_to_second_endpoint() {
    let seed = 61;
    let server_a = spawn_server(seed, fault_cloud_config(1));
    let server_b = spawn_server(seed, fault_cloud_config(1));
    let addr_a = server_a.addr.to_string();
    let addr_b = server_b.addr.to_string();

    let policy = ReconnectPolicy {
        max_attempts: 2,
        backoff_base_s: 0.001,
        backoff_cap_s: 0.01,
        jitter: 0.5,
        connect_timeout_s: 1.0,
    };
    let gate_a = addr_a.clone();
    let mut a_dials = 0u32;
    let dial: DialFn = Box::new(move |addr: &str| {
        if addr == gate_a {
            a_dials += 1;
            anyhow::ensure!(a_dials == 1, "endpoint A is down (cloud restart)");
            let upload = Box::new(TcpTransport::connect(addr)?);
            let infer = FaultTransport::new(
                TcpTransport::connect(addr)?,
                FaultPlan::new().sever_recv_at(1),
            );
            Ok((upload as Box<dyn Transport + Send>, Box::new(infer) as Box<dyn Transport>))
        } else {
            tcp_pair(addr)
        }
    });

    let dims = test_manifest().model;
    let mut cfg = DeploymentConfig::with_threshold(1.0);
    cfg.device_id = 0;
    cfg.max_new_tokens = 12;
    cfg.reconnect = policy;
    let link = CloudLink::connect_via(0, vec![addr_a, addr_b], policy, dial).unwrap();
    let mut client = EdgeClient::with_cloud(MockEdge::new(MockOracle::new(seed), dims), cfg, link);

    let out = client.generate("a failover prompt").unwrap();
    assert_eq!(
        out.tokens,
        local_trace(seed, 1.0, "a failover prompt", 12),
        "failover must not change served bytes"
    );
    assert!(out.counters.failovers >= 1, "rotation to B must be counted: {:?}", out.counters);
    assert!(out.counters.reconnects >= 1, "a failover is a reconnect: {:?}", out.counters);

    let stats_b = server_b.shutdown();
    assert!(stats_b.stale_resumes >= 1, "B never saw the session; resume must be stale: {stats_b:?}");
    assert!(stats_b.requests_served > 0, "B must serve the remainder of the run: {stats_b:?}");
    server_a.shutdown();
}

/// 32 edges lose their first infer connection simultaneously and
/// re-dial under jittered backoff — the reconnect-storm shape.  Every
/// device must resume its own session and finish bit-identical to its
/// own local reference.
#[test]
fn reconnect_storm_every_edge_resumes() {
    let devices = 32u64;
    let server = spawn_server(300, fault_cloud_config(4));
    let addr = server.addr.to_string();
    let gate = Arc::new(Barrier::new(devices as usize));
    let mut handles = Vec::new();
    for device in 0..devices {
        let addr = addr.clone();
        let gate = Arc::clone(&gate);
        handles.push(std::thread::spawn(move || {
            let dial = faulty_first_dial(FaultPlan::new().sever_recv_at(1));
            let mut client = client_via(
                &addr,
                device,
                300 + device,
                1.0,
                8,
                ReconnectPolicy::default(),
                dial,
            );
            gate.wait();
            (device, client.generate("a reconnect storm prompt").unwrap())
        }));
    }
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    for (device, out) in &results {
        assert_eq!(
            out.tokens,
            local_trace(300 + device, 1.0, "a reconnect storm prompt", 8),
            "device {device}: storm recovery must be bit-identical"
        );
        assert!(out.counters.reconnects >= 1, "device {device} never reconnected");
    }
    let stats = server.shutdown();
    assert!(
        stats.sessions_resumed >= devices,
        "all {devices} edges must resume their sessions: {stats:?}"
    );
}

/// Server-side schedule: every reactor connection is severed after its
/// 7th inbound frame (an explicit [`ReactorFault`], which wins over the
/// `CE_FAULT` env).  The edge sees repeated mid-run disconnects on both
/// channels and must reconnect through each one; n = 7 leaves room for
/// the resume replay plus several requests per round, so every round
/// makes forward progress.
fn reactor_sever_schedule_recovers(backend: ReactorBackend) {
    let seed = 83;
    let mut cfg = fault_cloud_config(1);
    cfg.reactor.backend = backend;
    cfg.reactor.fault = Some(ReactorFault { sever_in_at: Some(7), ..Default::default() });
    let server = spawn_server(seed, cfg);

    let link =
        CloudLink::connect(0, &[server.addr.to_string()], ReconnectPolicy::default()).unwrap();
    let dims = test_manifest().model;
    let mut dcfg = DeploymentConfig::with_threshold(1.0);
    dcfg.device_id = 0;
    dcfg.max_new_tokens = 16;
    let mut client = EdgeClient::with_cloud(MockEdge::new(MockOracle::new(seed), dims), dcfg, link);

    let out = client.generate("a server fault prompt").unwrap();
    assert_eq!(
        out.tokens,
        local_trace(seed, 1.0, "a server fault prompt", 16),
        "reactor severs must be invisible in the stream ({backend:?})"
    );
    assert!(out.counters.reconnects >= 1, "severs must force reconnects: {:?}", out.counters);

    let stats = server.shutdown();
    assert!(stats.reactor.faults_injected >= 1, "the schedule must have fired: {stats:?}");
    assert!(stats.sessions_resumed >= 1, "reconnects must resume, not reset: {stats:?}");
}

#[test]
fn reactor_sever_schedule_recovers_bit_identically() {
    reactor_sever_schedule_recovers(ReactorBackend::Auto);
}

#[test]
fn reactor_sever_schedule_recovers_bit_identically_other_backend() {
    reactor_sever_schedule_recovers(OTHER_BACKEND);
}

/// Order-of-operations for the `reorder_in:<n>:<k>` hold-and-release
/// queue, observed through in-reactor pings (pongs are answered in
/// routing order, so the pong sequence IS the routing order).  Frame
/// ordinals are 0-based and count the `Hello`: with `reorder_in:3:2`
/// the ping carrying nonce 3 (ordinal 3) is held in the conn's
/// one-slot queue, nonces 4 and 5 overtake it, and the held frame
/// routes right after ordinal 5 — the client must observe pongs
/// 1, 2, 4, 5, 3.  An explicit [`ReactorFault`] wins over the
/// `CE_FAULT` env, so the schedule is stable under every CI leg.
fn reorder_schedule_releases_after_gap(backend: ReactorBackend) {
    let mut cfg = fault_cloud_config(1);
    cfg.reactor.backend = backend;
    cfg.reactor.fault =
        Some(ReactorFault { reorder_in_at: Some(3), reorder_gap: 2, ..Default::default() });
    let server = spawn_server(9, cfg);

    let mut conn = TcpTransport::connect(&server.addr.to_string()).unwrap();
    conn.send(
        &Message::Hello {
            device_id: 21,
            session: 4,
            channel: Channel::Infer,
            resume: false,
            mirror: false,
        }
        .encode(),
    )
    .unwrap();
    assert_eq!(conn.recv().unwrap(), Message::Ack.encode(), "handshake completes");

    for nonce in 1..=5u64 {
        conn.send(&Message::Ping { nonce }.encode()).unwrap();
    }
    let mut order = Vec::new();
    for _ in 0..5 {
        match Message::decode(&conn.recv().unwrap()).unwrap() {
            Message::Pong { nonce } => order.push(nonce),
            other => panic!("expected a pong, got {other:?}"),
        }
    }
    assert_eq!(
        order,
        vec![1, 2, 4, 5, 3],
        "hold at ordinal 3 must release right after ordinal 5 ({backend:?})"
    );

    let stats = server.shutdown();
    assert!(stats.reactor.faults_injected >= 1, "the hold must be counted: {stats:?}");
}

#[test]
fn reorder_schedule_releases_held_frame_after_gap() {
    reorder_schedule_releases_after_gap(ReactorBackend::Auto);
}

#[test]
fn reorder_schedule_releases_held_frame_after_gap_other_backend() {
    reorder_schedule_releases_after_gap(OTHER_BACKEND);
}

/// Raw keepalive round trip: a `Ping` on an established infer channel
/// is answered in-reactor with a `Pong` carrying the same nonce (no
/// scheduler involvement, so it works even while workers are busy).
#[test]
fn ping_is_answered_with_matching_pong() {
    let server = spawn_server(5, fault_cloud_config(1));
    let mut conn = TcpTransport::connect(&server.addr.to_string()).unwrap();
    conn.send(
        &Message::Hello {
            device_id: 12,
            session: 3,
            channel: Channel::Infer,
            resume: false,
            mirror: false,
        }
        .encode(),
    )
    .unwrap();
    assert_eq!(conn.recv().unwrap(), Message::Ack.encode(), "handshake completes");

    conn.send(&Message::Ping { nonce: 42 }.encode()).unwrap();
    assert_eq!(
        Message::decode(&conn.recv().unwrap()).unwrap(),
        Message::Pong { nonce: 42 },
        "pong must echo the ping nonce"
    );
    server.shutdown();
}
