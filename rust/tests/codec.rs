//! Property tests for the sans-I/O frame codec: however a byte stream is
//! chopped up, the codec must deliver exactly the frames a one-shot
//! parser sees, and every frame must decode to the identical protocol
//! message.  Uses the in-tree deterministic PRNG (no proptest crate in
//! the offline environment); failures print the seed.

use ce_collm::coordinator::protocol::{Channel, Message};
use ce_collm::net::codec::{
    encode_frame, frame_wire_len, FrameCodec, DIRECT_READ_MIN, FRAME_HEADER, MAX_FRAME,
};
use ce_collm::quant::{self, Precision};
use ce_collm::util::rng::Rng;

const CASES: usize = 64;

/// Random protocol message (mirrors the generator in `proptests.rs`).
fn arb_message(rng: &mut Rng) -> Message {
    match rng.gen_range(7) {
        0 => Message::Hello {
            device_id: rng.next_u64(),
            session: rng.next_u64(),
            channel: if rng.gen_bool(0.5) { Channel::Upload } else { Channel::Infer },
            resume: rng.gen_bool(0.5),
            mirror: rng.gen_bool(0.5),
        },
        1 => {
            let precision = if rng.gen_bool(0.5) { Precision::F16 } else { Precision::F32 };
            let count = rng.gen_range(4) as u32 + 1;
            let n = count as usize * 8;
            let values: Vec<f32> = (0..n).map(|_| (rng.gen_f32() - 0.5) * 2000.0).collect();
            Message::UploadHidden {
                device_id: rng.next_u64(),
                req_id: rng.next_u64() as u32,
                start_pos: rng.gen_range(1000) as u32,
                count,
                prompt_len: rng.gen_range(256) as u32,
                precision,
                payload: quant::pack(&values, precision),
            }
        }
        2 => Message::InferRequest {
            device_id: rng.next_u64(),
            req_id: rng.next_u64() as u32,
            pos: rng.gen_range(4096) as u32,
            prompt_len: rng.gen_range(256) as u32,
            deadline_ms: rng.gen_range(5000) as u32,
        },
        3 => Message::TokenResponse {
            req_id: rng.next_u64() as u32,
            pos: rng.gen_range(4096) as u32,
            token: rng.gen_range(384) as i32,
            conf: rng.gen_f32(),
            compute_s: rng.gen_f32() * 0.1,
        },
        4 => Message::EndSession { device_id: rng.next_u64(), req_id: rng.next_u64() as u32 },
        5 => Message::Ack,
        _ => Message::Error {
            req_id: rng.next_u64() as u32,
            pos: rng.gen_range(4096) as u32,
            msg: (0..rng.gen_range(64)).map(|_| (rng.gen_range(94) as u8 + 32) as char).collect(),
        },
    }
}

/// One-shot reference parse of a whole wire stream (the "blocking
/// transport" view the incremental codec must agree with).
fn one_shot_frames(wire: &[u8]) -> Vec<Vec<u8>> {
    let mut frames = Vec::new();
    let mut i = 0;
    while i < wire.len() {
        let n = u32::from_le_bytes(wire[i..i + FRAME_HEADER].try_into().unwrap()) as usize;
        frames.push(wire[i + FRAME_HEADER..i + FRAME_HEADER + n].to_vec());
        i += FRAME_HEADER + n;
    }
    frames
}

#[test]
fn prop_byte_dribble_identical_to_one_shot() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::seed_from_u64(seed ^ 0xC0DE);
        let msgs: Vec<Message> = (0..1 + rng.gen_range(8)).map(|_| arb_message(&mut rng)).collect();
        let mut wire = Vec::new();
        for m in &msgs {
            wire.extend_from_slice(&encode_frame(&m.encode()));
        }
        let reference = one_shot_frames(&wire);
        assert_eq!(reference.len(), msgs.len(), "seed {seed}");

        // feed the stream 1..k bytes at a time (k varies per chunk)
        let mut codec = FrameCodec::new();
        let mut got: Vec<Vec<u8>> = Vec::new();
        let mut i = 0;
        while i < wire.len() {
            let k = (1 + rng.gen_range(17)).min(wire.len() - i);
            let mut next = codec
                .feed(&wire[i..i + k])
                .unwrap_or_else(|e| panic!("seed {seed}: feed failed: {e:#}"));
            while let Some(f) = next {
                got.push(f);
                next = codec.next_frame().unwrap();
            }
            i += k;
        }

        // frame-for-frame identity with the one-shot parse...
        assert_eq!(got, reference, "seed {seed}: dribbled frames diverge");
        assert_eq!(codec.buffered_in(), 0, "seed {seed}: residue after a whole stream");
        // ...and message-for-message identity with the originals
        for (frame, msg) in got.iter().zip(&msgs) {
            assert_eq!(&Message::decode(frame).unwrap(), msg, "seed {seed}");
        }
    }
}

#[test]
fn prop_feed_all_identical_to_incremental() {
    // the reactor's bulk-ingest entry point must agree with the
    // byte-dribble path and the one-shot parse for any chunking
    for seed in 0..CASES as u64 {
        let mut rng = Rng::seed_from_u64(seed ^ 0xFA11);
        let msgs: Vec<Message> = (0..1 + rng.gen_range(8)).map(|_| arb_message(&mut rng)).collect();
        let mut wire = Vec::new();
        for m in &msgs {
            wire.extend_from_slice(&encode_frame(&m.encode()));
        }
        let reference = one_shot_frames(&wire);
        let mut codec = FrameCodec::new();
        let mut got = Vec::new();
        let mut i = 0;
        while i < wire.len() {
            let k = (1 + rng.gen_range(33)).min(wire.len() - i);
            codec
                .feed_all(&wire[i..i + k], &mut got)
                .unwrap_or_else(|e| panic!("seed {seed}: feed_all failed: {e:#}"));
            i += k;
        }
        assert_eq!(got, reference, "seed {seed}: feed_all frames diverge");
        assert_eq!(codec.buffered_in(), 0, "seed {seed}");
    }
}

#[test]
fn prop_write_half_roundtrips_under_random_flush_sizes() {
    // enqueue random messages, drain writable_bytes in random-sized
    // slices into a reader codec: bytes_sent accounting and frames must
    // both survive any flush pattern
    for seed in 0..CASES as u64 {
        let mut rng = Rng::seed_from_u64(seed ^ 0xF1A5);
        let msgs: Vec<Message> = (0..1 + rng.gen_range(6)).map(|_| arb_message(&mut rng)).collect();
        let mut w = FrameCodec::new();
        let mut payload_bytes = 0u64;
        for m in &msgs {
            let enc = m.encode();
            payload_bytes += enc.len() as u64;
            w.enqueue_frame(&enc).unwrap();
        }
        assert_eq!(w.payload_bytes_enqueued(), payload_bytes, "seed {seed}");
        assert_eq!(
            w.pending_out() as u64,
            payload_bytes + (msgs.len() * FRAME_HEADER) as u64,
            "seed {seed}: framing overhead must be exactly {FRAME_HEADER}/frame"
        );

        let mut r = FrameCodec::new();
        let mut got = Vec::new();
        while w.pending_out() > 0 {
            let k = (1 + rng.gen_range(9)).min(w.pending_out());
            let chunk = w.writable_bytes()[..k].to_vec();
            w.consume_written(k);
            let mut next = r.feed(&chunk).unwrap();
            while let Some(f) = next {
                got.push(Message::decode(&f).unwrap());
                next = r.next_frame().unwrap();
            }
        }
        assert_eq!(got, msgs, "seed {seed}");
    }
}

#[test]
fn prop_read_into_identical_to_byte_dribbled_feed() {
    // the reserve-then-fill single-copy path (read_slot/commit) must
    // deliver exactly the frames — and the same frames_decoded count —
    // as the byte-dribbled feed path, for any mix of small and
    // threshold-clearing frame sizes and any read chunking
    for seed in 0..CASES as u64 {
        let mut rng = Rng::seed_from_u64(seed ^ 0x51D7);
        let mut wire = Vec::new();
        let mut want: Vec<Vec<u8>> = Vec::new();
        for _ in 0..1 + rng.gen_range(6) {
            // bias payload sizes toward the direct threshold's edges
            let n = match rng.gen_range(4) {
                0 => rng.gen_range(64),
                1 => DIRECT_READ_MIN - 1 - rng.gen_range(16),
                2 => DIRECT_READ_MIN + rng.gen_range(16),
                _ => DIRECT_READ_MIN * (2 + rng.gen_range(3)),
            };
            let payload: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
            wire.extend_from_slice(&encode_frame(&payload));
            want.push(payload);
        }

        // reference: the byte-dribbled feed path
        let mut rc = FrameCodec::new();
        let mut reference: Vec<Vec<u8>> = Vec::new();
        let mut i = 0;
        while i < wire.len() {
            let k = (1 + rng.gen_range(2048)).min(wire.len() - i);
            let mut next = rc.feed(&wire[i..i + k]).unwrap();
            while let Some(f) = next {
                reference.push(f);
                next = rc.next_frame().unwrap();
            }
            i += k;
        }
        assert_eq!(reference, want, "seed {seed}: feed reference diverges from encode");

        // read_into: take the codec's slot whenever it offers one
        // (direct single-copy fill), fall back to feed otherwise —
        // exactly the shape of the reactor's and transport's read loops
        let mut c = FrameCodec::new();
        let mut got: Vec<Vec<u8>> = Vec::new();
        let mut i = 0;
        while i < wire.len() {
            let k = (1 + rng.gen_range(2048)).min(wire.len() - i);
            if let Some(slot) = c.read_slot() {
                let take = slot.len().min(k);
                slot[..take].copy_from_slice(&wire[i..i + take]);
                c.commit(take);
                i += take;
            } else {
                let mut next = c.feed(&wire[i..i + k]).unwrap();
                while let Some(f) = next {
                    got.push(f);
                    next = c.next_frame().unwrap();
                }
                i += k;
            }
            while let Some(f) = c.next_frame().unwrap() {
                got.push(f);
            }
        }
        assert_eq!(got, reference, "seed {seed}: read_into frames diverge");
        assert_eq!(c.buffered_in(), 0, "seed {seed}: residue after a whole stream");
        assert_eq!(
            c.frames_decoded(),
            rc.frames_decoded(),
            "seed {seed}: frame accounting diverges across ingest styles"
        );
    }
}

#[test]
fn mid_stream_oversize_fails_before_the_body() {
    // a good frame, then a poisoned length prefix: the good frame is
    // delivered, the poison is rejected as soon as its 4 length bytes
    // are visible — no body needed, nothing allocated for it
    let mut wire = encode_frame(&Message::Ack.encode());
    wire.extend_from_slice(&((MAX_FRAME + 1) as u32).to_le_bytes());
    let mut codec = FrameCodec::new();
    let first = codec.feed(&wire[..wire.len() - 1]).unwrap();
    assert_eq!(first.unwrap(), Message::Ack.encode());
    assert!(codec.feed(&wire[wire.len() - 1..]).is_err());
}

#[test]
fn wire_len_helper_is_exact() {
    for n in [0usize, 1, 30, 286] {
        assert_eq!(frame_wire_len(n), encode_frame(&vec![0u8; n]).len());
    }
}
