//! Replicated-cloud integration tests: warm-standby sessions against
//! the real TCP serving path.  Every client here holds a primary
//! session plus [`ReplicaSet`] standbys opened with the Hello mirror
//! bit against *other* servers, and every fault is deterministic — a
//! scripted [`FaultTransport`] schedule on the primary dialer, or an
//! explicit server-side [`ReactorFault`] — so warm promotion, hedge
//! fencing, and the full degradation ladder are exercised at exact
//! protocol steps and compared bit-for-bit against the local
//! (never-severed) reference.
//!
//! The whole file also runs under the CI `CE_FAULT` legs (`sever_in`,
//! `drop_in`, `reorder_in`), where every server connection additionally
//! runs the env schedule.  Assertions are therefore lower bounds (`>=`)
//! on fault/recovery counters wherever the env schedule can add rounds,
//! and exact server-side tallies are gated on `CE_FAULT` being unset.

use std::sync::{Arc, Barrier};

use ce_collm::config::{
    CloudConfig, DeploymentConfig, ExitPolicy, ReactorBackend, ReconnectPolicy,
};
use ce_collm::coordinator::cloud::{CloudServer, SessionFactory};
use ce_collm::coordinator::edge::{CloudLink, DialFn, EdgeClient, ReplicaSet};
use ce_collm::model::manifest::test_manifest;
use ce_collm::net::fault::{FaultPlan, FaultTransport, ReactorFault};
use ce_collm::net::transport::{TcpTransport, Transport};
use ce_collm::runtime::mock::{MockCloud, MockEdge, MockOracle};

/// See `serve_tcp.rs`: the non-default readiness backend, so warm
/// promotions are exercised under both event loops.
const OTHER_BACKEND: ReactorBackend = ReactorBackend::Poll;

/// Server config for fault runs — see `fault.rs`: parks must expire
/// fast so a request waiting on state that will never arrive hands
/// control back to the client's failover ladder, and the idle reap is
/// tightened so an env-scheduled `drop_in`/`reorder_in` that swallows
/// an infer request un-blocks the deadline-less client via the reaped
/// connection's close instead of a 120 s default reap.
fn fault_cloud_config(workers: usize) -> CloudConfig {
    let mut cfg = CloudConfig::with_workers(workers);
    cfg.max_park_s = 0.2;
    cfg.reactor.idle_timeout_s = 2.0;
    cfg
}

/// One mock engine per device, all seeded `seed_base + device`.
/// Replica servers for the same fleet share `seed_base`, so a standby
/// derives the same token stream the primary would have — the property
/// warm promotion relies on.
fn spawn_server(seed_base: u64, cfg: CloudConfig) -> CloudServer {
    let dims = test_manifest().model;
    let sdims = dims.clone();
    CloudServer::bind("127.0.0.1:0", dims, cfg, move || {
        let sdims = sdims.clone();
        let f: SessionFactory = Box::new(move |device| {
            Ok(Box::new(MockCloud::new(MockOracle::new(seed_base + device), sdims.clone())) as _)
        });
        Ok(f)
    })
    .unwrap()
}

/// The local (in-process, never-severed) reference stream every
/// recovered wire run must match bit-for-bit.
fn local_trace(seed: u64, threshold: f32, prompt: &str, max_new: usize) -> Vec<i32> {
    let dims = test_manifest().model;
    let o = MockOracle::new(seed);
    let mut edge = MockEdge::new(o, dims.clone());
    let mut cloud = MockCloud::new(o, dims);
    let mut timings = ce_collm::harness::trace::CallTimings::default();
    ce_collm::harness::trace::record(
        &mut edge,
        &mut cloud,
        ExitPolicy::Threshold(threshold),
        ce_collm::quant::Precision::F16,
        prompt,
        max_new,
        &mut timings,
    )
    .unwrap()
    .tokens
}

/// Clean TCP `(upload, infer)` pair — the test twin of the default
/// dialer inside [`CloudLink::connect`].
fn tcp_pair(addr: &str) -> anyhow::Result<(Box<dyn Transport + Send>, Box<dyn Transport>)> {
    let upload = Box::new(TcpTransport::connect(addr)?);
    let infer = Box::new(TcpTransport::connect(addr)?);
    Ok((upload as Box<dyn Transport + Send>, infer as Box<dyn Transport>))
}

/// A dialer whose FIRST dial wraps the infer channel in `plan`; every
/// redial is clean TCP.  The scripted sever fires exactly once per run.
fn faulty_first_dial(plan: FaultPlan) -> DialFn {
    let mut first = Some(plan);
    Box::new(move |addr: &str| match first.take() {
        Some(plan) => {
            let upload = Box::new(TcpTransport::connect(addr)?);
            let infer = FaultTransport::new(TcpTransport::connect(addr)?, plan);
            Ok((upload as Box<dyn Transport + Send>, Box::new(infer) as Box<dyn Transport>))
        }
        None => tcp_pair(addr),
    })
}

/// A dialer for an endpoint that severs once and then stays down: the
/// first dial wraps the infer channel in `plan`, every redial is
/// refused outright.  Defeats both the backoff redial and the failover
/// rotation — the edge sees a cloud that died and never came back.
fn down_endpoint_dial(plan: FaultPlan) -> DialFn {
    let mut first = Some(plan);
    Box::new(move |addr: &str| {
        let Some(plan) = first.take() else {
            anyhow::bail!("scripted dead endpoint: redial refused");
        };
        let upload = Box::new(TcpTransport::connect(addr)?);
        let infer = FaultTransport::new(TcpTransport::connect(addr)?, plan);
        Ok((upload as Box<dyn Transport + Send>, Box::new(infer) as Box<dyn Transport>))
    })
}

/// A standby whose endpoint is doomed on every channel: the mirror
/// (upload) channel severs mid-fan-out, the infer channel severs on its
/// first post-promotion response, and redials are refused.  Whether the
/// run dies before or after this standby's promotion, it ends with no
/// cloud left — the ladder's last rung.
fn doomed_standby_dial() -> DialFn {
    let mut first = true;
    Box::new(move |addr: &str| {
        anyhow::ensure!(std::mem::take(&mut first), "scripted dead standby: redial refused");
        let upload =
            FaultTransport::new(TcpTransport::connect(addr)?, FaultPlan::new().sever_send_at(4));
        let infer =
            FaultTransport::new(TcpTransport::connect(addr)?, FaultPlan::new().sever_recv_at(1));
        Ok((Box::new(upload) as Box<dyn Transport + Send>, Box::new(infer) as Box<dyn Transport>))
    })
}

/// Edge client with a primary link plus warm standbys — the wire twin
/// of `DeploymentConfig::replication`.
#[allow(clippy::too_many_arguments)]
fn replica_client(
    primary: CloudLink,
    standbys: Vec<CloudLink>,
    hedge: bool,
    device: u64,
    seed: u64,
    threshold: f32,
    max_new: usize,
    budget_s: Option<f64>,
) -> EdgeClient<MockEdge> {
    let dims = test_manifest().model;
    let mut cfg = DeploymentConfig::with_threshold(threshold);
    cfg.device_id = device;
    cfg.max_new_tokens = max_new;
    cfg.cloud_token_budget_s = budget_s;
    let mut set = ReplicaSet::new(hedge);
    for sb in standbys {
        set.add_standby(sb);
    }
    EdgeClient::with_cloud_replicas(MockEdge::new(MockOracle::new(seed), dims), cfg, primary, set)
}

/// Kill the primary mid-generation (infer recv ordinal 1 — the first
/// deferred token's response is on the wire when the channel dies) and
/// require a warm promotion: the standby's mirrored coverage already
/// spans the watermark, so recovery must spend **zero** context replays
/// and the promoted stream must stay bit-identical to the local
/// reference.
fn warm_promotion_mid_stream_is_zero_replay(backend: ReactorBackend) {
    let seed = 41;
    let mut cfg_a = fault_cloud_config(1);
    cfg_a.reactor.backend = backend;
    let srv_a = spawn_server(seed, cfg_a);
    let mut cfg_b = fault_cloud_config(1);
    cfg_b.reactor.backend = backend;
    let srv_b = spawn_server(seed, cfg_b);

    let policy = ReconnectPolicy::default();
    let primary = CloudLink::connect_via(
        0,
        vec![srv_a.addr.to_string()],
        policy,
        faulty_first_dial(FaultPlan::new().sever_recv_at(1)),
    )
    .unwrap();
    let standby = CloudLink::connect_mirror(0, &[srv_b.addr.to_string()], policy).unwrap();
    let mut client = replica_client(primary, vec![standby], false, 0, seed, 0.8, 20, None);

    let out = client.generate("a warm failover prompt").unwrap();
    assert_eq!(
        out.tokens,
        local_trace(seed, 0.8, "a warm failover prompt", 20),
        "promoted stream diverges from the unsevered reference ({backend:?})"
    );
    assert_eq!(
        out.counters.context_replays, 0,
        "warm promotion must not replay history: {:?}",
        out.counters
    );
    assert!(out.counters.bytes_mirrored > 0, "mirrored fan-out must be priced apart");

    srv_a.shutdown();
    let stats_b = srv_b.shutdown();
    assert!(stats_b.uploads_mirrored >= 1, "the standby never saw a mirrored upload: {stats_b:?}");
    // an ambient env schedule can kill the standby before the scripted
    // sever fires, legitimately degrading this run to a cold resume —
    // the promotion story itself is only pinned on the clean legs
    if std::env::var("CE_FAULT").is_err() {
        assert!(
            out.counters.failovers_warm >= 1,
            "the dead primary must warm-promote: {:?}",
            out.counters
        );
        assert_eq!(out.counters.failovers_cold, 0, "nothing may go cold: {:?}", out.counters);
        assert!(stats_b.mirror_promotions >= 1, "the standby never went live: {stats_b:?}");
        assert!(stats_b.requests_served >= 1, "the standby must serve tokens: {stats_b:?}");
    }
}

#[test]
fn warm_promotion_mid_stream_spends_zero_replays() {
    warm_promotion_mid_stream_is_zero_replay(ReactorBackend::Auto);
}

#[test]
fn warm_promotion_mid_stream_spends_zero_replays_other_backend() {
    warm_promotion_mid_stream_is_zero_replay(OTHER_BACKEND);
}

/// Hedged infer under an explicit server-side reorder schedule: the
/// primary holds inbound frame ordinal 4 until ordinal 6 routes, so for
/// at least one token the standby's duplicate answer wins the race and
/// the primary's late echo arrives *after* the client has moved on.
/// The stale-response fence must skip it: the client bills each
/// deferral exactly once and the stream stays bit-identical.
fn hedge_race_under_reorder_is_fenced(backend: ReactorBackend) {
    let seed = 53;
    let mut cfg_a = fault_cloud_config(1);
    cfg_a.reactor.backend = backend;
    // explicit schedule (wins over the CE_FAULT env), so the race is
    // scripted even on the CI legs that set their own fault
    cfg_a.reactor.fault = Some(ReactorFault {
        reorder_in_at: Some(4),
        reorder_gap: 2,
        ..ReactorFault::default()
    });
    let srv_a = spawn_server(seed, cfg_a);
    let mut cfg_b = fault_cloud_config(1);
    cfg_b.reactor.backend = backend;
    let srv_b = spawn_server(seed, cfg_b);

    let policy = ReconnectPolicy::default();
    let primary = CloudLink::connect(0, &[srv_a.addr.to_string()], policy).unwrap();
    let standby = CloudLink::connect_mirror(0, &[srv_b.addr.to_string()], policy).unwrap();
    // θ = 1.0: every token defers; the generous budget arms hedging
    // without the deadline ever firing
    let mut client = replica_client(primary, vec![standby], true, 0, seed, 1.0, 16, Some(60.0));

    let out = client.generate("a hedged reorder prompt").unwrap();
    assert_eq!(
        out.tokens,
        local_trace(seed, 1.0, "a hedged reorder prompt", 16),
        "hedged stream diverges from the reference ({backend:?})"
    );
    assert!(out.counters.hedged_requests >= 1, "hedging never armed: {:?}", out.counters);
    assert_eq!(out.counters.cloud_fallbacks, 0, "no rung below hedging may engage");
    assert!(
        out.counters.cloud_requests >= 16,
        "every deferral reaches the cloud: {:?}",
        out.counters
    );
    assert!(
        out.counters.bytes_mirrored >= out.counters.hedged_requests as u64,
        "hedged duplicates must be priced on the mirror channel"
    );

    let stats_a = srv_a.shutdown();
    let stats_b = srv_b.shutdown();
    assert!(stats_b.uploads_mirrored >= 1, "the standby never saw a mirrored upload: {stats_b:?}");
    if std::env::var("CE_FAULT").is_err() {
        // 16 deferrals; the client accepted exactly one answer per
        // (req_id, pos).  A primary that re-served a hedged token the
        // standby already won would push its tally past the deferral
        // count — the double-billing the fence exists to prevent.
        assert_eq!(out.counters.cloud_requests, 16, "one billing per deferral");
        assert_eq!(out.counters.tokens_cloud, 16, "θ = 1.0: every token is a cloud token");
        assert!(
            stats_a.requests_served <= 16,
            "the primary must never serve a (req_id, pos) twice: {stats_a:?}"
        );
        assert!(
            stats_a.reactor.faults_injected >= 1,
            "the reorder schedule never fired: {stats_a:?}"
        );
    }
}

#[test]
fn hedge_race_under_reorder_is_fenced_once() {
    hedge_race_under_reorder_is_fenced(ReactorBackend::Auto);
}

#[test]
fn hedge_race_under_reorder_is_fenced_once_other_backend() {
    hedge_race_under_reorder_is_fenced(OTHER_BACKEND);
}

/// The ladder's last rung: the primary dies and stays down, the lone
/// standby is doomed on every channel, and no endpoint accepts a
/// redial.  In latency-aware mode the run must step down — warm
/// promotion, cold reconnect, then the §4.4 local fallback — and still
/// finish the generation on edge-only exits instead of erroring out.
#[test]
fn all_replicas_down_degrades_to_local_fallback() {
    let seed = 67;
    let srv_a = spawn_server(seed, fault_cloud_config(1));
    let srv_b = spawn_server(seed, fault_cloud_config(1));

    let policy = ReconnectPolicy::default();
    let primary = CloudLink::connect_via(
        0,
        vec![srv_a.addr.to_string()],
        policy,
        down_endpoint_dial(FaultPlan::new().sever_recv_at(1)),
    )
    .unwrap();
    let standby = CloudLink::connect_mirror_via(
        0,
        vec![srv_b.addr.to_string()],
        policy,
        doomed_standby_dial(),
    )
    .unwrap();
    let mut client = replica_client(primary, vec![standby], false, 0, seed, 0.8, 20, Some(30.0));

    let out = client.generate("a doomed fleet prompt").unwrap();
    assert!(
        out.counters.cloud_fallbacks >= 1,
        "losing every replica must fall back to local exits: {:?}",
        out.counters
    );
    assert!(!out.tokens.is_empty(), "the run must still finish on local exits");
    assert_eq!(out.counters.tokens_generated, out.tokens.len(), "{:?}", out.counters);
    assert!(
        out.counters.tokens_cloud < out.counters.tokens_generated,
        "after the fallback the cloud serves nothing: {:?}",
        out.counters
    );

    srv_a.shutdown();
    srv_b.shutdown();
}

/// Reconnect storm, replicated: six devices against a three-server
/// fleet, every primary severed on its first deferred response at the
/// same barrier-released instant.  Every device must warm-promote to a
/// standby (same `seed_base`, so same oracle) and finish bit-identical
/// with zero replays — the concurrent version of the promotion test.
fn replicated_reconnect_storm(backend: ReactorBackend) {
    const DEVICES: u64 = 6;
    let seed_base = 300;
    let mk = || {
        let mut cfg = fault_cloud_config(2);
        cfg.reactor.backend = backend;
        spawn_server(seed_base, cfg)
    };
    let (srv_a, srv_b, srv_c) = (mk(), mk(), mk());
    let (addr_a, addr_b, addr_c) =
        (srv_a.addr.to_string(), srv_b.addr.to_string(), srv_c.addr.to_string());

    let gate = Arc::new(Barrier::new(DEVICES as usize));
    let mut handles = Vec::new();
    for device in 0..DEVICES {
        let (addr_a, addr_b, addr_c) = (addr_a.clone(), addr_b.clone(), addr_c.clone());
        let gate = Arc::clone(&gate);
        handles.push(std::thread::spawn(move || {
            let policy = ReconnectPolicy::default();
            let primary = CloudLink::connect_via(
                device,
                vec![addr_a],
                policy,
                faulty_first_dial(FaultPlan::new().sever_recv_at(1)),
            )
            .unwrap();
            let sb_b = CloudLink::connect_mirror(device, &[addr_b], policy).unwrap();
            let sb_c = CloudLink::connect_mirror(device, &[addr_c], policy).unwrap();
            // θ = 1.0: every token defers, so every device trips the
            // scripted sever and the promotions overlap
            let mut client = replica_client(
                primary,
                vec![sb_b, sb_c],
                false,
                device,
                seed_base + device,
                1.0,
                8,
                None,
            );
            gate.wait();
            (device, client.generate("a replicated storm prompt").unwrap())
        }));
    }
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    for (device, out) in &results {
        assert_eq!(
            out.tokens,
            local_trace(seed_base + device, 1.0, "a replicated storm prompt", 8),
            "device {device}: promoted stream must be bit-identical ({backend:?})"
        );
        assert!(
            out.counters.failovers_warm >= 1,
            "device {device} never warm-promoted: {:?}",
            out.counters
        );
        assert_eq!(
            out.counters.context_replays, 0,
            "device {device}: promotion must not replay: {:?}",
            out.counters
        );
    }

    srv_a.shutdown();
    let stats_b = srv_b.shutdown();
    let stats_c = srv_c.shutdown();
    assert!(
        stats_b.mirror_promotions + stats_c.mirror_promotions >= DEVICES,
        "every device promotes one standby: {stats_b:?} / {stats_c:?}"
    );
    assert!(
        stats_b.requests_served + stats_c.requests_served >= 1,
        "the standby fleet must serve the post-promotion tokens"
    );
}

#[test]
fn replicated_storm_promotes_every_device() {
    replicated_reconnect_storm(ReactorBackend::Auto);
}

#[test]
fn replicated_storm_promotes_every_device_other_backend() {
    replicated_reconnect_storm(OTHER_BACKEND);
}
