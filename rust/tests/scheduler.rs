//! Integration tests for the event-driven serving core: the scheduler's
//! ordering contract (infer-before-upload parks and wakes — no polling,
//! no retries), multi-worker concurrency, deadline expiry, and the edge's
//! latency-aware local fallback against a stalled cloud.
//!
//! Everything runs on in-proc channels/transports with mock engines and
//! zero test-side waiting: each assertion blocks on a reply that the
//! system under test must produce.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use ce_collm::config::{CloudConfig, DeploymentConfig};
use ce_collm::coordinator::policy::ExitPoint;
use ce_collm::coordinator::scheduler::{
    InferOutcome, Reply, Router, SchedMsg, Scheduler, SessionFactory, TokenOut, UploadPayload,
};
use ce_collm::coordinator::edge::{CloudLink, EdgeClient};
use ce_collm::model::manifest::test_manifest;
use ce_collm::net::transport::{in_proc_pair, Transport};
use ce_collm::runtime::mock::{MockCloud, MockEdge, MockOracle};

const D: usize = 128; // test manifest d_model

/// Unwrap a reply into its served token (panics on an eviction notice —
/// these tests never configure a memory budget).
fn token(out: anyhow::Result<InferOutcome>) -> anyhow::Result<TokenOut> {
    out.map(|o| match o {
        InferOutcome::Token(t) => t,
        InferOutcome::Evicted => panic!("unexpected eviction notice"),
    })
}

fn mock_scheduler(seed: u64, workers: usize) -> Scheduler {
    let dims = test_manifest().model;
    let sdims = dims.clone();
    Scheduler::spawn(
        dims,
        CloudConfig::with_workers(workers),
        Arc::new(move || {
            let sdims = sdims.clone();
            let f: SessionFactory = Box::new(move |_device| {
                Ok(Box::new(MockCloud::new(MockOracle::new(seed), sdims.clone())) as _)
            });
            Ok(f)
        }),
    )
    .unwrap()
}

fn infer(
    router: &Router,
    device: u64,
    req_id: u32,
    pos: u32,
    prompt_len: u32,
    deadline: Option<Instant>,
) -> mpsc::Receiver<anyhow::Result<InferOutcome>> {
    let (tx, rx) = mpsc::channel();
    router
        .send(
            device,
            SchedMsg::Infer {
                device,
                session: 0,
                req_id,
                pos,
                prompt_len,
                deadline,
                reply: Reply::channel(tx),
            },
        )
        .unwrap();
    rx
}

fn upload(router: &Router, device: u64, req_id: u32, start_pos: u32, count: usize, plen: u32) {
    router
        .send(
            device,
            SchedMsg::Upload {
                device,
                session: 0,
                req_id,
                start_pos,
                prompt_len: plen,
                payload: UploadPayload::Floats(vec![0.5; count * D]),
            },
        )
        .unwrap();
}

#[test]
fn infer_before_upload_parks_then_completes() {
    let seed = 21;
    let sched = mock_scheduler(seed, 1);
    let router = sched.router();

    // the infer request overtakes its own uploads (they travel on the
    // other connection in the real system)
    let rx = infer(&router, 1, 1, 2, 3, None);

    // the stats round trip is processed after the infer on the same
    // worker queue, so "parked == 1, no reply" proves the request parked
    // rather than failed — with zero test-side waiting
    let stats = sched.stats().unwrap();
    assert_eq!(stats.parked, 1, "request must park while uploads are in flight");
    assert_eq!(stats.requests_served, 0);
    assert!(rx.try_recv().is_err(), "no token before the covering upload");

    // the covering prompt upload lands -> the parked request is woken
    upload(&router, 1, 1, 0, 3, 3);
    let out = token(rx.recv().unwrap()).expect("parked request must complete");
    assert_eq!(out.token, MockOracle::new(seed).cloud_token(2));

    let stats = sched.stats().unwrap();
    assert_eq!(stats.parked, 0);
    assert_eq!(stats.requests_served, 1);
    assert_eq!(stats.uploads, 1);
    let final_stats = sched.shutdown();
    assert_eq!(final_stats.requests_served, 1);
}

#[test]
fn one_upload_wakes_and_coalesces_all_covered_requests() {
    let seed = 5;
    let sched = mock_scheduler(seed, 1);
    let router = sched.router();
    let oracle = MockOracle::new(seed);

    // normal start: prompt upload, then the first token via cloud prefill
    upload(&router, 7, 1, 0, 3, 3);
    let first = token(infer(&router, 7, 1, 2, 3, None).recv().unwrap()).unwrap();
    assert_eq!(first.token, oracle.cloud_token(2));

    // two decode requests race ahead of their uploads and park
    let rx4 = infer(&router, 7, 1, 4, 3, None);
    let rx5 = infer(&router, 7, 1, 5, 3, None);
    assert_eq!(sched.stats().unwrap().parked, 2);

    // one upload covering positions 3..=5 wakes both; the worker answers
    // them from a single catch-up pass over the pending positions
    upload(&router, 7, 1, 3, 3, 3);
    assert_eq!(token(rx4.recv().unwrap()).unwrap().token, oracle.cloud_token(4));
    assert_eq!(token(rx5.recv().unwrap()).unwrap().token, oracle.cloud_token(5));

    let stats = sched.stats().unwrap();
    assert_eq!(stats.parked, 0);
    assert_eq!(stats.requests_served, 3);
    sched.shutdown();
}

#[test]
fn superseded_request_fails_instead_of_parking_forever() {
    let sched = mock_scheduler(3, 1);
    let router = sched.router();
    // request 1 parks...
    let rx = infer(&router, 2, 1, 1, 2, None);
    // ...then the device moves on to request 2: the old request can never
    // be served and must fail promptly
    upload(&router, 2, 2, 0, 2, 2);
    let err = rx.recv().unwrap().expect_err("stale request must fail");
    assert!(format!("{err:#}").contains("superseded"), "{err:#}");
    sched.shutdown();
}

#[test]
fn two_devices_progress_concurrently_with_two_workers() {
    let seed = 9;
    let sched = mock_scheduler(seed, 2);
    let router = sched.router();
    assert_eq!(router.workers(), 2);
    assert_ne!(router.worker_for(0), router.worker_for(1), "devices shard across workers");

    // device 0 (worker 0) parks indefinitely: its uploads never arrive
    let rx0 = infer(&router, 0, 1, 1, 2, None);

    // device 1 (worker 1) runs a complete request meanwhile: prompt,
    // first token, then a decode token — every reply arrives even though
    // the other worker has a parked request the whole time
    let oracle = MockOracle::new(seed);
    upload(&router, 1, 1, 0, 2, 2);
    let t1 = token(infer(&router, 1, 1, 1, 2, None).recv().unwrap()).unwrap();
    assert_eq!(t1.token, oracle.cloud_token(1));
    upload(&router, 1, 1, 2, 1, 2);
    let t2 = token(infer(&router, 1, 1, 2, 2, None).recv().unwrap()).unwrap();
    assert_eq!(t2.token, oracle.cloud_token(2));
    router.send(1, SchedMsg::End { device: 1, session: 0, req_id: 1 }).unwrap();

    let stats = sched.stats().unwrap();
    assert_eq!(stats.workers, 2);
    assert_eq!(stats.parked, 1, "device 0 still parked");
    assert_eq!(stats.requests_served, 2, "device 1 made full progress");

    // shutdown drops the parked request's reply channel
    sched.shutdown();
    assert!(rx0.recv().is_err());
}

#[test]
fn parked_request_deadline_expires_with_an_error() {
    let sched = mock_scheduler(1, 1);
    let router = sched.router();
    let deadline = Instant::now() + Duration::from_millis(40);
    let rx = infer(&router, 4, 1, 1, 2, Some(deadline));
    // blocking on the reply: the worker must wake itself at the deadline
    let err = rx.recv().unwrap().expect_err("deadline must expire the parked request");
    assert!(format!("{err:#}").contains("deadline"), "{err:#}");
    assert!(Instant::now() >= deadline, "no early expiry");
    let stats = sched.stats().unwrap();
    assert_eq!(stats.deadline_expired, 1);
    assert_eq!(stats.parked, 0);
    sched.shutdown();
}

#[test]
fn stale_session_frames_are_fenced_after_reconnect() {
    let seed = 13;
    let sched = mock_scheduler(seed, 1);
    let router = sched.router();
    let dev = 5u64;

    // connection pair A pins the device, then the client reconnects as B
    router
        .send(dev, SchedMsg::Reset { device: dev, session: 0xA, resume: false, mirror: false })
        .unwrap();
    router
        .send(dev, SchedMsg::Reset { device: dev, session: 0xB, resume: false, mirror: false })
        .unwrap();

    // B's prompt upload is accepted
    router
        .send(dev, SchedMsg::Upload {
            device: dev,
            session: 0xB,
            req_id: 1,
            start_pos: 0,
            prompt_len: 2,
            payload: UploadPayload::Floats(vec![0.5; 2 * D]),
        })
        .unwrap();
    // a straggling EndSession from A's infer connection must not tear
    // down B's fresh state...
    router.send(dev, SchedMsg::End { device: dev, session: 0xA, req_id: 1 }).unwrap();
    // ...and a straggling upload from A is dropped outright
    router
        .send(dev, SchedMsg::Upload {
            device: dev,
            session: 0xA,
            req_id: 1,
            start_pos: 0,
            prompt_len: 2,
            payload: UploadPayload::Floats(vec![0.5; 2 * D]),
        })
        .unwrap();

    // B's request still completes against its own uploads
    let (tx, rx) = mpsc::channel();
    router
        .send(dev, SchedMsg::Infer {
            device: dev,
            session: 0xB,
            req_id: 1,
            pos: 1,
            prompt_len: 2,
            deadline: None,
            reply: Reply::channel(tx),
        })
        .unwrap();
    let out = token(rx.recv().unwrap()).expect("session B must be unaffected by A's stragglers");
    assert_eq!(out.token, MockOracle::new(seed).cloud_token(1));

    let stats = sched.stats().unwrap();
    assert_eq!(stats.uploads, 1, "A's straggling upload must be fenced");
    assert_eq!(stats.requests_served, 1);
    sched.shutdown();
}

#[test]
fn missing_uploads_resolve_with_an_error_at_the_max_park_bound() {
    // no client deadline at all: the worker's own bound must still
    // resolve the request (a dead upload connection must not wedge it)
    let dims = test_manifest().model;
    let sdims = dims.clone();
    let sched = Scheduler::spawn(
        dims,
        CloudConfig { workers: 1, max_park_s: 0.04, ..Default::default() },
        Arc::new(move || {
            let sdims = sdims.clone();
            let f: SessionFactory = Box::new(move |_device| {
                Ok(Box::new(MockCloud::new(MockOracle::new(1), sdims.clone())) as _)
            });
            Ok(f)
        }),
    )
    .unwrap();
    let router = sched.router();
    let rx = infer(&router, 6, 1, 1, 2, None);
    let err = rx.recv().unwrap().expect_err("max-park bound must fire");
    assert!(format!("{err:#}").contains("deadline"), "{err:#}");
    let stats = sched.stats().unwrap();
    assert_eq!(stats.deadline_expired, 1);
    assert_eq!(stats.parked, 0);
    sched.shutdown();
}

/// Scheduler whose worker blocks in its engine builder until the test
/// releases `gate` — every message the test queues beforehand lands in
/// the worker's channel and is drained in ONE wake, which makes the
/// cross-device batch composition deterministic.  `spy` (when given)
/// records every `decode_batch` call as `(device, items)` in engine
/// order, so tests can observe pass composition from outside the worker
/// thread.
fn gated_scheduler(
    seed: u64,
    cfg: CloudConfig,
    gate: Arc<std::sync::Barrier>,
    spy: Option<Arc<std::sync::Mutex<Vec<(u64, usize)>>>>,
) -> Scheduler {
    use ce_collm::runtime::traits::{BatchItem, CloudEngine, CloudOut};

    struct Spy {
        inner: MockCloud,
        device: u64,
        log: Arc<std::sync::Mutex<Vec<(u64, usize)>>>,
    }

    impl CloudEngine for Spy {
        fn dims(&self) -> &ce_collm::model::manifest::ModelDims {
            self.inner.dims()
        }
        fn prefill(&mut self, h1: &[f32], len: usize) -> anyhow::Result<CloudOut> {
            self.inner.prefill(h1, len)
        }
        fn decode(&mut self, h1: &[f32], pos: usize) -> anyhow::Result<CloudOut> {
            self.inner.decode(h1, pos)
        }
        fn decode_batch(&mut self, items: &[BatchItem]) -> anyhow::Result<Vec<CloudOut>> {
            self.log.lock().unwrap().push((self.device, items.len()));
            self.inner.decode_batch(items)
        }
        fn batch_passes(&self) -> u64 {
            self.inner.batch_passes()
        }
        fn is_prefilled(&self) -> bool {
            self.inner.is_prefilled()
        }
        fn reset(&mut self) {
            self.inner.reset()
        }
    }

    let dims = test_manifest().model;
    let sdims = dims.clone();
    Scheduler::spawn(
        dims,
        cfg,
        Arc::new(move || {
            gate.wait();
            let sdims = sdims.clone();
            let spy = spy.clone();
            let f: SessionFactory = Box::new(move |device| {
                let inner = MockCloud::new(MockOracle::new(seed), sdims.clone());
                Ok(match &spy {
                    Some(log) => {
                        Box::new(Spy { inner, device, log: Arc::clone(log) }) as Box<dyn CloudEngine>
                    }
                    None => Box::new(inner) as Box<dyn CloudEngine>,
                })
            });
            Ok(f)
        }),
    )
    .unwrap()
}

#[test]
fn four_devices_share_one_padded_engine_pass() {
    let seed = 11;
    let gate = Arc::new(std::sync::Barrier::new(2));
    let sched = gated_scheduler(seed, CloudConfig::default(), Arc::clone(&gate), None);
    let router = sched.router();

    // queue everything while the worker is still held at the gate: each
    // device uploads its 3-position prompt plus decode hiddens for
    // positions 3 and 4, then asks for the token at position 4
    for dev in 0..4u64 {
        upload(&router, dev, 1, 0, 3, 3);
        upload(&router, dev, 1, 3, 2, 3);
    }
    let rxs: Vec<_> = (0..4u64).map(|dev| infer(&router, dev, 1, 4, 3, None)).collect();
    gate.wait();

    let oracle = MockOracle::new(seed);
    for rx in &rxs {
        let out = token(rx.recv().unwrap()).expect("batched request must complete");
        assert_eq!(out.token, oracle.cloud_token(4));
    }
    let stats = sched.stats().unwrap();
    assert_eq!(
        stats.engine_passes, 1,
        "all four devices' pending decodes must share one padded pass: {stats:?}"
    );
    assert_eq!(stats.batch_devices_max, 4);
    assert_eq!(stats.batched_items, 8, "positions 3 and 4 for each of the four devices");
    assert_eq!(stats.requests_served, 4);
    sched.shutdown();
}

#[test]
fn deep_backlog_is_capped_and_cannot_starve_other_devices() {
    let seed = 23;
    let gate = Arc::new(std::sync::Barrier::new(2));
    let log = Arc::new(std::sync::Mutex::new(Vec::new()));
    let cfg = CloudConfig { max_catchup_per_pass: 4, ..Default::default() };
    let sched = gated_scheduler(seed, cfg, Arc::clone(&gate), Some(Arc::clone(&log)));
    let router = sched.router();

    // device 0: 2-position prompt + a 20-position decode backlog
    upload(&router, 0, 1, 0, 2, 2);
    upload(&router, 0, 1, 2, 20, 2);
    let rx0 = infer(&router, 0, 1, 21, 2, None);
    // devices 1..4: one pending decode each
    let mut rxs = Vec::new();
    for dev in 1..4u64 {
        upload(&router, dev, 1, 0, 2, 2);
        upload(&router, dev, 1, 2, 1, 2);
        rxs.push(infer(&router, dev, 1, 2, 2, None));
    }
    gate.wait();

    let oracle = MockOracle::new(seed);
    for rx in &rxs {
        assert_eq!(token(rx.recv().unwrap()).unwrap().token, oracle.cloud_token(2));
    }
    assert_eq!(token(rx0.recv().unwrap()).unwrap().token, oracle.cloud_token(21));

    let stats = sched.stats().unwrap();
    // 20 backlog positions at <= 4 per pass: five passes, the other
    // devices' single items riding along in the first one
    assert_eq!(stats.engine_passes, 5, "{stats:?}");
    assert_eq!(stats.batched_items, 23);
    assert_eq!(stats.batch_devices_max, 4);

    // the first pass interleaves every device (capped device 0 included);
    // devices 1..4 never wait behind device 0's backlog
    let log = log.lock().unwrap();
    let first_pass: Vec<u64> = log.iter().take(4).map(|&(dev, _)| dev).collect();
    assert_eq!(first_pass, vec![0, 1, 2, 3], "pass 1 must cover all devices: {log:?}");
    assert_eq!(log[0].1, 4, "device 0 capped at 4 items in pass 1");
    assert!(log[4..].iter().all(|&(dev, n)| dev == 0 && n == 4), "later passes drain the backlog");
    assert_eq!(log.len(), 4 + 4, "5 passes total: 4 calls in pass 1, then 4 backlog chunks");
    sched.shutdown();
}

#[test]
fn router_queue_depth_tracks_undrained_messages() {
    // the reactor's backpressure signal: depth rises while the worker is
    // held at the gate, returns to zero once everything is drained
    let gate = Arc::new(std::sync::Barrier::new(2));
    let sched = gated_scheduler(1, CloudConfig::default(), Arc::clone(&gate), None);
    let router = sched.router();
    assert_eq!(router.queue_depth(0), 0);

    upload(&router, 0, 1, 0, 2, 2);
    for pos in 2..6u32 {
        upload(&router, 0, 1, pos, 1, 2);
    }
    assert_eq!(router.queue_depth(0), 5, "five undrained uploads");

    gate.wait();
    // the reply arrives only after the worker drained its whole queue,
    // so the gauge must read zero again by then
    let rx = infer(&router, 0, 1, 1, 2, None);
    token(rx.recv().unwrap()).unwrap();
    assert_eq!(router.queue_depth(0), 0);
    sched.shutdown();
}

/// A cloud that completes the dual-API handshake and then swallows every
/// frame without ever answering.
fn stalled_cloud_link(device_id: u64) -> CloudLink {
    use ce_collm::coordinator::protocol::Message;

    let (edge_up, cloud_up) = in_proc_pair();
    let (edge_inf, cloud_inf) = in_proc_pair();
    std::thread::spawn(move || {
        // CloudLink::new handshakes the infer channel first, then upload
        let mut inf: Box<dyn Transport> = Box::new(cloud_inf);
        let mut up: Box<dyn Transport> = Box::new(cloud_up);
        let _ = inf.recv();
        let _ = inf.send(&Message::Ack.encode());
        let _ = up.recv();
        let _ = up.send(&Message::Ack.encode());
        // drain both channels forever, never replying
        std::thread::spawn(move || while up.recv().is_ok() {});
        while inf.recv().is_ok() {}
    });
    CloudLink::new(device_id, Box::new(edge_up), Box::new(edge_inf)).unwrap()
}

#[test]
fn stalled_cloud_falls_back_to_best_local_exit_within_budget() {
    let seed = 5;
    let dims = test_manifest().model;
    // θ = 1.0: every token wants the cloud (confidences are < 1)
    let mut cfg = DeploymentConfig::with_threshold(1.0);
    cfg.device_id = 3;
    cfg.max_new_tokens = 4;
    let budget = 0.05;
    cfg.cloud_token_budget_s = Some(budget);

    let link = stalled_cloud_link(cfg.device_id);
    let mut client = EdgeClient::with_cloud(MockEdge::new(MockOracle::new(seed), dims), cfg, link);

    let wall0 = Instant::now();
    let out = client.generate("a stalled cloud must not block").unwrap();
    let wall = wall0.elapsed().as_secs_f64();

    assert_eq!(out.tokens.len(), 4);
    // every deferral fell back to a local exit within the budget
    assert_eq!(out.counters.cloud_fallbacks, 4, "{:?}", out.counters);
    assert_eq!(out.counters.cloud_requests, 4);
    assert_eq!(out.counters.tokens_cloud, 0);
    assert_eq!(out.counters.tokens_exit2, 4, "mock exit-2 confidence >= exit-1");
    assert!(
        wall < 4.0 * budget + 2.0,
        "fallbacks must not block past the budget (took {wall:.3}s)"
    );

    // deterministic fallback: the mock's exit-2 prediction at each position
    let oracle = MockOracle::new(seed);
    for t in &out.trace {
        assert_eq!(t.exit, ExitPoint::Exit2, "trace records the local exit used");
        assert_eq!(t.token, oracle.exit_token(t.pos, oracle.conf2(t.pos)));
    }
}
