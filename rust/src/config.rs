//! Deployment-level configuration: exit policy, ablation switches, and
//! experiment parameters.  Model architecture comes from
//! `artifacts/manifest.json` (see [`crate::model::manifest`]).

/// Confidence-threshold exit policy (paper §4.1).
///
/// `threshold = 1.0` disables early exits in practice (confidences are
/// strictly `< 1`), reproducing the paper's θ=1.0 rows; `Standalone`
/// removes the threshold condition at the *last* exit so the edge always
/// emits (low-latency mode).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ExitPolicy {
    /// Collaborative mode: exit early iff `conf >= threshold`, otherwise
    /// defer to the cloud partition (high-accuracy mode).
    Threshold(f32),
    /// Edge standalone: exit at exit-1 iff `conf >= threshold`, and
    /// unconditionally at exit-2.  Never contacts the cloud.
    Standalone { threshold: f32 },
}

impl ExitPolicy {
    pub fn threshold(&self) -> f32 {
        match *self {
            ExitPolicy::Threshold(t) => t,
            ExitPolicy::Standalone { threshold } => threshold,
        }
    }

    pub fn is_standalone(&self) -> bool {
        matches!(self, ExitPolicy::Standalone { .. })
    }
}

/// Ablation switches (paper §5.4, Table 4).  All `true` = full CE-CoLLM.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AblationFlags {
    /// Transmit hidden states as f16 (paper §4.3).  Off → f32 payloads.
    pub half_precision: bool,
    /// Early-exit mechanism.  Off → every token goes to the cloud (the
    /// edge still runs its partition, matching the paper's −EE row whose
    /// edge time equals the θ=1.0 row).
    pub early_exit: bool,
    /// Cloud content manager: dedup of uploaded hidden states + KV cache
    /// retention across tokens.  Off → every cloud request re-transmits
    /// the full hidden-state history (the O(T²) naïve behaviour).
    pub content_manager: bool,
    /// Overlap hidden-state upload with ongoing edge compute.  Off →
    /// uploads happen synchronously when the cloud request is issued.
    pub parallel_upload: bool,
}

impl Default for AblationFlags {
    fn default() -> Self {
        Self {
            half_precision: true,
            early_exit: true,
            content_manager: true,
            parallel_upload: true,
        }
    }
}

impl AblationFlags {
    /// The paper's "Without Content Manager & Parallel Upload" row flips
    /// both switches together.
    pub fn without_cm_and_parallel_upload() -> Self {
        Self { content_manager: false, parallel_upload: false, ..Self::default() }
    }

    pub fn without_half_precision() -> Self {
        Self { half_precision: false, ..Self::default() }
    }

    pub fn without_early_exit() -> Self {
        Self { early_exit: false, ..Self::default() }
    }
}

/// Edge-side reconnect policy: what a [`CloudLink`] does when one of
/// its transports breaks mid-run.  The link re-dials the current cloud
/// endpoint under exponential backoff, re-`Hello`s both channels with
/// the *same* session nonce (`resume = true`), and replays its retained
/// hidden-state history so the stream continues bit-identically — the
/// same recovery path as a context-store eviction.  When every attempt
/// against one endpoint fails, the link rotates to the next configured
/// endpoint (failover) and starts the attempt budget over.
///
/// [`CloudLink`]: crate::coordinator::edge::CloudLink
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReconnectPolicy {
    /// Dial attempts per endpoint before rotating to the next one.
    /// `0` disables reconnect entirely: a broken transport permanently
    /// downgrades the run to local exits (the pre-resilience behaviour).
    pub max_attempts: u32,
    /// Backoff before attempt `n` (0-based) is
    /// `min(backoff_base_s * 2^n, backoff_cap_s)`, jittered.
    pub backoff_base_s: f64,
    /// Ceiling on a single backoff sleep.
    pub backoff_cap_s: f64,
    /// Jitter fraction in `[0, 1]`: the actual sleep is drawn uniformly
    /// from `[(1 - jitter) * b, b]` so a severed fleet doesn't re-dial
    /// in lockstep (the reconnect-storm shape).
    pub jitter: f64,
    /// Per-attempt TCP connect timeout, seconds.
    pub connect_timeout_s: f64,
}

impl Default for ReconnectPolicy {
    fn default() -> Self {
        Self {
            max_attempts: 4,
            backoff_base_s: 0.05,
            backoff_cap_s: 2.0,
            jitter: 0.5,
            connect_timeout_s: 5.0,
        }
    }
}

impl ReconnectPolicy {
    /// The legacy no-reconnect behaviour: first transport error
    /// permanently downgrades the run.
    pub fn disabled() -> Self {
        Self { max_attempts: 0, ..Self::default() }
    }

    pub fn enabled(&self) -> bool {
        self.max_attempts > 0
    }

    /// Deterministic backoff for 0-based attempt `n`, before jitter.
    pub fn backoff_s(&self, attempt: u32) -> f64 {
        let b = self.backoff_base_s * f64::powi(2.0, attempt.min(30) as i32);
        b.min(self.backoff_cap_s)
    }
}

/// Warm-standby cloud replication: what a
/// [`ReplicaSet`](crate::coordinator::edge::ReplicaSet) maintains above
/// the primary [`CloudLink`](crate::coordinator::edge::CloudLink).
///
/// With `replicas = n`, the edge opens full dual-channel sessions
/// against the next `n` endpoints after the primary (their Hellos carry
/// the `mirror` bit so the cloud bills those uploads separately and
/// prefers the sessions as eviction victims), mirrors every upload to
/// them asynchronously on their own uploader threads, and keeps their
/// health scored from keepalive ping RTT plus error/reconnect history.
/// On primary failure the best-scored warm standby is promoted without
/// any ring replay — its `ContextStore` coverage already spans the
/// watermark, so tokens stay bit-identical with zero `context_replays`.
///
/// The degradation ladder (documented in [`crate::coordinator`]):
/// hedged (when `hedge` and ≥1 healthy standby) → primary-only (no
/// healthy standby) → the §4.4 local fallback (no link at all).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReplicationConfig {
    /// Warm standbys to mirror to, beyond the primary.  Capped by the
    /// number of configured endpoints minus one.
    pub replicas: usize,
    /// Hedged-infer mode: when the per-token deadline budget is tight,
    /// duplicate the infer to the best-scored standby as well; the
    /// first valid `(req_id, pos)` echo wins and the loser's late echo
    /// is fenced by the existing stale-response skip.
    pub hedge: bool,
}

impl Default for ReplicationConfig {
    fn default() -> Self {
        Self { replicas: 1, hedge: false }
    }
}

/// Everything the edge client needs to run one deployment.
#[derive(Debug, Clone)]
pub struct DeploymentConfig {
    pub policy: ExitPolicy,
    pub ablation: AblationFlags,
    /// Maximum number of generated tokens per request.
    pub max_new_tokens: usize,
    /// Logical device id reported to the cloud content manager.
    pub device_id: u64,
    /// Per-token latency budget for cloud deferrals (paper §4.4,
    /// latency-aware exit).  `Some(s)`: a deferred token that the cloud
    /// has not answered within `s` seconds is emitted from the best local
    /// exit instead, and a transport failure downgrades the whole run to
    /// local exits.  `None`: block on the cloud indefinitely.
    pub cloud_token_budget_s: Option<f64>,
    /// Positions of exit-1 hidden-state history the edge retains per
    /// request for cloud-eviction replay (the cloud's context store may
    /// evict an idle session; a `SessionEvicted` response is answered by
    /// re-uploading the history from position 0 so the cloud can
    /// re-prefill).  When a run outgrows the ring, position 0 is dropped
    /// and an eviction becomes unrecoverable (it then degrades exactly
    /// like a cloud error: local fallback with a latency budget, a hard
    /// error without one).  The default comfortably covers `max_seq` of
    /// every shipped manifest.
    pub replay_ring_positions: usize,
    /// What the [`CloudLink`](crate::coordinator::edge::CloudLink) does
    /// when a transport breaks: re-dial, resume the session, replay.
    /// Default is on (4 attempts/endpoint); `ReconnectPolicy::disabled()`
    /// restores the legacy permanent-downgrade behaviour.
    pub reconnect: ReconnectPolicy,
    /// Seconds an edge channel may sit idle before the link probes it
    /// with a keepalive `Ping` (answered by the server's `Pong`; the
    /// round trip is recorded as `ping_rtt_last_ms`).  Must stay well
    /// under the server's `ReactorConfig::idle_timeout_s` so a
    /// quiet-but-alive link is never reaped.  `0.0` disables keepalive.
    pub keepalive_idle_s: f64,
    /// Warm-standby cloud replication (see [`ReplicationConfig`]).
    /// `None` (the default) is byte-identical on the wire to the
    /// pre-replication behaviour: one session, cold failover via
    /// endpoint rotation + ring replay.
    pub replication: Option<ReplicationConfig>,
}

impl Default for DeploymentConfig {
    fn default() -> Self {
        Self {
            policy: ExitPolicy::Threshold(0.8),
            ablation: AblationFlags::default(),
            max_new_tokens: 96,
            device_id: 0,
            cloud_token_budget_s: None,
            replay_ring_positions: 4096,
            reconnect: ReconnectPolicy::default(),
            keepalive_idle_s: 45.0,
            replication: None,
        }
    }
}

impl DeploymentConfig {
    pub fn with_threshold(threshold: f32) -> Self {
        Self { policy: ExitPolicy::Threshold(threshold), ..Self::default() }
    }

    pub fn standalone() -> Self {
        Self { policy: ExitPolicy::Standalone { threshold: 0.8 }, ..Self::default() }
    }
}

/// Readiness backend for the reactor's event loop
/// ([`crate::net::event::EventSet`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReactorBackend {
    /// `CE_REACTOR_BACKEND=poll|epoll` when the env var is set, else
    /// the platform default: edge-triggered `epoll` on Linux, `poll(2)`
    /// elsewhere.
    #[default]
    Auto,
    /// The portable `poll(2)` loop: every wake rebuilds an O(conns)
    /// pollfd array.
    Poll,
    /// Linux `epoll`: interest changes are O(1) `epoll_ctl` calls and a
    /// wake costs only the connections that are actually ready.
    /// Degrades to `poll` (with a warning) off Linux.
    Epoll,
}

/// Env var consulted by [`ReactorConfig::resolved_shards`] when
/// `shards` is 0 (auto): `CE_REACTOR_SHARDS=<n>` pins the reactor fleet
/// size without a recompile.  An explicit `shards` value always wins,
/// so tests that assert exact thread budgets stay deterministic.
pub const SHARDS_ENV: &str = "CE_REACTOR_SHARDS";

/// Hard cap on reactor shards.  Connection ids carry the owning shard
/// in their top 8 bits (see `net::reactor`), so the representable
/// ceiling is 256; 64 is already far past the point where accept and
/// readiness stop being the bottleneck.
pub const MAX_REACTOR_SHARDS: usize = 64;

/// Knobs for the cloud's event-driven connection reactor fleet
/// ([`crate::net::reactor`]): `shards` threads share every cloud-side
/// socket (each owning its own event set and connection table), so
/// per-connection resource bounds are what protect the whole server.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReactorConfig {
    /// Reactor shard count.  `0` (the default) resolves at spawn time:
    /// the [`SHARDS_ENV`] env override if set, else `min(4, cores)` —
    /// see [`ReactorConfig::resolved_shards`].  Each shard is one
    /// thread with its own epoll/poll set, connection table, and (on
    /// Linux, when the server binds its own listeners) its own
    /// `SO_REUSEPORT` accept queue; the cloud's total thread budget is
    /// exactly `workers + shards`.
    pub shards: usize,
    /// Maximum simultaneously registered connections; connections
    /// accepted beyond this are dropped immediately (the edge sees a
    /// closed socket and degrades to local exits).  Each device costs
    /// two (the dual API's upload + infer channels).  Enforced as an
    /// even `max_conns / shards` share per shard (same split as the
    /// context store's per-worker budget): the kernel's reuseport hash
    /// spreads connections uniformly, so the shares sum back to the
    /// global bound without any cross-shard coordination.
    pub max_conns: usize,
    /// Per-connection write-queue cap in bytes.  A reader too slow to
    /// drain its token responses past this backlog is evicted (closed)
    /// rather than allowed to buffer the server into the ground.
    pub write_queue_cap: usize,
    /// Scheduler backpressure threshold: when a worker's undrained queue
    /// ([`crate::coordinator::scheduler::Router::queue_depth`]) exceeds
    /// this many messages, the reactor pauses *reading* from that
    /// worker's connections until it catches up, pushing the backlog
    /// into the kernel's TCP flow control instead of heap memory.
    pub worker_queue_cap: usize,
    /// Seconds a freshly accepted connection may sit without completing
    /// its `Hello` handshake before it is closed.  Prevents silent
    /// sockets from squatting on `max_conns` slots and locking real
    /// devices out.
    pub hello_timeout_s: f64,
    /// Seconds an *established* connection may go without a single byte
    /// read from or written to its peer before it is closed.  Catches
    /// silently-dead peers (NAT table expiry, powered-off devices) that
    /// would otherwise hold a `max_conns` slot until a write to them
    /// failed.  On by default (120s) now that the edge keeps quiet links
    /// alive with `Ping`/`Pong` keepalives
    /// (`DeploymentConfig::keepalive_idle_s`, well under this bound) and
    /// reconnects with session resume if a link is cut anyway — a reaped
    /// edge costs one replay round trip, not a degraded run.  `0.0`
    /// disables the reap.  Pairs with the context store's
    /// `session_ttl_s`: once a dead device's connections are reaped, its
    /// cloud session goes idle and the TTL sweep releases the bytes.
    pub idle_timeout_s: f64,
    /// Which readiness backend the reactor runs on.  `Auto` (default)
    /// honours the `CE_REACTOR_BACKEND` env toggle and otherwise picks
    /// `epoll` on Linux, `poll` elsewhere.
    pub backend: ReactorBackend,
    /// Deterministic fault schedule applied to every connection
    /// (test/CI only — `None` in production).  `None` falls back to the
    /// `CE_FAULT` env spec; see [`crate::net::fault::ReactorFault`].
    pub fault: Option<crate::net::fault::ReactorFault>,
}

impl Default for ReactorConfig {
    fn default() -> Self {
        Self {
            shards: 0,
            max_conns: 4096,
            write_queue_cap: 4 << 20,
            worker_queue_cap: 4096,
            hello_timeout_s: 10.0,
            idle_timeout_s: 120.0,
            backend: ReactorBackend::Auto,
            fault: None,
        }
    }
}

impl ReactorConfig {
    /// The shard count the fleet will actually spawn.  An explicit
    /// `shards` value is clamped and used as-is; `0` (auto) honours the
    /// [`SHARDS_ENV`] env override and otherwise picks `min(4, cores)`
    /// — one reactor saturates around ~100k connections, and four
    /// shards cover that envelope without stealing cores from the
    /// worker pool on small machines.
    pub fn resolved_shards(&self) -> usize {
        let n = if self.shards == 0 {
            std::env::var(SHARDS_ENV)
                .ok()
                .and_then(|v| v.trim().parse::<usize>().ok())
                .filter(|&n| n > 0)
                .unwrap_or_else(|| {
                    std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1).min(4)
                })
        } else {
            self.shards
        };
        n.clamp(1, MAX_REACTOR_SHARDS)
    }
}

/// Cloud serving-side configuration (the scheduler's worker pool).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CloudConfig {
    /// Serving threads.  Each worker owns its own engine sessions and
    /// content-manager shard; devices are assigned statically
    /// (`device_id % workers`).  1 reproduces the paper's single
    /// inference GPU.
    pub workers: usize,
    /// Upper bound, in seconds, on how long an infer request may stay
    /// parked waiting for its uploads (the bound applies even when the
    /// request carries no deadline of its own).  Protects the server and
    /// the edge from a dead upload connection: the request fails with an
    /// error instead of waiting forever.
    pub max_park_s: f64,
    /// Fairness bound for cross-device batched decode: at most this many
    /// catch-up positions of ONE device enter a single padded engine
    /// pass.  A device with a deep backlog finishes over several passes
    /// while other devices' pending tokens ride along in every one of
    /// them, so a chatty device cannot starve the batch.
    pub max_catchup_per_pass: usize,
    /// Global bound on resident per-device cloud context bytes — engine
    /// KV-cache positions plus buffered (pending) hidden states — across
    /// the whole worker pool.  The context store meters every device and
    /// evicts whole *idle* sessions in LRU order (last touch) until the
    /// pool fits; an evicted device recovers by replaying its hidden
    /// history from position 0 (see `protocol::Message::SessionEvicted`).
    /// Enforced as an even `budget / workers` share per worker (static
    /// device sharding makes the shares independent).  `None` disables
    /// eviction entirely: sessions live until `EndSession`, exactly the
    /// pre-store behaviour.
    pub memory_budget_bytes: Option<u64>,
    /// Idle TTL for per-device cloud context: a device whose session has
    /// not been touched (upload, plan, or serve) for this many seconds is
    /// evicted by the worker's sweep even when the pool is under budget.
    /// Recovery is the same replay path as a budget eviction.  `None`
    /// disables the reaper.
    pub session_ttl_s: Option<f64>,
    /// Connection-reactor bounds (max connections, write-queue cap,
    /// read-pause backpressure threshold).
    pub reactor: ReactorConfig,
    /// Deterministic trace recording (see [`crate::trace`]): `Some(path)`
    /// opens a JSONL [`TraceSink`](crate::trace::TraceSink) at spawn and
    /// taps every scheduler event and (when the server wires it through)
    /// every reactor frame into it.  `None` (the default) falls back to
    /// the `CE_TRACE` env var, and with neither set tracing is off — the
    /// hot path pays a single `Option` check per event site.  The path is
    /// `&'static str` so the config stays `Copy`; CLI callers leak the
    /// argument string (a one-off, process-lifetime allocation).
    pub trace: Option<&'static str>,
    /// Latency-histogram instrumentation (see [`crate::metrics::hist`]):
    /// `true` resolves the process-wide
    /// [`MetricsRegistry`](crate::metrics::MetricsRegistry) at spawn,
    /// every scheduler worker / reactor shard / edge link records
    /// per-stage latencies into it, and the reactor serves a Prometheus
    /// text snapshot to any connection that opens with `GET ` instead of
    /// a `Hello`.  `false` (the default) falls back to the `CE_METRICS`
    /// env var, and with neither set every instrumentation site pays a
    /// single `Option` check — the same discipline as `trace`.
    pub metrics: bool,
}

impl Default for CloudConfig {
    fn default() -> Self {
        Self {
            workers: 1,
            max_park_s: 30.0,
            max_catchup_per_pass: 32,
            memory_budget_bytes: None,
            session_ttl_s: None,
            reactor: ReactorConfig::default(),
            trace: None,
            metrics: false,
        }
    }
}

impl CloudConfig {
    pub fn with_workers(workers: usize) -> Self {
        Self { workers: workers.max(1), ..Self::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_flags_are_full_system() {
        let f = AblationFlags::default();
        assert!(f.half_precision && f.early_exit && f.content_manager && f.parallel_upload);
    }

    #[test]
    fn ablation_constructors_flip_one_axis() {
        assert!(!AblationFlags::without_half_precision().half_precision);
        assert!(!AblationFlags::without_early_exit().early_exit);
        let cm = AblationFlags::without_cm_and_parallel_upload();
        assert!(!cm.content_manager && !cm.parallel_upload && cm.half_precision);
    }

    #[test]
    fn policy_threshold_accessor() {
        assert_eq!(ExitPolicy::Threshold(0.9).threshold(), 0.9);
        assert!(ExitPolicy::Standalone { threshold: 0.8 }.is_standalone());
        assert!(!ExitPolicy::Threshold(0.8).is_standalone());
    }

    #[test]
    fn cloud_config_floors_workers_at_one() {
        assert_eq!(CloudConfig::default().workers, 1);
        assert_eq!(CloudConfig::with_workers(0).workers, 1);
        assert_eq!(CloudConfig::with_workers(4).workers, 4);
    }

    #[test]
    fn cloud_config_has_a_positive_fairness_bound() {
        assert!(CloudConfig::default().max_catchup_per_pass >= 1);
    }

    #[test]
    fn reactor_defaults_are_sane() {
        let r = ReactorConfig::default();
        assert!(r.max_conns >= 2, "room for at least one dual-API device");
        assert!(r.write_queue_cap > 0 && r.worker_queue_cap > 0);
        assert!(r.hello_timeout_s > 0.0, "silent sockets must not squat forever");
        // idle reap is on by default: the edge pings quiet links alive
        // and reconnects with session resume if one is cut anyway, so
        // the keepalive interval must sit well under the reap bound
        assert_eq!(r.idle_timeout_s, 120.0);
        assert!(DeploymentConfig::default().keepalive_idle_s * 2.0 <= r.idle_timeout_s);
        // backend choice defaults to Auto (env toggle, then platform)
        assert_eq!(r.backend, ReactorBackend::Auto);
        // shard count defaults to auto (env toggle, then min(4, cores))
        assert_eq!(r.shards, 0);
        // no fault schedule unless a test (or CE_FAULT) asks for one
        assert_eq!(r.fault, None);
    }

    #[test]
    fn reactor_shards_resolve_within_bounds() {
        // explicit values win and clamp; auto lands in [1, cap]
        let mut r = ReactorConfig::default();
        let auto = r.resolved_shards();
        assert!((1..=MAX_REACTOR_SHARDS).contains(&auto), "auto resolved to {auto}");
        if std::env::var(SHARDS_ENV).is_err() {
            assert!(auto <= 4, "auto must not exceed min(4, cores)");
        }
        r.shards = 1;
        assert_eq!(r.resolved_shards(), 1);
        r.shards = 4;
        assert_eq!(r.resolved_shards(), 4);
        r.shards = MAX_REACTOR_SHARDS + 100;
        assert_eq!(r.resolved_shards(), MAX_REACTOR_SHARDS, "explicit values clamp to the cap");
    }

    #[test]
    fn deployment_default_has_no_latency_budget() {
        assert!(DeploymentConfig::default().cloud_token_budget_s.is_none());
    }

    #[test]
    fn context_store_is_disabled_by_default() {
        // unset budget/TTL must reproduce the pre-store behaviour exactly
        let c = CloudConfig::default();
        assert!(c.memory_budget_bytes.is_none());
        assert!(c.session_ttl_s.is_none());
    }

    #[test]
    fn trace_is_off_by_default() {
        // recording must be strictly opt-in (config or CE_TRACE env)
        assert_eq!(CloudConfig::default().trace, None);
    }

    #[test]
    fn metrics_off_by_default() {
        // histograms must be strictly opt-in (config or CE_METRICS env)
        assert!(!CloudConfig::default().metrics);
    }

    #[test]
    fn replication_is_off_by_default() {
        // one session, cold failover — byte-identical to the
        // pre-replication wire behaviour unless explicitly enabled
        assert!(DeploymentConfig::default().replication.is_none());
        let r = ReplicationConfig::default();
        assert_eq!(r.replicas, 1);
        assert!(!r.hedge);
    }

    #[test]
    fn replay_ring_default_covers_shipped_manifests() {
        assert!(DeploymentConfig::default().replay_ring_positions >= 4096);
    }

    #[test]
    fn reconnect_policy_defaults_and_backoff() {
        let p = ReconnectPolicy::default();
        assert!(p.enabled() && p.max_attempts >= 1);
        assert!(!ReconnectPolicy::disabled().enabled());
        // backoff doubles then saturates at the cap
        assert_eq!(p.backoff_s(0), p.backoff_base_s);
        assert_eq!(p.backoff_s(1), p.backoff_base_s * 2.0);
        assert_eq!(p.backoff_s(63), p.backoff_cap_s);
        assert!((0.0..=1.0).contains(&p.jitter));
        assert!(p.connect_timeout_s > 0.0);
    }
}
