//! Trace recording: run the CE-CoLLM algorithm against local engine
//! sessions (no sockets) and record, per generated token, where it
//! exited, both confidences, and how much cloud catch-up work the
//! request triggered — plus measured per-call compute times.
//!
//! Traces are the bridge between real inference and the discrete-event
//! harness: tokens/exits depend only on (model, prompt, policy,
//! precision), so each deployment row of Table 2/4 and each point of
//! Fig 4 can be replayed analytically from one recorded trace without
//! re-running PJRT (see DESIGN.md §5).

use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{AblationFlags, ExitPolicy};
use crate::coordinator::content_manager::ContentManager;
use crate::coordinator::policy::{ExitPoint, TokenPolicy};
use crate::model::tokenizer::Tokenizer;
use crate::quant::{self, Precision};
use crate::runtime::traits::{CloudEngine, EdgeEngine};

/// One generated token in a trace.
#[derive(Debug, Clone)]
pub struct TraceStep {
    pub pos: usize,
    pub token: i32,
    pub exit: ExitPoint,
    pub conf1: f32,
    /// `None` when exit 1 fired (seg2 never ran).
    pub conf2: Option<f32>,
    /// Exit-head argmax tokens (Table 1 columns).
    pub tok1: i32,
    pub tok2: Option<i32>,
    /// Final-head confidence when the cloud produced the token.
    pub cloud_conf: Option<f32>,
    /// Cloud decode catch-up steps consumed by this request (0 unless
    /// `exit == Cloud`).
    pub cloud_catchup: usize,
    /// Whether this request triggered the cloud prefill.
    pub cloud_prefill: bool,
}

/// A full recorded generation.
#[derive(Debug, Clone)]
pub struct Trace {
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    pub text: String,
    pub steps: Vec<TraceStep>,
}

impl Trace {
    pub fn count(&self, e: ExitPoint) -> usize {
        self.steps.iter().filter(|s| s.exit == e).count()
    }

    pub fn cloud_rate(&self) -> f64 {
        if self.steps.is_empty() {
            return 0.0;
        }
        self.count(ExitPoint::Cloud) as f64 / self.steps.len() as f64
    }
}

/// Measured compute times, appended during recording.
#[derive(Debug, Clone, Default)]
pub struct CallTimings {
    pub edge_prefill: Vec<f64>,
    pub seg1: Vec<f64>,
    pub seg2: Vec<f64>,
    pub cloud_prefill: Vec<f64>,
    pub cloud_decode: Vec<f64>,
}

impl CallTimings {
    pub fn merge(&mut self, o: &CallTimings) {
        self.edge_prefill.extend_from_slice(&o.edge_prefill);
        self.seg1.extend_from_slice(&o.seg1);
        self.seg2.extend_from_slice(&o.seg2);
        self.cloud_prefill.extend_from_slice(&o.cloud_prefill);
        self.cloud_decode.extend_from_slice(&o.cloud_decode);
    }
}

/// Record one generation.
///
/// `precision` is applied to every hidden state handed to the cloud
/// engine (quantize→dequantize round trip), exactly what the wire does
/// in f16 mode (paper §4.3) — so f16-vs-f32 token divergence is real.
pub fn record(
    edge: &mut dyn EdgeEngine,
    cloud: &mut dyn CloudEngine,
    policy: ExitPolicy,
    precision: Precision,
    prompt: &str,
    max_new_tokens: usize,
    timings: &mut CallTimings,
) -> Result<Trace> {
    let tp = TokenPolicy::new(policy, AblationFlags::default());
    let dims = edge.dims().clone();
    let tok = Tokenizer::from_dims(&dims);
    let ids = tok.encode(prompt);
    let prompt_len = ids.len();
    anyhow::ensure!(prompt_len <= dims.max_prompt, "prompt too long ({prompt_len})");

    // the real content manager handles upload/consume bookkeeping
    let mut cm = ContentManager::new(dims.d_model);
    let quantize = |h: &[f32]| -> Vec<f32> {
        match precision {
            Precision::F32 => h.to_vec(),
            Precision::F16 => quant::unpack(&quant::pack(h, Precision::F16), Precision::F16)
                .expect("f16 roundtrip"),
        }
    };

    edge.reset();
    cloud.reset();

    let t0 = Instant::now();
    let pre = edge.prefill(&ids)?;
    timings.edge_prefill.push(t0.elapsed().as_secs_f64());
    if tp.uses_cloud() {
        cm.upload(0, 0, 0, prompt_len as u32, &quantize(&pre.h1))?;
    }

    let mut steps: Vec<TraceStep> = Vec::new();
    let mut tokens: Vec<i32> = Vec::new();

    // helper: defer one token to the cloud through the content manager
    let cloud_infer = |cm: &mut ContentManager,
                           cloud: &mut dyn CloudEngine,
                           pos: usize,
                           timings: &mut CallTimings|
     -> Result<(i32, f32, usize, bool)> {
        let plan = cm.plan(0, 0, pos as u32, prompt_len as u32)?;
        let mut last = None;
        let did_prefill = plan.prefill.is_some();
        if let Some((h, len)) = &plan.prefill {
            let t = Instant::now();
            let out = cloud.prefill(h, *len)?;
            timings.cloud_prefill.push(t.elapsed().as_secs_f64());
            if pos == *len - 1 {
                last = Some((out.exit.token, out.exit.conf));
            }
        }
        let catchup = plan.decode.len();
        for (p, h) in &plan.decode {
            let t = Instant::now();
            let out = cloud.decode(h, *p as usize)?;
            timings.cloud_decode.push(t.elapsed().as_secs_f64());
            last = Some((out.exit.token, out.exit.conf));
        }
        let (tok, conf) = last.context("cloud had no work")?;
        Ok((tok, conf, catchup, did_prefill))
    };

    // --- first token from the prefill heads -------------------------------
    let pos0 = prompt_len - 1;
    let (tok0, step0) = if tp.exit_at_1(pre.exit1.conf) {
        (
            pre.exit1.token,
            TraceStep {
                pos: pos0,
                token: pre.exit1.token,
                exit: ExitPoint::Exit1,
                conf1: pre.exit1.conf,
                conf2: None,
                tok1: pre.exit1.token,
                tok2: None,
                cloud_conf: None,
                cloud_catchup: 0,
                cloud_prefill: false,
            },
        )
    } else if tp.exit_at_2(pre.exit2.conf) {
        (
            pre.exit2.token,
            TraceStep {
                pos: pos0,
                token: pre.exit2.token,
                exit: ExitPoint::Exit2,
                conf1: pre.exit1.conf,
                conf2: Some(pre.exit2.conf),
                tok1: pre.exit1.token,
                tok2: Some(pre.exit2.token),
                cloud_conf: None,
                cloud_catchup: 0,
                cloud_prefill: false,
            },
        )
    } else {
        let (t, conf, catchup, did_prefill) = cloud_infer(&mut cm, cloud, pos0, timings)?;
        (
            t,
            TraceStep {
                pos: pos0,
                token: t,
                exit: ExitPoint::Cloud,
                conf1: pre.exit1.conf,
                conf2: Some(pre.exit2.conf),
                tok1: pre.exit1.token,
                tok2: Some(pre.exit2.token),
                cloud_conf: Some(conf),
                cloud_catchup: catchup,
                cloud_prefill: did_prefill,
            },
        )
    };
    steps.push(step0);
    tokens.push(tok0);

    // --- decode loop -------------------------------------------------------
    while !tok.is_eos(*tokens.last().unwrap())
        && tokens.len() < max_new_tokens
        && prompt_len + tokens.len() < dims.max_seq
    {
        let pos = prompt_len + tokens.len() - 1;
        let input = *tokens.last().unwrap();

        let t = Instant::now();
        let s1 = edge.seg1(input, pos)?;
        timings.seg1.push(t.elapsed().as_secs_f64());
        if tp.uses_cloud() {
            cm.upload(0, 0, pos as u32, prompt_len as u32, &quantize(&s1.h1))?;
        }

        let step = if tp.exit_at_1(s1.exit1.conf) {
            TraceStep {
                pos,
                token: s1.exit1.token,
                exit: ExitPoint::Exit1,
                conf1: s1.exit1.conf,
                conf2: None,
                tok1: s1.exit1.token,
                tok2: None,
                cloud_conf: None,
                cloud_catchup: 0,
                cloud_prefill: false,
            }
        } else {
            let t = Instant::now();
            let s2 = edge.seg2(&s1.h1, pos)?;
            timings.seg2.push(t.elapsed().as_secs_f64());
            if tp.exit_at_2(s2.exit2.conf) {
                TraceStep {
                    pos,
                    token: s2.exit2.token,
                    exit: ExitPoint::Exit2,
                    conf1: s1.exit1.conf,
                    conf2: Some(s2.exit2.conf),
                    tok1: s1.exit1.token,
                    tok2: Some(s2.exit2.token),
                    cloud_conf: None,
                    cloud_catchup: 0,
                    cloud_prefill: false,
                }
            } else {
                let (t, conf, catchup, did_prefill) = cloud_infer(&mut cm, cloud, pos, timings)?;
                TraceStep {
                    pos,
                    token: t,
                    exit: ExitPoint::Cloud,
                    conf1: s1.exit1.conf,
                    conf2: Some(s2.exit2.conf),
                    tok1: s1.exit1.token,
                    tok2: Some(s2.exit2.token),
                    cloud_conf: Some(conf),
                    cloud_catchup: catchup,
                    cloud_prefill: did_prefill,
                }
            }
        };
        tokens.push(step.token);
        steps.push(step);
    }

    Ok(Trace { prompt_len, tokens: tokens.clone(), text: tok.decode(&tokens), steps })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::test_manifest;
    use crate::runtime::mock::{MockCloud, MockEdge, MockOracle};

    fn setup(seed: u64) -> (MockEdge, MockCloud) {
        let dims = test_manifest().model;
        let o = MockOracle::new(seed);
        (MockEdge::new(o, dims.clone()), MockCloud::new(o, dims))
    }

    fn rec(policy: ExitPolicy, seed: u64) -> Trace {
        let (mut e, mut c) = setup(seed);
        let mut t = CallTimings::default();
        record(&mut e, &mut c, policy, Precision::F32, "hello world", 16, &mut t).unwrap()
    }

    #[test]
    fn standalone_never_calls_cloud() {
        let tr = rec(ExitPolicy::Standalone { threshold: 0.8 }, 1);
        assert_eq!(tr.count(ExitPoint::Cloud), 0);
        assert_eq!(tr.steps.len(), tr.tokens.len());
        assert!(tr.steps.iter().all(|s| s.exit != ExitPoint::Cloud));
    }

    #[test]
    fn threshold_one_always_cloud() {
        let tr = rec(ExitPolicy::Threshold(1.0), 2);
        assert_eq!(tr.count(ExitPoint::Cloud), tr.steps.len());
        // catch-up invariant: every generated position is consumed exactly once
        let total_catchup: usize = tr.steps.iter().map(|s| s.cloud_catchup).sum();
        // the first request consumes the prompt via prefill (catchup 0 at pos len-1)
        assert_eq!(total_catchup, tr.steps.len() - 1);
        assert!(tr.steps[0].cloud_prefill);
        assert_eq!(tr.steps.iter().filter(|s| s.cloud_prefill).count(), 1);
    }

    #[test]
    fn lower_threshold_fewer_cloud_tokens() {
        let hi = rec(ExitPolicy::Threshold(0.95), 3);
        let lo = rec(ExitPolicy::Threshold(0.5), 3);
        assert!(lo.cloud_rate() <= hi.cloud_rate());
    }

    #[test]
    fn catchup_accounts_for_skipped_positions() {
        // mid threshold: cloud requests are sparse, each catches up the
        // positions generated locally since the previous request
        let tr = rec(ExitPolicy::Threshold(0.7), 5);
        if tr.count(ExitPoint::Cloud) >= 2 {
            let mut last_cloud_pos = None;
            for s in &tr.steps {
                if s.exit == ExitPoint::Cloud {
                    if let Some(prev) = last_cloud_pos {
                        assert_eq!(s.cloud_catchup, s.pos - prev);
                    }
                    last_cloud_pos = Some(s.pos);
                }
            }
        }
    }

    #[test]
    fn timings_populated() {
        let (mut e, mut c) = setup(4);
        let mut t = CallTimings::default();
        let tr =
            record(&mut e, &mut c, ExitPolicy::Threshold(0.8), Precision::F32, "abc", 8, &mut t)
                .unwrap();
        assert_eq!(t.edge_prefill.len(), 1);
        assert_eq!(t.seg1.len(), tr.steps.len() - 1);
        assert!(t.seg2.len() <= tr.steps.len());
    }

    #[test]
    fn f16_trace_close_to_f32() {
        // with mock engines hiddens don't affect tokens, so traces match
        // exactly; the real-engine divergence test lives in rust/tests/
        let a = rec(ExitPolicy::Threshold(0.8), 6);
        let (mut e, mut c) = setup(6);
        let mut t = CallTimings::default();
        let b = record(&mut e, &mut c, ExitPolicy::Threshold(0.8), Precision::F16,
                       "hello world", 16, &mut t).unwrap();
        assert_eq!(a.tokens, b.tokens);
    }
}
