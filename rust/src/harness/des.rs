//! Discrete-event replay of recorded traces under a deployment strategy.
//!
//! Entities: per-client edge clock, per-client FIFO up/down links
//! ([`SimLink`]), and a cloud worker pool served FCFS per worker with
//! upload-dependency parking (`workers = 1` reproduces the paper's
//! testbed topology: N edge devices, one cloud inference GPU).  Compute
//! durations come from the calibrated [`CostModel`] (measured PJRT call
//! times); communication from the [`LinkProfile`].
//!
//! The same replay engine produces every row of Tables 2 and 4 and every
//! point of Figure 4: CE-CoLLM is a flag configuration, the baselines are
//! alternative strategies over the same traces (cloud-only and the naïve
//! split generate the θ=1.0 token sequence by construction, since both
//! run the full model).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::config::AblationFlags;
use crate::coordinator::policy::ExitPoint;
use crate::harness::cost::CostModel;
use crate::harness::trace::Trace;
use crate::metrics::{render_hist, CostBreakdown, HistSnapshot, LatencyHist, RunCounters};
use crate::model::manifest::ModelDims;
use crate::net::profiles::LinkProfile;
use crate::net::simulated::SimLink;
use crate::util::rng::Rng;

use crate::coordinator::protocol::{
    EVICTED_LEN, HELLO_LEN, INFER_REQ_LEN, TOKEN_RESP_LEN, UPLOAD_HDR_LEN,
};
use crate::net::codec::frame_wire_len;

/// Fixed wire sizes (codec frame prefix + exact message header bytes;
/// payloads added on top), derived from the protocol's encoded-length
/// constants through [`crate::net::codec::frame_wire_len`] — the same
/// arithmetic the live edge counters use, so simulated and measured
/// byte totals agree exactly.
const UPLOAD_HDR: usize = frame_wire_len(UPLOAD_HDR_LEN);
const REQ_BYTES: usize = frame_wire_len(INFER_REQ_LEN);
const RESP_BYTES: usize = frame_wire_len(TOKEN_RESP_LEN);
const EVICTED_BYTES: usize = frame_wire_len(EVICTED_LEN);
const HELLO_BYTES: usize = frame_wire_len(HELLO_LEN);
/// An `Ack` encodes to its tag byte alone.
const ACK_BYTES: usize = frame_wire_len(1);

/// Deployment strategy to replay.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Strategy {
    /// CE-CoLLM with the given ablation switches (paper §4, Table 4).
    CeCollm(AblationFlags),
    /// Edge standalone mode (paper §4.1) — replay of a standalone trace.
    Standalone,
    /// Cloud-based LLM deployment (paper Fig 1a): prompt up, full
    /// inference in the cloud, text down.
    CloudOnly,
    /// Naïve cloud-edge split (paper Fig 1b): per-token synchronous
    /// re-upload of the full fp32 hidden history, no content manager.
    NaiveSplit,
}

#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    pub strategy: Strategy,
    pub link: LinkProfile,
    pub seed: u64,
    /// Cloud scheduler worker pool size (paper testbed: 1 GPU).  Devices
    /// shard statically onto workers, mirroring the real scheduler's
    /// `device_id % workers` assignment.
    pub workers: usize,
    /// Model the scheduler's cross-device batched decode: every call
    /// queued on a worker that is ready when a pass starts joins that
    /// pass, which costs the *widest* call plus the batched marginal rate
    /// for each extra lane — instead of the calls running FCFS one after
    /// another.  `false` reproduces the pre-batching per-device serving
    /// law.
    pub cross_device_batch: bool,
    /// Model the cloud context store's memory budget
    /// (`CloudConfig::memory_budget_bytes`): per-client resident context
    /// — KV positions at [`ModelDims::cloud_kv_bytes_per_pos`] — is
    /// metered per worker (even `budget / workers` shares), and when a
    /// shard runs over, idle contexts are LRU-evicted.  A client whose
    /// context was evicted mid-request pays a full history re-upload
    /// plus a re-prefill on its next cloud call — extra bytes and time,
    /// never different tokens.  `None` disables the law (today's
    /// behaviour: zero evictions, zero extra uploads).
    pub memory_budget_bytes: Option<u64>,
    /// Model the store's idle TTL (`CloudConfig::session_ttl_s`):
    /// contexts untouched for this many simulated seconds are reaped
    /// when their worker next starts a pass.  Recovery is priced the
    /// same as a budget eviction.
    pub session_ttl_s: Option<f64>,
    /// Model link severs recovered by reconnect with session resume
    /// (`DeploymentConfig::reconnect`): every [`LinkFaultSim`]-selected
    /// cloud call first pays a reconnect — backoff delay, a fresh dual
    /// `Hello`/`Ack` handshake, the full-history replay the suspended
    /// cloud session needs, and a re-prefill on the cloud side.  Extra
    /// bytes and time, never different tokens.  `None` keeps the rng
    /// stream — and thus every cost — bit-identical to the no-fault law.
    pub link_fault: Option<LinkFaultSim>,
    /// Model the replicated cloud (`DeploymentConfig::replication`):
    /// the edge opens `replicas` warm-standby sessions up front
    /// (mirror-bit dual handshakes), fans every hidden-state upload
    /// out to each live standby — bytes on the standby channels,
    /// asynchronously, never generation time — and recovers each
    /// [`LinkFaultSim`] sever by *warm promotion* while standbys
    /// remain: no backoff, no re-handshake, no history replay, only
    /// the promoted mirror's cloud-side re-prefill (`failovers_warm`,
    /// `context_replays += 0`).  Once the standby budget is spent,
    /// severs fall back to the cold reconnect law (`failovers_cold`).
    /// `None` keeps the rng stream — and thus every cost —
    /// bit-identical to the pre-replication law.
    pub replication: Option<SimReplication>,
}

/// Warm-standby replication model for [`SimConfig::replication`],
/// mirroring [`crate::config::ReplicationConfig`]: a fixed standby
/// budget that shrinks by one per warm promotion and never refills
/// (replicas are a budget, not a pool).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimReplication {
    /// Warm standbys opened at session start.  `0` opens mirror
    /// handshakes for no one and recovers every sever cold.
    pub replicas: usize,
    /// Price a duplicated `InferRequest`/`TokenResponse` pair on the
    /// best standby's channel for every cloud call.  The live edge
    /// hedges only deadline-budgeted calls; the DES has no deadline to
    /// gate on, so it prices the upper bound.  Hedging costs standby
    /// bytes — never time, never different tokens.
    pub hedge: bool,
}

/// Deterministic sever schedule for [`SimConfig::link_fault`], mirroring
/// the frame-ordinal keying of the live fault injector
/// ([`crate::net::fault`]): faults land on fixed call ordinals, not on
/// sampled times, so two runs of the same config sever identically.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkFaultSim {
    /// Sever the link ahead of every n-th cloud call of each client
    /// (call numbers n, 2n, ...).  `0` never severs.
    pub sever_every: u64,
    /// Reconnect delay priced per sever (the policy's backoff sleep).
    pub reconnect_delay_s: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            strategy: Strategy::CeCollm(AblationFlags::default()),
            link: LinkProfile::wifi(),
            seed: 0,
            workers: 1,
            cross_device_batch: false,
            memory_budget_bytes: None,
            session_ttl_s: None,
            link_fault: None,
            replication: None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct ClientResult {
    pub cost: CostBreakdown,
    pub counters: RunCounters,
}

#[derive(Debug, Clone)]
pub struct SimOutcome {
    pub clients: Vec<ClientResult>,
    /// Finish time of the last client (total wall-clock of the run).
    pub makespan_s: f64,
    /// Total busy time summed over the cloud worker pool.
    pub cloud_busy_s: f64,
    /// Engine passes the pool executed.  Without cross-device batching
    /// this equals the number of cloud calls; with it, co-resident calls
    /// fuse and the count drops — the ratio is the batching win.
    pub cloud_passes: u64,
    /// Contexts evicted by memory-budget pressure (LRU).
    pub cloud_evictions: u64,
    /// Contexts reaped by the idle TTL.
    pub cloud_ttl_reaps: u64,
    /// Mid-request evictions recovered by a priced history replay.
    pub cloud_replays: u64,
    /// Simulated-clock latency distributions, priced in the same units
    /// and bucket grid as the live registry's families so simulated and
    /// measured percentiles compare directly: upload-dependency park
    /// per parked call (`ce_sched_park_wait_ns`), worker-queue wait per
    /// call (`ce_sched_queue_wait_ns`), engine-pass duration per pass
    /// (`ce_sched_batch_pass_ns`), and the edge-observed cloud round
    /// trip per call (`ce_edge_cloud_rtt_ns`).
    pub hist_park_wait: HistSnapshot,
    pub hist_queue_wait: HistSnapshot,
    pub hist_pass: HistSnapshot,
    pub hist_rtt: HistSnapshot,
}

impl SimOutcome {
    /// Render the simulated distributions in the exact exposition
    /// schema the live `GET /metrics` scrape uses, so a simulated and a
    /// measured snapshot diff family-for-family.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, snap) in [
            ("ce_sched_park_wait_ns", &self.hist_park_wait),
            ("ce_sched_queue_wait_ns", &self.hist_queue_wait),
            ("ce_sched_batch_pass_ns", &self.hist_pass),
            ("ce_edge_cloud_rtt_ns", &self.hist_rtt),
        ] {
            out.push_str(&format!("# TYPE {name} histogram\n"));
            out.push_str(&render_hist(name, "", snap));
        }
        out
    }

    /// Sum of per-client breakdowns (the paper's Table 2 reports the
    /// cumulative cost over all cases of a single client).
    pub fn summed(&self) -> (CostBreakdown, RunCounters) {
        let mut cost = CostBreakdown::default();
        let mut counters = RunCounters::default();
        for c in &self.clients {
            cost.add(&c.cost);
            counters.add(&c.counters);
        }
        cost.total_s = self.makespan_s;
        (cost, counters)
    }
}

/// A pending cloud request from one client.
struct CloudCall {
    client: usize,
    /// When the edge handed the request to its uplink — the start of
    /// the round trip the edge-side RTT histogram prices.
    sent_s: f64,
    arrive_s: f64,
    /// When the uploads this request depends on have all arrived.
    ready_s: f64,
    busy_s: f64,
    /// Decode lanes this call puts into a padded pass (its coalesced
    /// catch-up count) — sizes the batched marginal cost when the call
    /// rides along in another call's pass.
    items: usize,
    resp_bytes: usize,
    /// Token position the call answers — sizes the resident KV context
    /// after the pass, and the history replay if the context was lost.
    pos: usize,
    /// This call prefills the cloud anyway (first cloud step of its
    /// request), so a lost context costs it nothing extra.
    prefills: bool,
    /// Bytes of a full-history re-upload, if an eviction must be
    /// recovered before this call (0 when the law is off or the
    /// strategy retains no cloud context).
    replay_bytes: usize,
    /// Re-prefill seconds a recovery adds to this call's busy time
    /// (pre-sampled so the rng stream stays deterministic per config).
    replay_prefill_s: f64,
}

struct HeapEntry {
    arrive_s: f64,
    client: usize,
    /// Guards against stale entries: a call co-served by an earlier
    /// batched pass leaves its heap entry behind; the sequence number
    /// tells it apart from the client's next call.
    seq: u64,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.arrive_s == other.arrive_s && self.client == other.client && self.seq == other.seq
    }
}
impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap by arrival time (FCFS), tie-break by client id, then
        // seq — the full field set, keeping Ord consistent with Eq
        other
            .arrive_s
            .total_cmp(&self.arrive_s)
            .then_with(|| other.client.cmp(&self.client))
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Per-client replay state machine.
struct ClientSim<'a> {
    id: usize,
    traces: &'a [Trace],
    strategy: Strategy,
    d_model: usize,
    cost_model: &'a CostModel,
    rng: Rng,
    uplink: SimLink,
    downlink: SimLink,

    req_idx: usize,
    step_idx: usize,
    edge_t: f64,
    /// Arrival time of the newest upload the cloud may need.
    upload_ready: f64,
    /// Price context-store evictions: each cloud call pre-samples its
    /// would-be recovery cost (replay upload + re-prefill).  Off when
    /// the sim has no budget/TTL, keeping the rng stream — and thus
    /// every cost — bit-identical to the pre-store law.
    price_replay: bool,
    /// Sever schedule ([`SimConfig::link_fault`]); `None` prices nothing.
    link_fault: Option<LinkFaultSim>,
    /// Replication model ([`SimConfig::replication`]); `None` prices
    /// nothing and keeps the rng stream bit-identical to the legacy law.
    replication: Option<SimReplication>,
    /// Warm standbys still open — each promotion spends one (the set
    /// shrinks; it never refills).
    standbys_left: usize,
    /// Cloud calls issued so far — the ordinal the sever schedule keys on.
    cloud_calls: u64,
    /// Pending (not yet cloud-requested) call produced by `advance`.
    cost: CostBreakdown,
    counters: RunCounters,
    done: bool,
}

impl<'a> ClientSim<'a> {
    #[allow(clippy::too_many_arguments)]
    fn new(
        id: usize,
        traces: &'a [Trace],
        strategy: Strategy,
        dims: &ModelDims,
        cost_model: &'a CostModel,
        link: LinkProfile,
        seed: u64,
        price_replay: bool,
        link_fault: Option<LinkFaultSim>,
        replication: Option<SimReplication>,
    ) -> Self {
        // only CE-CoLLM holds persistent cloud sessions worth
        // mirroring; the baselines are stateless per call
        let standbys = match (replication, strategy) {
            (Some(r), Strategy::CeCollm(_)) => r.replicas,
            _ => 0,
        };
        let mut counters = RunCounters::default();
        // the dual-channel mirror handshakes that open each standby
        // session up front ride the standby channels, not the primary
        counters.bytes_mirrored += (standbys * 2 * (HELLO_BYTES + ACK_BYTES)) as u64;
        Self {
            id,
            traces,
            strategy,
            d_model: dims.d_model,
            cost_model,
            rng: Rng::seed_from_u64(seed ^ (id as u64).wrapping_mul(0x9E3779B9)),
            uplink: SimLink::new(link),
            downlink: SimLink::new(link),
            req_idx: 0,
            step_idx: 0,
            edge_t: 0.0,
            upload_ready: 0.0,
            price_replay,
            link_fault,
            replication,
            standbys_left: standbys,
            cloud_calls: 0,
            cost: CostBreakdown::default(),
            counters,
            done: false,
        }
    }

    /// Price the asynchronous fan-out of a hidden-state upload to every
    /// live warm standby ([`SimConfig::replication`]): the bytes ride
    /// the standbys' own uploader threads, off the generation critical
    /// path, so mirroring costs bytes — never time.  A no-op with no
    /// replication or once the standby budget is spent.
    fn mirror_hidden(&mut self, bytes: usize) {
        self.counters.bytes_mirrored += (bytes * self.standbys_left) as u64;
    }

    fn flags(&self) -> AblationFlags {
        match self.strategy {
            Strategy::CeCollm(f) => f,
            _ => AblationFlags::default(),
        }
    }

    fn esz(&self) -> usize {
        if self.flags().half_precision {
            2
        } else {
            4
        }
    }

    fn hidden_bytes(&self, positions: usize) -> usize {
        UPLOAD_HDR + positions * self.d_model * self.esz()
    }

    /// Run edge-local work until the next cloud call or completion.
    fn advance(&mut self) -> Option<CloudCall> {
        match self.strategy {
            Strategy::Standalone => {
                self.run_standalone();
                None
            }
            Strategy::CloudOnly => self.advance_cloud_only(),
            Strategy::NaiveSplit => self.advance_naive(),
            Strategy::CeCollm(_) => self.advance_ce(),
        }
    }

    // --- standalone: pure edge, no events --------------------------------
    fn run_standalone(&mut self) {
        for tr in self.traces {
            let d = self.cost_model.sample_edge_prefill(&mut self.rng);
            self.edge_t += d;
            self.cost.edge_s += d;
            for (i, step) in tr.steps.iter().enumerate() {
                if i > 0 {
                    let d = self.cost_model.sample_seg1(&mut self.rng);
                    self.edge_t += d;
                    self.cost.edge_s += d;
                    if step.conf2.is_some() {
                        let d = self.cost_model.sample_seg2(&mut self.rng);
                        self.edge_t += d;
                        self.cost.edge_s += d;
                    }
                }
                match step.exit {
                    ExitPoint::Exit1 => self.counters.tokens_exit1 += 1,
                    _ => self.counters.tokens_exit2 += 1,
                }
                self.counters.tokens_generated += 1;
            }
        }
        self.cost.total_s = self.edge_t;
        self.done = true;
    }

    // --- cloud-only baseline ----------------------------------------------
    fn advance_cloud_only(&mut self) -> Option<CloudCall> {
        if self.req_idx >= self.traces.len() {
            self.finish();
            return None;
        }
        let tr = &self.traces[self.req_idx];
        // API request: the prompt text itself
        let up_bytes = UPLOAD_HDR + tr.prompt_len;
        let arrive = self.uplink.transfer(self.edge_t, up_bytes);
        self.counters.bytes_up += up_bytes as u64;
        self.counters.cloud_requests += 1;
        self.cost.comm_s += arrive - self.edge_t;
        let mut busy = self.cost_model.sample_full_prefill(&mut self.rng);
        for _ in 1..tr.steps.len() {
            busy += self.cost_model.sample_full_decode(&mut self.rng);
        }
        self.counters.tokens_generated += tr.steps.len();
        self.counters.tokens_cloud += tr.steps.len();
        Some(CloudCall {
            client: self.id,
            sent_s: self.edge_t,
            arrive_s: arrive,
            ready_s: arrive,
            busy_s: busy,
            items: tr.steps.len(),
            resp_bytes: UPLOAD_HDR + tr.tokens.len(),
            pos: 0,
            prefills: true,
            replay_bytes: 0,
            replay_prefill_s: 0.0,
        })
    }

    // --- naïve split baseline ----------------------------------------------
    fn advance_naive(&mut self) -> Option<CloudCall> {
        loop {
            if self.req_idx >= self.traces.len() {
                self.finish();
                return None;
            }
            let tr = &self.traces[self.req_idx];
            if self.step_idx >= tr.steps.len() {
                self.req_idx += 1;
                self.step_idx = 0;
                continue;
            }
            let pos = tr.steps[self.step_idx].pos;
            let first = self.step_idx == 0;
            if first {
                // edge runs only layers 0..l_ee1 over the prompt
                let share = self.cost_model.seg1.mean_s
                    / (self.cost_model.seg1.mean_s + self.cost_model.seg2.mean_s).max(1e-12);
                let d = self.cost_model.sample_edge_prefill(&mut self.rng) * share;
                self.edge_t += d;
                self.cost.edge_s += d;
            } else {
                let d = self.cost_model.sample_seg1(&mut self.rng);
                self.edge_t += d;
                self.cost.edge_s += d;
            }
            // synchronous re-upload of the ENTIRE fp32 history (no content
            // manager, Fig 1b)
            let bytes = UPLOAD_HDR + (pos + 1) * self.d_model * 4;
            let arrived = self.uplink.transfer(self.edge_t, bytes);
            self.counters.bytes_up += bytes as u64;
            self.cost.comm_s += arrived - self.edge_t;
            self.edge_t = arrived;
            // request rides behind the upload
            let req_arrive = self.uplink.transfer(self.edge_t, REQ_BYTES);
            self.counters.bytes_up += REQ_BYTES as u64;
            self.cost.comm_s += req_arrive - self.edge_t;
            self.counters.cloud_requests += 1;
            self.counters.tokens_cloud += 1;
            self.counters.tokens_generated += 1;
            let mut busy = self.cost_model.sample_cloud_decode(&mut self.rng);
            if first {
                busy = self.cost_model.sample_cloud_prefill(&mut self.rng);
            }
            return Some(CloudCall {
                client: self.id,
                sent_s: self.edge_t,
                arrive_s: req_arrive,
                ready_s: req_arrive,
                busy_s: busy,
                items: 1,
                resp_bytes: RESP_BYTES,
                // the naïve split retransmits everything anyway: no
                // retained cloud context, nothing to evict
                pos: 0,
                prefills: first,
                replay_bytes: 0,
                replay_prefill_s: 0.0,
            });
        }
    }

    // --- CE-CoLLM ------------------------------------------------------------
    fn advance_ce(&mut self) -> Option<CloudCall> {
        let flags = self.flags();
        loop {
            if self.req_idx >= self.traces.len() {
                self.finish();
                return None;
            }
            let tr = &self.traces[self.req_idx];
            if self.step_idx >= tr.steps.len() {
                self.req_idx += 1;
                self.step_idx = 0;
                continue;
            }

            if self.step_idx == 0 {
                // prefill + parallel prompt upload
                let d = self.cost_model.sample_edge_prefill(&mut self.rng);
                self.edge_t += d;
                self.cost.edge_s += d;
                self.upload_ready = 0.0;
                if flags.parallel_upload && flags.content_manager {
                    let bytes = self.hidden_bytes(tr.prompt_len);
                    self.upload_ready = self.uplink.transfer(self.edge_t, bytes);
                    self.counters.bytes_up += bytes as u64;
                    self.mirror_hidden(bytes);
                }
            }

            let step = &tr.steps[self.step_idx];
            if self.step_idx > 0 {
                let d = self.cost_model.sample_seg1(&mut self.rng);
                self.edge_t += d;
                self.cost.edge_s += d;
                if flags.parallel_upload && flags.content_manager {
                    let bytes = self.hidden_bytes(1);
                    self.upload_ready = self.uplink.transfer(self.edge_t, bytes);
                    self.counters.bytes_up += bytes as u64;
                    self.mirror_hidden(bytes);
                }
                if step.conf2.is_some() {
                    let d = self.cost_model.sample_seg2(&mut self.rng);
                    self.edge_t += d;
                    self.cost.edge_s += d;
                }
            }

            self.counters.tokens_generated += 1;
            match step.exit {
                ExitPoint::Exit1 => {
                    self.counters.tokens_exit1 += 1;
                    self.step_idx += 1;
                    continue;
                }
                ExitPoint::Exit2 => {
                    self.counters.tokens_exit2 += 1;
                    self.step_idx += 1;
                    continue;
                }
                ExitPoint::Cloud => {
                    self.counters.tokens_cloud += 1;
                    self.counters.cloud_requests += 1;
                    self.cloud_calls += 1;
                    // scheduled link sever: recovery walks the
                    // degradation ladder.  While a warm standby remains,
                    // promote it — an already-open session whose mirrored
                    // coverage spans the watermark: no backoff, no
                    // re-handshake, no replay bytes, zero context
                    // replays; the promoted mirror holds hidden state
                    // but no KV, so the pass below re-prefills on the
                    // cloud side.  Otherwise the edge reconnects with
                    // session resume — backoff, dual re-Hello/Ack, then
                    // the full-history replay the suspended cloud
                    // session needs (the same bytes the live edge's
                    // reconnect path sends).  Counted as a reconnect,
                    // NOT a context replay.
                    let severed = self.link_fault.is_some_and(|f| {
                        f.sever_every > 0 && self.cloud_calls % f.sever_every == 0
                    });
                    let mut resume_prefill_s = 0.0;
                    if severed && self.standbys_left > 0 {
                        self.standbys_left -= 1;
                        self.counters.failovers_warm += 1;
                        resume_prefill_s = self.cost_model.sample_cloud_prefill(&mut self.rng);
                    } else if severed {
                        let f = self.link_fault.expect("checked above");
                        let t0 = self.edge_t;
                        self.edge_t += f.reconnect_delay_s.max(0.0);
                        let hello_at = self.uplink.transfer(self.edge_t, 2 * HELLO_BYTES);
                        self.counters.bytes_up += 2 * HELLO_BYTES as u64;
                        let ack_at = self.downlink.transfer(hello_at, 2 * ACK_BYTES);
                        self.counters.bytes_down += 2 * ACK_BYTES as u64;
                        let replay_bytes = self.hidden_bytes(step.pos + 1);
                        let replay_at = self.uplink.transfer(ack_at, replay_bytes);
                        self.counters.bytes_up += replay_bytes as u64;
                        self.edge_t = replay_at;
                        self.cost.comm_s += replay_at - t0;
                        self.counters.reconnects += 1;
                        if self.replication.is_some() {
                            self.counters.failovers_cold += 1;
                        }
                        resume_prefill_s = self.cost_model.sample_cloud_prefill(&mut self.rng);
                    }
                    let mut ready = self.upload_ready;
                    if !flags.content_manager {
                        // synchronous full-history retransmission
                        let bytes = self.hidden_bytes(step.pos + 1);
                        let arrived = self.uplink.transfer(self.edge_t, bytes);
                        self.counters.bytes_up += bytes as u64;
                        self.mirror_hidden(bytes);
                        self.cost.comm_s += arrived - self.edge_t;
                        self.edge_t = arrived;
                        ready = arrived;
                    } else if !flags.parallel_upload {
                        // synchronous upload of positions since last request
                        let mut unsent = step.cloud_catchup
                            + if step.cloud_prefill { tr.prompt_len } else { 0 };
                        if unsent == 0 {
                            unsent = 1;
                        }
                        let bytes = self.hidden_bytes(unsent);
                        let arrived = self.uplink.transfer(self.edge_t, bytes);
                        self.counters.bytes_up += bytes as u64;
                        self.mirror_hidden(bytes);
                        self.cost.comm_s += arrived - self.edge_t;
                        self.edge_t = arrived;
                        ready = arrived;
                    }
                    // hedged infer (ladder rung 1): duplicate the
                    // request to the best standby; the loser's echo is
                    // fenced by the stale-response skip, so hedging
                    // costs standby-channel bytes, never time or tokens
                    if self.replication.is_some_and(|r| r.hedge) && self.standbys_left > 0 {
                        self.counters.hedged_requests += 1;
                        self.counters.bytes_mirrored += (REQ_BYTES + RESP_BYTES) as u64;
                    }
                    let sent_s = self.edge_t;
                    let req_arrive = self.uplink.transfer(self.edge_t, REQ_BYTES);
                    self.counters.bytes_up += REQ_BYTES as u64;
                    self.cost.comm_s += req_arrive - self.edge_t;
                    // waiting for a still-in-flight upload is comm time
                    self.cost.comm_s += (ready - req_arrive).max(0.0);

                    let mut busy = resume_prefill_s;
                    if step.cloud_prefill {
                        busy += self.cost_model.sample_cloud_prefill(&mut self.rng);
                        if step.cloud_catchup > 0 {
                            busy += self
                                .cost_model
                                .sample_cloud_request(step.cloud_catchup, &mut self.rng);
                        }
                    } else {
                        // batched catch-up (paper: one forward over all
                        // pending positions; cloud time ∝ request count)
                        busy += self
                            .cost_model
                            .sample_cloud_request(step.cloud_catchup.max(1), &mut self.rng);
                    }
                    // recovery cost of a context-store eviction hitting
                    // this call: full-history re-upload + re-prefill
                    // (pre-sampled; only priced if the eviction happens)
                    let price = self.price_replay && flags.content_manager;
                    let replay_bytes = if price { self.hidden_bytes(step.pos + 1) } else { 0 };
                    let replay_prefill_s = if price {
                        self.cost_model.sample_cloud_prefill(&mut self.rng)
                    } else {
                        0.0
                    };
                    return Some(CloudCall {
                        client: self.id,
                        sent_s,
                        arrive_s: req_arrive,
                        ready_s: ready.max(req_arrive),
                        busy_s: busy,
                        items: step.cloud_catchup.max(1),
                        resp_bytes: RESP_BYTES,
                        pos: step.pos,
                        prefills: step.cloud_prefill,
                        replay_bytes,
                        replay_prefill_s,
                    });
                }
            }
        }
    }

    /// Scheduler callback: the cloud answered at `resp_start` after
    /// `busy_s` of compute; response transfer completes the round trip.
    /// Returns when the response reached the edge (the end of the round
    /// trip the RTT histogram prices).
    fn resume(&mut self, cloud_done: f64, busy_s: f64, resp_bytes: usize) -> f64 {
        let resp_arrive = self.downlink.transfer(cloud_done, resp_bytes);
        self.counters.bytes_down += resp_bytes as u64;
        self.cost.cloud_s += busy_s;
        self.cost.comm_s += resp_arrive - cloud_done;
        self.edge_t = resp_arrive.max(self.edge_t);
        self.step_idx += 1;
        if matches!(self.strategy, Strategy::CloudOnly) {
            // one call covered the whole request
            self.req_idx += 1;
            self.step_idx = 0;
        }
        resp_arrive
    }

    fn finish(&mut self) {
        self.cost.total_s = self.edge_t;
        self.done = true;
    }
}

/// Per-client cloud context the eviction law tracks (the real store's
/// resident gauge + LRU clock, one entry per client).
#[derive(Clone, Copy, Default)]
struct SimCtx {
    resident_bytes: u64,
    last_touch_s: f64,
    alive: bool,
}

/// Replay `traces_per_client` under `cfg`.  The cloud is a pool of
/// `cfg.workers` engines (1 = the paper's single GPU); each client's
/// requests run FCFS on its statically assigned worker, and a request
/// whose uploads are still in flight parks until `ready_s` — the same
/// dependency rule the real scheduler enforces.
///
/// With `memory_budget_bytes`/`session_ttl_s` set, the context store's
/// law runs on top: per-client resident KV context is metered against an
/// even per-worker budget share, idle contexts are LRU-evicted (or
/// TTL-reaped as a worker's clock passes their deadline), and a client
/// whose context was lost mid-request pays a full-history re-upload plus
/// a re-prefill before its next call serves — more bytes and time, the
/// same tokens.  A context is implicitly released when its client's next
/// request prefills (the DES replays requests back-to-back, so this
/// coincides with the real `EndSession` release up to the think-time the
/// traces do not model).
pub fn simulate(
    traces_per_client: &[Vec<Trace>],
    dims: &ModelDims,
    cost_model: &CostModel,
    cfg: &SimConfig,
) -> SimOutcome {
    let price_replay = cfg.memory_budget_bytes.is_some() || cfg.session_ttl_s.is_some();
    let mut clients: Vec<ClientSim> = traces_per_client
        .iter()
        .enumerate()
        .map(|(i, t)| {
            ClientSim::new(
                i,
                t,
                cfg.strategy,
                dims,
                cost_model,
                cfg.link,
                cfg.seed,
                price_replay,
                cfg.link_fault,
                cfg.replication,
            )
        })
        .collect();

    let mut heap: BinaryHeap<HeapEntry> = BinaryHeap::new();
    let mut pending: Vec<Option<(u64, CloudCall)>> = Vec::with_capacity(clients.len());
    let mut seq = 0u64;
    for c in clients.iter_mut() {
        let call = c.advance();
        if let Some(call) = call {
            seq += 1;
            heap.push(HeapEntry { arrive_s: call.arrive_s, client: call.client, seq });
            pending.push(Some((seq, call)));
        } else {
            pending.push(None);
        }
    }

    let workers = cfg.workers.max(1);
    let marginal_s = cost_model.cloud_batch_marginal.mean_s;
    let kv_per_pos = dims.cloud_kv_bytes_per_pos() as u64;
    let budget_share = cfg.memory_budget_bytes.map(|b| (b / workers as u64).max(1));
    // only CE-CoLLM keeps per-device cloud context between calls; the
    // baselines are stateless per call, so the law is a no-op for them
    let track_ctx = price_replay && matches!(cfg.strategy, Strategy::CeCollm(_));
    let mut ctx: Vec<SimCtx> = vec![SimCtx::default(); clients.len()];
    let mut cloud_evictions = 0u64;
    let mut cloud_ttl_reaps = 0u64;
    let mut cloud_replays = 0u64;
    let mut worker_free = vec![0.0f64; workers];
    let mut cloud_busy_total = 0.0f64;
    let mut cloud_passes = 0u64;
    // simulated-clock counterparts of the live instrumented sites;
    // priced at serve time from the event times the law already tracks
    let hist_park_wait = LatencyHist::new();
    let hist_queue_wait = LatencyHist::new();
    let hist_pass = LatencyHist::new();
    let hist_rtt = LatencyHist::new();
    let s_to_ns = |s: f64| (s.max(0.0) * 1e9) as u64;
    while let Some(entry) = heap.pop() {
        // skip stale entries (their call was co-served by an earlier pass)
        match &pending[entry.client] {
            Some((s, _)) if *s == entry.seq => {}
            _ => continue,
        }
        let (_, mut call) = pending[entry.client].take().expect("pending call");
        let w = call.client % workers;
        let mut start = worker_free[w].max(call.arrive_s).max(call.ready_s);

        // TTL reap: as this worker's clock reaches `start`, contexts
        // idle past the TTL are gone (same sweep the real worker runs
        // between passes).
        if let Some(ttl) = cfg.session_ttl_s {
            for (j, c) in ctx.iter_mut().enumerate() {
                if j % workers == w && c.alive && start - c.last_touch_s > ttl {
                    c.alive = false;
                    c.resident_bytes = 0;
                    cloud_ttl_reaps += 1;
                }
            }
        }

        // Eviction recovery: a mid-request call whose context was lost
        // pays the full SessionEvicted round trip — the edge only
        // *discovers* the eviction when the worker picks the call up and
        // bounces it (at `start`, not at the call's arrival), then the
        // notice travels down, the full history replays up, and the
        // re-issued request rides behind it; the pass re-prefills on top.
        if call.replay_bytes > 0 && !call.prefills && !ctx[call.client].alive {
            let c = &mut clients[call.client];
            let notice_at = c.downlink.transfer(start, EVICTED_BYTES);
            c.counters.bytes_down += EVICTED_BYTES as u64;
            let replay_done = c.uplink.transfer(notice_at, call.replay_bytes);
            c.counters.bytes_up += call.replay_bytes as u64;
            c.mirror_hidden(call.replay_bytes);
            let rerequest_at = c.uplink.transfer(replay_done, REQ_BYTES);
            c.counters.bytes_up += REQ_BYTES as u64;
            c.counters.context_replays += 1;
            c.cost.comm_s += rerequest_at - start;
            call.ready_s = call.ready_s.max(rerequest_at);
            call.busy_s += call.replay_prefill_s;
            cloud_replays += 1;
            start = worker_free[w].max(call.arrive_s).max(call.ready_s);
        }

        // Cross-device batching (the real scheduler's padded pass): every
        // other call queued on this worker that is ready by `start` joins
        // the pass instead of waiting its FCFS turn.  A call that must
        // first recover an evicted context never rides along — it pays
        // its replay as its own pass head later.
        let mut calls = vec![call];
        if cfg.cross_device_batch {
            for (j, slot) in pending.iter_mut().enumerate() {
                if j == entry.client || j % workers != w {
                    continue;
                }
                let joins = matches!(
                    slot,
                    Some((_, c)) if c.arrive_s <= start
                        && c.ready_s <= start
                        && (c.replay_bytes == 0 || c.prefills || ctx[j].alive)
                );
                if joins {
                    calls.push(slot.take().expect("matched above").1);
                }
            }
        }

        // The padded pass costs its widest lane; every extra lane rides
        // along at the batched marginal rate (paper §4.3: per-token
        // overheads, not model math, dominate — fusing passes removes
        // them).  A batch of one degenerates to exactly the old FCFS law.
        let widest_idx = (0..calls.len())
            .max_by(|&a, &b| calls[a].busy_s.total_cmp(&calls[b].busy_s))
            .expect("non-empty pass");
        let extra_items: usize = calls
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != widest_idx)
            .map(|(_, c)| c.items)
            .sum();
        let busy_pass = calls[widest_idx].busy_s + marginal_s * extra_items as f64;
        let done = start + busy_pass;
        worker_free[w] = done;
        cloud_busy_total += busy_pass;
        cloud_passes += 1;
        hist_pass.record(s_to_ns(busy_pass));
        let pass_clients: Vec<usize> = calls.iter().map(|c| c.client).collect();
        for call in calls {
            // the served context is resident and MRU (the real store's
            // post-pass state: pending drained into pos+1 KV positions)
            if track_ctx && (call.replay_bytes > 0 || call.prefills) {
                ctx[call.client] = SimCtx {
                    resident_bytes: kv_per_pos * (call.pos + 1) as u64,
                    last_touch_s: done,
                    alive: true,
                };
            }
            // the park site mirrors the live scheduler's: only a call
            // whose uploads lagged its request actually parked
            if call.ready_s > call.arrive_s {
                hist_park_wait.record(s_to_ns(call.ready_s - call.arrive_s));
            }
            hist_queue_wait.record(s_to_ns(start - call.ready_s.max(call.arrive_s)));
            let c = &mut clients[call.client];
            // the whole pass is attributed to every call it answered,
            // matching the real scheduler's compute_s accounting
            let resp_arrive = c.resume(done, busy_pass, call.resp_bytes);
            hist_rtt.record(s_to_ns(resp_arrive - call.sent_s));
            if let Some(next) = c.advance() {
                seq += 1;
                heap.push(HeapEntry { arrive_s: next.arrive_s, client: next.client, seq });
                pending[call.client] = Some((seq, next));
            }
        }

        // Budget enforcement between passes: LRU-evict idle contexts on
        // this worker until its shard fits.  Clients of the pass that
        // just ran are never the victim (they are MRU, and the real
        // sweep protects the devices it is about to serve again).
        if let Some(share) = budget_share {
            loop {
                let used: u64 = ctx
                    .iter()
                    .enumerate()
                    .filter(|(j, c)| j % workers == w && c.alive)
                    .map(|(_, c)| c.resident_bytes)
                    .sum();
                if used <= share {
                    break;
                }
                let victim = ctx
                    .iter()
                    .enumerate()
                    .filter(|(j, c)| {
                        *j % workers == w && c.alive && !pass_clients.contains(j)
                    })
                    .min_by(|(_, a), (_, b)| a.last_touch_s.total_cmp(&b.last_touch_s))
                    .map(|(j, _)| j);
                let Some(victim) = victim else { break };
                ctx[victim].alive = false;
                ctx[victim].resident_bytes = 0;
                cloud_evictions += 1;
            }
        }
    }

    let mut out = SimOutcome {
        clients: Vec::with_capacity(clients.len()),
        makespan_s: 0.0,
        cloud_busy_s: cloud_busy_total,
        cloud_passes,
        cloud_evictions,
        cloud_ttl_reaps,
        cloud_replays,
        hist_park_wait: hist_park_wait.snapshot(),
        hist_queue_wait: hist_queue_wait.snapshot(),
        hist_pass: hist_pass.snapshot(),
        hist_rtt: hist_rtt.snapshot(),
    };
    for c in clients {
        debug_assert!(c.done);
        out.makespan_s = out.makespan_s.max(c.cost.total_s);
        out.clients.push(ClientResult { cost: c.cost, counters: c.counters });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::ExitPoint;
    use crate::harness::trace::TraceStep;
    use crate::model::manifest::test_manifest;

    /// Build a synthetic trace: exits chosen by a repeating pattern.
    /// Catch-up counts follow the content-manager semantics: the first
    /// cloud request prefills the prompt and decodes positions
    /// `prompt_len ..= pos`; later requests decode everything since the
    /// previous request.
    fn mk_trace(prompt_len: usize, pattern: &[ExitPoint]) -> Trace {
        let mut steps = Vec::new();
        let mut prefilled = false;
        let mut consumed_upto = prompt_len; // cm.consumed_upto after prefill
        for (i, &exit) in pattern.iter().enumerate() {
            let pos = prompt_len - 1 + i;
            let (catchup, cp) = if exit == ExitPoint::Cloud {
                let did_prefill = !prefilled;
                prefilled = true;
                let catch = (pos + 1).saturating_sub(consumed_upto);
                consumed_upto = pos + 1;
                (catch, did_prefill)
            } else {
                (0, false)
            };
            steps.push(TraceStep {
                pos,
                token: 97,
                exit,
                conf1: 0.5,
                conf2: if exit == ExitPoint::Exit1 { None } else { Some(0.6) },
                tok1: 97,
                tok2: if exit == ExitPoint::Exit1 { None } else { Some(97) },
                cloud_conf: if exit == ExitPoint::Cloud { Some(0.9) } else { None },
                cloud_catchup: catchup,
                cloud_prefill: cp,
            });
        }
        Trace {
            prompt_len,
            tokens: vec![97; pattern.len()],
            text: "a".repeat(pattern.len()),
            steps,
        }
    }

    fn dims() -> crate::model::manifest::ModelDims {
        test_manifest().model
    }

    fn cost() -> CostModel {
        CostModel::synthetic(&dims())
    }

    fn cfg(strategy: Strategy) -> SimConfig {
        SimConfig {
            strategy,
            link: LinkProfile::wifi(),
            seed: 7,
            workers: 1,
            cross_device_batch: false,
            ..Default::default()
        }
    }

    use ExitPoint::*;

    #[test]
    fn standalone_has_no_cloud_or_comm() {
        let traces = vec![vec![mk_trace(10, &[Exit1, Exit2, Exit1, Exit2])]];
        let out = simulate(&traces, &dims(), &cost(), &cfg(Strategy::Standalone));
        let (c, k) = out.summed();
        assert_eq!(c.cloud_s, 0.0);
        assert_eq!(c.comm_s, 0.0);
        assert!(c.edge_s > 0.0);
        assert_eq!(k.tokens_cloud, 0);
        assert_eq!(k.transmitted_mb(), 0.0);
    }

    #[test]
    fn ce_collm_cheaper_than_cloud_only_and_naive() {
        // the paper's headline shape at θ=0.8-ish exit rates
        let pattern = [Cloud, Exit1, Exit2, Exit1, Cloud, Exit1, Exit2, Exit1];
        let traces = vec![vec![mk_trace(20, &pattern); 5]];
        let ce = simulate(&traces, &dims(), &cost(), &cfg(Strategy::CeCollm(AblationFlags::default())));
        let cl = simulate(&traces, &dims(), &cost(), &cfg(Strategy::CloudOnly));
        let nv = simulate(&traces, &dims(), &cost(), &cfg(Strategy::NaiveSplit));
        let (ce_c, ce_k) = ce.summed();
        let (cl_c, _) = cl.summed();
        let (nv_c, nv_k) = nv.summed();
        // naive is dominated by comm and much slower than everything
        assert!(nv_c.total_s > 2.0 * cl_c.total_s, "naive {} vs cloud {}", nv_c.total_s, cl_c.total_s);
        assert!(nv_c.comm_s > nv_c.cloud_s);
        // CE-CoLLM reduces cloud compute vs cloud-only
        assert!(ce_c.cloud_s < 0.6 * cl_c.cloud_s);
        // and transmits far less than naive
        assert!(nv_k.bytes_up > 10 * ce_k.bytes_up);
    }

    #[test]
    fn without_cm_explodes_comm() {
        // serialization-dominated regime (the paper's): long prompt, many
        // cloud round trips, paper-scaled bandwidth
        let pattern = [Cloud, Exit1, Cloud, Exit1, Cloud, Exit2, Cloud, Exit1,
                       Cloud, Exit1, Cloud, Exit2, Cloud, Exit1, Cloud, Exit1];
        let traces = vec![vec![mk_trace(150, &pattern); 3]];
        let link = LinkProfile::paper_scaled();
        let scfg = |s| SimConfig {
            strategy: s,
            link,
            seed: 7,
            workers: 1,
            cross_device_batch: false,
            ..Default::default()
        };
        let full = simulate(&traces, &dims(), &cost(),
                            &scfg(Strategy::CeCollm(AblationFlags::default())));
        let nocm = simulate(&traces, &dims(), &cost(),
                            &scfg(Strategy::CeCollm(AblationFlags::without_cm_and_parallel_upload())));
        let (f, fk) = full.summed();
        let (n, nk) = nocm.summed();
        assert!(n.comm_s > 3.0 * f.comm_s, "no-CM comm {} vs {}", n.comm_s, f.comm_s);
        assert!(nk.bytes_up > 3 * fk.bytes_up);
        // cloud compute is unchanged (manager dedups, KV retained)
        assert!((n.cloud_s - f.cloud_s).abs() / f.cloud_s < 0.2);
    }

    #[test]
    fn fp32_transmits_twice_the_hidden_bytes() {
        let pattern = [Cloud, Exit1, Exit2, Cloud];
        let traces = vec![vec![mk_trace(10, &pattern)]];
        let f16 = simulate(&traces, &dims(), &cost(),
                           &cfg(Strategy::CeCollm(AblationFlags::default())));
        let f32_ = simulate(&traces, &dims(), &cost(),
                            &cfg(Strategy::CeCollm(AblationFlags::without_half_precision())));
        let up16 = f16.summed().1.bytes_up;
        let up32 = f32_.summed().1.bytes_up;
        assert!(up32 > up16 && up32 < 2 * up16 + 2000, "{up16} vs {up32}");
    }

    #[test]
    fn multi_client_scaling_shapes() {
        // cloud-only: total grows ~linearly with clients (GPU saturates);
        // CE-CoLLM: edge time per client constant, total grows slower
        let pattern = [Cloud, Exit1, Exit2, Exit1, Exit1, Exit2, Exit1, Exit1];
        let one: Vec<Vec<Trace>> = vec![vec![mk_trace(20, &pattern); 4]];
        let five: Vec<Vec<Trace>> = (0..5).map(|_| vec![mk_trace(20, &pattern); 4]).collect();

        let c1 = simulate(&one, &dims(), &cost(), &cfg(Strategy::CloudOnly)).makespan_s;
        let c5 = simulate(&five, &dims(), &cost(), &cfg(Strategy::CloudOnly)).makespan_s;
        assert!(c5 > 3.5 * c1, "cloud-only should saturate: {c1} -> {c5}");

        let e1 = simulate(&one, &dims(), &cost(),
                          &cfg(Strategy::CeCollm(AblationFlags::default())));
        let e5 = simulate(&five, &dims(), &cost(),
                          &cfg(Strategy::CeCollm(AblationFlags::default())));
        // per-client edge compute identical across scales
        let edge1 = e1.clients[0].cost.edge_s;
        for c in &e5.clients {
            assert!((c.cost.edge_s - edge1).abs() / edge1 < 0.2);
        }
        assert!(e5.makespan_s < c5, "CE-CoLLM scales better than cloud-only");
    }

    #[test]
    fn deterministic_given_seed() {
        let traces = vec![vec![mk_trace(12, &[Cloud, Exit1, Exit2, Cloud])]];
        let a = simulate(&traces, &dims(), &cost(), &cfg(Strategy::CeCollm(AblationFlags::default())));
        let b = simulate(&traces, &dims(), &cost(), &cfg(Strategy::CeCollm(AblationFlags::default())));
        assert_eq!(a.summed().0, b.summed().0);
    }

    #[test]
    fn worker_pool_shortens_cloud_heavy_makespan() {
        // four cloud-heavy clients against 1 vs 2 workers: sharding the
        // devices halves the queueing on the serving path
        let pattern = [Cloud; 12];
        let traces: Vec<Vec<Trace>> = (0..4).map(|_| vec![mk_trace(16, &pattern); 3]).collect();
        let mk = |workers| SimConfig {
            strategy: Strategy::CeCollm(AblationFlags::default()),
            link: LinkProfile::wifi(),
            seed: 7,
            workers,
            cross_device_batch: false,
            ..Default::default()
        };
        let w1 = simulate(&traces, &dims(), &cost(), &mk(1));
        let w2 = simulate(&traces, &dims(), &cost(), &mk(2));
        assert!(
            w2.makespan_s < w1.makespan_s,
            "2 workers should beat 1: {} vs {}",
            w2.makespan_s,
            w1.makespan_s
        );
        // the same compute is done either way, just less serialized
        assert!((w1.cloud_busy_s - w2.cloud_busy_s).abs() / w1.cloud_busy_s < 0.05);
    }

    #[test]
    fn cross_device_batching_fuses_contended_passes() {
        // four cloud-heavy clients on one worker: under FCFS their calls
        // queue; with batching, queued calls fuse into padded passes
        let pattern = [Cloud; 12];
        let traces: Vec<Vec<Trace>> = (0..4).map(|_| vec![mk_trace(16, &pattern); 3]).collect();
        let mk = |batch| SimConfig {
            strategy: Strategy::CeCollm(AblationFlags::default()),
            link: LinkProfile::wifi(),
            seed: 7,
            workers: 1,
            cross_device_batch: batch,
            ..Default::default()
        };
        let fcfs = simulate(&traces, &dims(), &cost(), &mk(false));
        let batched = simulate(&traces, &dims(), &cost(), &mk(true));
        let calls = fcfs.summed().1.cloud_requests as u64;
        assert_eq!(fcfs.cloud_passes, calls, "FCFS: one pass per call");
        assert!(
            batched.cloud_passes < fcfs.cloud_passes,
            "contended calls must fuse: {} vs {}",
            batched.cloud_passes,
            fcfs.cloud_passes
        );
        assert!(
            batched.makespan_s < fcfs.makespan_s,
            "fused passes must shorten the makespan: {} vs {}",
            batched.makespan_s,
            fcfs.makespan_s
        );
        // same tokens served either way
        assert_eq!(fcfs.summed().1.tokens_generated, batched.summed().1.tokens_generated);
    }

    #[test]
    fn batching_a_single_client_is_a_no_op() {
        // one client's calls never overlap (synchronous round trips), so
        // every pass is a batch of one and the laws coincide exactly
        let pattern = [Cloud, Exit1, Cloud, Exit2, Cloud, Cloud];
        let traces = vec![vec![mk_trace(12, &pattern); 2]];
        let mk = |batch| SimConfig {
            strategy: Strategy::CeCollm(AblationFlags::default()),
            link: LinkProfile::wifi(),
            seed: 3,
            workers: 1,
            cross_device_batch: batch,
            ..Default::default()
        };
        let a = simulate(&traces, &dims(), &cost(), &mk(false));
        let b = simulate(&traces, &dims(), &cost(), &mk(true));
        assert_eq!(a.cloud_passes, b.cloud_passes);
        assert!((a.makespan_s - b.makespan_s).abs() < 1e-12);
        assert!((a.cloud_busy_s - b.cloud_busy_s).abs() < 1e-12);
        assert_eq!(a.summed().1.cloud_requests as u64, a.cloud_passes);
    }

    #[test]
    fn tight_budget_prices_replays_not_wrong_tokens() {
        // two cloud-heavy clients on one worker: a budget below their
        // combined context forces LRU ping-pong evictions, each priced
        // as a full-history re-upload + re-prefill — more bytes, more
        // time, identical token counts
        let pattern = [Cloud; 10];
        let traces: Vec<Vec<Trace>> = (0..2).map(|_| vec![mk_trace(16, &pattern); 2]).collect();
        let d = dims();
        // one client's context peaks at ~26 positions; fit one, not two
        let one_ctx = (26 * d.cloud_kv_bytes_per_pos()) as u64;
        let mk = |budget| SimConfig {
            strategy: Strategy::CeCollm(AblationFlags::default()),
            link: LinkProfile::wifi(),
            seed: 7,
            workers: 1,
            cross_device_batch: false,
            memory_budget_bytes: budget,
            session_ttl_s: None,
            link_fault: None,
            replication: None,
        };
        let free = simulate(&traces, &d, &cost(), &mk(None));
        let tight = simulate(&traces, &d, &cost(), &mk(Some(one_ctx)));
        assert_eq!(free.cloud_evictions, 0);
        assert_eq!(free.cloud_replays, 0);
        assert!(tight.cloud_evictions > 0, "budget below working set must evict");
        assert!(tight.cloud_replays > 0, "mid-request evictions must be replayed");
        let (fc, fk) = free.summed();
        let (tc, tk) = tight.summed();
        assert!(
            tk.bytes_up > fk.bytes_up,
            "replays cost extra uploads: {} vs {}",
            tk.bytes_up,
            fk.bytes_up
        );
        assert_eq!(tk.context_replays as u64, tight.cloud_replays);
        assert!(tc.total_s >= fc.total_s - 1e-9, "eviction cannot make the run faster");
        // same tokens served either way — eviction is a cost, never a
        // correctness change
        assert_eq!(fk.tokens_generated, tk.tokens_generated);
        assert_eq!(fk.tokens_cloud, tk.tokens_cloud);
    }

    #[test]
    fn unset_budget_matches_the_legacy_law_exactly() {
        let pattern = [Cloud, Exit1, Cloud, Exit2, Cloud];
        let traces = vec![vec![mk_trace(12, &pattern); 3]];
        let base = simulate(
            &traces,
            &dims(),
            &cost(),
            &cfg(Strategy::CeCollm(AblationFlags::default())),
        );
        let with_fields = simulate(
            &traces,
            &dims(),
            &cost(),
            &SimConfig {
                strategy: Strategy::CeCollm(AblationFlags::default()),
                link: LinkProfile::wifi(),
                seed: 7,
                workers: 1,
                cross_device_batch: false,
                memory_budget_bytes: None,
                session_ttl_s: None,
                link_fault: None,
                replication: None,
            },
        );
        assert_eq!(base.summed().0, with_fields.summed().0);
        assert_eq!(with_fields.cloud_evictions + with_fields.cloud_ttl_reaps, 0);
    }

    #[test]
    fn ttl_reaps_are_priced_like_evictions() {
        // two alternating cloud-heavy clients with a near-zero TTL: every
        // pass reaps the other client's idle context, so mid-request
        // calls keep paying the replay
        let pattern = [Cloud; 6];
        let traces: Vec<Vec<Trace>> = (0..2).map(|_| vec![mk_trace(12, &pattern)]).collect();
        let mk = |ttl| SimConfig {
            strategy: Strategy::CeCollm(AblationFlags::default()),
            link: LinkProfile::wifi(),
            seed: 3,
            workers: 1,
            cross_device_batch: false,
            memory_budget_bytes: None,
            session_ttl_s: ttl,
            link_fault: None,
            replication: None,
        };
        let free = simulate(&traces, &dims(), &cost(), &mk(None));
        let reaped = simulate(&traces, &dims(), &cost(), &mk(Some(1e-9)));
        assert_eq!(free.cloud_ttl_reaps, 0);
        assert!(reaped.cloud_ttl_reaps > 0, "near-zero TTL must reap between passes");
        assert!(reaped.cloud_replays > 0);
        let (_, fk) = free.summed();
        let (_, rk) = reaped.summed();
        assert!(rk.bytes_up > fk.bytes_up);
        assert_eq!(fk.tokens_generated, rk.tokens_generated);
    }

    #[test]
    fn link_faults_price_reconnects_not_wrong_tokens() {
        let pattern = [Cloud, Exit1, Cloud, Exit2, Cloud, Exit1, Cloud, Exit1];
        let traces = vec![vec![mk_trace(12, &pattern); 3]];
        let base = cfg(Strategy::CeCollm(AblationFlags::default()));
        let faulty = SimConfig {
            link_fault: Some(LinkFaultSim { sever_every: 3, reconnect_delay_s: 0.05 }),
            ..base
        };
        let clean = simulate(&traces, &dims(), &cost(), &base);
        let hurt = simulate(&traces, &dims(), &cost(), &faulty);
        let (cc, ck) = clean.summed();
        let (hc, hk) = hurt.summed();
        // a sever costs bytes and time, never different tokens — and a
        // resume is not an eviction replay
        assert_eq!(ck.reconnects, 0);
        assert!(hk.reconnects > 0, "scheduled severs must be priced");
        assert!(hk.bytes_up > ck.bytes_up, "{} vs {}", hk.bytes_up, ck.bytes_up);
        assert!(hc.total_s > cc.total_s);
        assert_eq!(hk.context_replays, ck.context_replays);
        assert_eq!(ck.tokens_generated, hk.tokens_generated);
        assert_eq!(ck.tokens_cloud, hk.tokens_cloud);
        // the schedule keys on call ordinals: identical config, identical
        // severs, identical costs
        let again = simulate(&traces, &dims(), &cost(), &faulty);
        let (ac, ak) = again.summed();
        assert_eq!(ak.reconnects, hk.reconnects);
        assert_eq!(ak.bytes_up, hk.bytes_up);
        assert_eq!(ac, hc);
    }

    #[test]
    fn warm_failover_prices_no_replay_bytes() {
        // every sever recovered by warm promotion: the paper-facing
        // uplink bill matches the fault-free run exactly — no backoff,
        // no re-Hello, no history replay — while the cold law pays all
        // three.  Mirroring is billed on its own channel.
        let pattern = [Cloud, Exit1, Cloud, Exit2, Cloud, Exit1, Cloud, Exit1];
        let traces = vec![vec![mk_trace(12, &pattern); 3]];
        let base = cfg(Strategy::CeCollm(AblationFlags::default()));
        let fault = Some(LinkFaultSim { sever_every: 3, reconnect_delay_s: 0.05 });
        let cold_cfg = SimConfig { link_fault: fault, ..base };
        let warm_cfg = SimConfig {
            link_fault: fault,
            replication: Some(SimReplication { replicas: 8, hedge: false }),
            ..base
        };
        let clean = simulate(&traces, &dims(), &cost(), &base);
        let cold = simulate(&traces, &dims(), &cost(), &cold_cfg);
        let warm = simulate(&traces, &dims(), &cost(), &warm_cfg);
        let (cc, ck) = clean.summed();
        let (oc, ok) = cold.summed();
        let (wc, wk) = warm.summed();
        assert!(ok.reconnects > 0, "the cold law must reconnect");
        assert_eq!(wk.reconnects, 0, "warm promotion is not a reconnect");
        assert_eq!(wk.failovers_warm, ok.reconnects, "every sever recovered warm");
        assert_eq!(wk.failovers_cold, 0);
        assert_eq!(wk.context_replays, 0, "zero-replay recovery");
        // primary-channel bytes identical to the fault-free run; the
        // cold law pays the replay on the paper-facing bill
        assert_eq!(wk.bytes_up, ck.bytes_up);
        assert!(ok.bytes_up > ck.bytes_up);
        assert!(wk.bytes_mirrored > 0, "mirrored uploads are billed");
        assert_eq!(ck.bytes_mirrored, 0);
        // warm recovery is strictly cheaper in time than cold, and
        // tokens are identical everywhere
        assert!(wc.total_s <= oc.total_s, "{} vs {}", wc.total_s, oc.total_s);
        assert!(wc.total_s >= cc.total_s - 1e-9, "a sever cannot speed the run up");
        assert_eq!(wk.tokens_generated, ck.tokens_generated);
        assert_eq!(wk.tokens_cloud, ck.tokens_cloud);
    }

    #[test]
    fn standby_budget_exhausts_to_cold_failover() {
        // 4 severs against 2 standbys: the first two promote warm, the
        // rest walk down the ladder to the cold reconnect law — the
        // set shrinks, it never refills
        let pattern = [Cloud, Exit1, Cloud, Exit2, Cloud, Exit1, Cloud, Exit1];
        let traces = vec![vec![mk_trace(12, &pattern); 3]];
        let base = cfg(Strategy::CeCollm(AblationFlags::default()));
        let fault = Some(LinkFaultSim { sever_every: 3, reconnect_delay_s: 0.05 });
        let mixed_cfg = SimConfig {
            link_fault: fault,
            replication: Some(SimReplication { replicas: 2, hedge: false }),
            ..base
        };
        let cold = simulate(&traces, &dims(), &cost(), &SimConfig { link_fault: fault, ..base });
        let mixed = simulate(&traces, &dims(), &cost(), &mixed_cfg);
        let (_, ok) = cold.summed();
        let (_, mk) = mixed.summed();
        assert_eq!(mk.failovers_warm, 2);
        assert_eq!(mk.failovers_cold, ok.reconnects - 2);
        assert_eq!(mk.reconnects, ok.reconnects - 2, "cold rungs still reconnect");
        assert!(mk.bytes_up < ok.bytes_up, "two replays avoided");
        assert_eq!(mk.tokens_generated, ok.tokens_generated);
    }

    #[test]
    fn hedging_prices_duplicates_on_the_standby_channel_only() {
        // hedged infer duplicates every cloud call to the standby:
        // extra bytes on the mirror bill, zero change to the
        // paper-facing cost breakdown (the loser's echo is fenced)
        let pattern = [Cloud, Exit1, Cloud, Exit2, Cloud];
        let traces = vec![vec![mk_trace(12, &pattern); 3]];
        let base = cfg(Strategy::CeCollm(AblationFlags::default()));
        let hedged_cfg = SimConfig {
            replication: Some(SimReplication { replicas: 1, hedge: true }),
            ..base
        };
        let clean = simulate(&traces, &dims(), &cost(), &base);
        let hedged = simulate(&traces, &dims(), &cost(), &hedged_cfg);
        let (cc, ck) = clean.summed();
        let (hc, hk) = hedged.summed();
        assert_eq!(hk.hedged_requests, hk.cloud_requests, "every cloud call hedged");
        assert!(
            hk.bytes_mirrored
                >= hk.hedged_requests as u64 * (REQ_BYTES + RESP_BYTES) as u64,
            "duplicate request+response pairs are billed to the mirror channel"
        );
        assert_eq!(hk.bytes_up, ck.bytes_up, "primary uplink bill unchanged");
        assert_eq!(hk.bytes_down, ck.bytes_down, "primary downlink bill unchanged");
        assert_eq!(hc, cc, "hedging costs no simulated time");
        assert_eq!(ck.hedged_requests, 0);
    }

    #[test]
    fn unset_replication_is_bit_identical_to_the_legacy_law() {
        // the same invariant link_fault: None already keeps: a None
        // replication config must not touch the rng stream, the byte
        // counters, or any cost
        let pattern = [Cloud, Exit1, Cloud, Exit2, Cloud, Exit1];
        let traces = vec![vec![mk_trace(12, &pattern); 3]];
        let base = cfg(Strategy::CeCollm(AblationFlags::default()));
        let explicit = SimConfig { replication: None, ..base };
        let a = simulate(&traces, &dims(), &cost(), &base);
        let b = simulate(&traces, &dims(), &cost(), &explicit);
        let (ac, ak) = a.summed();
        let (bc, bk) = b.summed();
        assert_eq!(ac, bc);
        assert_eq!(ak.bytes_up, bk.bytes_up);
        assert_eq!(ak.bytes_mirrored, 0);
        assert_eq!(bk.bytes_mirrored, 0);
        assert_eq!(bk.failovers_warm + bk.failovers_cold, 0);
        assert_eq!(bk.hedged_requests, 0);
    }

    #[test]
    fn simulated_histograms_follow_the_live_schema() {
        let pattern = [Cloud, Exit1, Cloud, Exit2, Cloud];
        let traces = vec![vec![mk_trace(12, &pattern); 3]];
        let out =
            simulate(&traces, &dims(), &cost(), &cfg(Strategy::CeCollm(AblationFlags::default())));
        assert_eq!(out.hist_pass.count(), out.cloud_passes, "one pass sample per pass");
        assert_eq!(
            out.hist_rtt.count(),
            out.summed().1.cloud_requests as u64,
            "one round trip per cloud call"
        );
        let text = out.render_prometheus();
        let exp = crate::metrics::parse_exposition(&text).expect("exposition must parse");
        for name in
            ["ce_sched_park_wait_ns", "ce_sched_queue_wait_ns", "ce_sched_batch_pass_ns",
             "ce_edge_cloud_rtt_ns"]
        {
            assert_eq!(exp.types.get(name).map(String::as_str), Some("histogram"), "{name}");
        }
        let p50 = exp.hist_quantile("ce_edge_cloud_rtt_ns", &[], 0.5).expect("rtt quantile");
        assert!(p50 > 0.0, "simulated round trips take simulated time");
        // quantiles priced by the simulated clock bound the recorded max
        let p99 = out.hist_rtt.quantile(0.99);
        assert!(p99 <= out.hist_rtt.max as f64 + 1.0, "{p99} vs {}", out.hist_rtt.max);
    }

    #[test]
    fn naive_bytes_grow_quadratically() {
        let short = vec![vec![mk_trace(10, &[Cloud; 5])]];
        let long = vec![vec![mk_trace(10, &[Cloud; 50])]];
        let bs = simulate(&short, &dims(), &cost(), &cfg(Strategy::NaiveSplit)).summed().1.bytes_up;
        let bl = simulate(&long, &dims(), &cost(), &cfg(Strategy::NaiveSplit)).summed().1.bytes_up;
        // 10x the tokens must cost far more than 10x the bytes (O(T^2))
        assert!(bl > 2 * 10 * bs, "{bs} -> {bl}");
    }
}
