//! Discrete-event replay of recorded traces under a deployment strategy.
//!
//! Entities: per-client edge clock, per-client FIFO up/down links
//! ([`SimLink`]), and a cloud worker pool served FCFS per worker with
//! upload-dependency parking (`workers = 1` reproduces the paper's
//! testbed topology: N edge devices, one cloud inference GPU).  Compute
//! durations come from the calibrated [`CostModel`] (measured PJRT call
//! times); communication from the [`LinkProfile`].
//!
//! The same replay engine produces every row of Tables 2 and 4 and every
//! point of Figure 4: CE-CoLLM is a flag configuration, the baselines are
//! alternative strategies over the same traces (cloud-only and the naïve
//! split generate the θ=1.0 token sequence by construction, since both
//! run the full model).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::config::AblationFlags;
use crate::coordinator::policy::ExitPoint;
use crate::harness::cost::CostModel;
use crate::harness::trace::Trace;
use crate::metrics::{CostBreakdown, RunCounters};
use crate::model::manifest::ModelDims;
use crate::net::profiles::LinkProfile;
use crate::net::simulated::SimLink;
use crate::util::rng::Rng;

use crate::coordinator::protocol::{INFER_REQ_LEN, TOKEN_RESP_LEN, UPLOAD_HDR_LEN};
use crate::net::codec::frame_wire_len;

/// Fixed wire sizes (codec frame prefix + exact message header bytes;
/// payloads added on top), derived from the protocol's encoded-length
/// constants through [`crate::net::codec::frame_wire_len`] — the same
/// arithmetic the live edge counters use, so simulated and measured
/// byte totals agree exactly.
const UPLOAD_HDR: usize = frame_wire_len(UPLOAD_HDR_LEN);
const REQ_BYTES: usize = frame_wire_len(INFER_REQ_LEN);
const RESP_BYTES: usize = frame_wire_len(TOKEN_RESP_LEN);

/// Deployment strategy to replay.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Strategy {
    /// CE-CoLLM with the given ablation switches (paper §4, Table 4).
    CeCollm(AblationFlags),
    /// Edge standalone mode (paper §4.1) — replay of a standalone trace.
    Standalone,
    /// Cloud-based LLM deployment (paper Fig 1a): prompt up, full
    /// inference in the cloud, text down.
    CloudOnly,
    /// Naïve cloud-edge split (paper Fig 1b): per-token synchronous
    /// re-upload of the full fp32 hidden history, no content manager.
    NaiveSplit,
}

#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    pub strategy: Strategy,
    pub link: LinkProfile,
    pub seed: u64,
    /// Cloud scheduler worker pool size (paper testbed: 1 GPU).  Devices
    /// shard statically onto workers, mirroring the real scheduler's
    /// `device_id % workers` assignment.
    pub workers: usize,
    /// Model the scheduler's cross-device batched decode: every call
    /// queued on a worker that is ready when a pass starts joins that
    /// pass, which costs the *widest* call plus the batched marginal rate
    /// for each extra lane — instead of the calls running FCFS one after
    /// another.  `false` reproduces the pre-batching per-device serving
    /// law.
    pub cross_device_batch: bool,
}

#[derive(Debug, Clone)]
pub struct ClientResult {
    pub cost: CostBreakdown,
    pub counters: RunCounters,
}

#[derive(Debug, Clone)]
pub struct SimOutcome {
    pub clients: Vec<ClientResult>,
    /// Finish time of the last client (total wall-clock of the run).
    pub makespan_s: f64,
    /// Total busy time summed over the cloud worker pool.
    pub cloud_busy_s: f64,
    /// Engine passes the pool executed.  Without cross-device batching
    /// this equals the number of cloud calls; with it, co-resident calls
    /// fuse and the count drops — the ratio is the batching win.
    pub cloud_passes: u64,
}

impl SimOutcome {
    /// Sum of per-client breakdowns (the paper's Table 2 reports the
    /// cumulative cost over all cases of a single client).
    pub fn summed(&self) -> (CostBreakdown, RunCounters) {
        let mut cost = CostBreakdown::default();
        let mut counters = RunCounters::default();
        for c in &self.clients {
            cost.add(&c.cost);
            counters.add(&c.counters);
        }
        cost.total_s = self.makespan_s;
        (cost, counters)
    }
}

/// A pending cloud request from one client.
struct CloudCall {
    client: usize,
    arrive_s: f64,
    /// When the uploads this request depends on have all arrived.
    ready_s: f64,
    busy_s: f64,
    /// Decode lanes this call puts into a padded pass (its coalesced
    /// catch-up count) — sizes the batched marginal cost when the call
    /// rides along in another call's pass.
    items: usize,
    resp_bytes: usize,
}

struct HeapEntry {
    arrive_s: f64,
    client: usize,
    /// Guards against stale entries: a call co-served by an earlier
    /// batched pass leaves its heap entry behind; the sequence number
    /// tells it apart from the client's next call.
    seq: u64,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.arrive_s == other.arrive_s && self.client == other.client && self.seq == other.seq
    }
}
impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap by arrival time (FCFS), tie-break by client id, then
        // seq — the full field set, keeping Ord consistent with Eq
        other
            .arrive_s
            .total_cmp(&self.arrive_s)
            .then_with(|| other.client.cmp(&self.client))
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Per-client replay state machine.
struct ClientSim<'a> {
    id: usize,
    traces: &'a [Trace],
    strategy: Strategy,
    d_model: usize,
    cost_model: &'a CostModel,
    rng: Rng,
    uplink: SimLink,
    downlink: SimLink,

    req_idx: usize,
    step_idx: usize,
    edge_t: f64,
    /// Arrival time of the newest upload the cloud may need.
    upload_ready: f64,
    /// Pending (not yet cloud-requested) call produced by `advance`.
    cost: CostBreakdown,
    counters: RunCounters,
    done: bool,
}

impl<'a> ClientSim<'a> {
    fn new(
        id: usize,
        traces: &'a [Trace],
        strategy: Strategy,
        dims: &ModelDims,
        cost_model: &'a CostModel,
        link: LinkProfile,
        seed: u64,
    ) -> Self {
        Self {
            id,
            traces,
            strategy,
            d_model: dims.d_model,
            cost_model,
            rng: Rng::seed_from_u64(seed ^ (id as u64).wrapping_mul(0x9E3779B9)),
            uplink: SimLink::new(link),
            downlink: SimLink::new(link),
            req_idx: 0,
            step_idx: 0,
            edge_t: 0.0,
            upload_ready: 0.0,
            cost: CostBreakdown::default(),
            counters: RunCounters::default(),
            done: false,
        }
    }

    fn flags(&self) -> AblationFlags {
        match self.strategy {
            Strategy::CeCollm(f) => f,
            _ => AblationFlags::default(),
        }
    }

    fn esz(&self) -> usize {
        if self.flags().half_precision {
            2
        } else {
            4
        }
    }

    fn hidden_bytes(&self, positions: usize) -> usize {
        UPLOAD_HDR + positions * self.d_model * self.esz()
    }

    /// Run edge-local work until the next cloud call or completion.
    fn advance(&mut self) -> Option<CloudCall> {
        match self.strategy {
            Strategy::Standalone => {
                self.run_standalone();
                None
            }
            Strategy::CloudOnly => self.advance_cloud_only(),
            Strategy::NaiveSplit => self.advance_naive(),
            Strategy::CeCollm(_) => self.advance_ce(),
        }
    }

    // --- standalone: pure edge, no events --------------------------------
    fn run_standalone(&mut self) {
        for tr in self.traces {
            let d = self.cost_model.sample_edge_prefill(&mut self.rng);
            self.edge_t += d;
            self.cost.edge_s += d;
            for (i, step) in tr.steps.iter().enumerate() {
                if i > 0 {
                    let d = self.cost_model.sample_seg1(&mut self.rng);
                    self.edge_t += d;
                    self.cost.edge_s += d;
                    if step.conf2.is_some() {
                        let d = self.cost_model.sample_seg2(&mut self.rng);
                        self.edge_t += d;
                        self.cost.edge_s += d;
                    }
                }
                match step.exit {
                    ExitPoint::Exit1 => self.counters.tokens_exit1 += 1,
                    _ => self.counters.tokens_exit2 += 1,
                }
                self.counters.tokens_generated += 1;
            }
        }
        self.cost.total_s = self.edge_t;
        self.done = true;
    }

    // --- cloud-only baseline ----------------------------------------------
    fn advance_cloud_only(&mut self) -> Option<CloudCall> {
        if self.req_idx >= self.traces.len() {
            self.finish();
            return None;
        }
        let tr = &self.traces[self.req_idx];
        // API request: the prompt text itself
        let up_bytes = UPLOAD_HDR + tr.prompt_len;
        let arrive = self.uplink.transfer(self.edge_t, up_bytes);
        self.counters.bytes_up += up_bytes as u64;
        self.counters.cloud_requests += 1;
        self.cost.comm_s += arrive - self.edge_t;
        let mut busy = self.cost_model.sample_full_prefill(&mut self.rng);
        for _ in 1..tr.steps.len() {
            busy += self.cost_model.sample_full_decode(&mut self.rng);
        }
        self.counters.tokens_generated += tr.steps.len();
        self.counters.tokens_cloud += tr.steps.len();
        Some(CloudCall {
            client: self.id,
            arrive_s: arrive,
            ready_s: arrive,
            busy_s: busy,
            items: tr.steps.len(),
            resp_bytes: UPLOAD_HDR + tr.tokens.len(),
        })
    }

    // --- naïve split baseline ----------------------------------------------
    fn advance_naive(&mut self) -> Option<CloudCall> {
        loop {
            if self.req_idx >= self.traces.len() {
                self.finish();
                return None;
            }
            let tr = &self.traces[self.req_idx];
            if self.step_idx >= tr.steps.len() {
                self.req_idx += 1;
                self.step_idx = 0;
                continue;
            }
            let pos = tr.steps[self.step_idx].pos;
            let first = self.step_idx == 0;
            if first {
                // edge runs only layers 0..l_ee1 over the prompt
                let share = self.cost_model.seg1.mean_s
                    / (self.cost_model.seg1.mean_s + self.cost_model.seg2.mean_s).max(1e-12);
                let d = self.cost_model.sample_edge_prefill(&mut self.rng) * share;
                self.edge_t += d;
                self.cost.edge_s += d;
            } else {
                let d = self.cost_model.sample_seg1(&mut self.rng);
                self.edge_t += d;
                self.cost.edge_s += d;
            }
            // synchronous re-upload of the ENTIRE fp32 history (no content
            // manager, Fig 1b)
            let bytes = UPLOAD_HDR + (pos + 1) * self.d_model * 4;
            let arrived = self.uplink.transfer(self.edge_t, bytes);
            self.counters.bytes_up += bytes as u64;
            self.cost.comm_s += arrived - self.edge_t;
            self.edge_t = arrived;
            // request rides behind the upload
            let req_arrive = self.uplink.transfer(self.edge_t, REQ_BYTES);
            self.counters.bytes_up += REQ_BYTES as u64;
            self.cost.comm_s += req_arrive - self.edge_t;
            self.counters.cloud_requests += 1;
            self.counters.tokens_cloud += 1;
            self.counters.tokens_generated += 1;
            let mut busy = self.cost_model.sample_cloud_decode(&mut self.rng);
            if first {
                busy = self.cost_model.sample_cloud_prefill(&mut self.rng);
            }
            return Some(CloudCall {
                client: self.id,
                arrive_s: req_arrive,
                ready_s: req_arrive,
                busy_s: busy,
                items: 1,
                resp_bytes: RESP_BYTES,
            });
        }
    }

    // --- CE-CoLLM ------------------------------------------------------------
    fn advance_ce(&mut self) -> Option<CloudCall> {
        let flags = self.flags();
        loop {
            if self.req_idx >= self.traces.len() {
                self.finish();
                return None;
            }
            let tr = &self.traces[self.req_idx];
            if self.step_idx >= tr.steps.len() {
                self.req_idx += 1;
                self.step_idx = 0;
                continue;
            }

            if self.step_idx == 0 {
                // prefill + parallel prompt upload
                let d = self.cost_model.sample_edge_prefill(&mut self.rng);
                self.edge_t += d;
                self.cost.edge_s += d;
                self.upload_ready = 0.0;
                if flags.parallel_upload && flags.content_manager {
                    let bytes = self.hidden_bytes(tr.prompt_len);
                    self.upload_ready = self.uplink.transfer(self.edge_t, bytes);
                    self.counters.bytes_up += bytes as u64;
                }
            }

            let step = &tr.steps[self.step_idx];
            if self.step_idx > 0 {
                let d = self.cost_model.sample_seg1(&mut self.rng);
                self.edge_t += d;
                self.cost.edge_s += d;
                if flags.parallel_upload && flags.content_manager {
                    let bytes = self.hidden_bytes(1);
                    self.upload_ready = self.uplink.transfer(self.edge_t, bytes);
                    self.counters.bytes_up += bytes as u64;
                }
                if step.conf2.is_some() {
                    let d = self.cost_model.sample_seg2(&mut self.rng);
                    self.edge_t += d;
                    self.cost.edge_s += d;
                }
            }

            self.counters.tokens_generated += 1;
            match step.exit {
                ExitPoint::Exit1 => {
                    self.counters.tokens_exit1 += 1;
                    self.step_idx += 1;
                    continue;
                }
                ExitPoint::Exit2 => {
                    self.counters.tokens_exit2 += 1;
                    self.step_idx += 1;
                    continue;
                }
                ExitPoint::Cloud => {
                    self.counters.tokens_cloud += 1;
                    self.counters.cloud_requests += 1;
                    let mut ready = self.upload_ready;
                    if !flags.content_manager {
                        // synchronous full-history retransmission
                        let bytes = self.hidden_bytes(step.pos + 1);
                        let arrived = self.uplink.transfer(self.edge_t, bytes);
                        self.counters.bytes_up += bytes as u64;
                        self.cost.comm_s += arrived - self.edge_t;
                        self.edge_t = arrived;
                        ready = arrived;
                    } else if !flags.parallel_upload {
                        // synchronous upload of positions since last request
                        let mut unsent = step.cloud_catchup
                            + if step.cloud_prefill { tr.prompt_len } else { 0 };
                        if unsent == 0 {
                            unsent = 1;
                        }
                        let bytes = self.hidden_bytes(unsent);
                        let arrived = self.uplink.transfer(self.edge_t, bytes);
                        self.counters.bytes_up += bytes as u64;
                        self.cost.comm_s += arrived - self.edge_t;
                        self.edge_t = arrived;
                        ready = arrived;
                    }
                    let req_arrive = self.uplink.transfer(self.edge_t, REQ_BYTES);
                    self.counters.bytes_up += REQ_BYTES as u64;
                    self.cost.comm_s += req_arrive - self.edge_t;
                    // waiting for a still-in-flight upload is comm time
                    self.cost.comm_s += (ready - req_arrive).max(0.0);

                    let mut busy = 0.0;
                    if step.cloud_prefill {
                        busy += self.cost_model.sample_cloud_prefill(&mut self.rng);
                        if step.cloud_catchup > 0 {
                            busy += self
                                .cost_model
                                .sample_cloud_request(step.cloud_catchup, &mut self.rng);
                        }
                    } else {
                        // batched catch-up (paper: one forward over all
                        // pending positions; cloud time ∝ request count)
                        busy += self
                            .cost_model
                            .sample_cloud_request(step.cloud_catchup.max(1), &mut self.rng);
                    }
                    return Some(CloudCall {
                        client: self.id,
                        arrive_s: req_arrive,
                        ready_s: ready.max(req_arrive),
                        busy_s: busy,
                        items: step.cloud_catchup.max(1),
                        resp_bytes: RESP_BYTES,
                    });
                }
            }
        }
    }

    /// Scheduler callback: the cloud answered at `resp_start` after
    /// `busy_s` of compute; response transfer completes the round trip.
    fn resume(&mut self, cloud_done: f64, busy_s: f64, resp_bytes: usize) {
        let resp_arrive = self.downlink.transfer(cloud_done, resp_bytes);
        self.counters.bytes_down += resp_bytes as u64;
        self.cost.cloud_s += busy_s;
        self.cost.comm_s += resp_arrive - cloud_done;
        self.edge_t = resp_arrive.max(self.edge_t);
        self.step_idx += 1;
        if matches!(self.strategy, Strategy::CloudOnly) {
            // one call covered the whole request
            self.req_idx += 1;
            self.step_idx = 0;
        }
    }

    fn finish(&mut self) {
        self.cost.total_s = self.edge_t;
        self.done = true;
    }
}

/// Replay `traces_per_client` under `cfg`.  The cloud is a pool of
/// `cfg.workers` engines (1 = the paper's single GPU); each client's
/// requests run FCFS on its statically assigned worker, and a request
/// whose uploads are still in flight parks until `ready_s` — the same
/// dependency rule the real scheduler enforces.
pub fn simulate(
    traces_per_client: &[Vec<Trace>],
    dims: &ModelDims,
    cost_model: &CostModel,
    cfg: &SimConfig,
) -> SimOutcome {
    let mut clients: Vec<ClientSim> = traces_per_client
        .iter()
        .enumerate()
        .map(|(i, t)| ClientSim::new(i, t, cfg.strategy, dims, cost_model, cfg.link, cfg.seed))
        .collect();

    let mut heap: BinaryHeap<HeapEntry> = BinaryHeap::new();
    let mut pending: Vec<Option<(u64, CloudCall)>> = Vec::with_capacity(clients.len());
    let mut seq = 0u64;
    for c in clients.iter_mut() {
        let call = c.advance();
        if let Some(call) = call {
            seq += 1;
            heap.push(HeapEntry { arrive_s: call.arrive_s, client: call.client, seq });
            pending.push(Some((seq, call)));
        } else {
            pending.push(None);
        }
    }

    let workers = cfg.workers.max(1);
    let marginal_s = cost_model.cloud_batch_marginal.mean_s;
    let mut worker_free = vec![0.0f64; workers];
    let mut cloud_busy_total = 0.0f64;
    let mut cloud_passes = 0u64;
    while let Some(entry) = heap.pop() {
        // skip stale entries (their call was co-served by an earlier pass)
        match &pending[entry.client] {
            Some((s, _)) if *s == entry.seq => {}
            _ => continue,
        }
        let (_, call) = pending[entry.client].take().expect("pending call");
        let w = call.client % workers;
        let start = worker_free[w].max(call.arrive_s).max(call.ready_s);

        // Cross-device batching (the real scheduler's padded pass): every
        // other call queued on this worker that is ready by `start` joins
        // the pass instead of waiting its FCFS turn.
        let mut calls = vec![call];
        if cfg.cross_device_batch {
            for (j, slot) in pending.iter_mut().enumerate() {
                if j == entry.client || j % workers != w {
                    continue;
                }
                let joins =
                    matches!(slot, Some((_, c)) if c.arrive_s <= start && c.ready_s <= start);
                if joins {
                    calls.push(slot.take().expect("matched above").1);
                }
            }
        }

        // The padded pass costs its widest lane; every extra lane rides
        // along at the batched marginal rate (paper §4.3: per-token
        // overheads, not model math, dominate — fusing passes removes
        // them).  A batch of one degenerates to exactly the old FCFS law.
        let widest_idx = (0..calls.len())
            .max_by(|&a, &b| calls[a].busy_s.total_cmp(&calls[b].busy_s))
            .expect("non-empty pass");
        let extra_items: usize = calls
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != widest_idx)
            .map(|(_, c)| c.items)
            .sum();
        let busy_pass = calls[widest_idx].busy_s + marginal_s * extra_items as f64;
        let done = start + busy_pass;
        worker_free[w] = done;
        cloud_busy_total += busy_pass;
        cloud_passes += 1;
        for call in calls {
            let c = &mut clients[call.client];
            // the whole pass is attributed to every call it answered,
            // matching the real scheduler's compute_s accounting
            c.resume(done, busy_pass, call.resp_bytes);
            if let Some(next) = c.advance() {
                seq += 1;
                heap.push(HeapEntry { arrive_s: next.arrive_s, client: next.client, seq });
                pending[call.client] = Some((seq, next));
            }
        }
    }

    let mut out = SimOutcome {
        clients: Vec::with_capacity(clients.len()),
        makespan_s: 0.0,
        cloud_busy_s: cloud_busy_total,
        cloud_passes,
    };
    for c in clients {
        debug_assert!(c.done);
        out.makespan_s = out.makespan_s.max(c.cost.total_s);
        out.clients.push(ClientResult { cost: c.cost, counters: c.counters });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::ExitPoint;
    use crate::harness::trace::TraceStep;
    use crate::model::manifest::test_manifest;

    /// Build a synthetic trace: exits chosen by a repeating pattern.
    /// Catch-up counts follow the content-manager semantics: the first
    /// cloud request prefills the prompt and decodes positions
    /// `prompt_len ..= pos`; later requests decode everything since the
    /// previous request.
    fn mk_trace(prompt_len: usize, pattern: &[ExitPoint]) -> Trace {
        let mut steps = Vec::new();
        let mut prefilled = false;
        let mut consumed_upto = prompt_len; // cm.consumed_upto after prefill
        for (i, &exit) in pattern.iter().enumerate() {
            let pos = prompt_len - 1 + i;
            let (catchup, cp) = if exit == ExitPoint::Cloud {
                let did_prefill = !prefilled;
                prefilled = true;
                let catch = (pos + 1).saturating_sub(consumed_upto);
                consumed_upto = pos + 1;
                (catch, did_prefill)
            } else {
                (0, false)
            };
            steps.push(TraceStep {
                pos,
                token: 97,
                exit,
                conf1: 0.5,
                conf2: if exit == ExitPoint::Exit1 { None } else { Some(0.6) },
                tok1: 97,
                tok2: if exit == ExitPoint::Exit1 { None } else { Some(97) },
                cloud_conf: if exit == ExitPoint::Cloud { Some(0.9) } else { None },
                cloud_catchup: catchup,
                cloud_prefill: cp,
            });
        }
        Trace {
            prompt_len,
            tokens: vec![97; pattern.len()],
            text: "a".repeat(pattern.len()),
            steps,
        }
    }

    fn dims() -> crate::model::manifest::ModelDims {
        test_manifest().model
    }

    fn cost() -> CostModel {
        CostModel::synthetic(&dims())
    }

    fn cfg(strategy: Strategy) -> SimConfig {
        SimConfig {
            strategy,
            link: LinkProfile::wifi(),
            seed: 7,
            workers: 1,
            cross_device_batch: false,
        }
    }

    use ExitPoint::*;

    #[test]
    fn standalone_has_no_cloud_or_comm() {
        let traces = vec![vec![mk_trace(10, &[Exit1, Exit2, Exit1, Exit2])]];
        let out = simulate(&traces, &dims(), &cost(), &cfg(Strategy::Standalone));
        let (c, k) = out.summed();
        assert_eq!(c.cloud_s, 0.0);
        assert_eq!(c.comm_s, 0.0);
        assert!(c.edge_s > 0.0);
        assert_eq!(k.tokens_cloud, 0);
        assert_eq!(k.transmitted_mb(), 0.0);
    }

    #[test]
    fn ce_collm_cheaper_than_cloud_only_and_naive() {
        // the paper's headline shape at θ=0.8-ish exit rates
        let pattern = [Cloud, Exit1, Exit2, Exit1, Cloud, Exit1, Exit2, Exit1];
        let traces = vec![vec![mk_trace(20, &pattern); 5]];
        let ce = simulate(&traces, &dims(), &cost(), &cfg(Strategy::CeCollm(AblationFlags::default())));
        let cl = simulate(&traces, &dims(), &cost(), &cfg(Strategy::CloudOnly));
        let nv = simulate(&traces, &dims(), &cost(), &cfg(Strategy::NaiveSplit));
        let (ce_c, ce_k) = ce.summed();
        let (cl_c, _) = cl.summed();
        let (nv_c, nv_k) = nv.summed();
        // naive is dominated by comm and much slower than everything
        assert!(nv_c.total_s > 2.0 * cl_c.total_s, "naive {} vs cloud {}", nv_c.total_s, cl_c.total_s);
        assert!(nv_c.comm_s > nv_c.cloud_s);
        // CE-CoLLM reduces cloud compute vs cloud-only
        assert!(ce_c.cloud_s < 0.6 * cl_c.cloud_s);
        // and transmits far less than naive
        assert!(nv_k.bytes_up > 10 * ce_k.bytes_up);
    }

    #[test]
    fn without_cm_explodes_comm() {
        // serialization-dominated regime (the paper's): long prompt, many
        // cloud round trips, paper-scaled bandwidth
        let pattern = [Cloud, Exit1, Cloud, Exit1, Cloud, Exit2, Cloud, Exit1,
                       Cloud, Exit1, Cloud, Exit2, Cloud, Exit1, Cloud, Exit1];
        let traces = vec![vec![mk_trace(150, &pattern); 3]];
        let link = LinkProfile::paper_scaled();
        let scfg =
            |s| SimConfig { strategy: s, link, seed: 7, workers: 1, cross_device_batch: false };
        let full = simulate(&traces, &dims(), &cost(),
                            &scfg(Strategy::CeCollm(AblationFlags::default())));
        let nocm = simulate(&traces, &dims(), &cost(),
                            &scfg(Strategy::CeCollm(AblationFlags::without_cm_and_parallel_upload())));
        let (f, fk) = full.summed();
        let (n, nk) = nocm.summed();
        assert!(n.comm_s > 3.0 * f.comm_s, "no-CM comm {} vs {}", n.comm_s, f.comm_s);
        assert!(nk.bytes_up > 3 * fk.bytes_up);
        // cloud compute is unchanged (manager dedups, KV retained)
        assert!((n.cloud_s - f.cloud_s).abs() / f.cloud_s < 0.2);
    }

    #[test]
    fn fp32_transmits_twice_the_hidden_bytes() {
        let pattern = [Cloud, Exit1, Exit2, Cloud];
        let traces = vec![vec![mk_trace(10, &pattern)]];
        let f16 = simulate(&traces, &dims(), &cost(),
                           &cfg(Strategy::CeCollm(AblationFlags::default())));
        let f32_ = simulate(&traces, &dims(), &cost(),
                            &cfg(Strategy::CeCollm(AblationFlags::without_half_precision())));
        let up16 = f16.summed().1.bytes_up;
        let up32 = f32_.summed().1.bytes_up;
        assert!(up32 > up16 && up32 < 2 * up16 + 2000, "{up16} vs {up32}");
    }

    #[test]
    fn multi_client_scaling_shapes() {
        // cloud-only: total grows ~linearly with clients (GPU saturates);
        // CE-CoLLM: edge time per client constant, total grows slower
        let pattern = [Cloud, Exit1, Exit2, Exit1, Exit1, Exit2, Exit1, Exit1];
        let one: Vec<Vec<Trace>> = vec![vec![mk_trace(20, &pattern); 4]];
        let five: Vec<Vec<Trace>> = (0..5).map(|_| vec![mk_trace(20, &pattern); 4]).collect();

        let c1 = simulate(&one, &dims(), &cost(), &cfg(Strategy::CloudOnly)).makespan_s;
        let c5 = simulate(&five, &dims(), &cost(), &cfg(Strategy::CloudOnly)).makespan_s;
        assert!(c5 > 3.5 * c1, "cloud-only should saturate: {c1} -> {c5}");

        let e1 = simulate(&one, &dims(), &cost(),
                          &cfg(Strategy::CeCollm(AblationFlags::default())));
        let e5 = simulate(&five, &dims(), &cost(),
                          &cfg(Strategy::CeCollm(AblationFlags::default())));
        // per-client edge compute identical across scales
        let edge1 = e1.clients[0].cost.edge_s;
        for c in &e5.clients {
            assert!((c.cost.edge_s - edge1).abs() / edge1 < 0.2);
        }
        assert!(e5.makespan_s < c5, "CE-CoLLM scales better than cloud-only");
    }

    #[test]
    fn deterministic_given_seed() {
        let traces = vec![vec![mk_trace(12, &[Cloud, Exit1, Exit2, Cloud])]];
        let a = simulate(&traces, &dims(), &cost(), &cfg(Strategy::CeCollm(AblationFlags::default())));
        let b = simulate(&traces, &dims(), &cost(), &cfg(Strategy::CeCollm(AblationFlags::default())));
        assert_eq!(a.summed().0, b.summed().0);
    }

    #[test]
    fn worker_pool_shortens_cloud_heavy_makespan() {
        // four cloud-heavy clients against 1 vs 2 workers: sharding the
        // devices halves the queueing on the serving path
        let pattern = [Cloud; 12];
        let traces: Vec<Vec<Trace>> = (0..4).map(|_| vec![mk_trace(16, &pattern); 3]).collect();
        let mk = |workers| SimConfig {
            strategy: Strategy::CeCollm(AblationFlags::default()),
            link: LinkProfile::wifi(),
            seed: 7,
            workers,
            cross_device_batch: false,
        };
        let w1 = simulate(&traces, &dims(), &cost(), &mk(1));
        let w2 = simulate(&traces, &dims(), &cost(), &mk(2));
        assert!(
            w2.makespan_s < w1.makespan_s,
            "2 workers should beat 1: {} vs {}",
            w2.makespan_s,
            w1.makespan_s
        );
        // the same compute is done either way, just less serialized
        assert!((w1.cloud_busy_s - w2.cloud_busy_s).abs() / w1.cloud_busy_s < 0.05);
    }

    #[test]
    fn cross_device_batching_fuses_contended_passes() {
        // four cloud-heavy clients on one worker: under FCFS their calls
        // queue; with batching, queued calls fuse into padded passes
        let pattern = [Cloud; 12];
        let traces: Vec<Vec<Trace>> = (0..4).map(|_| vec![mk_trace(16, &pattern); 3]).collect();
        let mk = |batch| SimConfig {
            strategy: Strategy::CeCollm(AblationFlags::default()),
            link: LinkProfile::wifi(),
            seed: 7,
            workers: 1,
            cross_device_batch: batch,
        };
        let fcfs = simulate(&traces, &dims(), &cost(), &mk(false));
        let batched = simulate(&traces, &dims(), &cost(), &mk(true));
        let calls = fcfs.summed().1.cloud_requests as u64;
        assert_eq!(fcfs.cloud_passes, calls, "FCFS: one pass per call");
        assert!(
            batched.cloud_passes < fcfs.cloud_passes,
            "contended calls must fuse: {} vs {}",
            batched.cloud_passes,
            fcfs.cloud_passes
        );
        assert!(
            batched.makespan_s < fcfs.makespan_s,
            "fused passes must shorten the makespan: {} vs {}",
            batched.makespan_s,
            fcfs.makespan_s
        );
        // same tokens served either way
        assert_eq!(fcfs.summed().1.tokens_generated, batched.summed().1.tokens_generated);
    }

    #[test]
    fn batching_a_single_client_is_a_no_op() {
        // one client's calls never overlap (synchronous round trips), so
        // every pass is a batch of one and the laws coincide exactly
        let pattern = [Cloud, Exit1, Cloud, Exit2, Cloud, Cloud];
        let traces = vec![vec![mk_trace(12, &pattern); 2]];
        let mk = |batch| SimConfig {
            strategy: Strategy::CeCollm(AblationFlags::default()),
            link: LinkProfile::wifi(),
            seed: 3,
            workers: 1,
            cross_device_batch: batch,
        };
        let a = simulate(&traces, &dims(), &cost(), &mk(false));
        let b = simulate(&traces, &dims(), &cost(), &mk(true));
        assert_eq!(a.cloud_passes, b.cloud_passes);
        assert!((a.makespan_s - b.makespan_s).abs() < 1e-12);
        assert!((a.cloud_busy_s - b.cloud_busy_s).abs() < 1e-12);
        assert_eq!(a.summed().1.cloud_requests as u64, a.cloud_passes);
    }

    #[test]
    fn naive_bytes_grow_quadratically() {
        let short = vec![vec![mk_trace(10, &[Cloud; 5])]];
        let long = vec![vec![mk_trace(10, &[Cloud; 50])]];
        let bs = simulate(&short, &dims(), &cost(), &cfg(Strategy::NaiveSplit)).summed().1.bytes_up;
        let bl = simulate(&long, &dims(), &cost(), &cfg(Strategy::NaiveSplit)).summed().1.bytes_up;
        // 10x the tokens must cost far more than 10x the bytes (O(T^2))
        assert!(bl > 2 * 10 * bs, "{bs} -> {bl}");
    }
}
