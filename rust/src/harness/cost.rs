//! Calibrated compute-cost model.
//!
//! The paper's tables are wall-clock sums over a two-A100 testbed.  Our
//! testbed executes both partitions on one CPU PJRT client, so the
//! harness measures real per-call times during trace recording
//! ([`super::trace::CallTimings`]) and replays them through the DES with
//! lognormal-ish jitter, giving the tables their `±` columns just as the
//! paper's five repeats do.

use crate::util::rng::Rng;

/// Mean/std summary of one call type's measured durations.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stat {
    pub mean_s: f64,
    pub std_s: f64,
}

impl Stat {
    pub fn from_samples(samples: &[f64]) -> Stat {
        if samples.is_empty() {
            return Stat { mean_s: 0.0, std_s: 0.0 };
        }
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / n.max(2.0);
        Stat { mean_s: mean, std_s: var.sqrt() }
    }

    /// Draw one duration: mean + gaussian jitter, clamped to stay
    /// positive (Box–Muller on the deterministic PRNG).
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        if self.std_s == 0.0 {
            return self.mean_s;
        }
        let u1 = rng.gen_f64().max(1e-12);
        let u2 = rng.gen_f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        (self.mean_s + z * self.std_s).max(self.mean_s * 0.2)
    }
}

/// Per-call-type costs for the whole stack.
///
/// `cloud_speedup` scales cloud-partition times: the paper uses identical
/// A100s on both sides (factor 1.0); other edge hardware can be modelled
/// by raising it.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    pub edge_prefill: Stat,
    pub seg1: Stat,
    pub seg2: Stat,
    pub cloud_prefill: Stat,
    pub cloud_decode: Stat,
    pub cloud_speedup: f64,
    /// Marginal cost per *additional* catch-up position in one cloud
    /// request.  The paper's cloud batches all pending hidden states into
    /// one forward (its Table 2 cloud time is proportional to the request
    /// rate, not to the position count); we calibrate the batched rate
    /// from the measured prefill artifact: prefill processes `max_prompt`
    /// positions in one call, so marginal ≈ cloud_prefill / max_prompt.
    pub cloud_batch_marginal: Stat,
}

impl CostModel {
    pub fn from_timings(t: &super::trace::CallTimings) -> CostModel {
        Self::from_timings_with_prompt(t, 256)
    }

    pub fn from_timings_with_prompt(
        t: &super::trace::CallTimings,
        max_prompt: usize,
    ) -> CostModel {
        let cloud_prefill = Stat::from_samples(&t.cloud_prefill);
        let per_pos = cloud_prefill.mean_s / max_prompt.max(1) as f64;
        CostModel {
            edge_prefill: Stat::from_samples(&t.edge_prefill),
            seg1: Stat::from_samples(&t.seg1),
            seg2: Stat::from_samples(&t.seg2),
            cloud_prefill,
            cloud_decode: Stat::from_samples(&t.cloud_decode),
            cloud_speedup: 1.0,
            cloud_batch_marginal: Stat {
                mean_s: per_pos,
                std_s: cloud_prefill.std_s / max_prompt.max(1) as f64,
            },
        }
    }

    /// Busy time of one cloud request that catches up `catchup` pending
    /// positions (>= 1): one full decode step for the requested token plus
    /// the batched marginal rate for the rest.
    pub fn sample_cloud_request(&self, catchup: usize, rng: &mut Rng) -> f64 {
        let mut busy = self.cloud_decode.sample(rng);
        for _ in 1..catchup.max(1) {
            busy += self.cloud_batch_marginal.sample(rng);
        }
        busy / self.cloud_speedup
    }

    /// A deterministic synthetic model for unit tests and dry runs:
    /// segment costs proportional to their layer counts.
    pub fn synthetic(dims: &crate::model::manifest::ModelDims) -> CostModel {
        let per_layer = 1e-3;
        let exact = |mean: f64| Stat { mean_s: mean, std_s: 0.0 };
        let n1 = dims.l_ee1 as f64;
        let n2 = (dims.l_ee2 - dims.l_ee1) as f64;
        let nc = (dims.n_layers - dims.l_ee1) as f64;
        CostModel {
            edge_prefill: exact(per_layer * (n1 + n2) * 8.0),
            seg1: exact(per_layer * n1),
            seg2: exact(per_layer * n2),
            cloud_prefill: exact(per_layer * nc * 8.0),
            cloud_decode: exact(per_layer * nc),
            cloud_speedup: 1.0,
            cloud_batch_marginal: exact(per_layer * nc * 8.0 / dims.max_prompt as f64),
        }
    }

    pub fn sample_edge_prefill(&self, rng: &mut Rng) -> f64 {
        self.edge_prefill.sample(rng)
    }

    pub fn sample_seg1(&self, rng: &mut Rng) -> f64 {
        self.seg1.sample(rng)
    }

    pub fn sample_seg2(&self, rng: &mut Rng) -> f64 {
        self.seg2.sample(rng)
    }

    pub fn sample_cloud_prefill(&self, rng: &mut Rng) -> f64 {
        self.cloud_prefill.sample(rng) / self.cloud_speedup
    }

    pub fn sample_cloud_decode(&self, rng: &mut Rng) -> f64 {
        self.cloud_decode.sample(rng) / self.cloud_speedup
    }

    /// Full-model decode step (cloud-only baseline): the full network is
    /// layers `0..l_ee1` (= seg1) plus the cloud partition `l_ee1..N`.
    pub fn sample_full_decode(&self, rng: &mut Rng) -> f64 {
        (self.seg1.sample(rng) + self.cloud_decode.sample(rng)) / self.cloud_speedup
    }

    /// Full-model prefill (cloud-only baseline).  The edge prefill
    /// measures layers `0..l_ee2` + two exit heads; the full model is
    /// layers `0..l_ee1` + cloud partition, approximated by scaling the
    /// edge prefill to seg1's share and adding the cloud prefill.
    pub fn sample_full_prefill(&self, rng: &mut Rng) -> f64 {
        let l1_share = self.seg1.mean_s / (self.seg1.mean_s + self.seg2.mean_s).max(1e-12);
        (self.edge_prefill.sample(rng) * l1_share + self.cloud_prefill.sample(rng))
            / self.cloud_speedup
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::test_manifest;

    #[test]
    fn stat_from_samples() {
        let s = Stat::from_samples(&[1.0, 2.0, 3.0]);
        assert!((s.mean_s - 2.0).abs() < 1e-12);
        assert!(s.std_s > 0.5 && s.std_s < 1.0);
        let empty = Stat::from_samples(&[]);
        assert_eq!(empty.mean_s, 0.0);
    }

    #[test]
    fn sampling_is_deterministic_given_seed() {
        let s = Stat { mean_s: 1.0, std_s: 0.1 };
        let mut a = Rng::seed_from_u64(5);
        let mut b = Rng::seed_from_u64(5);
        for _ in 0..10 {
            assert_eq!(s.sample(&mut a), s.sample(&mut b));
        }
    }

    #[test]
    fn samples_cluster_around_mean() {
        let s = Stat { mean_s: 1.0, std_s: 0.05 };
        let mut rng = Rng::seed_from_u64(1);
        let mean: f64 = (0..2000).map(|_| s.sample(&mut rng)).sum::<f64>() / 2000.0;
        assert!((mean - 1.0).abs() < 0.01, "{mean}");
    }

    #[test]
    fn samples_stay_positive() {
        let s = Stat { mean_s: 0.001, std_s: 0.01 }; // heavy jitter
        let mut rng = Rng::seed_from_u64(2);
        for _ in 0..1000 {
            assert!(s.sample(&mut rng) > 0.0);
        }
    }

    #[test]
    fn synthetic_model_ordering() {
        let m = CostModel::synthetic(&test_manifest().model);
        // cloud partition (5 layers) costs more than seg1 (3 layers)
        assert!(m.cloud_decode.mean_s > m.seg1.mean_s);
        // full decode = seg1 + cloud
        let mut rng = Rng::seed_from_u64(0);
        let full = m.sample_full_decode(&mut rng);
        assert!((full - (m.seg1.mean_s + m.cloud_decode.mean_s)).abs() < 1e-12);
    }

    #[test]
    fn cloud_speedup_scales_cloud_only() {
        let mut m = CostModel::synthetic(&test_manifest().model);
        m.cloud_speedup = 2.0;
        let mut rng = Rng::seed_from_u64(0);
        assert!((m.sample_cloud_decode(&mut rng) - m.cloud_decode.mean_s / 2.0).abs() < 1e-12);
        assert!((m.sample_seg1(&mut rng) - m.seg1.mean_s).abs() < 1e-12);
    }
}
