//! Experiment harnesses regenerating the paper's tables and figures.
//!
//! Pipeline (DESIGN.md §5): real engines record per-prompt [`trace`]s and
//! measured call timings; [`cost`] summarizes timings into a calibrated
//! cost model; [`des`] replays traces under each deployment strategy over
//! a WAN model; [`tables`] renders the paper's rows; [`runner`] wires it
//! all together behind the `ce-collm` CLI.

pub mod cost;
pub mod des;
pub mod runner;
pub mod tables;
pub mod trace;
