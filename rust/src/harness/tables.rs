//! Drivers that regenerate every table and figure of the paper's
//! evaluation section (see DESIGN.md §5 for the experiment index).

use anyhow::Result;

use crate::config::AblationFlags;
use crate::eval::datasets::{self, Dataset};
use crate::eval::{exact_match, rouge_l};
use crate::harness::cost::CostModel;
use crate::harness::des::{simulate, SimConfig, Strategy};
use crate::harness::runner::{
    record_set, rouge_vs_reference, ExperimentConfig, PolicyKey, PolicyTraces, Recorded,
};
use crate::harness::trace::{record, CallTimings, Trace};
use crate::metrics::{Aggregate, Table};
use crate::model::manifest::ModelDims;
use crate::net::profiles::LinkProfile;
use crate::quant::Precision;
use crate::runtime::traits::{CloudEngine, EdgeEngine};

fn aggregate_strategy(
    traces: &[Trace],
    dims: &ModelDims,
    cost: &CostModel,
    link: LinkProfile,
    strategy: Strategy,
    repeats: usize,
    seed: u64,
) -> Aggregate {
    let mut agg = Aggregate::default();
    let per_client = vec![traces.to_vec()];
    for r in 0..repeats.max(1) {
        let cfg = SimConfig {
            strategy,
            link,
            seed: seed ^ (r as u64) << 17,
            workers: 1,
            cross_device_batch: true,
            ..Default::default()
        };
        let out = simulate(&per_client, dims, cost, &cfg);
        let (c, k) = out.summed();
        agg.push(&c, &k, None);
    }
    agg
}

/// Table 2: cost & performance across deployment strategies, one block
/// per dataset (Alpaca-like, XSum-like).
pub fn table2(rec: &Recorded, dims: &ModelDims, link: LinkProfile, cfg: &ExperimentConfig) -> String {
    let mut t = Table::new(&[
        "Dataset",
        "Deployment Strategy",
        "Total Time Cost (s)",
        "Edge Time Cost (s)",
        "Cloud Time Cost (s)",
        "Comm Time Cost (s)",
        "Request Cloud Rate (%)",
        "Transmitted (MB)",
        "Rouge-L",
    ]);
    for pt in [&rec.alpaca, &rec.xsum] {
        table2_block(&mut t, pt, dims, &pt.cost, link, cfg);
    }
    t.render()
}

fn table2_block(
    t: &mut Table,
    pt: &PolicyTraces,
    dims: &ModelDims,
    cost: &CostModel,
    link: LinkProfile,
    cfg: &ExperimentConfig,
) {
    let refs = pt.reference_texts();
    let ds = pt.dataset.name();
    let mut push = |label: &str, agg: Aggregate, rouge: Option<f64>| {
        t.row(vec![
            ds.to_string(),
            label.to_string(),
            agg.total_s.fmt_pm(3),
            agg.edge_s.fmt_pm(3),
            agg.cloud_s.fmt_pm(3),
            agg.comm_s.fmt_pm(3),
            if label.contains("Cloud-based") {
                "N/A".into()
            } else {
                format!("{:.2}", agg.request_rate.mean())
            },
            if label.contains("Cloud-based") {
                "N/A".into()
            } else {
                format!("{:.2}", agg.transmitted_mb.mean())
            },
            rouge.map(|r| format!("{r:.4}")).unwrap_or_else(|| "N/A".into()),
        ]);
    };

    let run = |traces: &[Trace], strategy: Strategy| {
        aggregate_strategy(traces, dims, cost, link, strategy, cfg.repeats, cfg.seed)
    };

    push("Cloud-based LLM Deployment", run(&pt.t10, Strategy::CloudOnly), None);
    push("Naive Cloud-Edge Deployment", run(&pt.t10, Strategy::NaiveSplit), Some(1.0));
    push(
        "CE-CoLLM (standalone)",
        run(&pt.standalone, Strategy::Standalone),
        Some(rouge_vs_reference(&pt.standalone, &refs)),
    );
    for key in [PolicyKey::T08, PolicyKey::T09, PolicyKey::T10] {
        let traces = pt.for_policy(key);
        push(
            key.label(),
            run(traces, Strategy::CeCollm(AblationFlags::default())),
            Some(rouge_vs_reference(traces, &refs)),
        );
    }
}

/// Table 4: ablation at θ=0.8 (−fp16, −early-exit, −content-manager &
/// parallel upload) for both datasets.
pub fn table4(rec: &Recorded, dims: &ModelDims, link: LinkProfile, cfg: &ExperimentConfig) -> String {
    let mut t = Table::new(&[
        "Dataset",
        "Condition",
        "Total Time Cost (s)",
        "Edge Time Cost (s)",
        "Cloud Time Cost (s)",
        "Comm Time Cost (s)",
        "Relative Total Cost (%)",
    ]);
    for pt in [&rec.alpaca, &rec.xsum] {
        let ds = pt.dataset.name();
        let run = |traces: &[Trace], flags: AblationFlags| {
            aggregate_strategy(
                traces,
                dims,
                &pt.cost,
                link,
                Strategy::CeCollm(flags),
                cfg.repeats,
                cfg.seed,
            )
        };
        let base = run(&pt.t08, AblationFlags::default());
        let base_total = base.total_s.mean();
        let rows: Vec<(&str, Aggregate)> = vec![
            ("Our Proposal Method (Threshold=0.8)", base),
            ("Without Half Precision Transmission", run(&pt.t08, AblationFlags::without_half_precision())),
            // −EE: every token goes to the cloud == replaying the θ=1.0 trace
            ("Without Early Exit Mechanism", run(&pt.t10, AblationFlags::without_early_exit())),
            (
                "Without Content Manager & Parallel Upload",
                run(&pt.t08, AblationFlags::without_cm_and_parallel_upload()),
            ),
        ];
        for (label, agg) in rows {
            let rel = 100.0 * agg.total_s.mean() / base_total.max(1e-12);
            t.row(vec![
                ds.to_string(),
                label.to_string(),
                agg.total_s.fmt_pm(3),
                agg.edge_s.fmt_pm(3),
                agg.cloud_s.fmt_pm(3),
                agg.comm_s.fmt_pm(3),
                format!("{rel:.2}"),
            ]);
        }
    }
    t.render()
}

/// Figure 4 (a)(b): edge/comm/cloud time vs number of edge devices for
/// θ ∈ {0.8, 0.9}, with the cloud-based total as the baseline series;
/// (c): request-cloud rate and transmitted MB, CE-CoLLM vs naïve.
pub fn fig4(
    rec: &Recorded,
    dims: &ModelDims,
    link: LinkProfile,
    cfg: &ExperimentConfig,
    max_clients: usize,
) -> String {
    let mut out = String::new();
    for pt in [&rec.alpaca, &rec.xsum] {
        out.push_str(&format!("Figure 4 — {} dataset\n", pt.dataset.name()));
        let mut t = Table::new(&[
            "Clients",
            "Strategy",
            "Makespan (s)",
            "Edge (s, per client)",
            "Cloud (s, total)",
            "Comm (s, total)",
        ]);
        for n in 1..=max_clients {
            for (label, traces, strategy) in [
                ("CE-CoLLM θ=0.8", &pt.t08, Strategy::CeCollm(AblationFlags::default())),
                ("CE-CoLLM θ=0.9", &pt.t09, Strategy::CeCollm(AblationFlags::default())),
                ("Cloud-based", &pt.t10, Strategy::CloudOnly),
            ] {
                let per_client: Vec<Vec<Trace>> = (0..n).map(|_| traces.to_vec()).collect();
                let mut makespan = crate::metrics::MeanStd::default();
                let mut edge = crate::metrics::MeanStd::default();
                let mut cloud = crate::metrics::MeanStd::default();
                let mut comm = crate::metrics::MeanStd::default();
                for r in 0..cfg.repeats.max(1) {
                    let sim = SimConfig {
                        strategy,
                        link,
                        seed: cfg.seed ^ (r as u64) << 9,
                        workers: 1,
                        cross_device_batch: true,
                        ..Default::default()
                    };
                    let o = simulate(&per_client, dims, &pt.cost, &sim);
                    let (c, _) = o.summed();
                    makespan.push(o.makespan_s);
                    edge.push(c.edge_s / n as f64);
                    cloud.push(c.cloud_s);
                    comm.push(c.comm_s);
                }
                t.row(vec![
                    n.to_string(),
                    label.to_string(),
                    makespan.fmt_pm(3),
                    edge.fmt_pm(3),
                    cloud.fmt_pm(3),
                    comm.fmt_pm(3),
                ]);
            }
        }
        out.push_str(&t.render());
        out.push_str("\n\n");
    }

    // (c) request rate + transmitted data, single client
    out.push_str("Figure 4(c) — request cloud rate & transmitted data\n");
    let mut t = Table::new(&["Dataset", "Strategy", "Request Cloud Rate (%)", "Transmitted (MB)"]);
    for pt in [&rec.alpaca, &rec.xsum] {
        for (label, traces, strategy) in [
            ("CE-CoLLM θ=0.8", &pt.t08, Strategy::CeCollm(AblationFlags::default())),
            ("CE-CoLLM θ=0.9", &pt.t09, Strategy::CeCollm(AblationFlags::default())),
            ("Naive Cloud-Edge", &pt.t10, Strategy::NaiveSplit),
        ] {
            let sim = SimConfig {
                strategy,
                link,
                seed: cfg.seed,
                workers: 1,
                cross_device_batch: true,
                ..Default::default()
            };
            let o = simulate(&[traces.to_vec()], dims, &pt.cost, &sim);
            let (_, k) = o.summed();
            t.row(vec![
                pt.dataset.name().to_string(),
                label.to_string(),
                format!("{:.2}", k.request_cloud_rate() * 100.0),
                format!("{:.2}", k.transmitted_mb()),
            ]);
        }
    }
    out.push_str(&t.render());
    out.push('\n');
    out
}

/// Table 3: EM / ROUGE-L across thresholds × transmission precision on
/// TruthfulQA / XSum / CNN-DailyMail-like sets, vs the cloud (fp32) row.
pub fn table3(
    edge: &mut dyn EdgeEngine,
    cloud: &mut dyn CloudEngine,
    cfg: &ExperimentConfig,
) -> Result<String> {
    let sets = [
        (Dataset::TruthfulQa, "TruthfulQA"),
        (Dataset::Xsum, "XSum"),
        (Dataset::CnnDailyMail, "CNN/Daily Mail"),
    ];
    // rows: (label, policy key or cloud, precision)
    let mut rows: Vec<(String, Option<PolicyKey>, Precision)> = Vec::new();
    for key in [PolicyKey::T08, PolicyKey::T09, PolicyKey::T10] {
        for (p, pn) in [(Precision::F32, "float32"), (Precision::F16, "float16")] {
            let theta = match key {
                PolicyKey::T08 => "0.8",
                PolicyKey::T09 => "0.9",
                _ => "1.0",
            };
            rows.push((format!("CE-CoLLM (threshold={theta}, {pn})"), Some(key), p));
        }
    }
    rows.push(("Cloud-based LLM (float32)".into(), None, Precision::F32));

    let mut table = Table::new(&["Condition", "TruthfulQA", "XSum", "CNN/Daily Mail"]);
    let mut cells: Vec<Vec<String>> = vec![vec![]; rows.len()];
    let mut timings = CallTimings::default();

    for (ds, _name) in sets {
        let set = datasets::generate(ds, cfg.n_prompts, cfg.seed ^ 0x73);
        for (i, (_, key, precision)) in rows.iter().enumerate() {
            let policy = key.map(|k| k.policy()).unwrap_or(crate::config::ExitPolicy::Threshold(1.0));
            let traces = record_set(edge, cloud, &set, policy, *precision,
                                    cfg.max_new_tokens, &mut timings)?;
            let score: f64 = set
                .cases
                .iter()
                .zip(&traces)
                .map(|(case, tr)| {
                    let reference = case.reference.as_deref().unwrap_or("");
                    match ds {
                        // template-validity EM — see eval::em::template_match
                        Dataset::TruthfulQa => {
                            exact_match(&tr.text, reference)
                                .max(crate::eval::em::template_match(&tr.text))
                        }
                        _ => rouge_l(&tr.text, reference),
                    }
                })
                .sum::<f64>()
                / set.cases.len().max(1) as f64;
            cells[i].push(format!("{score:.4}"));
        }
    }
    for ((label, _, _), scores) in rows.iter().zip(cells) {
        let mut row = vec![label.clone()];
        row.extend(scores);
        table.row(row);
    }
    Ok(table.render())
}

/// Table 1: predicted tokens + confidence at each exit for one prompt.
pub fn table1(
    edge: &mut dyn EdgeEngine,
    cloud: &mut dyn CloudEngine,
    prompt: &str,
    max_new_tokens: usize,
) -> Result<String> {
    let mut timings = CallTimings::default();
    // θ=1.0: every position evaluates both exits AND the final head
    let tr = record(
        edge,
        cloud,
        crate::config::ExitPolicy::Threshold(1.0),
        Precision::F16,
        prompt,
        max_new_tokens,
        &mut timings,
    )?;
    let show = |tok: i32| -> String {
        match tok {
            0..=255 => {
                let c = tok as u8 as char;
                if c.is_ascii_graphic() || c == ' ' {
                    format!("{c:?}")
                } else {
                    format!("0x{tok:02x}")
                }
            }
            256 => "<BOS>".into(),
            257 => "<EOS>".into(),
            _ => format!("#{tok}"),
        }
    };
    let mut t = Table::new(&[
        "ID",
        "Exit1 Token",
        "conf",
        "Exit2 Token",
        "conf",
        "Final Token",
        "conf",
    ]);
    for (i, s) in tr.steps.iter().enumerate() {
        t.row(vec![
            (i + 1).to_string(),
            show(s.tok1),
            format!("{:.4}", s.conf1),
            s.tok2.map(show).unwrap_or_else(|| "-".into()),
            s.conf2.map(|c| format!("{c:.4}")).unwrap_or_else(|| "-".into()),
            show(s.token),
            s.cloud_conf.map(|c| format!("{c:.4}")).unwrap_or_else(|| "-".into()),
        ]);
    }
    Ok(format!("prompt: {prompt:?}\ngenerated: {:?}\n{}", tr.text, t.render()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::runner::record_main_experiments;
    use crate::model::manifest::test_manifest;
    use crate::runtime::mock::{MockCloud, MockEdge, MockOracle};

    fn pair(seed: u64) -> (MockEdge, MockCloud) {
        let dims = test_manifest().model;
        let o = MockOracle::new(seed);
        (MockEdge::new(o, dims.clone()), MockCloud::new(o, dims))
    }

    fn small_cfg() -> ExperimentConfig {
        ExperimentConfig { n_prompts: 3, repeats: 2, max_new_tokens: 10, seed: 3 }
    }

    #[test]
    fn table2_renders_all_rows() {
        let (mut e, mut c) = pair(1);
        let cfg = small_cfg();
        let rec = record_main_experiments(&mut e, &mut c, &cfg).unwrap();
        let dims = test_manifest().model;
        let s = table2(&rec, &dims, LinkProfile::wifi(), &cfg);
        assert_eq!(s.lines().count(), 2 + 12, "6 strategies x 2 datasets\n{s}");
        assert!(s.contains("CE-CoLLM (standalone)"));
        assert!(s.contains("Naive Cloud-Edge Deployment"));
        assert!(s.contains("XSum"));
    }

    #[test]
    fn table4_relative_costs_above_100() {
        let (mut e, mut c) = pair(2);
        let cfg = small_cfg();
        let rec = record_main_experiments(&mut e, &mut c, &cfg).unwrap();
        let dims = test_manifest().model;
        let s = table4(&rec, &dims, LinkProfile::wifi(), &cfg);
        assert!(s.contains("Without Early Exit Mechanism"));
        // baseline rows are exactly 100.00
        assert_eq!(s.matches("| 100.00").count(), 2, "{s}");
    }

    #[test]
    fn fig4_renders_series() {
        let (mut e, mut c) = pair(3);
        let cfg = small_cfg();
        let rec = record_main_experiments(&mut e, &mut c, &cfg).unwrap();
        let dims = test_manifest().model;
        let s = fig4(&rec, &dims, LinkProfile::wifi(), &cfg, 3);
        assert!(s.contains("Figure 4(c)"));
        assert!(s.contains("Cloud-based"));
    }

    #[test]
    fn table1_has_per_token_rows() {
        let (mut e, mut c) = pair(4);
        let s = table1(&mut e, &mut c, "the turing test is", 8).unwrap();
        assert!(s.contains("Exit1 Token"));
        assert!(s.lines().count() >= 8);
    }

    #[test]
    fn table3_renders() {
        let (mut e, mut c) = pair(5);
        let cfg = ExperimentConfig { n_prompts: 2, repeats: 1, max_new_tokens: 8, seed: 9 };
        let s = table3(&mut e, &mut c, &cfg).unwrap();
        assert!(s.contains("Cloud-based LLM (float32)"));
        assert!(s.contains("threshold=0.9, float16"));
    }
}
