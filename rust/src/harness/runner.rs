//! Orchestration: prompt sets → recorded traces → calibrated cost model.
//!
//! Generic over the engine traits so the full pipeline runs against mock
//! engines in tests and against the PJRT stack from the CLI/examples.

use anyhow::Result;

use crate::config::ExitPolicy;
use crate::eval::datasets::{self, Dataset, PromptSet};
use crate::harness::cost::CostModel;
use crate::harness::trace::{record, CallTimings, Trace};
use crate::quant::Precision;
use crate::runtime::traits::{CloudEngine, EdgeEngine};

/// Experiment-wide knobs (defaults sized for the 1-core CI testbed; the
/// paper-scale run uses `--prompts 100 --repeats 5`).
#[derive(Debug, Clone, Copy)]
pub struct ExperimentConfig {
    pub n_prompts: usize,
    pub repeats: usize,
    pub max_new_tokens: usize,
    pub seed: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self { n_prompts: 25, repeats: 5, max_new_tokens: 96, seed: 42 }
    }
}

/// Traces for one dataset across the policies Table 2 needs, plus the
/// per-dataset calibrated cost model (prefill costs differ by bucket:
/// short Alpaca prompts use the P=64 artifacts, XSum the P=256 ones).
pub struct PolicyTraces {
    pub dataset: Dataset,
    pub standalone: Vec<Trace>,
    pub t08: Vec<Trace>,
    pub t09: Vec<Trace>,
    pub t10: Vec<Trace>,
    pub cost: CostModel,
}

impl PolicyTraces {
    pub fn for_policy(&self, key: PolicyKey) -> &[Trace] {
        match key {
            PolicyKey::Standalone => &self.standalone,
            PolicyKey::T08 => &self.t08,
            PolicyKey::T09 => &self.t09,
            PolicyKey::T10 => &self.t10,
        }
    }

    /// Reference text per prompt = the cloud deployment's output (θ=1.0
    /// runs the full model for every token).
    pub fn reference_texts(&self) -> Vec<&str> {
        self.t10.iter().map(|t| t.text.as_str()).collect()
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKey {
    Standalone,
    T08,
    T09,
    T10,
}

impl PolicyKey {
    pub fn policy(self) -> ExitPolicy {
        match self {
            PolicyKey::Standalone => ExitPolicy::Standalone { threshold: 0.8 },
            PolicyKey::T08 => ExitPolicy::Threshold(0.8),
            PolicyKey::T09 => ExitPolicy::Threshold(0.9),
            PolicyKey::T10 => ExitPolicy::Threshold(1.0),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            PolicyKey::Standalone => "CE-CoLLM (standalone)",
            PolicyKey::T08 => "CE-CoLLM (threshold=0.8)",
            PolicyKey::T09 => "CE-CoLLM (threshold=0.9)",
            PolicyKey::T10 => "CE-CoLLM (threshold=1.0)",
        }
    }
}

/// Record traces for a whole prompt set under one policy/precision.
pub fn record_set(
    edge: &mut dyn EdgeEngine,
    cloud: &mut dyn CloudEngine,
    set: &PromptSet,
    policy: ExitPolicy,
    precision: Precision,
    max_new_tokens: usize,
    timings: &mut CallTimings,
) -> Result<Vec<Trace>> {
    let mut out = Vec::with_capacity(set.cases.len());
    for case in &set.cases {
        out.push(record(edge, cloud, policy, precision, &case.prompt, max_new_tokens, timings)?);
    }
    Ok(out)
}

/// Record the four policy variants Table 2 compares, for one dataset.
pub fn record_policy_traces(
    edge: &mut dyn EdgeEngine,
    cloud: &mut dyn CloudEngine,
    dataset: Dataset,
    cfg: &ExperimentConfig,
    timings: &mut CallTimings,
) -> Result<PolicyTraces> {
    let set = datasets::generate(dataset, cfg.n_prompts, cfg.seed);
    let rec = |edge: &mut dyn EdgeEngine,
               cloud: &mut dyn CloudEngine,
               key: PolicyKey,
               timings: &mut CallTimings|
     -> Result<Vec<Trace>> {
        record_set(edge, cloud, &set, key.policy(), Precision::F16, cfg.max_new_tokens, timings)
    };
    let mut own = CallTimings::default();
    let pt = PolicyTraces {
        dataset,
        standalone: rec(edge, cloud, PolicyKey::Standalone, &mut own)?,
        t08: rec(edge, cloud, PolicyKey::T08, &mut own)?,
        t09: rec(edge, cloud, PolicyKey::T09, &mut own)?,
        t10: rec(edge, cloud, PolicyKey::T10, &mut own)?,
        cost: CostModel::from_timings_with_prompt(&own, edge.dims().max_prompt),
    };
    timings.merge(&own);
    Ok(pt)
}

/// Record traces + calibrate the cost model for the Table 2/4 + Fig 4
/// experiments (Alpaca-like and XSum-like sets).
pub struct Recorded {
    pub alpaca: PolicyTraces,
    pub xsum: PolicyTraces,
    pub cost: CostModel,
    pub timings: CallTimings,
}

pub fn record_main_experiments(
    edge: &mut dyn EdgeEngine,
    cloud: &mut dyn CloudEngine,
    cfg: &ExperimentConfig,
) -> Result<Recorded> {
    let mut timings = CallTimings::default();
    let alpaca = record_policy_traces(edge, cloud, Dataset::Alpaca, cfg, &mut timings)?;
    let xsum = record_policy_traces(edge, cloud, Dataset::Xsum, cfg, &mut timings)?;
    let cost = CostModel::from_timings_with_prompt(&timings, edge.dims().max_prompt);
    Ok(Recorded { alpaca, xsum, cost, timings })
}

/// Mean ROUGE-L of each trace's text against the θ=1.0 reference.
pub fn rouge_vs_reference(traces: &[Trace], refs: &[&str]) -> f64 {
    if traces.is_empty() {
        return 0.0;
    }
    let sum: f64 = traces
        .iter()
        .zip(refs)
        .map(|(t, r)| crate::eval::rouge::rouge_l(&t.text, r))
        .sum();
    sum / traces.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::test_manifest;
    use crate::runtime::mock::{MockCloud, MockEdge, MockOracle};

    fn pair(seed: u64) -> (MockEdge, MockCloud) {
        let dims = test_manifest().model;
        let o = MockOracle::new(seed);
        (MockEdge::new(o, dims.clone()), MockCloud::new(o, dims))
    }

    #[test]
    fn record_policy_traces_end_to_end() {
        let (mut e, mut c) = pair(11);
        let cfg = ExperimentConfig { n_prompts: 4, repeats: 2, max_new_tokens: 12, seed: 1 };
        let mut t = CallTimings::default();
        let pt = record_policy_traces(&mut e, &mut c, Dataset::Alpaca, &cfg, &mut t).unwrap();
        assert_eq!(pt.standalone.len(), 4);
        assert_eq!(pt.t10.len(), 4);
        // θ=1.0 routes everything to the cloud
        for tr in &pt.t10 {
            assert!(tr.cloud_rate() > 0.999);
        }
        // standalone never does
        for tr in &pt.standalone {
            assert_eq!(tr.cloud_rate(), 0.0);
        }
        // monotone: lower θ -> no more cloud tokens than higher θ
        let rate = |ts: &[Trace]| {
            ts.iter().map(|t| t.cloud_rate()).sum::<f64>() / ts.len() as f64
        };
        assert!(rate(&pt.t08) <= rate(&pt.t09) + 1e-9);
        assert!(rate(&pt.t09) <= rate(&pt.t10) + 1e-9);
    }

    #[test]
    fn rouge_reference_is_identity_for_t10() {
        let (mut e, mut c) = pair(5);
        let cfg = ExperimentConfig { n_prompts: 3, repeats: 1, max_new_tokens: 10, seed: 2 };
        let mut t = CallTimings::default();
        let pt = record_policy_traces(&mut e, &mut c, Dataset::Alpaca, &cfg, &mut t).unwrap();
        let refs = pt.reference_texts();
        let r = rouge_vs_reference(&pt.t10, &refs);
        assert!((r - 1.0).abs() < 1e-12);
    }
}
