//! Cloud server (paper §4.2): receives hidden-state uploads, manages
//! per-device context, and serves single-token inference requests.
//!
//! Thread model — `workers + 1` threads total, independent of how many
//! devices are connected (see [`crate::coordinator::scheduler`] for the
//! serving core and [`crate::net::reactor`] for the connection layer):
//! * a **worker pool** ([`Scheduler`]) — each worker thread owns its own
//!   `CloudEngine` sessions and content-manager shard for the devices
//!   assigned to it (`device_id % workers`; PJRT handles are `!Send`, so
//!   each worker builds its engines on its own thread).  An infer request
//!   whose uploads have not landed parks on its worker and is woken by
//!   the covering `Upload` — purely event-driven, no polling;
//! * one **reactor** thread owns the listener fd *and* all connection
//!   sockets (nonblocking, multiplexed through
//!   [`EventSet`](crate::net::event::EventSet) — edge-triggered `epoll`
//!   on Linux, `poll(2)` elsewhere).  Accepting happens inside the wake
//!   loop, so the dedicated acceptor thread of earlier revisions is
//!   gone along with the per-connection `std::thread::spawn` before it:
//!   a thousand edge devices cost two thousand registered sockets, not
//!   two thousand blocked threads plus an acceptor.  The reactor
//!   decodes frames through the shared
//!   [`FrameCodec`](crate::net::codec::FrameCodec), routes work to the
//!   owning worker through a [`Router`], and writes responses back as
//!   each socket accepts them.
//!
//! The paper's "Dual API" maps to two connections per device (upload
//! channel + infer channel), each announced by a `Hello`.  Because the
//! channels are independent, an `InferRequest` may overtake its own
//! uploads in flight; the scheduler's parking makes that race benign.
//!
//! Shutdown is deterministic: [`CloudServer::shutdown`] joins the
//! reactor — which stops accepting and closes every registered socket
//! before exiting — then drains the worker pool.  When it returns, no
//! connection can still produce a response.

use std::net::TcpListener;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::CloudConfig;
use crate::model::manifest::ModelDims;
use crate::net::reactor::{Reactor, ReactorStats};

pub use crate::coordinator::context_store::{ContextStore, ContextStoreStats};
pub use crate::coordinator::scheduler::{
    CloudStats, FactoryBuilder, InferOutcome, Reply, Router, SchedMsg, Scheduler, SessionFactory,
    TokenOut, UploadPayload,
};

/// A running cloud server bound to a TCP listener.
pub struct CloudServer {
    pub addr: std::net::SocketAddr,
    scheduler: Option<Scheduler>,
    reactor: Option<Reactor>,
}

impl CloudServer {
    /// Spawn the server with `cfg.workers` serving threads plus the
    /// connection reactor (which owns the listener — no acceptor
    /// thread).  `builder` runs on every worker thread and constructs
    /// that worker's engine factory there (PJRT objects never cross
    /// threads).
    pub fn spawn<B>(
        listener: TcpListener,
        dims: ModelDims,
        cfg: CloudConfig,
        builder: B,
    ) -> Result<CloudServer>
    where
        B: Fn() -> Result<SessionFactory> + Send + Sync + 'static,
    {
        let addr = listener.local_addr()?;
        let scheduler = Scheduler::spawn(dims.clone(), cfg, Arc::new(builder))?;
        let reactor = Reactor::spawn(scheduler.router(), dims, cfg.reactor, Some(listener))?;
        Ok(CloudServer { addr, scheduler: Some(scheduler), reactor: Some(reactor) })
    }

    pub fn stats(&self) -> Result<CloudStats> {
        self.scheduler.as_ref().context("scheduler gone")?.stats()
    }

    /// Connection-layer counters (open connections, evictions, frames).
    pub fn reactor_stats(&self) -> Result<ReactorStats> {
        self.reactor.as_ref().context("reactor gone")?.handle().stats()
    }

    /// Stop accepting, close every connection, and shut down the worker
    /// pool; returns final serving stats.  Deterministic: when this
    /// returns, every socket the server ever registered is closed.
    pub fn shutdown(mut self) -> CloudStats {
        if let Some(r) = self.reactor.take() {
            // joining the reactor closes the listener and every socket
            let rs = r.shutdown();
            log::debug!(
                "reactor ({}) closed: {} conns opened, {} evicted slow, \
                 {} frames in / {} out over {} wakes",
                rs.backend,
                rs.conns_opened,
                rs.evicted_slow,
                rs.frames_in,
                rs.frames_out,
                rs.wakes
            );
        }
        self.scheduler.take().map(Scheduler::shutdown).unwrap_or_default()
    }
}

impl Drop for CloudServer {
    fn drop(&mut self) {
        // dropping the reactor stops accepting and closes every
        // connection; dropping the scheduler tells every worker to stop
        self.reactor.take();
        self.scheduler.take();
    }
}
