//! Cloud server (paper §4.2): receives hidden-state uploads, manages
//! per-device context, and serves single-token inference requests.
//!
//! Thread model:
//! * one **GPU worker** thread owns all `CloudEngine` sessions (PJRT
//!   handles are `!Send`, and the paper's cloud has a single inference
//!   GPU — FIFO processing falls out naturally from the mpsc queue);
//! * one **acceptor** thread takes TCP connections;
//! * one thread per connection decodes frames and forwards work.
//!
//! The paper's "Dual API" maps to two connections per device (upload
//! channel + infer channel), each announced by a `Hello`.

use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::content_manager::ContentManager;
use crate::coordinator::protocol::Message;
use crate::model::manifest::ModelDims;
use crate::net::transport::{TcpTransport, Transport};
use crate::quant;
use crate::runtime::traits::CloudEngine;

/// Session factory living on the GPU worker thread.
pub type SessionFactory = Box<dyn FnMut(u64) -> Result<Box<dyn CloudEngine>>>;

/// Work items for the GPU worker.
pub enum GpuMsg {
    Upload { device: u64, req_id: u32, start_pos: u32, prompt_len: u32, hiddens: Vec<f32> },
    Infer {
        device: u64,
        req_id: u32,
        pos: u32,
        prompt_len: u32,
        reply: Sender<Result<(i32, f32, f64)>>,
        /// Dependency-wait counter: an infer can overtake its own uploads
        /// (they travel on a different connection); the worker requeues it
        /// a bounded number of times until the uploads land.
        retries: u16,
    },
    End { device: u64 },
    Stats { reply: Sender<CloudStats> },
    Shutdown,
}

#[derive(Debug, Clone, Default)]
pub struct CloudStats {
    pub requests_served: u64,
    pub uploads: u64,
    pub busy_s: f64,
    pub active_devices: usize,
    pub pending_floats: usize,
}

/// The GPU worker loop: single consumer of [`GpuMsg`], owner of every
/// cloud session and the content manager.  Public so in-process tests and
/// the DES harness can drive it without sockets.
pub fn gpu_worker(
    dims: ModelDims,
    mut factory: SessionFactory,
    rx: Receiver<GpuMsg>,
    self_tx: Sender<GpuMsg>,
) -> CloudStats {
    let mut cm = ContentManager::new(dims.d_model);
    let mut sessions: HashMap<u64, Box<dyn CloudEngine>> = HashMap::new();
    let mut stats = CloudStats::default();

    while let Ok(msg) = rx.recv() {
        match msg {
            GpuMsg::Upload { device, req_id, start_pos, prompt_len, hiddens } => {
                stats.uploads += 1;
                if let Err(e) = cm.upload(device, req_id, start_pos, prompt_len, &hiddens) {
                    log::warn!("upload from device {device} rejected: {e:#}");
                }
            }
            GpuMsg::Infer { device, req_id, pos, prompt_len, reply, retries } => {
                let t0 = Instant::now();
                let plan = match cm.plan(device, req_id, pos, prompt_len) {
                    Ok(p) => p,
                    Err(e) if retries < 500 => {
                        // uploads still in flight on the other connection:
                        // requeue behind them (paper: uploads always precede
                        // the request logically)
                        std::thread::sleep(std::time::Duration::from_millis(1));
                        let _ = self_tx.send(GpuMsg::Infer {
                            device,
                            req_id,
                            pos,
                            prompt_len,
                            reply,
                            retries: retries + 1,
                        });
                        let _ = e;
                        continue;
                    }
                    Err(e) => {
                        stats.requests_served += 1;
                        let _ = reply.send(Err(e));
                        continue;
                    }
                };
                let result = (|| -> Result<(i32, f32, f64)> {
                    let session = match sessions.entry(device) {
                        std::collections::hash_map::Entry::Occupied(o) => o.into_mut(),
                        std::collections::hash_map::Entry::Vacant(v) => v.insert(factory(device)?),
                    };
                    let mut last = None;
                    if let Some((h, len)) = &plan.prefill {
                        session.reset();
                        let out = session.prefill(h, *len)?;
                        if pos as usize == *len - 1 {
                            // request answered by the prefill head itself
                            last = Some((out.exit.token, out.exit.conf));
                        }
                    }
                    for (p, h) in &plan.decode {
                        let out = session.decode(h, *p as usize)?;
                        last = Some((out.exit.token, out.exit.conf));
                    }
                    let (token, conf) = match last {
                        Some(tc) => tc,
                        None => anyhow::bail!("nothing to compute for pos {pos}"),
                    };
                    Ok((token, conf, t0.elapsed().as_secs_f64()))
                })();
                stats.requests_served += 1;
                stats.busy_s += t0.elapsed().as_secs_f64();
                let _ = reply.send(result);
            }
            GpuMsg::End { device } => {
                cm.end_session(device);
                sessions.remove(&device);
            }
            GpuMsg::Stats { reply } => {
                stats.active_devices = cm.device_count();
                stats.pending_floats = cm.pending_floats();
                let _ = reply.send(stats.clone());
            }
            GpuMsg::Shutdown => break,
        }
    }
    stats
}

/// A running cloud server bound to a TCP listener.
pub struct CloudServer {
    pub addr: std::net::SocketAddr,
    gpu_tx: Sender<GpuMsg>,
    stop: Arc<AtomicBool>,
    acceptor: Option<std::thread::JoinHandle<()>>,
    gpu: Option<std::thread::JoinHandle<CloudStats>>,
}

impl CloudServer {
    /// Spawn the server.  `builder` runs on the GPU thread and constructs
    /// the engine factory there (PJRT objects never cross threads).
    pub fn spawn<B>(listener: TcpListener, dims: ModelDims, builder: B) -> Result<CloudServer>
    where
        B: FnOnce() -> Result<SessionFactory> + Send + 'static,
    {
        let addr = listener.local_addr()?;
        let (gpu_tx, gpu_rx) = channel::<GpuMsg>();
        let gdims = dims.clone();
        let self_tx = gpu_tx.clone();
        let gpu = std::thread::Builder::new()
            .name("cloud-gpu".into())
            .spawn(move || {
                let factory = match builder() {
                    Ok(f) => f,
                    Err(e) => {
                        log::error!("cloud engine builder failed: {e:#}");
                        return CloudStats::default();
                    }
                };
                gpu_worker(gdims, factory, gpu_rx, self_tx)
            })?;

        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let conn_tx = gpu_tx.clone();
        let dims2 = dims;
        let acceptor = std::thread::Builder::new().name("cloud-accept".into()).spawn(move || {
            for stream in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                match stream {
                    Ok(s) => {
                        let tx = conn_tx.clone();
                        let dims = dims2.clone();
                        std::thread::spawn(move || {
                            if let Err(e) = handle_connection(s, tx, &dims) {
                                log::debug!("connection closed: {e:#}");
                            }
                        });
                    }
                    Err(e) => log::warn!("accept error: {e}"),
                }
            }
        })?;

        Ok(CloudServer { addr, gpu_tx, stop, acceptor: Some(acceptor), gpu: Some(gpu) })
    }

    pub fn stats(&self) -> Result<CloudStats> {
        let (tx, rx) = channel();
        self.gpu_tx.send(GpuMsg::Stats { reply: tx }).context("gpu thread gone")?;
        rx.recv().context("stats reply")
    }

    /// Stop accepting and shut down the GPU worker; returns final stats.
    pub fn shutdown(mut self) -> CloudStats {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.gpu_tx.send(GpuMsg::Shutdown);
        // unblock the acceptor
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        self.gpu.take().map(|g| g.join().unwrap_or_default()).unwrap_or_default()
    }
}

impl Drop for CloudServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.gpu_tx.send(GpuMsg::Shutdown);
        let _ = TcpStream::connect(self.addr);
    }
}

/// Handle one client connection (either channel of the dual API).
fn handle_connection(stream: TcpStream, gpu: Sender<GpuMsg>, dims: &ModelDims) -> Result<()> {
    let mut t = TcpTransport::new(stream)?;
    let hello = Message::decode(&t.recv()?)?;
    let (device_id, channel) = match hello {
        Message::Hello { device_id, channel } => (device_id, channel),
        other => anyhow::bail!("expected Hello, got {other:?}"),
    };
    t.send(&Message::Ack.encode())?;
    log::debug!("device {device_id} opened {channel:?} channel");

    loop {
        let frame = match t.recv() {
            Ok(f) => f,
            Err(_) => return Ok(()), // peer closed
        };
        match Message::decode(&frame)? {
            Message::UploadHidden { device_id, req_id, start_pos, prompt_len, precision, payload, .. } => {
                let hiddens = quant::unpack(&payload, precision)?;
                anyhow::ensure!(hiddens.len() % dims.d_model == 0, "ragged upload");
                gpu.send(GpuMsg::Upload { device: device_id, req_id, start_pos, prompt_len, hiddens })
                    .context("gpu thread gone")?;
                // uploads are fire-and-forget (parallel with edge compute);
                // no ack so the uploader never stalls the edge
            }
            Message::InferRequest { device_id, req_id, pos, prompt_len } => {
                let (reply_tx, reply_rx) = std::sync::mpsc::channel();
                gpu.send(GpuMsg::Infer {
                    device: device_id,
                    req_id,
                    pos,
                    prompt_len,
                    reply: reply_tx,
                    retries: 0,
                })
                .context("gpu thread gone")?;
                match reply_rx.recv().context("gpu reply")? {
                    Ok((token, conf, compute_s)) => t.send(
                        &Message::TokenResponse {
                            req_id,
                            token,
                            conf,
                            compute_s: compute_s as f32,
                        }
                        .encode(),
                    )?,
                    Err(e) => t.send(&Message::Error { msg: format!("{e:#}") }.encode())?,
                }
            }
            Message::EndSession { device_id, .. } => {
                gpu.send(GpuMsg::End { device: device_id }).context("gpu thread gone")?;
            }
            other => anyhow::bail!("unexpected message on {channel:?} channel: {other:?}"),
        }
    }
}
