//! Cloud server (paper §4.2): receives hidden-state uploads, manages
//! per-device context, and serves single-token inference requests.
//!
//! Thread model — **`workers + shards`** threads total, independent of
//! how many devices are connected (see [`crate::coordinator::scheduler`]
//! for the serving core and [`crate::net::reactor`] for the connection
//! layer):
//! * a **worker pool** ([`Scheduler`]) — each worker thread owns its own
//!   `CloudEngine` sessions and content-manager shard for the devices
//!   assigned to it (`device_id % workers`; PJRT handles are `!Send`, so
//!   each worker builds its engines on its own thread).  An infer request
//!   whose uploads have not landed parks on its worker and is woken by
//!   the covering `Upload` — purely event-driven, no polling;
//! * a **reactor fleet** ([`Reactor`]) of `cfg.reactor` shards (default
//!   `min(4, cores)`, `CE_REACTOR_SHARDS` override) — each shard owns
//!   its own [`EventSet`](crate::net::event::EventSet) (edge-triggered
//!   `epoll` on Linux, `poll(2)` elsewhere), its own connection table
//!   and write queues, and its own accept path.  Servers started with
//!   [`CloudServer::bind`] get per-shard `SO_REUSEPORT` listeners on
//!   Linux (kernel-level accept load balancing, no shared queue);
//!   [`CloudServer::spawn`] with a caller-bound listener shares its
//!   accept queue across the shards via dup'd fds.  Either way
//!   accepting happens inside each shard's wake loop — a thousand edge
//!   devices cost two thousand registered sockets spread over the
//!   fleet, not two thousand blocked threads plus an acceptor.  Each
//!   shard decodes frames through the shared
//!   [`FrameCodec`](crate::net::codec::FrameCodec), routes work to the
//!   owning worker through a [`Router`], and writes responses back as
//!   each socket accepts them; completions come back to the shard that
//!   owns the connection (conn ids are shard-tagged).
//!
//! The paper's "Dual API" maps to two connections per device (upload
//! channel + infer channel), each announced by a `Hello`.  Because the
//! channels are independent, an `InferRequest` may overtake its own
//! uploads in flight; the scheduler's parking makes that race benign.
//! The two connections of one device may land on *different* shards —
//! that is fine, because uploads and infers meet at the device's
//! worker, not in the connection layer.
//!
//! Shutdown is deterministic: [`CloudServer::shutdown`] joins the
//! reactor fleet — every shard stops accepting and closes every socket
//! it registered before exiting — then drains the worker pool.  When it
//! returns, no connection can still produce a response.

use std::net::TcpListener;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::CloudConfig;
use crate::metrics::MetricsRegistry;
use crate::model::manifest::ModelDims;
use crate::net::listener::{bind_shard_listeners, share_listener};
use crate::net::reactor::{Reactor, ReactorStats};

pub use crate::coordinator::context_store::{ContextStore, ContextStoreStats};
pub use crate::coordinator::scheduler::{
    CloudStats, FactoryBuilder, InferOutcome, Reply, Router, SchedMsg, Scheduler, SessionFactory,
    TokenOut, UploadPayload,
};

/// A running cloud server bound to a TCP listening address.
pub struct CloudServer {
    pub addr: std::net::SocketAddr,
    scheduler: Option<Scheduler>,
    reactor: Option<Reactor>,
}

impl CloudServer {
    /// Bind `addr` and spawn the server with `cfg.workers` serving
    /// threads plus the reactor fleet.  This is the preferred entry
    /// point: on Linux with more than one shard it binds one
    /// `SO_REUSEPORT` listener *per shard* — the kernel load-balances
    /// accepts across the fleet and no shard ever touches another's
    /// accept queue.  (Elsewhere, or at one shard, it degrades to the
    /// same shared/single accept arrangement as [`CloudServer::spawn`].)
    /// `builder` runs on every worker thread and constructs that
    /// worker's engine factory there (PJRT objects never cross threads).
    pub fn bind<B>(addr: &str, dims: ModelDims, cfg: CloudConfig, builder: B) -> Result<CloudServer>
    where
        B: Fn() -> Result<SessionFactory> + Send + Sync + 'static,
    {
        let shards = cfg.reactor.resolved_shards();
        let (mode, listeners) = bind_shard_listeners(addr, shards)?;
        let bound = listeners
            .iter()
            .flatten()
            .next()
            .context("no listener bound")?
            .local_addr()?;
        let scheduler = Scheduler::spawn(dims.clone(), cfg, Arc::new(builder))?;
        // the fleet shares the scheduler's sink so reactor frames and
        // scheduler events interleave in one seq-ordered recording —
        // and the scheduler's registry, so one scrape shows both layers
        let sink = scheduler.trace_sink();
        let reactor = Reactor::spawn_fleet_full(
            scheduler.router(),
            dims,
            cfg.reactor,
            listeners,
            mode,
            sink,
            MetricsRegistry::resolve(cfg.metrics),
        )?;
        Ok(CloudServer { addr: bound, scheduler: Some(scheduler), reactor: Some(reactor) })
    }

    /// Spawn the server on a caller-bound listener.  The fleet shares
    /// the listener's one accept queue (dup'd fds, every shard races
    /// `accept`) — correct everywhere, but without the kernel-level
    /// balancing of [`CloudServer::bind`]'s per-shard reuseport
    /// listeners.
    pub fn spawn<B>(
        listener: TcpListener,
        dims: ModelDims,
        cfg: CloudConfig,
        builder: B,
    ) -> Result<CloudServer>
    where
        B: Fn() -> Result<SessionFactory> + Send + Sync + 'static,
    {
        let addr = listener.local_addr()?;
        let scheduler = Scheduler::spawn(dims.clone(), cfg, Arc::new(builder))?;
        let sink = scheduler.trace_sink();
        let (mode, listeners) = share_listener(listener, cfg.reactor.resolved_shards());
        let reactor = Reactor::spawn_fleet_full(
            scheduler.router(),
            dims,
            cfg.reactor,
            listeners,
            mode,
            sink,
            MetricsRegistry::resolve(cfg.metrics),
        )?;
        Ok(CloudServer { addr, scheduler: Some(scheduler), reactor: Some(reactor) })
    }

    /// Reactor shards actually spawned.
    pub fn shards(&self) -> usize {
        self.reactor.as_ref().map(Reactor::shards).unwrap_or(0)
    }

    /// Full serving snapshot: worker-pool counters with the connection
    /// layer filled in ([`CloudStats::reactor`] aggregate plus the
    /// per-shard [`CloudStats::reactor_shards`] vector).
    pub fn stats(&self) -> Result<CloudStats> {
        let mut stats = self.scheduler.as_ref().context("scheduler gone")?.stats()?;
        if let Some(r) = &self.reactor {
            stats.reactor_shards = r.handle().shard_stats()?;
            for s in &stats.reactor_shards {
                stats.reactor.merge(s);
            }
        }
        Ok(stats)
    }

    /// Connection-layer counters summed across the fleet.
    pub fn reactor_stats(&self) -> Result<ReactorStats> {
        self.reactor.as_ref().context("reactor gone")?.handle().stats()
    }

    /// Connection-layer counters per shard (index = shard).
    pub fn reactor_shard_stats(&self) -> Result<Vec<ReactorStats>> {
        self.reactor.as_ref().context("reactor gone")?.handle().shard_stats()
    }

    /// Stop accepting, close every connection, and shut down the worker
    /// pool; returns final serving stats with the fleet's final
    /// connection counters folded in.  Deterministic: when this returns,
    /// every socket the server ever registered is closed.
    pub fn shutdown(mut self) -> CloudStats {
        let mut shard_finals = Vec::new();
        if let Some(r) = self.reactor.take() {
            // joining the fleet closes the listeners and every socket
            shard_finals = r.shutdown();
            for (shard, rs) in shard_finals.iter().enumerate() {
                log::debug!(
                    "reactor shard {shard} ({}/{}) closed: {} accepted, {} conns opened, \
                     {} evicted slow, {} frames in / {} out over {} wakes",
                    rs.backend,
                    rs.accept_mode,
                    rs.accepts,
                    rs.conns_opened,
                    rs.evicted_slow,
                    rs.frames_in,
                    rs.frames_out,
                    rs.wakes
                );
            }
        }
        let mut stats = self.scheduler.take().map(Scheduler::shutdown).unwrap_or_default();
        for s in &shard_finals {
            stats.reactor.merge(s);
        }
        stats.reactor_shards = shard_finals;
        // one stable single-line JSON snapshot — the machine-grepable
        // counterpart of the per-shard debug lines above
        log::info!("cloud final stats: {}", stats.to_json());
        stats
    }
}

impl Drop for CloudServer {
    fn drop(&mut self) {
        // dropping the reactor stops accepting and closes every
        // connection; dropping the scheduler tells every worker to stop
        self.reactor.take();
        self.scheduler.take();
    }
}
