//! Cloud server (paper §4.2): receives hidden-state uploads, manages
//! per-device context, and serves single-token inference requests.
//!
//! Thread model (see [`crate::coordinator::scheduler`] for the serving
//! core itself):
//! * a **worker pool** ([`Scheduler`]) — each worker thread owns its own
//!   `CloudEngine` sessions and content-manager shard for the devices
//!   assigned to it (`device_id % workers`; PJRT handles are `!Send`, so
//!   each worker builds its engines on its own thread).  An infer request
//!   whose uploads have not landed parks on its worker and is woken by
//!   the covering `Upload` — purely event-driven, no polling;
//! * one **acceptor** thread takes TCP connections;
//! * one thread per connection decodes frames and routes work to the
//!   owning worker through a [`Router`].
//!
//! The paper's "Dual API" maps to two connections per device (upload
//! channel + infer channel), each announced by a `Hello`.  Because the
//! channels are independent, an `InferRequest` may overtake its own
//! uploads in flight; the scheduler's parking makes that race benign.

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::CloudConfig;
use crate::coordinator::protocol::{Channel, Message, NO_REQ};
use crate::model::manifest::ModelDims;
use crate::net::transport::{TcpTransport, Transport};
use crate::quant;

pub use crate::coordinator::scheduler::{
    CloudStats, FactoryBuilder, Router, SchedMsg, Scheduler, SessionFactory, TokenOut,
};

/// A running cloud server bound to a TCP listener.
pub struct CloudServer {
    pub addr: std::net::SocketAddr,
    scheduler: Option<Scheduler>,
    stop: Arc<AtomicBool>,
    acceptor: Option<std::thread::JoinHandle<()>>,
}

impl CloudServer {
    /// Spawn the server with `cfg.workers` serving threads.  `builder`
    /// runs on every worker thread and constructs that worker's engine
    /// factory there (PJRT objects never cross threads).
    pub fn spawn<B>(
        listener: TcpListener,
        dims: ModelDims,
        cfg: CloudConfig,
        builder: B,
    ) -> Result<CloudServer>
    where
        B: Fn() -> Result<SessionFactory> + Send + Sync + 'static,
    {
        let addr = listener.local_addr()?;
        let scheduler = Scheduler::spawn(dims.clone(), cfg, Arc::new(builder))?;

        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let conn_router = scheduler.router();
        let acceptor = std::thread::Builder::new().name("cloud-accept".into()).spawn(move || {
            for stream in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                match stream {
                    Ok(s) => {
                        let router = conn_router.clone();
                        let dims = dims.clone();
                        std::thread::spawn(move || {
                            if let Err(e) = handle_connection(s, router, &dims) {
                                log::debug!("connection closed: {e:#}");
                            }
                        });
                    }
                    Err(e) => log::warn!("accept error: {e}"),
                }
            }
        })?;

        Ok(CloudServer { addr, scheduler: Some(scheduler), stop, acceptor: Some(acceptor) })
    }

    pub fn stats(&self) -> Result<CloudStats> {
        self.scheduler.as_ref().context("scheduler gone")?.stats()
    }

    /// Stop accepting and shut down the worker pool; returns final stats.
    pub fn shutdown(mut self) -> CloudStats {
        self.stop.store(true, Ordering::SeqCst);
        // unblock the acceptor
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        self.scheduler.take().map(Scheduler::shutdown).unwrap_or_default()
    }
}

impl Drop for CloudServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // dropping the scheduler tells every worker to stop
        self.scheduler.take();
        let _ = TcpStream::connect(self.addr);
    }
}

/// Handle one client connection (either channel of the dual API).
fn handle_connection(stream: TcpStream, router: Router, dims: &ModelDims) -> Result<()> {
    let mut t = TcpTransport::new(stream)?;
    let hello = Message::decode(&t.recv()?)?;
    let (device_id, session, channel) = match hello {
        Message::Hello { device_id, session, channel } => (device_id, session, channel),
        other => anyhow::bail!("expected Hello, got {other:?}"),
    };
    if channel == Channel::Upload {
        // A fresh upload channel means a fresh client session: clear any
        // state (and end-request tombstones) left by a previous process
        // that used this device id, and pin the device to this session so
        // stragglers from the old connections are fenced out.  Sent
        // before the Ack so it is queued ahead of everything the new
        // session will send.
        router
            .send(device_id, SchedMsg::Reset { device: device_id, session })
            .context("scheduler gone")?;
    }
    t.send(&Message::Ack.encode())?;
    log::debug!("device {device_id} opened {channel:?} channel (session {session:x})");

    loop {
        let frame = match t.recv() {
            Ok(f) => f,
            Err(_) => return Ok(()), // peer closed
        };
        // Zero-copy fast path for the dominant per-token frame: the
        // payload stays borrowed from the frame buffer, so the owned
        // `decode`'s payload copy disappears from the upload hot path.
        // The unpacked vector itself must still be allocated — it is
        // moved across threads into the scheduler (and from there into
        // the content manager without further copies).
        if let Some(v) = Message::decode_upload(&frame)? {
            let hiddens = quant::unpack(v.payload, v.precision)?;
            anyhow::ensure!(hiddens.len() % dims.d_model == 0, "ragged upload");
            router
                .send(
                    v.device_id,
                    SchedMsg::Upload {
                        device: v.device_id,
                        session,
                        req_id: v.req_id,
                        start_pos: v.start_pos,
                        prompt_len: v.prompt_len,
                        hiddens,
                    },
                )
                .context("scheduler gone")?;
            // uploads are fire-and-forget (parallel with edge compute);
            // no ack so the uploader never stalls the edge
            continue;
        }
        match Message::decode(&frame)? {
            Message::InferRequest { device_id, req_id, pos, prompt_len, deadline_ms } => {
                let deadline = (deadline_ms > 0)
                    .then(|| Instant::now() + Duration::from_millis(deadline_ms as u64));
                let (reply_tx, reply_rx) = std::sync::mpsc::channel();
                router
                    .send(
                        device_id,
                        SchedMsg::Infer {
                            device: device_id,
                            session,
                            req_id,
                            pos,
                            prompt_len,
                            deadline,
                            reply: reply_tx,
                        },
                    )
                    .context("scheduler gone")?;
                match reply_rx.recv().context("scheduler reply")? {
                    Ok(out) => t.send(
                        &Message::TokenResponse {
                            req_id,
                            pos,
                            token: out.token,
                            conf: out.conf,
                            compute_s: out.compute_s as f32,
                        }
                        .encode(),
                    )?,
                    Err(e) => {
                        t.send(&Message::Error { req_id, pos, msg: format!("{e:#}") }.encode())?
                    }
                }
            }
            Message::EndSession { device_id, req_id } => {
                router
                    .send(device_id, SchedMsg::End { device: device_id, session, req_id })
                    .context("scheduler gone")?;
            }
            other => {
                let msg = format!("unexpected message on {channel:?} channel: {other:?}");
                let _ = t.send(&Message::Error { req_id: NO_REQ, pos: NO_REQ, msg: msg.clone() }.encode());
                anyhow::bail!(msg)
            }
        }
    }
}
