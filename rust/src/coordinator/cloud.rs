//! Cloud server (paper §4.2): receives hidden-state uploads, manages
//! per-device context, and serves single-token inference requests.
//!
//! Thread model — `workers + 2` threads total, independent of how many
//! devices are connected (see [`crate::coordinator::scheduler`] for the
//! serving core and [`crate::net::reactor`] for the connection layer):
//! * a **worker pool** ([`Scheduler`]) — each worker thread owns its own
//!   `CloudEngine` sessions and content-manager shard for the devices
//!   assigned to it (`device_id % workers`; PJRT handles are `!Send`, so
//!   each worker builds its engines on its own thread).  An infer request
//!   whose uploads have not landed parks on its worker and is woken by
//!   the covering `Upload` — purely event-driven, no polling;
//! * one **acceptor** thread takes TCP connections and registers them
//!   with the reactor;
//! * one **reactor** thread owns *all* connection sockets (nonblocking,
//!   `poll(2)`-multiplexed), decodes frames through the shared
//!   [`FrameCodec`](crate::net::codec::FrameCodec), routes work to the
//!   owning worker through a [`Router`], and writes responses back as
//!   each socket accepts them.  The per-connection
//!   `std::thread::spawn` of earlier revisions is gone: a thousand edge
//!   devices now cost two thousand registered sockets, not two thousand
//!   blocked threads.
//!
//! The paper's "Dual API" maps to two connections per device (upload
//! channel + infer channel), each announced by a `Hello`.  Because the
//! channels are independent, an `InferRequest` may overtake its own
//! uploads in flight; the scheduler's parking makes that race benign.
//!
//! Shutdown is deterministic: [`CloudServer::shutdown`] stops the
//! acceptor, then joins the reactor — which closes every registered
//! socket before exiting — then drains the worker pool.  When it
//! returns, no connection can still produce a response.

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::CloudConfig;
use crate::model::manifest::ModelDims;
use crate::net::reactor::{Reactor, ReactorStats};

pub use crate::coordinator::context_store::{ContextStore, ContextStoreStats};
pub use crate::coordinator::scheduler::{
    CloudStats, FactoryBuilder, InferOutcome, Reply, Router, SchedMsg, Scheduler, SessionFactory,
    TokenOut, UploadPayload,
};

/// A running cloud server bound to a TCP listener.
pub struct CloudServer {
    pub addr: std::net::SocketAddr,
    scheduler: Option<Scheduler>,
    reactor: Option<Reactor>,
    stop: Arc<AtomicBool>,
    acceptor: Option<std::thread::JoinHandle<()>>,
}

impl CloudServer {
    /// Spawn the server with `cfg.workers` serving threads plus the
    /// acceptor and the connection reactor.  `builder` runs on every
    /// worker thread and constructs that worker's engine factory there
    /// (PJRT objects never cross threads).
    pub fn spawn<B>(
        listener: TcpListener,
        dims: ModelDims,
        cfg: CloudConfig,
        builder: B,
    ) -> Result<CloudServer>
    where
        B: Fn() -> Result<SessionFactory> + Send + Sync + 'static,
    {
        let addr = listener.local_addr()?;
        let scheduler = Scheduler::spawn(dims.clone(), cfg, Arc::new(builder))?;
        let reactor = Reactor::spawn(scheduler.router(), dims, cfg.reactor)?;
        let conns = reactor.handle();

        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let acceptor = std::thread::Builder::new().name("cloud-accept".into()).spawn(move || {
            for stream in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                match stream {
                    Ok(s) => {
                        if conns.register(s).is_err() {
                            break; // reactor gone: the server is tearing down
                        }
                    }
                    Err(e) => log::warn!("accept error: {e}"),
                }
            }
        })?;

        Ok(CloudServer {
            addr,
            scheduler: Some(scheduler),
            reactor: Some(reactor),
            stop,
            acceptor: Some(acceptor),
        })
    }

    pub fn stats(&self) -> Result<CloudStats> {
        self.scheduler.as_ref().context("scheduler gone")?.stats()
    }

    /// Connection-layer counters (open connections, evictions, frames).
    pub fn reactor_stats(&self) -> Result<ReactorStats> {
        self.reactor.as_ref().context("reactor gone")?.handle().stats()
    }

    /// Stop accepting, close every connection, and shut down the worker
    /// pool; returns final serving stats.  Deterministic: when this
    /// returns, every socket the server ever registered is closed.
    pub fn shutdown(mut self) -> CloudStats {
        self.stop.store(true, Ordering::SeqCst);
        // unblock the acceptor
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        if let Some(r) = self.reactor.take() {
            let rs = r.shutdown();
            log::debug!(
                "reactor closed: {} conns opened, {} evicted slow, {} frames in / {} out",
                rs.conns_opened,
                rs.evicted_slow,
                rs.frames_in,
                rs.frames_out
            );
        }
        self.scheduler.take().map(Scheduler::shutdown).unwrap_or_default()
    }
}

impl Drop for CloudServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // dropping the reactor closes every connection; dropping the
        // scheduler tells every worker to stop
        self.reactor.take();
        self.scheduler.take();
        let _ = TcpStream::connect(self.addr);
    }
}
