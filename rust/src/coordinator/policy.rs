//! Confidence-based exit policy (paper §4.4, Algorithm 1 lines 7–21).
//!
//! Per generated token the edge evaluates exit 1 after layer `l_ee1` and
//! exit 2 after layer `l_ee2`; the policy decides where the token is
//! produced.  The ablation flag `early_exit = false` reproduces the
//! paper's "Without Early Exit" row: the edge still runs its partition
//! but every token defers to the cloud.

use crate::config::{AblationFlags, ExitPolicy};

/// Where a token was produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ExitPoint {
    /// Early exit 1 (after layer `l_ee1`) — cheapest.
    Exit1,
    /// Early exit 2 (after layer `l_ee2`).
    Exit2,
    /// Cloud partition (final LM head) — full accuracy.
    Cloud,
}

impl ExitPoint {
    pub fn name(&self) -> &'static str {
        match self {
            ExitPoint::Exit1 => "exit1",
            ExitPoint::Exit2 => "exit2",
            ExitPoint::Cloud => "cloud",
        }
    }
}

/// The exit decision procedure for one token.
#[derive(Debug, Clone, Copy)]
pub struct TokenPolicy {
    pub policy: ExitPolicy,
    pub flags: AblationFlags,
}

impl TokenPolicy {
    pub fn new(policy: ExitPolicy, flags: AblationFlags) -> Self {
        Self { policy, flags }
    }

    /// Algorithm 1 line 13: exit at `l_ee1` iff `conf >= θ` (and early
    /// exits are enabled).
    pub fn exit_at_1(&self, conf1: f32) -> bool {
        self.flags.early_exit && conf1 >= self.policy.threshold()
    }

    /// Algorithm 1 line 17 / §4.1 standalone mode: at the *last* exit the
    /// standalone policy drops the threshold condition and always emits.
    pub fn exit_at_2(&self, conf2: f32) -> bool {
        if self.policy.is_standalone() {
            return true;
        }
        self.flags.early_exit && conf2 >= self.policy.threshold()
    }

    /// Full decision given both confidences (exit 2's confidence is only
    /// consulted when exit 1 declines).
    pub fn decide(&self, conf1: f32, conf2: f32) -> ExitPoint {
        if self.exit_at_1(conf1) {
            ExitPoint::Exit1
        } else if self.exit_at_2(conf2) {
            ExitPoint::Exit2
        } else {
            ExitPoint::Cloud
        }
    }

    /// Whether this policy can ever contact the cloud.
    pub fn uses_cloud(&self) -> bool {
        !self.policy.is_standalone()
    }

    /// Latency-aware exit (paper §4.4): when a cloud deferral cannot
    /// complete within the per-token budget, pick the best *local* exit
    /// to emit instead of blocking the stream.  Exit 2 has seen more
    /// layers, so it wins whenever its confidence is at least exit 1's.
    pub fn local_fallback(&self, conf1: f32, conf2: Option<f32>) -> ExitPoint {
        match conf2 {
            Some(c2) if c2 >= conf1 => ExitPoint::Exit2,
            _ => ExitPoint::Exit1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(threshold: f32) -> TokenPolicy {
        TokenPolicy::new(ExitPolicy::Threshold(threshold), AblationFlags::default())
    }

    #[test]
    fn threshold_routes_by_confidence() {
        let pol = p(0.8);
        assert_eq!(pol.decide(0.9, 0.0), ExitPoint::Exit1);
        assert_eq!(pol.decide(0.79, 0.85), ExitPoint::Exit2);
        assert_eq!(pol.decide(0.5, 0.5), ExitPoint::Cloud);
    }

    #[test]
    fn boundary_is_inclusive() {
        // paper: conf >= θ exits
        let pol = p(0.8);
        assert!(pol.exit_at_1(0.8));
        assert!(!pol.exit_at_1(0.7999));
    }

    #[test]
    fn threshold_one_never_exits_early() {
        // confidences are strictly < 1 in practice -> 100% cloud rate
        let pol = p(1.0);
        assert_eq!(pol.decide(0.9999, 0.9999), ExitPoint::Cloud);
    }

    #[test]
    fn standalone_always_emits_at_exit2() {
        let pol = TokenPolicy::new(
            ExitPolicy::Standalone { threshold: 0.8 },
            AblationFlags::default(),
        );
        assert_eq!(pol.decide(0.9, 0.0), ExitPoint::Exit1);
        assert_eq!(pol.decide(0.1, 0.1), ExitPoint::Exit2);
        assert!(!pol.uses_cloud());
    }

    #[test]
    fn disabled_early_exit_forces_cloud() {
        let pol = TokenPolicy::new(ExitPolicy::Threshold(0.8), AblationFlags::without_early_exit());
        assert_eq!(pol.decide(0.99, 0.99), ExitPoint::Cloud);
    }

    #[test]
    fn local_fallback_prefers_deeper_exit() {
        let pol = p(0.8);
        // the usual case: exit 2 at least as confident as exit 1
        assert_eq!(pol.local_fallback(0.3, Some(0.5)), ExitPoint::Exit2);
        assert_eq!(pol.local_fallback(0.5, Some(0.5)), ExitPoint::Exit2);
        // exit 1 more confident, or exit 2 never evaluated
        assert_eq!(pol.local_fallback(0.6, Some(0.4)), ExitPoint::Exit1);
        assert_eq!(pol.local_fallback(0.2, None), ExitPoint::Exit1);
    }

    #[test]
    fn monotone_in_threshold() {
        // lower threshold can only move tokens earlier, never later
        let confs = [(0.85f32, 0.92f32), (0.5, 0.85), (0.3, 0.4)];
        for (c1, c2) in confs {
            let lo = p(0.8).decide(c1, c2);
            let hi = p(0.9).decide(c1, c2);
            let rank = |e: ExitPoint| match e {
                ExitPoint::Exit1 => 0,
                ExitPoint::Exit2 => 1,
                ExitPoint::Cloud => 2,
            };
            assert!(rank(lo) <= rank(hi), "({c1},{c2})");
        }
    }
}
