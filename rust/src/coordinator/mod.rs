//! The paper's L3 contribution: early-exit edge client, cloud server with
//! content manager, wire protocol, and exit policy.
pub mod policy;
pub mod protocol;
pub mod content_manager;
pub mod scheduler;
pub mod edge;
pub mod cloud;
