//! The paper's L3 contribution: early-exit edge client, cloud server with
//! content manager, wire protocol, and exit policy.
//!
//! Cloud-side layering (bottom to top):
//!
//! * [`content_manager`] — pure hidden-state bookkeeping per device:
//!   dedup, coverage, and work planning.  Knows nothing about time,
//!   memory budgets, or engines.
//! * [`context_store`] — **owns the bytes**: every engine KV session and
//!   every content-manager buffer lives inside a per-worker store shard
//!   that meters residency, refreshes an LRU clock on every touch, and
//!   evicts whole idle devices under `CloudConfig::memory_budget_bytes`
//!   pressure or past `CloudConfig::session_ttl_s`.  Eviction is
//!   recoverable: the edge is told via
//!   [`protocol::Message::SessionEvicted`] and replays its retained
//!   hidden-state history from position 0.
//! * [`scheduler`] — **owns the compute**: parks infer requests until
//!   coverage, coalesces and cross-device-batches engine passes, expires
//!   deadlines, and runs the store's eviction sweeps strictly *between*
//!   passes (a device being served is never evicted mid-pass).
//! * [`cloud`] — the serving binary's shell: reactor fleet (each shard
//!   owns its accept path and accepts in-loop; per-shard `SO_REUSEPORT`
//!   listeners on Linux) + worker pool wiring, exactly
//!   `workers + shards` threads total.
//!
//! The edge side ([`edge`]) keeps a bounded replay ring of its exit-1
//! hidden states per request, so a `SessionEvicted` response costs one
//! extra upload round trip and zero token differences.
//!
//! The same ring powers the [`edge::CloudLink`] reconnect state
//! machine (paper §4.4's resilience requirement — a flaky edge link
//! must degrade latency, never correctness):
//!
//! ```text
//!            transport error / dead upload channel
//!   CONNECTED ────────────────────────────────────────► BROKEN
//!       ▲                                                  │
//!       │                              re-dial endpoint[i] │ backoff
//!       │                              (≤ max_attempts,    │ 2^n·base,
//!       │                               jittered)          │ jittered
//!       │              exhausted: i ← i+1 (FAILOVER)  ◄────┤
//!       │                                                  ▼
//!       │   resume Hello (same session nonce, resume=1) RE-DIALED
//!       │   dual handshake: infer Ack, then upload Ack     │
//!       │                                                  ▼
//!       │       full-history replay from the ring      RESUMING
//!       │   cloud: suspend (honored) or reset (stale),     │
//!       └───────── re-prefill, answer the pending request ─┘
//! ```
//!
//! Ordering invariant: the scheduler's `Reset` is enqueued when the
//! upload-channel Hello is routed, *before* its `Ack` is queued, and
//! the replay is only sent after that `Ack` arrives — per-worker FIFO
//! then guarantees the reset always precedes the replayed history, on
//! any shard.  A resumed nonce is cooperative suspension: tombstones
//! survive (stale frames from the dead socket stay fenced) and nothing
//! is billed to the eviction counters.
//!
//! # Replication protocol (warm standbys)
//!
//! Above reconnect sits the replicated-cloud layer
//! ([`edge::ReplicaSet`], `DeploymentConfig::replication`).  The edge
//! holds concurrent sessions against several endpoints: one primary
//! plus `replicas` warm standbys, each a full dual-channel session
//! whose `Hello`s carry the **mirror bit** (bit `0x40` of the channel
//! byte, next to resume's `0x80`).  The wire change is
//! backward-compatible: a fresh non-mirror `Hello` is byte-identical
//! to every release before the bit existed.
//!
//! Mirror semantics on the cloud: the session stores uploads like any
//! other (same coverage, same dedup) but is billed under
//! `uploads_mirrored`, is a *preferred eviction victim* (a passive
//! copy must never push a live session out of memory), and converts to
//! a live session on its first `InferRequest` (`mirror_promotions`,
//! traced as `mirror_promote`) — which is exactly what a warm failover
//! does.
//!
//! The edge mirrors every hidden-state upload to each live standby,
//! asynchronously on the standby's own uploader thread, and
//! health-scores replicas from keepalive ping RTT plus reconnect
//! history.  Failure then walks a documented **degradation ladder** —
//! each rung strictly cheaper in guarantees and cost than the one
//! below is in damage:
//!
//! ```text
//!          ┌──────────────────────────────────────────────────────┐
//!          │ HEDGED   (hedge=true, deadline set, live standby)    │
//!          │   InferRequest duplicated to best standby;           │
//!          │   first valid (req_id,pos) echo wins, loser fenced   │
//!          │   by the stale-response skip                         │
//!          └───────────────┬──────────────────────────────────────┘
//!                          │ primary transport error / dead uploads
//!                          ▼
//!          ┌──────────────────────────────────────────────────────┐
//!          │ WARM FAILOVER (live standby)                         │
//!          │   promote best-scored standby: swap links, re-issue  │
//!          │   request, NO replay — mirrored coverage already     │
//!          │   spans the watermark (failovers_warm,               │
//!          │   context_replays += 0, bit-identical tokens)        │
//!          └───────────────┬──────────────────────────────────────┘
//!                          │ no live standby
//!                          ▼
//!          ┌──────────────────────────────────────────────────────┐
//!          │ COLD RECONNECT (PRIMARY-ONLY)                        │
//!          │   re-dial + resume Hello + full-history replay from  │
//!          │   the ring (failovers_cold) — the pre-replication    │
//!          │   recovery path, unchanged                           │
//!          └───────────────┬──────────────────────────────────────┘
//!                          │ reconnect exhausted / disabled
//!                          ▼
//!          ┌──────────────────────────────────────────────────────┐
//!          │ LOCAL FALLBACK (§4.4)                                │
//!          │   finish the run on the best local exit              │
//!          │   (latency-aware mode) or fail (strict mode)         │
//!          └──────────────────────────────────────────────────────┘
//! ```
pub mod policy;
pub mod protocol;
pub mod content_manager;
pub mod context_store;
pub mod scheduler;
pub mod edge;
pub mod cloud;
