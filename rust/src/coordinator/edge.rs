//! Edge client (paper §4.1, §4.4, Algorithm 1): the early-exit decode
//! loop with asynchronous parallel hidden-state upload and adaptive
//! cloud deferral.
//!
//! Thread model: the engine (PJRT) stays on the caller's thread; uploads
//! go through a dedicated uploader thread feeding the upload channel
//! (paper: "the edge device concurrently continues the inference process"
//! while states transfer).  The infer channel is used synchronously —
//! a deferred token cannot proceed without the cloud's response.

use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::DeploymentConfig;
use crate::coordinator::policy::{ExitPoint, TokenPolicy};
use crate::coordinator::protocol::{Channel, Message};
use crate::metrics::{CostBreakdown, RunCounters};
use crate::model::tokenizer::Tokenizer;
use crate::net::transport::Transport;
use crate::quant::{self, Precision};
use crate::runtime::traits::EdgeEngine;

/// One generated token with its provenance (Table 1 columns).
#[derive(Debug, Clone)]
pub struct TokenTrace {
    pub pos: usize,
    pub token: i32,
    pub exit: ExitPoint,
    pub conf1: f32,
    pub conf2: Option<f32>,
}

/// Result of one generation request.
#[derive(Debug, Clone)]
pub struct GenerateOutput {
    pub text: String,
    pub tokens: Vec<i32>,
    pub trace: Vec<TokenTrace>,
    pub cost: CostBreakdown,
    pub counters: RunCounters,
}

enum UploadJob {
    Send(Message),
    Flush(Sender<()>),
    Done,
}

/// The cloud half of the client: dual channels + upload thread.
pub struct CloudLink {
    infer: Box<dyn Transport>,
    upload_tx: Sender<UploadJob>,
    uploader: Option<JoinHandle<u64>>,
}

impl CloudLink {
    /// Open the dual API from two transports (paper §4.2): `upload` is
    /// drained by a background thread, `infer` is synchronous.
    pub fn new(
        device_id: u64,
        mut upload: Box<dyn Transport + Send>,
        mut infer: Box<dyn Transport>,
    ) -> Result<Self> {
        infer.send(&Message::Hello { device_id, channel: Channel::Infer }.encode())?;
        expect_ack(&mut *infer)?;
        upload.send(&Message::Hello { device_id, channel: Channel::Upload }.encode())?;
        expect_ack(&mut *upload)?;

        let (upload_tx, upload_rx) = channel::<UploadJob>();
        let uploader = std::thread::Builder::new().name("edge-upload".into()).spawn(move || {
            let mut sent = 0u64;
            while let Ok(job) = upload_rx.recv() {
                match job {
                    UploadJob::Send(msg) => {
                        let frame = msg.encode();
                        sent += frame.len() as u64;
                        if upload.send(&frame).is_err() {
                            break;
                        }
                    }
                    UploadJob::Flush(ack) => {
                        let _ = ack.send(());
                    }
                    UploadJob::Done => break,
                }
            }
            sent
        })?;
        Ok(Self { infer, upload_tx, uploader: Some(uploader) })
    }

    fn enqueue_upload(&self, msg: Message) {
        let _ = self.upload_tx.send(UploadJob::Send(msg));
    }

    /// Block until every enqueued upload has been written to the wire.
    fn flush_uploads(&self) {
        let (tx, rx) = channel();
        if self.upload_tx.send(UploadJob::Flush(tx)).is_ok() {
            let _ = rx.recv();
        }
    }

    fn close(&mut self) -> u64 {
        let _ = self.upload_tx.send(UploadJob::Done);
        self.uploader.take().map(|u| u.join().unwrap_or(0)).unwrap_or(0)
    }
}

impl Drop for CloudLink {
    fn drop(&mut self) {
        let _ = self.upload_tx.send(UploadJob::Done);
    }
}

fn expect_ack(t: &mut dyn Transport) -> Result<()> {
    match Message::decode(&t.recv()?)? {
        Message::Ack => Ok(()),
        other => anyhow::bail!("expected Ack, got {other:?}"),
    }
}

/// The edge client: engine + policy + optional cloud link.
pub struct EdgeClient<E: EdgeEngine> {
    pub engine: E,
    pub tokenizer: Tokenizer,
    pub cfg: DeploymentConfig,
    link: Option<CloudLink>,
    req_id: u32,
}

impl<E: EdgeEngine> EdgeClient<E> {
    /// Standalone-capable client (no cloud link).  With a collaborative
    /// policy, deferred tokens fail — use [`Self::with_cloud`].
    pub fn standalone(engine: E, cfg: DeploymentConfig) -> Self {
        let tokenizer = Tokenizer::from_dims(engine.dims());
        Self { engine, tokenizer, cfg, link: None, req_id: 0 }
    }

    pub fn with_cloud(engine: E, cfg: DeploymentConfig, link: CloudLink) -> Self {
        let tokenizer = Tokenizer::from_dims(engine.dims());
        Self { engine, tokenizer, cfg, link: Some(link), req_id: 0 }
    }

    fn precision(&self) -> Precision {
        Precision::from_flag(self.cfg.ablation.half_precision)
    }

    /// Generate a completion for `prompt` (Algorithm 1).
    pub fn generate(&mut self, prompt: &str) -> Result<GenerateOutput> {
        self.req_id += 1;
        let req_id = self.req_id;
        let policy = TokenPolicy::new(self.cfg.policy, self.cfg.ablation);
        let dims = self.engine.dims().clone();
        let precision = self.precision();
        let flags = self.cfg.ablation;
        let device_id = self.cfg.device_id;

        let prompt_ids = self.tokenizer.encode(prompt);
        let prompt_len = prompt_ids.len();
        anyhow::ensure!(prompt_len <= dims.max_prompt, "prompt too long");

        let wall0 = Instant::now();
        let mut cost = CostBreakdown::default();
        let mut counters = RunCounters::default();
        let mut trace: Vec<TokenTrace> = Vec::new();
        let mut tokens: Vec<i32> = Vec::new();

        self.engine.reset();

        // --- prefill -----------------------------------------------------
        let t0 = Instant::now();
        let pre = self.engine.prefill(&prompt_ids)?;
        cost.edge_s += t0.elapsed().as_secs_f64();

        // h1 history retained only when the edge must retransmit (no
        // content manager on the server)
        let mut h1_history: Vec<Vec<f32>> = Vec::new();
        let keep_history = !flags.content_manager;
        if keep_history {
            for c in pre.h1.chunks(dims.d_model) {
                h1_history.push(c.to_vec());
            }
        }

        // parallel upload of prompt hidden states (Algorithm 1 line 12)
        if policy.uses_cloud() && flags.parallel_upload && flags.content_manager {
            let payload = quant::pack(&pre.h1, precision);
            counters.bytes_up += payload.len() as u64;
            self.link_ref()?.enqueue_upload(Message::UploadHidden {
                device_id,
                req_id,
                start_pos: 0,
                count: prompt_len as u32,
                prompt_len: prompt_len as u32,
                precision,
                payload,
            });
        }

        // --- first token decision at the last prompt position -------------
        let mut pos = prompt_len - 1;
        let mut next = self.decide_token(
            &policy, req_id, pos, prompt_len,
            pre.exit1.conf, pre.exit1.token,
            Some((pre.exit2.conf, pre.exit2.token)),
            &mut cost, &mut counters, &mut h1_history,
        )?;
        trace.push(next.1.clone());
        tokens.push(next.0);

        // --- decode loop ---------------------------------------------------
        while !self.tokenizer.is_eos(*tokens.last().unwrap())
            && tokens.len() < self.cfg.max_new_tokens
            && prompt_len + tokens.len() < dims.max_seq
        {
            pos = prompt_len + tokens.len() - 1;
            let input = *tokens.last().unwrap();

            let t0 = Instant::now();
            let s1 = self.engine.seg1(input, pos)?;
            cost.edge_s += t0.elapsed().as_secs_f64();

            if keep_history {
                h1_history.push(s1.h1.clone());
            }
            if policy.uses_cloud() && flags.parallel_upload && flags.content_manager {
                let payload = quant::pack(&s1.h1, precision);
                counters.bytes_up += payload.len() as u64;
                self.link_ref()?.enqueue_upload(Message::UploadHidden {
                    device_id,
                    req_id,
                    start_pos: pos as u32,
                    count: 1,
                    prompt_len: prompt_len as u32,
                    precision,
                    payload,
                });
            }

            next = if policy.exit_at_1(s1.exit1.conf) {
                counters.tokens_exit1 += 1;
                (
                    s1.exit1.token,
                    TokenTrace {
                        pos,
                        token: s1.exit1.token,
                        exit: ExitPoint::Exit1,
                        conf1: s1.exit1.conf,
                        conf2: None,
                    },
                )
            } else {
                let t0 = Instant::now();
                let s2 = self.engine.seg2(&s1.h1, pos)?;
                cost.edge_s += t0.elapsed().as_secs_f64();
                if policy.exit_at_2(s2.exit2.conf) {
                    counters.tokens_exit2 += 1;
                    (
                        s2.exit2.token,
                        TokenTrace {
                            pos,
                            token: s2.exit2.token,
                            exit: ExitPoint::Exit2,
                            conf1: s1.exit1.conf,
                            conf2: Some(s2.exit2.conf),
                        },
                    )
                } else {
                    let (tok, conf) = self.cloud_token(
                        req_id, pos, prompt_len, &mut cost, &mut counters, &mut h1_history,
                    )?;
                    counters.tokens_cloud += 1;
                    counters.cloud_requests += 1;
                    let _ = conf;
                    (
                        tok,
                        TokenTrace {
                            pos,
                            token: tok,
                            exit: ExitPoint::Cloud,
                            conf1: s1.exit1.conf,
                            conf2: Some(s2.exit2.conf),
                        },
                    )
                }
            };
            trace.push(next.1.clone());
            tokens.push(next.0);
        }

        // --- session teardown (§4.4 step 6) --------------------------------
        if let Some(link) = self.link.as_mut() {
            let _ = link.infer.send(&Message::EndSession { device_id, req_id }.encode());
        }

        cost.total_s = wall0.elapsed().as_secs_f64();
        counters.tokens_generated = tokens.len();
        Ok(GenerateOutput {
            text: self.tokenizer.decode(&tokens),
            tokens,
            trace,
            cost,
            counters,
        })
    }

    /// First-token decision shares the cloud path with the decode loop.
    #[allow(clippy::too_many_arguments)]
    fn decide_token(
        &mut self,
        policy: &TokenPolicy,
        req_id: u32,
        pos: usize,
        prompt_len: usize,
        conf1: f32,
        tok1: i32,
        exit2: Option<(f32, i32)>,
        cost: &mut CostBreakdown,
        counters: &mut RunCounters,
        h1_history: &mut Vec<Vec<f32>>,
    ) -> Result<(i32, TokenTrace)> {
        if policy.exit_at_1(conf1) {
            counters.tokens_exit1 += 1;
            return Ok((
                tok1,
                TokenTrace { pos, token: tok1, exit: ExitPoint::Exit1, conf1, conf2: None },
            ));
        }
        let (conf2, tok2) = exit2.context("exit-2 evaluation missing")?;
        if policy.exit_at_2(conf2) {
            counters.tokens_exit2 += 1;
            return Ok((
                tok2,
                TokenTrace { pos, token: tok2, exit: ExitPoint::Exit2, conf1, conf2: Some(conf2) },
            ));
        }
        let (tok, _conf) =
            self.cloud_token(req_id, pos, prompt_len, cost, counters, h1_history)?;
        counters.tokens_cloud += 1;
        counters.cloud_requests += 1;
        Ok((tok, TokenTrace { pos, token: tok, exit: ExitPoint::Cloud, conf1, conf2: Some(conf2) }))
    }

    /// Defer one token to the cloud (Algorithm 1, CloudInference call).
    fn cloud_token(
        &mut self,
        req_id: u32,
        pos: usize,
        prompt_len: usize,
        cost: &mut CostBreakdown,
        counters: &mut RunCounters,
        h1_history: &mut Vec<Vec<f32>>,
    ) -> Result<(i32, f32)> {
        let device_id = self.cfg.device_id;
        let precision = self.precision();
        let flags = self.cfg.ablation;
        let dims_d = self.engine.dims().d_model;

        // without content manager / parallel upload the hidden states go
        // out synchronously now, on the infer channel (and without the
        // manager, the WHOLE history is retransmitted every request)
        if !flags.content_manager || !flags.parallel_upload {
            let t0 = Instant::now();
            let all: Vec<f32> = h1_history.iter().flatten().copied().collect();
            anyhow::ensure!(
                all.len() == (pos + 1) * dims_d,
                "history incomplete: {} floats for pos {pos}",
                all.len()
            );
            let payload = quant::pack(&all, precision);
            counters.bytes_up += payload.len() as u64;
            let link = self.link.as_mut().context("collaborative policy without cloud link")?;
            link.infer.send(
                &Message::UploadHidden {
                    device_id,
                    req_id,
                    start_pos: 0,
                    count: (pos + 1) as u32,
                    prompt_len: prompt_len as u32,
                    precision,
                    payload,
                }
                .encode(),
            )?;
            cost.comm_s += t0.elapsed().as_secs_f64();
        } else {
            // make sure async uploads for <= pos are on the wire before
            // measuring the request round trip
            let t0 = Instant::now();
            self.link_ref()?.flush_uploads();
            cost.comm_s += t0.elapsed().as_secs_f64();
        }

        let t0 = Instant::now();
        let link = self.link.as_mut().context("collaborative policy without cloud link")?;
        let req = Message::InferRequest {
            device_id,
            req_id,
            pos: pos as u32,
            prompt_len: prompt_len as u32,
        };
        let frame = req.encode();
        counters.bytes_up += frame.len() as u64;
        link.infer.send(&frame)?;
        let resp = Message::decode(&link.infer.recv()?)?;
        let rtt = t0.elapsed().as_secs_f64();
        match resp {
            Message::TokenResponse { token, conf, compute_s, .. } => {
                counters.bytes_down += 17; // token response frame size
                cost.cloud_s += compute_s as f64;
                cost.comm_s += (rtt - compute_s as f64).max(0.0);
                Ok((token, conf))
            }
            Message::Error { msg } => anyhow::bail!("cloud error: {msg}"),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    fn link_ref(&self) -> Result<&CloudLink> {
        self.link.as_ref().context("collaborative policy without cloud link")
    }

    /// Tear down the link, returning bytes sent on the upload channel.
    pub fn close(mut self) -> u64 {
        self.link.as_mut().map(|l| l.close()).unwrap_or(0)
    }
}
