//! Edge client (paper §4.1, §4.4, Algorithm 1): the early-exit decode
//! loop with asynchronous parallel hidden-state upload, adaptive cloud
//! deferral, and a latency-aware local fallback.
//!
//! Thread model: the engine (PJRT) stays on the caller's thread; uploads
//! go through a dedicated uploader thread feeding the upload channel
//! (paper: "the edge device concurrently continues the inference process"
//! while states transfer).  The infer channel carries one outstanding
//! request at a time; the cloud's event-driven scheduler parks a request
//! until its uploads land, so the edge never has to drain its upload
//! queue before asking for a token.
//!
//! Latency-aware exit (§4.4): with `cloud_token_budget_s` configured, a
//! deferred token the cloud has not answered within the budget is emitted
//! from the best local exit instead ([`TokenPolicy::local_fallback`]),
//! and the abandoned response is recognized by its `(req_id, pos)` echo
//! and skipped when it eventually arrives.
//!
//! Resilience: a broken transport no longer ends the collaboration.
//! Under `DeploymentConfig::reconnect` the link re-dials (exponential
//! backoff + jitter, rotating through its endpoint list on exhaustion —
//! failover), re-`Hello`s both channels with the *same* session nonce
//! and `resume = true`, replays the retained hidden-state history from
//! the [`ReplayRing`], and re-issues the in-flight request — the exact
//! recovery path a `SessionEvicted` already exercises, so a severed
//! link costs one replay round trip and zero token differences.  Only
//! when reconnect is disabled or exhausted does the run degrade to
//! local exits (latency-aware mode) or fail (strict mode).  Quiet links
//! are kept alive — and dead ones detected early — by `Ping`/`Pong`
//! keepalives (`DeploymentConfig::keepalive_idle_s`).
//!
//! Replication ([`ReplicaSet`], `DeploymentConfig::replication`): the
//! client can hold extra *warm standby* `CloudLink`s against further
//! endpoints, opened with the Hello `mirror` bit so the cloud knows the
//! session is a passive copy.  Every hidden-state upload is duplicated
//! to each live standby — asynchronously, on the standby's own uploader
//! thread — so standby context coverage tracks the primary's watermark.
//! Standbys are health-scored from keepalive ping RTT and reconnect
//! history ([`CloudLink::health_score`]); when the primary dies the
//! best-scored standby is *promoted*: the links swap, the pending
//! request is re-issued, and **no history replay** happens — the
//! standby's mirrored coverage already spans the watermark, so a warm
//! failover costs zero `context_replays` and zero token differences.
//! The degradation ladder is hedged → primary-only → §4.4 local
//! fallback: with `hedge` on, a tight-deadline deferral is duplicated to
//! the best standby and the first valid `(req_id, pos)` echo wins (the
//! loser's late echo is fenced by the stale-response skip); with no live
//! standby, failure falls back to the cold reconnect-and-replay path;
//! with nothing left, the run degrades to local exits exactly as before
//! replication existed.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::sync::{Arc, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::{DeploymentConfig, ReconnectPolicy};
use crate::coordinator::policy::{ExitPoint, TokenPolicy};
use crate::coordinator::protocol::{Channel, Message, NO_REQ, UPLOAD_HDR_LEN};
use crate::metrics::{CostBreakdown, LatencyHist, MetricsRegistry, RunCounters};
use crate::model::tokenizer::Tokenizer;
use crate::net::codec::frame_wire_len;
use crate::net::transport::{TcpTransport, Transport};
use crate::quant::{self, Precision};
use crate::runtime::traits::EdgeEngine;
use crate::trace::{Ev, TraceSink, EDGE_TRACE_ENV};
use crate::util::rng::Rng;

/// One generated token with its provenance (Table 1 columns).
#[derive(Debug, Clone)]
pub struct TokenTrace {
    pub pos: usize,
    pub token: i32,
    pub exit: ExitPoint,
    pub conf1: f32,
    pub conf2: Option<f32>,
}

/// Result of one generation request.
#[derive(Debug, Clone)]
pub struct GenerateOutput {
    pub text: String,
    pub tokens: Vec<i32>,
    pub trace: Vec<TokenTrace>,
    pub cost: CostBreakdown,
    pub counters: RunCounters,
}

enum UploadJob {
    Send(Message),
    Flush(Sender<()>),
    Done,
}

/// How long teardown waits for a wedged upload transport before
/// detaching the uploader thread instead of joining it.
const WEDGE_GUARD: Duration = Duration::from_secs(5);

/// Nonce identifying one `CloudLink` connection pair; the server fences
/// out frames from older connections of the same device id.  Never 0
/// (0 means "untagged" on the wire).
fn session_nonce() -> u64 {
    let t = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    (t.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ ((std::process::id() as u64) << 32)).max(1)
}

/// Produces a fresh `(upload, infer)` transport pair for an endpoint
/// address.  The default dialer opens two TCP connections under the
/// policy's connect timeout; tests substitute dialers that wrap the
/// transports in [`crate::net::fault::FaultTransport`] or refuse
/// certain endpoints to script failover.
pub type DialFn =
    Box<dyn FnMut(&str) -> Result<(Box<dyn Transport + Send>, Box<dyn Transport>)> + Send>;

/// How long a keepalive probe waits for its `Pong` before declaring the
/// channel dead.
const PONG_WAIT: Duration = Duration::from_secs(5);

/// Process-wide edge-side trace recorder, resolved once from
/// [`EDGE_TRACE_ENV`].  Separate from the cloud sink because edge and
/// cloud are typically separate processes — and in-process tests want
/// the two recordings distinguishable anyway.  A path that cannot be
/// opened logs a warning and leaves tracing off.
fn edge_sink() -> Option<&'static Arc<TraceSink>> {
    static SINK: OnceLock<Option<Arc<TraceSink>>> = OnceLock::new();
    SINK.get_or_init(|| match std::env::var(EDGE_TRACE_ENV) {
        Ok(p) if !p.trim().is_empty() => match TraceSink::to_file(&p) {
            Ok(s) => Some(s),
            Err(e) => {
                log::warn!("edge trace disabled: {e:#}");
                None
            }
        },
        _ => None,
    })
    .as_ref()
}

/// The cloud half of the client: dual channels + upload thread, plus
/// the reconnect state machine (endpoint list, dialer, backoff policy).
pub struct CloudLink {
    device_id: u64,
    /// Session nonce, chosen once and kept across reconnects: a resume
    /// `Hello` re-announces it so the cloud can tell "same edge, new
    /// socket" from "new edge reusing the device id".
    session: u64,
    infer: Box<dyn Transport>,
    upload_tx: Sender<UploadJob>,
    uploader: Option<JoinHandle<u64>>,
    /// Set by the uploader thread when the upload transport fails (a
    /// send error or a keepalive probe with no answer): the next cloud
    /// round trip reconnects instead of parking forever on a dead
    /// upload channel.
    upload_dead: Arc<AtomicBool>,
    /// Keepalive interval in f64 bits, shared with the uploader thread
    /// so `EdgeClient::with_cloud` can apply its config after the link
    /// was built.  `0.0` disables keepalive.
    keepalive_bits: Arc<AtomicU64>,
    /// Ordered cloud endpoints; `endpoint_idx` is the one currently
    /// connected.  Empty for transport-injected links, which cannot
    /// reconnect.
    endpoints: Vec<String>,
    endpoint_idx: usize,
    dial: Option<DialFn>,
    policy: ReconnectPolicy,
    /// Jitter source for backoff and ping nonces (splitmix64; seeded
    /// from the session nonce, so two links never share a sequence).
    rng: Rng,
    /// Whether this link's session was announced as a *mirror* (warm
    /// standby, Hello mirror bit): the cloud accepts its uploads without
    /// letting the passive copy distort LRU/eviction accounting.
    /// Cleared on promotion so a later resume Hello re-announces the
    /// link as a live primary.
    mirror: bool,
    /// Last keepalive round trip in f64-millisecond bits, shared with
    /// the uploader thread (which probes on idle) so health scoring has
    /// a fresh observation even on links whose infer channel is quiet —
    /// exactly the warm-standby case.  `0.0` until the first probe.
    ping_rtt_bits: Arc<AtomicU64>,
    /// Successful reconnects over this link's lifetime.
    pub reconnects: u64,
    /// Reconnects that landed on a *different* endpoint than the one
    /// that broke (cloud-restart failovers).
    pub failovers: u64,
    /// Last measured keepalive round trip on the infer channel, ms.
    /// `0.0` until the first ping completes.
    pub ping_rtt_last_ms: f64,
    /// Upload bytes pushed by uploader threads already retired by
    /// reconnects, so [`CloudLink::close`] reports the link-lifetime
    /// total rather than only the final uploader's share.
    retired_upload_bytes: u64,
    /// Per-channel data-frame ordinals for the edge trace tap
    /// ([`EDGE_TRACE_ENV`]) — the unit
    /// [`anchored_plan`](crate::trace::anchored_plan) keys client-side
    /// fault plans on.  Atomics because uploads are enqueued through
    /// `&self`.
    trace_upload_n: AtomicU64,
    trace_infer_send_n: AtomicU64,
    trace_infer_recv_n: AtomicU64,
    /// Edge-side latency histograms (`ce_edge_cloud_rtt_ns`,
    /// `ce_edge_ping_rtt_ns`), resolved from `CE_METRICS` when the link
    /// is built; `None` keeps both record sites at one `Option` check.
    hist_cloud_rtt: Option<Arc<LatencyHist>>,
    hist_ping_rtt: Option<Arc<LatencyHist>>,
}

/// Resolve the edge's two RTT histograms from the environment-gated
/// registry (the edge has no `CloudConfig`, so `CE_METRICS` is its only
/// switch).
fn edge_rtt_hists() -> (Option<Arc<LatencyHist>>, Option<Arc<LatencyHist>>) {
    match MetricsRegistry::resolve(false) {
        Some(reg) => {
            (Some(reg.hist("ce_edge_cloud_rtt_ns")), Some(reg.hist("ce_edge_ping_rtt_ns")))
        }
        None => (None, None),
    }
}

/// Send both `Hello`s and wait for both `Ack`s.  Waiting for the
/// upload-channel `Ack` before returning is what makes resume safe: the
/// reactor forwards the session pin/reset to the worker *before* it
/// acks, and the worker drains its queue in order, so a replay sent
/// after this handshake can never be wiped by its own Hello.
fn handshake(
    device_id: u64,
    session: u64,
    resume: bool,
    mirror: bool,
    upload: &mut dyn Transport,
    infer: &mut dyn Transport,
) -> Result<()> {
    infer.send(
        &Message::Hello { device_id, session, channel: Channel::Infer, resume, mirror }.encode(),
    )?;
    expect_ack(infer)?;
    upload.send(
        &Message::Hello { device_id, session, channel: Channel::Upload, resume, mirror }.encode(),
    )?;
    expect_ack(upload)?;
    Ok(())
}

/// Spawn the upload drain thread.  When idle past the keepalive
/// interval it probes the channel with a `Ping` and waits for the
/// `Pong`; any failure marks the link dead (`upload_dead`) so the next
/// round trip reconnects instead of discovering the corpse via a park
/// timeout.  Each successful probe also records its round trip into the
/// shared `rtt_bits` cell (f64 milliseconds as bits) — this is how warm
/// standby links, whose infer channel is otherwise quiet, keep a fresh
/// RTT observation for health scoring.  Returns the job sender and the
/// join handle (whose value is the bytes pushed onto the channel).
fn spawn_uploader(
    mut upload: Box<dyn Transport + Send>,
    keepalive_bits: Arc<AtomicU64>,
    dead: Arc<AtomicBool>,
    rtt_bits: Arc<AtomicU64>,
) -> Result<(Sender<UploadJob>, JoinHandle<u64>)> {
    let (tx, rx) = channel::<UploadJob>();
    let handle = std::thread::Builder::new().name("edge-upload".into()).spawn(move || {
        let mut sent = 0u64;
        let mut nonce = 0u64;
        loop {
            let ka = f64::from_bits(keepalive_bits.load(Ordering::Relaxed));
            let job = if ka > 0.0 {
                match rx.recv_timeout(Duration::from_secs_f64(ka)) {
                    Ok(job) => job,
                    Err(RecvTimeoutError::Timeout) => {
                        nonce += 1;
                        let ping = Message::Ping { nonce }.encode();
                        sent += ping.len() as u64;
                        let t0 = Instant::now();
                        let alive = upload.send(&ping).is_ok()
                            && matches!(
                                upload.recv_deadline(Instant::now() + PONG_WAIT),
                                Ok(Some(_))
                            );
                        if !alive {
                            dead.store(true, Ordering::Release);
                            break;
                        }
                        let rtt_ms = t0.elapsed().as_secs_f64() * 1e3;
                        rtt_bits.store(rtt_ms.to_bits(), Ordering::Relaxed);
                        continue;
                    }
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            } else {
                match rx.recv() {
                    Ok(job) => job,
                    Err(_) => break,
                }
            };
            match job {
                UploadJob::Send(msg) => {
                    let frame = msg.encode();
                    sent += frame.len() as u64;
                    if upload.send(&frame).is_err() {
                        dead.store(true, Ordering::Release);
                        break;
                    }
                }
                UploadJob::Flush(ack) => {
                    let _ = ack.send(());
                }
                UploadJob::Done => break,
            }
        }
        sent
    })?;
    Ok((tx, handle))
}

impl CloudLink {
    /// Open the dual API from two injected transports (paper §4.2):
    /// `upload` is drained by a background thread, `infer` is
    /// synchronous.  A link built this way has no dialer, so it cannot
    /// reconnect — a broken transport degrades the run exactly as
    /// before the resilience layer.  Use [`CloudLink::connect`] (or
    /// [`CloudLink::connect_via`]) for reconnect + failover.
    pub fn new(
        device_id: u64,
        mut upload: Box<dyn Transport + Send>,
        mut infer: Box<dyn Transport>,
    ) -> Result<Self> {
        let session = session_nonce();
        handshake(device_id, session, false, false, &mut *upload, &mut *infer)?;
        let keepalive_bits =
            Arc::new(AtomicU64::new(DeploymentConfig::default().keepalive_idle_s.to_bits()));
        let upload_dead = Arc::new(AtomicBool::new(false));
        let ping_rtt_bits = Arc::new(AtomicU64::new(0));
        let (upload_tx, uploader) = spawn_uploader(
            upload,
            Arc::clone(&keepalive_bits),
            Arc::clone(&upload_dead),
            Arc::clone(&ping_rtt_bits),
        )?;
        let (hist_cloud_rtt, hist_ping_rtt) = edge_rtt_hists();
        Ok(Self {
            device_id,
            session,
            infer,
            upload_tx,
            uploader: Some(uploader),
            upload_dead,
            keepalive_bits,
            endpoints: Vec::new(),
            endpoint_idx: 0,
            dial: None,
            policy: ReconnectPolicy::disabled(),
            rng: Rng::seed_from_u64(session),
            mirror: false,
            ping_rtt_bits,
            reconnects: 0,
            failovers: 0,
            ping_rtt_last_ms: 0.0,
            retired_upload_bytes: 0,
            trace_upload_n: AtomicU64::new(0),
            trace_infer_send_n: AtomicU64::new(0),
            trace_infer_recv_n: AtomicU64::new(0),
            hist_cloud_rtt,
            hist_ping_rtt,
        })
    }

    /// Dial an ordered list of cloud endpoints over TCP and open the
    /// dual API against the first one that answers.  The link keeps the
    /// endpoint list and `policy`: a transport broken mid-run is
    /// re-dialed under exponential backoff, and when every attempt
    /// against the current endpoint fails the link rotates to the next
    /// one (failover) — a cloud restart costs one replay round trip
    /// instead of a degraded run.
    pub fn connect(device_id: u64, endpoints: &[String], policy: ReconnectPolicy) -> Result<Self> {
        Self::connect_role(device_id, endpoints.to_vec(), policy, Self::tcp_dialer(&policy), false)
    }

    /// [`CloudLink::connect`] for a *warm standby*: both `Hello`s carry
    /// the mirror bit, so the cloud accepts this session's uploads
    /// without letting the passive copy distort eviction accounting.
    pub fn connect_mirror(
        device_id: u64,
        endpoints: &[String],
        policy: ReconnectPolicy,
    ) -> Result<Self> {
        Self::connect_role(device_id, endpoints.to_vec(), policy, Self::tcp_dialer(&policy), true)
    }

    fn tcp_dialer(policy: &ReconnectPolicy) -> DialFn {
        let timeout = Duration::from_secs_f64(policy.connect_timeout_s.max(1e-3));
        Box::new(move |addr: &str| {
            let upload = Box::new(TcpTransport::connect_timeout(addr, timeout)?);
            let infer = Box::new(TcpTransport::connect_timeout(addr, timeout)?);
            Ok((upload as Box<dyn Transport + Send>, infer as Box<dyn Transport>))
        })
    }

    /// [`CloudLink::connect`] with a caller-supplied dialer — the
    /// fault-injection seam: tests dial through
    /// [`crate::net::fault::FaultTransport`] wrappers or refuse
    /// endpoints to script severs and failovers deterministically.
    pub fn connect_via(
        device_id: u64,
        endpoints: Vec<String>,
        policy: ReconnectPolicy,
        dial: DialFn,
    ) -> Result<Self> {
        Self::connect_role(device_id, endpoints, policy, dial, false)
    }

    /// [`CloudLink::connect_mirror`] with a caller-supplied dialer.
    pub fn connect_mirror_via(
        device_id: u64,
        endpoints: Vec<String>,
        policy: ReconnectPolicy,
        dial: DialFn,
    ) -> Result<Self> {
        Self::connect_role(device_id, endpoints, policy, dial, true)
    }

    fn connect_role(
        device_id: u64,
        endpoints: Vec<String>,
        policy: ReconnectPolicy,
        mut dial: DialFn,
        mirror: bool,
    ) -> Result<Self> {
        anyhow::ensure!(!endpoints.is_empty(), "no cloud endpoints");
        let session = session_nonce();
        let mut last_err = None;
        for (idx, ep) in endpoints.iter().enumerate() {
            match dial(ep).and_then(|(mut upload, mut infer)| {
                handshake(device_id, session, false, mirror, &mut *upload, &mut *infer)?;
                Ok((upload, infer))
            }) {
                Ok((upload, infer)) => {
                    let keepalive_bits = Arc::new(AtomicU64::new(
                        DeploymentConfig::default().keepalive_idle_s.to_bits(),
                    ));
                    let upload_dead = Arc::new(AtomicBool::new(false));
                    let ping_rtt_bits = Arc::new(AtomicU64::new(0));
                    let (upload_tx, uploader) = spawn_uploader(
                        upload,
                        Arc::clone(&keepalive_bits),
                        Arc::clone(&upload_dead),
                        Arc::clone(&ping_rtt_bits),
                    )?;
                    let (hist_cloud_rtt, hist_ping_rtt) = edge_rtt_hists();
                    return Ok(Self {
                        device_id,
                        session,
                        infer,
                        upload_tx,
                        uploader: Some(uploader),
                        upload_dead,
                        keepalive_bits,
                        endpoints,
                        endpoint_idx: idx,
                        dial: Some(dial),
                        policy,
                        rng: Rng::seed_from_u64(session),
                        mirror,
                        ping_rtt_bits,
                        reconnects: 0,
                        failovers: 0,
                        ping_rtt_last_ms: 0.0,
                        retired_upload_bytes: 0,
                        trace_upload_n: AtomicU64::new(0),
                        trace_infer_send_n: AtomicU64::new(0),
                        trace_infer_recv_n: AtomicU64::new(0),
                        hist_cloud_rtt,
                        hist_ping_rtt,
                    });
                }
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.unwrap_or_else(|| anyhow::anyhow!("no cloud endpoints")))
            .context("every cloud endpoint refused the initial connection")
    }

    /// Apply the deployment's keepalive interval (seconds; `0` off).
    pub fn set_keepalive(&self, idle_s: f64) {
        self.keepalive_bits.store(idle_s.to_bits(), Ordering::Relaxed);
    }

    /// True when the uploader thread has declared its transport dead.
    fn upload_is_dead(&self) -> bool {
        self.upload_dead.load(Ordering::Acquire)
    }

    /// Last keepalive round trip observed on *either* channel, in
    /// milliseconds: the freshest of the uploader thread's idle probes
    /// and explicit [`CloudLink::ping`] calls.  `0.0` until one lands.
    pub fn ping_rtt_ms(&self) -> f64 {
        let cell = f64::from_bits(self.ping_rtt_bits.load(Ordering::Relaxed));
        if cell > 0.0 {
            cell
        } else {
            self.ping_rtt_last_ms
        }
    }

    /// Replica health, lower is better: the last keepalive RTT in
    /// milliseconds plus a fixed penalty per reconnect this link has
    /// survived (a flapping link should lose a promotion race to a
    /// stable one even when its last probe was fast).  A link whose
    /// uploader declared the transport dead scores infinitely bad and
    /// is never selected.
    pub fn health_score(&self) -> f64 {
        /// Score penalty (in RTT-equivalent milliseconds) per survived
        /// reconnect.
        const RECONNECT_PENALTY_MS: f64 = 25.0;
        if self.upload_is_dead() {
            return f64::INFINITY;
        }
        self.ping_rtt_ms() + RECONNECT_PENALTY_MS * self.reconnects as f64
    }

    /// Probe the infer channel with a `Ping` and record the round trip
    /// in `ping_rtt_last_ms`.  Stale frames from an earlier abandoned
    /// deferral are drained and skipped while waiting for the `Pong`.
    pub fn ping(&mut self) -> Result<f64> {
        let nonce = self.rng.next_u64();
        let t0 = Instant::now();
        self.infer.send(&Message::Ping { nonce }.encode())?;
        let deadline = t0 + PONG_WAIT;
        loop {
            let frame = self
                .infer
                .recv_deadline(deadline)?
                .context("keepalive ping timed out with no pong")?;
            match Message::decode(&frame)? {
                Message::Pong { nonce: n } if n == nonce => {
                    if let Some(h) = &self.hist_ping_rtt {
                        h.record_duration(t0.elapsed());
                    }
                    let rtt_ms = t0.elapsed().as_secs_f64() * 1e3;
                    self.ping_rtt_last_ms = rtt_ms;
                    self.ping_rtt_bits.store(rtt_ms.to_bits(), Ordering::Relaxed);
                    return Ok(rtt_ms);
                }
                // stale token/error/evicted/pong frames from an
                // abandoned deferral: skip, keep waiting for our pong
                _ => continue,
            }
        }
    }

    /// The reconnect state machine: tear down the dead pair, then
    /// re-dial under the policy — `max_attempts` backoff-jittered tries
    /// against the current endpoint, rotating through the endpoint list
    /// on exhaustion — and re-`Hello` both channels with the same
    /// session nonce (`resume = true`).  On success the link is live
    /// again (counters updated); the caller still owns replaying the
    /// in-flight request's history.  Fails only once every endpoint is
    /// exhausted, or when the link has no dialer / a disabled policy.
    pub fn reestablish(&mut self) -> Result<()> {
        anyhow::ensure!(self.policy.enabled(), "reconnect disabled by policy");
        let mut dial = self
            .dial
            .take()
            .context("link was built from injected transports; no dialer to reconnect with")?;
        let result = self.reestablish_with(&mut dial);
        self.dial = Some(dial);
        result
    }

    fn reestablish_with(&mut self, dial: &mut DialFn) -> Result<()> {
        // the old pair is dead: stop the uploader (it usually already
        // exited on a send error) and let the transports drop
        self.retired_upload_bytes += self.stop_uploader();
        let mut last_err: Option<anyhow::Error> = None;
        for round in 0..self.endpoints.len() {
            let ep = self.endpoints[self.endpoint_idx].clone();
            for attempt in 0..self.policy.max_attempts {
                let backoff = self.policy.backoff_s(attempt);
                let jittered = backoff * (1.0 - self.policy.jitter * self.rng.gen_f64());
                if jittered > 0.0 {
                    std::thread::sleep(Duration::from_secs_f64(jittered));
                }
                match dial(&ep).and_then(|(mut upload, mut infer)| {
                    handshake(
                        self.device_id,
                        self.session,
                        true,
                        self.mirror,
                        &mut *upload,
                        &mut *infer,
                    )?;
                    Ok((upload, infer))
                }) {
                    Ok((upload, infer)) => {
                        self.upload_dead.store(false, Ordering::Release);
                        let (upload_tx, uploader) = spawn_uploader(
                            upload,
                            Arc::clone(&self.keepalive_bits),
                            Arc::clone(&self.upload_dead),
                            Arc::clone(&self.ping_rtt_bits),
                        )?;
                        self.infer = infer;
                        self.upload_tx = upload_tx;
                        self.uploader = Some(uploader);
                        self.reconnects += 1;
                        if let Some(sink) = edge_sink() {
                            sink.emit(
                                Ev::new("edge_reconnect")
                                    .u("device", self.device_id)
                                    .u("round", round as u64),
                            );
                        }
                        if round > 0 {
                            self.failovers += 1;
                            log::info!(
                                "failover: device {} resumed session on {ep}",
                                self.device_id
                            );
                        }
                        return Ok(());
                    }
                    Err(e) => last_err = Some(e),
                }
            }
            // this endpoint is exhausted: rotate and start the attempt
            // budget over against the next one
            self.endpoint_idx = (self.endpoint_idx + 1) % self.endpoints.len();
        }
        Err(last_err.unwrap_or_else(|| anyhow::anyhow!("reconnect disabled")))
            .with_context(|| {
                format!("reconnect exhausted across {} endpoint(s)", self.endpoints.len())
            })
    }

    /// Emit one edge-side trace event when [`EDGE_TRACE_ENV`] is active.
    fn trace_edge(&self, ev: &str, chan: &str, n: u64, frame: &[u8]) {
        if let Some(sink) = edge_sink() {
            sink.emit(
                Ev::new(ev)
                    .u("device", self.device_id)
                    .s("chan", chan)
                    .u("n", n)
                    .u("tag", frame.first().copied().unwrap_or(0) as u64)
                    .u("len", frame.len() as u64),
            );
        }
    }

    /// Trace an infer-channel send.  Call right before putting `frame`
    /// on the wire so the recorded per-channel ordinal matches the send
    /// order.
    fn trace_infer_send(&self, frame: &[u8]) {
        if edge_sink().is_some() {
            let n = self.trace_infer_send_n.fetch_add(1, Ordering::Relaxed);
            self.trace_edge("edge_send", "infer", n, frame);
        }
    }

    /// Trace an infer-channel receive (call once per received frame).
    fn trace_infer_recv(&self, frame: &[u8]) {
        if edge_sink().is_some() {
            let n = self.trace_infer_recv_n.fetch_add(1, Ordering::Relaxed);
            self.trace_edge("edge_recv", "infer", n, frame);
        }
    }

    fn enqueue_upload(&self, msg: Message) {
        if edge_sink().is_some() {
            // encode only on the traced path; the ordinal is the enqueue
            // order, which the FIFO uploader preserves on the wire
            let frame = msg.encode();
            let n = self.trace_upload_n.fetch_add(1, Ordering::Relaxed);
            self.trace_edge("edge_send", "upload", n, &frame);
        }
        let _ = self.upload_tx.send(UploadJob::Send(msg));
    }

    /// Block until every upload enqueued so far is on the wire, or until
    /// `timeout` (`None` waits indefinitely).  `false` means the wait
    /// timed out: the uploader is wedged on a transport that stopped
    /// accepting bytes.
    fn flush_uploads_within(&self, timeout: Option<Duration>) -> bool {
        let (tx, rx) = channel();
        if self.upload_tx.send(UploadJob::Flush(tx)).is_err() {
            return true; // uploader already exited; nothing left to flush
        }
        match timeout {
            Some(t) => rx.recv_timeout(t).is_ok(),
            None => rx.recv().is_ok(),
        }
    }

    /// Stop the uploader thread, returning the bytes it put on the wire.
    ///
    /// Bounded drain before the join: the queue is FIFO, so a flush ack
    /// proves every pending Send is on the wire and Done will be
    /// processed immediately.  A transport that stopped accepting bytes
    /// (cloud hung without closing the socket) must not wedge teardown —
    /// detach the uploader instead of joining it.  Used both by final
    /// teardown ([`Self::close`]) and by reconnect, which retires the
    /// dead pair's uploader before spawning one on the fresh transport.
    fn stop_uploader(&mut self) -> u64 {
        if !self.flush_uploads_within(Some(WEDGE_GUARD)) {
            log::warn!("upload channel wedged; detaching uploader thread without joining");
            self.uploader.take();
            return 0;
        }
        let _ = self.upload_tx.send(UploadJob::Done);
        self.uploader.take().map(|u| u.join().unwrap_or(0)).unwrap_or(0)
    }

    fn close(&mut self) -> u64 {
        self.retired_upload_bytes + self.stop_uploader()
    }
}

impl Drop for CloudLink {
    fn drop(&mut self) {
        // same guarantees as close(): tail uploads flushed when the
        // transport is live, bounded detach when it is wedged
        let _ = self.close();
    }
}

fn expect_ack(t: &mut dyn Transport) -> Result<()> {
    match Message::decode(&t.recv()?)? {
        Message::Ack => Ok(()),
        other => anyhow::bail!("expected Ack, got {other:?}"),
    }
}

/// Best local alternative to a cloud deferral (paper §4.4): the exit
/// point the policy picks, with its token.
fn best_local(
    policy: &TokenPolicy,
    conf1: f32,
    tok1: i32,
    exit2: Option<(f32, i32)>,
) -> (ExitPoint, i32) {
    match (policy.local_fallback(conf1, exit2.map(|(c, _)| c)), exit2) {
        (ExitPoint::Exit2, Some((_, tok2))) => (ExitPoint::Exit2, tok2),
        _ => (ExitPoint::Exit1, tok1),
    }
}

/// How a cloud deferral concluded.
enum CloudAnswer {
    /// The cloud answered within budget.
    Answered { token: i32 },
    /// Budget expired with no answer yet.
    DeadlineExpired,
}

/// Evictions one deferral will recover from before giving up — a cloud
/// that evicts the session faster than the edge can replay it is treated
/// like a failing link, not retried forever.
const REPLAY_LIMIT: usize = 3;

/// Bounded per-request retention of the exit-1 hidden states, kept
/// whenever the policy may use the cloud:
///
/// * the cloud's context store may evict this device's session (memory
///   budget or idle TTL); the `SessionEvicted` response is answered by
///   replaying the history from position 0 so the cloud can re-prefill —
///   one extra upload round trip, bit-identical tokens;
/// * the no-content-manager / no-parallel-upload ablations retransmit the
///   history synchronously on every cloud request (paper §5.4).
///
/// The ring is bounded by `DeploymentConfig::replay_ring_positions`;
/// once position 0 has been dropped, [`ReplayRing::history_upto`]
/// returns `None` and an eviction degrades exactly like a cloud error.
struct ReplayRing {
    cap: usize,
    /// Position of `bufs[0]` (> 0 once the cap has forced drops).
    start: usize,
    bufs: VecDeque<Vec<f32>>,
}

impl ReplayRing {
    fn new(cap: usize) -> Self {
        Self { cap: cap.max(1), start: 0, bufs: VecDeque::new() }
    }

    /// Retain the hidden state of the next position, dropping the oldest
    /// one past the cap.
    fn push(&mut self, h: Vec<f32>) {
        if self.bufs.len() == self.cap {
            self.bufs.pop_front();
            self.start += 1;
        }
        self.bufs.push_back(h);
    }

    /// Concatenated history for positions `0..=pos`, or `None` when the
    /// ring no longer reaches back to position 0 (or has not reached
    /// `pos` yet).
    fn history_upto(&self, pos: usize) -> Option<Vec<f32>> {
        if self.start > 0 || self.bufs.len() < pos + 1 {
            return None;
        }
        let total: usize = self.bufs.iter().take(pos + 1).map(Vec::len).sum();
        let mut out = Vec::with_capacity(total);
        for b in self.bufs.iter().take(pos + 1) {
            out.extend_from_slice(b);
        }
        Some(out)
    }
}

impl CloudLink {
    /// Send the full `0..=pos` hidden-state history on the infer channel
    /// as one `UploadHidden` (start 0, same request id), with the
    /// standard byte accounting.  One definition serves both users of
    /// the shape — the synchronous-retransmit ablations and the eviction
    /// replay — so the wire format and counters cannot drift apart.
    #[allow(clippy::too_many_arguments)]
    fn send_full_history(
        &mut self,
        ring: &ReplayRing,
        req_id: u32,
        pos: usize,
        prompt_len: usize,
        d_model: usize,
        precision: Precision,
        counters: &mut RunCounters,
    ) -> Result<()> {
        let all = ring.history_upto(pos).with_context(|| {
            format!(
                "hidden-state history no longer reaches position 0 at pos {pos} (ring overflow)"
            )
        })?;
        anyhow::ensure!(
            all.len() == (pos + 1) * d_model,
            "history incomplete: {} floats for pos {pos}",
            all.len()
        );
        let payload = quant::pack(&all, precision);
        counters.bytes_up += frame_wire_len(UPLOAD_HDR_LEN + payload.len()) as u64;
        let frame = Message::UploadHidden {
            device_id: self.device_id,
            req_id,
            start_pos: 0,
            count: (pos + 1) as u32,
            prompt_len: prompt_len as u32,
            precision,
            payload,
        }
        .encode();
        self.trace_infer_send(&frame);
        self.infer.send(&frame)
    }
}

/// Warm standby replicas above the primary [`CloudLink`]
/// (`DeploymentConfig::replication`).
///
/// Each standby is a full dual-channel session against a *different*
/// endpoint, opened with the Hello mirror bit.  The client duplicates
/// every hidden-state upload to each live standby, so standby context
/// coverage tracks the primary's watermark; on primary failure the
/// best-scored standby ([`CloudLink::health_score`]) is promoted with
/// **zero** history replay.  A promoted or dead standby leaves the set —
/// replicas are a budget spent over the run's lifetime, not a pool that
/// refills.
pub struct ReplicaSet {
    standbys: Vec<CloudLink>,
    /// Duplicate tight-deadline infer requests to the best standby; the
    /// first valid `(req_id, pos)` echo wins.
    pub hedge: bool,
    /// Warm promotions over this set's lifetime.
    pub failovers_warm: u64,
}

impl ReplicaSet {
    pub fn new(hedge: bool) -> Self {
        Self { standbys: Vec::new(), hedge, failovers_warm: 0 }
    }

    /// Attach one warm standby (a link opened with
    /// [`CloudLink::connect_mirror`] / [`CloudLink::connect_mirror_via`]).
    pub fn add_standby(&mut self, link: CloudLink) {
        self.standbys.push(link);
    }

    pub fn len(&self) -> usize {
        self.standbys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.standbys.is_empty()
    }

    /// Index of the healthiest live standby, or `None` when every
    /// standby is dead (or the set is empty).
    fn best(&self) -> Option<usize> {
        let mut best: Option<(usize, f64)> = None;
        for (i, sb) in self.standbys.iter().enumerate() {
            let score = sb.health_score();
            if !score.is_finite() {
                continue;
            }
            if best.map_or(true, |(_, s)| score < s) {
                best = Some((i, score));
            }
        }
        best.map(|(i, _)| i)
    }

    /// Last keepalive RTT per standby, milliseconds, in replica order
    /// (`0.0` until a probe lands) — the `replica_ping_rtt_ms` gauge.
    pub fn ping_rtts_ms(&self) -> Vec<f64> {
        self.standbys.iter().map(CloudLink::ping_rtt_ms).collect()
    }

    /// Health score per standby, in replica order (lower is better,
    /// `inf` = dead).
    pub fn health_scores(&self) -> Vec<f64> {
        self.standbys.iter().map(CloudLink::health_score).collect()
    }
}

/// The edge client: engine + policy + optional cloud link.
pub struct EdgeClient<E: EdgeEngine> {
    pub engine: E,
    pub tokenizer: Tokenizer,
    pub cfg: DeploymentConfig,
    link: Option<CloudLink>,
    /// Warm standby replicas; `None` (the default) keeps every code
    /// path byte-identical to the pre-replication client.
    replicas: Option<ReplicaSet>,
    /// Set when the infer transport failed mid-run (latency-aware mode
    /// only): the rest of the run uses local exits.
    link_broken: bool,
    req_id: u32,
}

impl<E: EdgeEngine> EdgeClient<E> {
    /// Standalone-capable client (no cloud link).  With a collaborative
    /// policy, deferred tokens fail — use [`Self::with_cloud`].
    pub fn standalone(engine: E, cfg: DeploymentConfig) -> Self {
        let tokenizer = Tokenizer::from_dims(engine.dims());
        Self { engine, tokenizer, cfg, link: None, replicas: None, link_broken: false, req_id: 0 }
    }

    pub fn with_cloud(engine: E, cfg: DeploymentConfig, link: CloudLink) -> Self {
        let tokenizer = Tokenizer::from_dims(engine.dims());
        // the uploader thread owns the keepalive cadence; hand it the
        // deployment's idle bound (must stay under the cloud reactor's
        // idle_timeout_s for quiet links to survive the reap)
        link.set_keepalive(cfg.keepalive_idle_s);
        Self {
            engine,
            tokenizer,
            cfg,
            link: Some(link),
            replicas: None,
            link_broken: false,
            req_id: 0,
        }
    }

    /// [`Self::with_cloud`] plus a set of warm standby replicas.  Every
    /// standby gets the deployment's keepalive cadence — the probes are
    /// what keep a quiet standby alive under the reactor's idle reap
    /// *and* what feed its health score.
    pub fn with_cloud_replicas(
        engine: E,
        cfg: DeploymentConfig,
        link: CloudLink,
        set: ReplicaSet,
    ) -> Self {
        for sb in &set.standbys {
            sb.set_keepalive(cfg.keepalive_idle_s);
        }
        let mut client = Self::with_cloud(engine, cfg, link);
        client.replicas = Some(set);
        client
    }

    /// The live replica set, when replication is configured.
    pub fn replicas(&self) -> Option<&ReplicaSet> {
        self.replicas.as_ref()
    }

    fn precision(&self) -> Precision {
        Precision::from_flag(self.cfg.ablation.half_precision)
    }

    /// Generate a completion for `prompt` (Algorithm 1).
    pub fn generate(&mut self, prompt: &str) -> Result<GenerateOutput> {
        self.req_id += 1;
        let req_id = self.req_id;
        let policy = TokenPolicy::new(self.cfg.policy, self.cfg.ablation);
        let dims = self.engine.dims().clone();
        let precision = self.precision();
        let flags = self.cfg.ablation;
        let device_id = self.cfg.device_id;

        let prompt_ids = self.tokenizer.encode(prompt);
        let prompt_len = prompt_ids.len();
        anyhow::ensure!(prompt_len <= dims.max_prompt, "prompt too long");

        let wall0 = Instant::now();
        let mut cost = CostBreakdown::default();
        let mut counters = RunCounters::default();
        let mut trace: Vec<TokenTrace> = Vec::new();
        let mut tokens: Vec<i32> = Vec::new();

        // resilience counters are link-lifetime totals; snapshot so this
        // run reports only its own reconnect/failover deltas
        let (reconnects0, failovers0) =
            self.link.as_ref().map(|l| (l.reconnects, l.failovers)).unwrap_or((0, 0));

        self.engine.reset();

        // --- prefill -----------------------------------------------------
        let t0 = Instant::now();
        let pre = self.engine.prefill(&prompt_ids)?;
        cost.edge_s += t0.elapsed().as_secs_f64();

        // h1 history retained UNCONDITIONALLY (but bounded) whenever the
        // policy may use the cloud: the cloud's context store can evict
        // this device's session at any idle moment, and recovery replays
        // the history from position 0.  The non-CM / non-parallel-upload
        // ablations read the same ring for their synchronous
        // retransmissions.
        let keep_history = policy.uses_cloud();
        let mut ring = ReplayRing::new(self.cfg.replay_ring_positions);
        if keep_history {
            for c in pre.h1.chunks(dims.d_model) {
                ring.push(c.to_vec());
            }
        }

        // parallel upload of prompt hidden states (Algorithm 1 line 12)
        if policy.uses_cloud() && flags.parallel_upload && flags.content_manager {
            let payload = quant::pack(&pre.h1, precision);
            // full wire cost (frame prefix + message header + payload):
            // the same arithmetic the DES harness prices, so simulated
            // and measured byte totals agree exactly
            let wire = frame_wire_len(UPLOAD_HDR_LEN + payload.len()) as u64;
            counters.bytes_up += wire;
            let msg = Message::UploadHidden {
                device_id,
                req_id,
                start_pos: 0,
                count: prompt_len as u32,
                prompt_len: prompt_len as u32,
                precision,
                payload,
            };
            self.mirror_upload(&msg, wire, &mut counters);
            self.link_ref()?.enqueue_upload(msg);
        }

        // --- first token decision at the last prompt position -------------
        let mut pos = prompt_len - 1;
        let mut next = self.decide_token(
            &policy, req_id, pos, prompt_len,
            pre.exit1.conf, pre.exit1.token,
            Some((pre.exit2.conf, pre.exit2.token)),
            &mut cost, &mut counters, &ring,
        )?;
        trace.push(next.1.clone());
        tokens.push(next.0);

        // --- decode loop ---------------------------------------------------
        while !self.tokenizer.is_eos(*tokens.last().unwrap())
            && tokens.len() < self.cfg.max_new_tokens
            && prompt_len + tokens.len() < dims.max_seq
        {
            pos = prompt_len + tokens.len() - 1;
            let input = *tokens.last().unwrap();

            let t0 = Instant::now();
            let s1 = self.engine.seg1(input, pos)?;
            cost.edge_s += t0.elapsed().as_secs_f64();

            if keep_history {
                ring.push(s1.h1.clone());
            }
            if policy.uses_cloud() && flags.parallel_upload && flags.content_manager {
                let payload = quant::pack(&s1.h1, precision);
                let wire = frame_wire_len(UPLOAD_HDR_LEN + payload.len()) as u64;
                counters.bytes_up += wire;
                let msg = Message::UploadHidden {
                    device_id,
                    req_id,
                    start_pos: pos as u32,
                    count: 1,
                    prompt_len: prompt_len as u32,
                    precision,
                    payload,
                };
                self.mirror_upload(&msg, wire, &mut counters);
                self.link_ref()?.enqueue_upload(msg);
            }

            next = if policy.exit_at_1(s1.exit1.conf) {
                counters.tokens_exit1 += 1;
                (
                    s1.exit1.token,
                    TokenTrace {
                        pos,
                        token: s1.exit1.token,
                        exit: ExitPoint::Exit1,
                        conf1: s1.exit1.conf,
                        conf2: None,
                    },
                )
            } else {
                let t0 = Instant::now();
                let s2 = self.engine.seg2(&s1.h1, pos)?;
                cost.edge_s += t0.elapsed().as_secs_f64();
                if policy.exit_at_2(s2.exit2.conf) {
                    counters.tokens_exit2 += 1;
                    (
                        s2.exit2.token,
                        TokenTrace {
                            pos,
                            token: s2.exit2.token,
                            exit: ExitPoint::Exit2,
                            conf1: s1.exit1.conf,
                            conf2: Some(s2.exit2.conf),
                        },
                    )
                } else {
                    let fb = best_local(
                        &policy,
                        s1.exit1.conf,
                        s1.exit1.token,
                        Some((s2.exit2.conf, s2.exit2.token)),
                    );
                    let (tok, exit) = self.cloud_token(
                        req_id, pos, prompt_len, Some(fb),
                        &mut cost, &mut counters, &ring,
                    )?;
                    (
                        tok,
                        TokenTrace {
                            pos,
                            token: tok,
                            exit,
                            conf1: s1.exit1.conf,
                            conf2: Some(s2.exit2.conf),
                        },
                    )
                }
            };
            trace.push(next.1.clone());
            tokens.push(next.0);
        }

        // --- session teardown (§4.4 step 6) --------------------------------
        let flush_cap = self.cfg.cloud_token_budget_s.map_or(WEDGE_GUARD, Duration::from_secs_f64);
        if let Some(link) = self.link.as_mut() {
            // drain queued uploads first so EndSession (on the other
            // connection) cannot release server state that a straggling
            // upload would then recreate; bounded so a cloud that stopped
            // reading cannot wedge the generate call
            if !link.flush_uploads_within(Some(flush_cap)) {
                log::warn!("upload flush timed out during teardown");
            }
            let end = Message::EndSession { device_id, req_id }.encode();
            link.trace_infer_send(&end);
            let _ = link.infer.send(&end);
        }
        if let Some(set) = self.replicas.as_mut() {
            // mirrored sessions end with the request too, under the same
            // flush-before-end ordering; a dead standby is skipped (its
            // server reaps the session on idle timeout)
            let end = Message::EndSession { device_id, req_id }.encode();
            for sb in set.standbys.iter_mut() {
                if sb.upload_is_dead() || !sb.flush_uploads_within(Some(flush_cap)) {
                    continue;
                }
                sb.trace_infer_send(&end);
                let _ = sb.infer.send(&end);
            }
        }

        cost.total_s = wall0.elapsed().as_secs_f64();
        counters.tokens_generated = tokens.len();
        if let Some(link) = self.link.as_ref() {
            // saturating: a warm promotion swaps in a standby whose
            // lifetime totals started from zero, which can sit below the
            // old primary's snapshot
            counters.reconnects = link.reconnects.saturating_sub(reconnects0);
            counters.failovers = link.failovers.saturating_sub(failovers0);
            counters.ping_rtt_last_ms = link.ping_rtt_last_ms;
        }
        if let Some(set) = self.replicas.as_ref() {
            counters.replica_ping_rtt_ms = set.ping_rtts_ms();
        }
        Ok(GenerateOutput {
            text: self.tokenizer.decode(&tokens),
            tokens,
            trace,
            cost,
            counters,
        })
    }

    /// First-token decision shares the cloud path with the decode loop.
    #[allow(clippy::too_many_arguments)]
    fn decide_token(
        &mut self,
        policy: &TokenPolicy,
        req_id: u32,
        pos: usize,
        prompt_len: usize,
        conf1: f32,
        tok1: i32,
        exit2: Option<(f32, i32)>,
        cost: &mut CostBreakdown,
        counters: &mut RunCounters,
        ring: &ReplayRing,
    ) -> Result<(i32, TokenTrace)> {
        if policy.exit_at_1(conf1) {
            counters.tokens_exit1 += 1;
            return Ok((
                tok1,
                TokenTrace { pos, token: tok1, exit: ExitPoint::Exit1, conf1, conf2: None },
            ));
        }
        let (conf2, tok2) = exit2.context("exit-2 evaluation missing")?;
        if policy.exit_at_2(conf2) {
            counters.tokens_exit2 += 1;
            return Ok((
                tok2,
                TokenTrace { pos, token: tok2, exit: ExitPoint::Exit2, conf1, conf2: Some(conf2) },
            ));
        }
        let fb = best_local(policy, conf1, tok1, Some((conf2, tok2)));
        let (tok, exit) =
            self.cloud_token(req_id, pos, prompt_len, Some(fb), cost, counters, ring)?;
        Ok((tok, TokenTrace { pos, token: tok, exit, conf1, conf2: Some(conf2) }))
    }

    /// Defer one token to the cloud (Algorithm 1, CloudInference call),
    /// degrading to `fallback` when the latency budget is configured and
    /// the cloud cannot answer in time.  Returns the emitted token and
    /// where it was produced; updates the cloud/fallback counters.
    #[allow(clippy::too_many_arguments)]
    fn cloud_token(
        &mut self,
        req_id: u32,
        pos: usize,
        prompt_len: usize,
        fallback: Option<(ExitPoint, i32)>,
        cost: &mut CostBreakdown,
        counters: &mut RunCounters,
        ring: &ReplayRing,
    ) -> Result<(i32, ExitPoint)> {
        // the fallback only engages in latency-aware mode; without a
        // budget the behaviour is the strict "block on the cloud" of the
        // base algorithm
        let fallback = match self.cfg.cloud_token_budget_s {
            Some(_) => fallback,
            None => None,
        };
        let emit_fallback = |counters: &mut RunCounters, (exit, tok): (ExitPoint, i32)| {
            counters.cloud_fallbacks += 1;
            match exit {
                ExitPoint::Exit1 => counters.tokens_exit1 += 1,
                _ => counters.tokens_exit2 += 1,
            }
            (tok, exit)
        };

        if self.link_broken {
            let fb = fallback.context("cloud link failed earlier in this run")?;
            return Ok(emit_fallback(counters, fb));
        }

        counters.cloud_requests += 1;
        match self.cloud_roundtrip_resilient(req_id, pos, prompt_len, cost, counters, ring) {
            Ok(CloudAnswer::Answered { token }) => {
                counters.tokens_cloud += 1;
                Ok((token, ExitPoint::Cloud))
            }
            Ok(CloudAnswer::DeadlineExpired) => {
                let fb = fallback.context("cloud deadline expired with no local fallback")?;
                Ok(emit_fallback(counters, fb))
            }
            Err(e) => match fallback {
                Some(fb) => {
                    log::warn!("cloud link failed ({e:#}); finishing the run on local exits");
                    self.link_broken = true;
                    Ok(emit_fallback(counters, fb))
                }
                None => Err(e),
            },
        }
    }

    /// Reconnect rounds one deferral will attempt before the failure
    /// propagates to [`Self::cloud_token`]'s degrade path.  Bounds the
    /// worst case at `rounds × endpoints × max_attempts` dials.
    const RECONNECT_ROUNDS: usize = 3;

    /// [`Self::cloud_roundtrip`] under the failover ladder.  A transport
    /// failure first tries a **warm promotion** ([`Self::promote_standby`]):
    /// the best live standby becomes the primary and the round trip
    /// retries with no replay at all.  Only with no live standby does
    /// the failure fall to the **cold** path — re-establish the link
    /// with session resume ([`CloudLink::reestablish`]) and replay the
    /// retained history on the fresh infer channel.  The cold replay is
    /// NOT counted as a context replay — the resumed session was
    /// suspended cooperatively, not evicted — so replay counters keep
    /// measuring context-store pressure only.  When neither rung is
    /// available (no standbys, disabled policy, injected transports,
    /// exhausted endpoints) the original error propagates and the
    /// caller degrades exactly as before this wrapper existed.
    #[allow(clippy::too_many_arguments)]
    fn cloud_roundtrip_resilient(
        &mut self,
        req_id: u32,
        pos: usize,
        prompt_len: usize,
        cost: &mut CostBreakdown,
        counters: &mut RunCounters,
        ring: &ReplayRing,
    ) -> Result<CloudAnswer> {
        let mut rounds = 0usize;
        loop {
            // the uploader noticing a dead transport is the earliest
            // failure signal (keepalive probes fire on idle links); act
            // on it before spending a request on a socket known broken
            let preempt = self.link.as_ref().is_some_and(|l| l.upload_is_dead());
            if preempt && (self.has_live_standby() || self.can_reconnect()) {
                anyhow::ensure!(
                    rounds < Self::RECONNECT_ROUNDS,
                    "cloud link kept dying through {rounds} failover(s) within one deferral"
                );
                rounds += 1;
                if self.promote_standby(counters) {
                    log::warn!("upload channel dead; promoted a warm standby");
                } else {
                    log::warn!("upload channel dead; reconnecting before the deferral");
                    self.reconnect_and_replay(req_id, pos, prompt_len, cost, counters, ring)?;
                }
            }
            match self.cloud_roundtrip(req_id, pos, prompt_len, cost, counters, ring) {
                Ok(answer) => return Ok(answer),
                Err(e)
                    if rounds < Self::RECONNECT_ROUNDS
                        && (self.has_live_standby() || self.can_reconnect()) =>
                {
                    rounds += 1;
                    if self.promote_standby(counters) {
                        log::warn!(
                            "cloud round trip failed ({e:#}); promoted a warm standby \
                             (round {rounds})"
                        );
                    } else {
                        log::warn!(
                            "cloud round trip failed ({e:#}); reconnecting (round {rounds})"
                        );
                        self.reconnect_and_replay(req_id, pos, prompt_len, cost, counters, ring)
                            .with_context(|| format!("after transport failure: {e:#}"))?;
                    }
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Whether the link can be re-established at all (reconnect policy
    /// enabled and a dialer present — injected-transport links have
    /// neither).
    fn can_reconnect(&self) -> bool {
        self.link.as_ref().is_some_and(|l| l.policy.enabled() && l.dial.is_some())
    }

    /// Whether at least one warm standby is live enough to promote.
    fn has_live_standby(&self) -> bool {
        self.replicas.as_ref().is_some_and(|s| s.best().is_some())
    }

    /// Duplicate one upload to every live warm standby — asynchronous,
    /// each copy on the standby's own uploader thread, priced in
    /// `bytes_mirrored` so the paper-facing `bytes_up` column is
    /// unchanged by replication.  A standby whose uploader already
    /// declared its transport dead is skipped (it will be skipped at
    /// promotion time too).
    fn mirror_upload(&self, msg: &Message, wire_len: u64, counters: &mut RunCounters) {
        let Some(set) = self.replicas.as_ref() else { return };
        for sb in &set.standbys {
            if sb.upload_is_dead() {
                continue;
            }
            counters.bytes_mirrored += wire_len;
            sb.enqueue_upload(msg.clone());
        }
    }

    /// Warm failover: swap the healthiest live standby in as the
    /// primary link.  The standby's mirrored uploads already cover the
    /// watermark, so **no** history replay is issued — the caller
    /// simply retries the round trip on the promoted link and the
    /// cloud's scheduler parks the request until the standby's coverage
    /// (already on its uploader, or landed) catches up.  Zero
    /// `context_replays`, bit-identical tokens.
    ///
    /// The demoted primary is dropped — its uploader detaches bounded —
    /// and the set shrinks: replicas are a budget, not a refilling
    /// pool.  Returns `false` when no live standby exists, sending the
    /// caller down the cold `reconnect_and_replay` ladder instead.
    fn promote_standby(&mut self, counters: &mut RunCounters) -> bool {
        let (Some(set), Some(link)) = (self.replicas.as_mut(), self.link.as_mut()) else {
            return false;
        };
        let Some(idx) = set.best() else { return false };
        let mut promoted = set.standbys.swap_remove(idx);
        // from here on this session is the live one: a later resume
        // Hello must not re-announce it as a passive mirror
        promoted.mirror = false;
        let old = std::mem::replace(link, promoted);
        set.failovers_warm += 1;
        counters.failovers_warm += 1;
        if let Some(sink) = edge_sink() {
            sink.emit(
                Ev::new("edge_promote")
                    .u("device", old.device_id)
                    .u("standbys_left", set.standbys.len() as u64),
            );
        }
        log::info!(
            "warm failover: device {} promoted a mirror standby ({} left)",
            old.device_id,
            set.standbys.len()
        );
        true
    }

    /// Re-establish the severed link (same session nonce, `resume`
    /// Hello) and replay the full retained history on the fresh infer
    /// channel.  The cloud suspended the session on the resume Hello —
    /// state dropped, tombstones kept — so the next request must
    /// re-prefill from position 0; the replay also covers any parallel
    /// uploads that died with the old upload channel.  Bit-identical
    /// tokens, one extra round trip, no `context_replays` increment.
    #[allow(clippy::too_many_arguments)]
    fn reconnect_and_replay(
        &mut self,
        req_id: u32,
        pos: usize,
        prompt_len: usize,
        cost: &mut CostBreakdown,
        counters: &mut RunCounters,
        ring: &ReplayRing,
    ) -> Result<()> {
        let precision = self.precision();
        let dims_d = self.engine.dims().d_model;
        let t0 = Instant::now();
        let link = self.link.as_mut().context("collaborative policy without cloud link")?;
        link.reestablish()?;
        counters.failovers_cold += 1;
        link.send_full_history(ring, req_id, pos, prompt_len, dims_d, precision, counters)?;
        cost.comm_s += t0.elapsed().as_secs_f64();
        Ok(())
    }

    /// One request/response round trip on the infer channel.  A
    /// `SessionEvicted` response is recovered from in place: the retained
    /// history replays from position 0 (same request id) and the request
    /// is re-issued — the loop then continues waiting for the token.
    #[allow(clippy::too_many_arguments)]
    fn cloud_roundtrip(
        &mut self,
        req_id: u32,
        pos: usize,
        prompt_len: usize,
        cost: &mut CostBreakdown,
        counters: &mut RunCounters,
        ring: &ReplayRing,
    ) -> Result<CloudAnswer> {
        let device_id = self.cfg.device_id;
        let precision = self.precision();
        let flags = self.cfg.ablation;
        let dims_d = self.engine.dims().d_model;
        let budget = self.cfg.cloud_token_budget_s;

        // without content manager / parallel upload the hidden states go
        // out synchronously now, on the infer channel (and without the
        // manager, the WHOLE history is retransmitted every request)
        if !flags.content_manager || !flags.parallel_upload {
            let t0 = Instant::now();
            let link = self.link.as_mut().context("collaborative policy without cloud link")?;
            link.send_full_history(ring, req_id, pos, prompt_len, dims_d, precision, counters)?;
            cost.comm_s += t0.elapsed().as_secs_f64();
        }
        // with parallel upload there is nothing to wait for here: the
        // scheduler parks the request until the covering upload lands, so
        // the request overtaking its uploads is part of the design

        let deadline = budget.map(|s| Instant::now() + Duration::from_secs_f64(s));
        let deadline_ms =
            budget.map(|s| (s * 1e3).clamp(1.0, u32::MAX as f64) as u32).unwrap_or(0);
        let t0 = Instant::now();
        let link = self.link.as_mut().context("collaborative policy without cloud link")?;
        let req = Message::InferRequest {
            device_id,
            req_id,
            pos: pos as u32,
            prompt_len: prompt_len as u32,
            deadline_ms,
        };
        let req_frame = req.encode();
        counters.bytes_up += frame_wire_len(req_frame.len()) as u64;
        link.trace_infer_send(&req_frame);
        link.infer.send(&req_frame)?;

        // hedged infer (degradation-ladder rung 1): when the deadline
        // budget is tight, duplicate the request to the best-scored live
        // standby.  Both servers derive the same token (mirrored
        // coverage, same oracle), so whichever valid `(req_id, pos)`
        // echo arrives first wins; the loser's late echo is fenced by
        // the stale-response skip below, exactly like an abandoned
        // deferral.  A failed duplicate send just forfeits the hedge.
        let mut hedge_idx = match (deadline.is_some(), self.replicas.as_mut()) {
            (true, Some(set)) if set.hedge => set.best().and_then(|i| {
                let sb = &mut set.standbys[i];
                sb.trace_infer_send(&req_frame);
                sb.infer.send(&req_frame).ok().map(|_| i)
            }),
            _ => None,
        };
        if hedge_idx.is_some() {
            counters.hedged_requests += 1;
            counters.bytes_mirrored += frame_wire_len(req_frame.len()) as u64;
            if let Some(sink) = edge_sink() {
                sink.emit(
                    Ev::new("edge_hedge")
                        .u("device", device_id)
                        .u("req", req_id as u64)
                        .u("pos", pos as u64),
                );
            }
        }

        let mut replays = 0usize;
        loop {
            // acquire the next frame: primary only, or — while the hedge
            // is live — both infer channels polled in short alternating
            // slices, first frame wins
            let mut from_standby = false;
            let frame = match deadline {
                Some(dl) => {
                    let got = loop {
                        let Some(si) = hedge_idx else {
                            break link.infer.recv_deadline(dl)?.map(|f| (f, false));
                        };
                        const SLICE: Duration = Duration::from_millis(2);
                        let now = Instant::now();
                        if now >= dl {
                            break None;
                        }
                        if let Some(f) = link.infer.recv_deadline(dl.min(now + SLICE))? {
                            break Some((f, false));
                        }
                        let set = self.replicas.as_mut().expect("hedged without replicas");
                        let sb = &mut set.standbys[si];
                        let now = Instant::now();
                        if now >= dl {
                            break None;
                        }
                        match sb.infer.recv_deadline(dl.min(now + SLICE)) {
                            Ok(Some(f)) => break Some((f, true)),
                            Ok(None) => {}
                            // a standby dying mid-race just loses the
                            // hedge; the primary is still in play
                            Err(_) => hedge_idx = None,
                        }
                    };
                    match got {
                        Some((f, sb)) => {
                            from_standby = sb;
                            f
                        }
                        None => {
                            cost.comm_s += t0.elapsed().as_secs_f64();
                            return Ok(CloudAnswer::DeadlineExpired);
                        }
                    }
                }
                None => link.infer.recv()?,
            };
            if from_standby {
                if let (Some(set), Some(si)) = (self.replicas.as_ref(), hedge_idx) {
                    set.standbys[si].trace_infer_recv(&frame);
                }
                // replica traffic is priced apart from the paper-facing
                // bytes_down column, like the mirrored uploads
                counters.bytes_mirrored += frame_wire_len(frame.len()) as u64;
            } else {
                link.trace_infer_recv(&frame);
                counters.bytes_down += frame_wire_len(frame.len()) as u64;
            }
            let rtt = t0.elapsed().as_secs_f64();
            match Message::decode(&frame)? {
                Message::TokenResponse { req_id: r, pos: p, token, conf, compute_s } => {
                    if r != req_id || p != pos as u32 {
                        continue; // stale answer for an abandoned deferral
                    }
                    let _ = conf;
                    if let Some(h) = &link.hist_cloud_rtt {
                        h.record((rtt * 1e9) as u64);
                    }
                    cost.cloud_s += compute_s as f64;
                    cost.comm_s += (rtt - compute_s as f64).max(0.0);
                    return Ok(CloudAnswer::Answered { token });
                }
                Message::Error { req_id: r, pos: p, msg } => {
                    if r == NO_REQ || (r == req_id && p == pos as u32) {
                        if from_standby {
                            // the hedge lost (standby not covered /
                            // refused); the primary race continues
                            hedge_idx = None;
                            continue;
                        }
                        anyhow::bail!("cloud error: {msg}");
                    }
                    continue; // stale error for an abandoned deferral
                }
                Message::SessionEvicted { device_id: d, req_id: r, pos: p } => {
                    if d != device_id || r != req_id || p != pos as u32 {
                        continue; // stale notice for an abandoned deferral
                    }
                    if from_standby {
                        // a standby evicted mid-race loses the hedge; no
                        // replay is spent on a passive copy
                        hedge_idx = None;
                        continue;
                    }
                    anyhow::ensure!(
                        replays < REPLAY_LIMIT,
                        "cloud evicted the session {replays} times within one deferral"
                    );
                    replays += 1;
                    counters.context_replays += 1;
                    // replay the whole history from position 0 on THIS
                    // channel (ordered ahead of the re-issued request),
                    // then ask again: the cloud re-prefills and the
                    // token comes out bit-identical
                    link.send_full_history(
                        ring, req_id, pos, prompt_len, dims_d, precision, counters,
                    )?;
                    counters.bytes_up += frame_wire_len(req_frame.len()) as u64;
                    link.trace_infer_send(&req_frame);
                    link.infer.send(&req_frame)?;
                    continue;
                }
                other => {
                    if from_standby {
                        hedge_idx = None;
                        continue;
                    }
                    anyhow::bail!("unexpected response {other:?}")
                }
            }
        }
    }

    fn link_ref(&self) -> Result<&CloudLink> {
        self.link.as_ref().context("collaborative policy without cloud link")
    }

    /// Tear down the link, returning bytes sent on the upload channel.
    pub fn close(mut self) -> u64 {
        self.link.as_mut().map(|l| l.close()).unwrap_or(0)
    }
}
