//! Cloud context store: every byte of per-device cloud state — engine KV
//! sessions and content-manager pending buffers — owned under an explicit
//! memory budget, so "millions of users" stops meaning "millions of KV
//! caches resident forever".
//!
//! Layering (the contract with the scheduler): **the store owns bytes,
//! the scheduler owns compute.**  A worker never touches its
//! [`ContentManager`] or its engine sessions directly any more; every
//! upload, coverage check, plan, and session lookup goes through the
//! store, which meters residency and refreshes the device's LRU clock as
//! a side effect.  The scheduler decides *when* to run passes and what
//! to protect; the store decides *what fits*.
//!
//! Accounting: a device's resident bytes are
//!
//! ```text
//!   kv_bytes_per_pos × consumed_upto      (engine KV, while a session exists)
//! + pending_floats × 4                    (buffered uploads not yet consumed)
//! ```
//!
//! with `kv_bytes_per_pos` from [`ModelDims::cloud_kv_bytes_per_pos`] —
//! the same rate the DES harness prices, so the simulated and enforced
//! budgets agree.
//!
//! Eviction policy:
//! * **Budget (LRU)** — [`ContextStore::enforce_budget`] evicts whole
//!   devices in last-touch order until the shard fits its share of
//!   `CloudConfig::memory_budget_bytes`.  Callers pass a `protected`
//!   predicate (the scheduler protects every device with parked
//!   requests, and enforcement only ever runs *between* passes, so a
//!   device being served in a batch pass is never evicted).  The single
//!   most-recently-touched device is additionally never evicted: that
//!   guarantees forward progress — a device replaying its history after
//!   an eviction is MRU when its re-upload lands, so even a budget
//!   smaller than one session cannot evict it back into a replay loop.
//! * **TTL** — [`ContextStore::reap_ttl`] evicts devices idle past
//!   `CloudConfig::session_ttl_s` regardless of budget (the abandoned-
//!   edge-device leak the budget alone would only catch under pressure).
//!
//! Eviction is *recoverable*: the store remembers the evicted request id
//! and the scheduler answers the device's next infer with
//! [`SessionEvicted`](crate::coordinator::protocol::Message::SessionEvicted)
//! instead of parking it.  The edge replays its retained exit-layer
//! hidden states from position 0 (same request id), the replay upload
//! clears the eviction mark, the content manager rebuilds coverage, and
//! the next plan re-prefills a fresh engine session — the request
//! completes with bit-identical tokens at the cost of one extra upload
//! round trip.

use std::collections::{HashMap, HashSet};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::content_manager::{ContentManager, Coverage, PlanReq, WorkPlan};
use crate::model::manifest::ModelDims;
use crate::runtime::traits::CloudEngine;

/// Session factory living on a worker thread (PJRT objects never cross
/// threads, so the store builds sessions with whatever factory the
/// worker hands it at the call site).
pub type SessionFactory = Box<dyn FnMut(u64) -> Result<Box<dyn CloudEngine>>>;

/// Context-store counters, surfaced through
/// [`CloudStats`](crate::coordinator::scheduler::CloudStats).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ContextStoreStats {
    /// Resident per-device context bytes (gauge: KV positions + pending
    /// hidden states, summed over this shard's devices).
    pub resident_bytes: u64,
    /// Devices evicted by budget pressure (LRU order).
    pub evictions: u64,
    /// Devices evicted by the idle TTL reaper.
    pub ttl_reaps: u64,
    /// Evicted contexts rebuilt by an edge replay (a position-0 upload
    /// with the evicted request id landed after the eviction).
    pub replays: u64,
}

impl ContextStoreStats {
    pub fn merge(&mut self, o: &ContextStoreStats) {
        self.resident_bytes += o.resident_bytes;
        self.evictions += o.evictions;
        self.ttl_reaps += o.ttl_reaps;
        self.replays += o.replays;
    }
}

/// Owner of one worker shard's per-device cloud state.
pub struct ContextStore {
    cm: ContentManager,
    sessions: HashMap<u64, Box<dyn CloudEngine>>,
    /// LRU clock AND device index: holds exactly the devices with
    /// resident state (content-manager buffers and/or an engine
    /// session), refreshed by uploads, plans, and session lookups —
    /// [`Self::settle`] maintains the invariant.  Sweeps iterate this
    /// map directly instead of rebuilding a device list per call.
    last_touch: HashMap<u64, Instant>,
    /// Running resident gauge, kept in lockstep with every mutation by
    /// the before/after deltas in [`Self::settle`] — the per-pass budget
    /// check is O(1) instead of a full shard walk.
    resident: u64,
    /// Devices whose context was dropped, keyed to the request id that
    /// was live at eviction time — the scheduler's "answer the next
    /// infer with `SessionEvicted`" signal.  Cleared by a position-0
    /// upload (replay or a new request's prompt), `EndSession`, or a
    /// device reset.
    evicted: HashMap<u64, u32>,
    /// Devices whose session Hello carried the `mirror` bit: warm
    /// standbys kept warm by replicated uploads.  They are served
    /// exactly like primaries but *preferred as eviction victims* under
    /// budget pressure, so standbys never push a primary's live context
    /// out of the LRU.  Cleared by a non-mirror reset (promotion) or a
    /// full device reset.
    mirror: HashSet<u64>,
    kv_bytes_per_pos: u64,
    budget: Option<u64>,
    ttl: Option<Duration>,
    evictions: u64,
    ttl_reaps: u64,
    replays: u64,
}

impl ContextStore {
    /// `budget` is this shard's share (the scheduler splits the global
    /// `CloudConfig::memory_budget_bytes` evenly across workers).
    pub fn new(dims: &ModelDims, budget: Option<u64>, ttl_s: Option<f64>) -> Self {
        Self {
            cm: ContentManager::new(dims.d_model),
            sessions: HashMap::new(),
            last_touch: HashMap::new(),
            resident: 0,
            evicted: HashMap::new(),
            mirror: HashSet::new(),
            kv_bytes_per_pos: dims.cloud_kv_bytes_per_pos() as u64,
            budget,
            ttl: ttl_s.map(|s| Duration::from_secs_f64(s.max(0.0))),
            evictions: 0,
            ttl_reaps: 0,
            replays: 0,
        }
    }

    /// Fold one device's state change into the gauge and the index:
    /// callers snapshot [`Self::device_resident_bytes`] *before* mutating
    /// and settle with it afterwards.  A device that still holds state is
    /// (re)stamped as most recently used; one that released everything
    /// leaves the index, so sweeps and TTL deadlines never see ghosts.
    fn settle(&mut self, device: u64, before: u64) {
        let after = self.device_resident_bytes(device);
        self.resident = self.resident.saturating_sub(before) + after;
        if self.cm.has_device(device) || self.sessions.contains_key(&device) {
            self.last_touch.insert(device, Instant::now());
        } else {
            self.last_touch.remove(&device);
        }
    }

    // -- the scheduler's data path (every call refreshes the LRU clock) --

    /// Ingest an upload, taking ownership of the payload.  An *accepted*
    /// position-0 upload clears the device's eviction mark: either the
    /// edge replayed the evicted request's history (counted as a replay)
    /// or a new request's prompt landed (the old context is moot either
    /// way).  Mid-stream uploads (start > 0) leave the mark in place —
    /// they cannot rebuild coverage from position 0 on their own — and
    /// so does a position-0 upload the content manager fenced or
    /// rejected (watermark still 0): clearing on those would leave the
    /// next infer parking forever instead of being told to replay.
    pub fn upload_owned(
        &mut self,
        device: u64,
        req_id: u32,
        start_pos: u32,
        prompt_len: u32,
        hiddens: Vec<f32>,
    ) -> Result<()> {
        let before = self.device_resident_bytes(device);
        let out = self.cm.upload_owned(device, req_id, start_pos, prompt_len, hiddens);
        self.settle(device, before);
        if start_pos == 0 && out.is_ok() && self.cm.watermark(device) > 0 {
            if let Some(evicted_req) = self.evicted.remove(&device) {
                if self.cm.current_req(device) == Some(evicted_req) {
                    self.replays += 1;
                }
            }
        }
        out
    }

    /// Pure park/wake classification (no touch: a coverage probe is not
    /// device activity).
    pub fn coverage(&self, device: u64, req_id: u32, pos: u32, prompt_len: u32) -> Coverage {
        self.cm.coverage(device, req_id, pos, prompt_len)
    }

    /// Capped work plans for a batch pass; every planned device counts as
    /// touched (it is about to be served).
    pub fn plan_batch(
        &mut self,
        reqs: &[PlanReq],
        max_decode_per_device: usize,
    ) -> Vec<Result<WorkPlan>> {
        reqs.iter()
            .map(|r| {
                let before = self.device_resident_bytes(r.device);
                let cap = max_decode_per_device;
                let plan = self.cm.plan_capped(r.device, r.req_id, r.pos, r.prompt_len, cap);
                self.settle(r.device, before);
                plan
            })
            .collect()
    }

    /// The device's engine session, building one with `factory` on first
    /// use (or after an eviction dropped the previous one).
    #[allow(clippy::borrowed_box)] // `&mut SessionFactory` is the worker's field type
    pub fn session(
        &mut self,
        device: u64,
        factory: &mut SessionFactory,
    ) -> Result<&mut dyn CloudEngine> {
        if !self.sessions.contains_key(&device) {
            // a fresh session makes the consumed KV positions resident
            let before = self.device_resident_bytes(device);
            let session = factory(device)?;
            self.sessions.insert(device, session);
            self.settle(device, before);
        } else {
            self.last_touch.insert(device, Instant::now());
        }
        Ok(self.sessions.get_mut(&device).expect("present by construction").as_mut())
    }

    /// The request id a pending `SessionEvicted` notice carries for this
    /// device, if its context was evicted and not yet replayed.
    pub fn evicted_req(&self, device: u64) -> Option<u32> {
        self.evicted.get(&device).copied()
    }

    /// Release a finished request (tombstoned against stragglers) and its
    /// engine session; a pending eviction notice is moot once the request
    /// is over.
    pub fn end_request(&mut self, device: u64, req_id: u32) {
        let before = self.device_resident_bytes(device);
        self.cm.end_request(device, req_id);
        self.sessions.remove(&device);
        self.evicted.remove(&device);
        // a newer request's racing uploads may have survived the
        // teardown; settle keeps the device indexed exactly then
        self.settle(device, before);
    }

    /// Drop a device's buffered state and engine session *without*
    /// tombstoning its request and *without* marking an eviction: the
    /// session-resume path.  A reconnecting edge replays its history
    /// from position 0 proactively (it cannot know whether a served
    /// token was lost with the severed socket), so no `SessionEvicted`
    /// bounce is needed — and the rebuild is not an eviction replay, so
    /// it must not count as one.  Tombstones survive: the old
    /// connection's stragglers carry the *same* session nonce (resume
    /// keeps it), so they pass the session fence and only the
    /// tombstones keep them from resurrecting released state.
    pub fn suspend_device(&mut self, device: u64) {
        let before = self.device_resident_bytes(device);
        self.cm.evict_device(device);
        self.sessions.remove(&device);
        self.evicted.remove(&device);
        self.settle(device, before);
    }

    /// Forget a device entirely (fresh upload-channel Hello).
    pub fn reset_device(&mut self, device: u64) {
        let before = self.device_resident_bytes(device);
        self.cm.reset_device(device);
        self.sessions.remove(&device);
        self.evicted.remove(&device);
        self.mirror.remove(&device);
        self.settle(device, before);
    }

    /// (Un)mark a device as a warm-standby mirror session (the Hello's
    /// `mirror` bit, applied by the scheduler's reset path).  Mirror
    /// devices are billed separately by the scheduler and preferred as
    /// eviction victims; clearing the mark is a promotion — the standby
    /// became the device's serving session.
    pub fn set_mirror(&mut self, device: u64, mirror: bool) {
        if mirror {
            self.mirror.insert(device);
        } else {
            self.mirror.remove(&device);
        }
    }

    /// Whether this device's session was opened with the `mirror` bit.
    pub fn is_mirror(&self, device: u64) -> bool {
        self.mirror.contains(&device)
    }

    // -- metering ------------------------------------------------------------

    /// Resident context bytes of one device: KV positions already folded
    /// into its engine session plus buffered hidden states.
    pub fn device_resident_bytes(&self, device: u64) -> u64 {
        let kv = if self.sessions.contains_key(&device) {
            self.kv_bytes_per_pos * self.cm.consumed_upto(device) as u64
        } else {
            0
        };
        kv + self.cm.pending_floats_of(device) as u64 * 4
    }

    /// Resident context bytes across this shard (the per-worker gauge;
    /// the scheduler sums shards into the global one).  O(1): a running
    /// counter maintained by [`Self::settle`], not a shard walk.
    pub fn resident_bytes(&self) -> u64 {
        self.resident
    }

    /// Recompute the gauge from first principles — the invariant the
    /// running counter must match; tests pin the two together.
    #[cfg(test)]
    fn recompute_resident_bytes(&self) -> u64 {
        let mut devices: Vec<u64> = self.cm.device_ids();
        devices.extend(self.sessions.keys().copied());
        devices.sort_unstable();
        devices.dedup();
        devices.into_iter().map(|d| self.device_resident_bytes(d)).sum()
    }

    pub fn device_count(&self) -> usize {
        self.cm.device_count()
    }

    pub fn pending_floats(&self) -> usize {
        self.cm.pending_floats()
    }

    pub fn stats(&self) -> ContextStoreStats {
        ContextStoreStats {
            resident_bytes: self.resident_bytes(),
            evictions: self.evictions,
            ttl_reaps: self.ttl_reaps,
            replays: self.replays,
        }
    }

    // -- eviction ------------------------------------------------------------

    fn evict(&mut self, device: u64) {
        let before = self.device_resident_bytes(device);
        let req = self.cm.evict_device(device);
        self.sessions.remove(&device);
        self.evicted.insert(device, req.unwrap_or(0));
        self.settle(device, before); // releases the bytes and the index slot
    }

    /// Evict idle devices in LRU order until the shard fits its budget.
    /// Warm-standby mirror devices are preferred victims — every
    /// evictable mirror goes (LRU order among mirrors) before any
    /// primary, so replicated standbys never push a primary's live
    /// context out.  `protected` devices (the scheduler's parked set)
    /// and the single most-recently-touched device are never evicted;
    /// if nothing evictable remains the shard stays over budget rather
    /// than break a live pass or livelock a replaying device.  Returns
    /// the evicted device ids in eviction order (the scheduler's trace
    /// tap emits one `evict` event per victim).  The budget check is
    /// O(1) per pass; victim selection walks the index only while
    /// actually evicting.
    pub fn enforce_budget(&mut self, protected: impl Fn(u64) -> bool) -> Vec<u64> {
        let Some(budget) = self.budget else { return Vec::new() };
        let mut victims = Vec::new();
        while self.resident > budget {
            // ties broken by device id so eviction order is deterministic
            // even when the monotonic clock is coarse; mirror-ness keys
            // the sort ahead of the LRU clock (standbys go first)
            let mru =
                self.last_touch.iter().map(|(&d, &t)| (t, d)).max().map(|(_, d)| d);
            let victim = self
                .last_touch
                .iter()
                .map(|(&d, &t)| (!self.mirror.contains(&d), t, d))
                .filter(|&(_, _, d)| !protected(d) && Some(d) != mru)
                .min()
                .map(|(_, _, d)| d);
            let Some(victim) = victim else { break };
            self.evict(victim);
            self.evictions += 1;
            victims.push(victim);
        }
        victims
    }

    /// Evict devices idle past the TTL (explicit `now` so tests need no
    /// sleeping).  Same protection rule as the budget path, minus the
    /// MRU exemption — an MRU device idle past a whole TTL is still dead
    /// weight.  Returns the reaped device ids (in deterministic id order,
    /// for the scheduler's trace tap).
    pub fn reap_ttl(&mut self, now: Instant, protected: impl Fn(u64) -> bool) -> Vec<u64> {
        let Some(ttl) = self.ttl else { return Vec::new() };
        let mut stale: Vec<u64> = self
            .last_touch
            .iter()
            .filter(|&(&d, &t)| !protected(d) && now.saturating_duration_since(t) >= ttl)
            .map(|(&d, _)| d)
            .collect();
        stale.sort_unstable();
        for &d in &stale {
            self.evict(d);
            self.ttl_reaps += 1;
        }
        stale
    }

    /// Earliest instant at which a currently resident, *unprotected*
    /// device crosses the TTL — the scheduler caps its idle wait here so
    /// the reaper runs without polling.  Protected (parked) devices are
    /// excluded: the reaper will skip them anyway, and arming their
    /// already-expired deadline would spin the worker's wait loop at
    /// zero timeout until the park resolves.  `None` when the TTL is off
    /// or nothing unprotected is resident.
    pub fn next_ttl_deadline(&self, protected: impl Fn(u64) -> bool) -> Option<Instant> {
        let ttl = self.ttl?;
        self.last_touch
            .iter()
            .filter(|&(&d, _)| !protected(d))
            .map(|(_, &t)| t + ttl)
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::test_manifest;
    use crate::runtime::mock::{MockCloud, MockOracle};

    fn dims() -> ModelDims {
        test_manifest().model
    }

    fn factory() -> SessionFactory {
        Box::new(|_| Ok(Box::new(MockCloud::new(MockOracle::new(1), test_manifest().model)) as _))
    }

    /// Upload a `plen`-position prompt and plan it to completion, leaving
    /// the device with a resident session of `plen` KV positions.
    fn settle(store: &mut ContextStore, f: &mut SessionFactory, device: u64, plen: u32) {
        let d = dims().d_model;
        store.upload_owned(device, 1, 0, plen, vec![0.5; plen as usize * d]).unwrap();
        let req = PlanReq { device, req_id: 1, pos: plen - 1, prompt_len: plen };
        let plan = store.plan_batch(&[req], usize::MAX).remove(0).unwrap();
        let s = store.session(device, f).unwrap();
        s.reset();
        let (h, len) = plan.prefill.unwrap();
        s.prefill(&h, len).unwrap();
    }

    #[test]
    fn resident_bytes_meter_pending_and_kv() {
        let m = dims();
        let mut store = ContextStore::new(&m, None, None);
        let mut f = factory();
        store.upload_owned(1, 1, 0, 3, vec![0.5; 3 * m.d_model]).unwrap();
        // buffered only: 3 positions of pending floats, no KV yet
        assert_eq!(store.device_resident_bytes(1), 3 * m.d_model as u64 * 4);
        settle(&mut store, &mut f, 1, 3);
        // consumed: pending released, 3 KV positions resident
        assert_eq!(store.device_resident_bytes(1), 3 * m.cloud_kv_bytes_per_pos() as u64);
        assert_eq!(store.resident_bytes(), store.device_resident_bytes(1));
        store.end_request(1, 1);
        assert_eq!(store.resident_bytes(), 0);
    }

    #[test]
    fn budget_evicts_in_lru_order() {
        let m = dims();
        let kv3 = 3 * m.cloud_kv_bytes_per_pos() as u64;
        // room for exactly two settled devices
        let mut store = ContextStore::new(&m, Some(2 * kv3), None);
        let mut f = factory();
        for dev in [1u64, 2, 3] {
            settle(&mut store, &mut f, dev, 3);
        }
        assert!(store.resident_bytes() > 2 * kv3);
        let victims = store.enforce_budget(|_| false);
        assert_eq!(victims, vec![1]);
        // device 1 is the least recently touched -> evicted first
        assert_eq!(store.evicted_req(1), Some(1));
        assert!(store.evicted_req(2).is_none() && store.evicted_req(3).is_none());
        assert!(store.resident_bytes() <= 2 * kv3);
        assert_eq!(store.stats().evictions, 1);
    }

    #[test]
    fn protected_and_mru_devices_are_never_evicted() {
        let m = dims();
        let mut store = ContextStore::new(&m, Some(1), None); // absurd budget
        let mut f = factory();
        settle(&mut store, &mut f, 1, 3);
        settle(&mut store, &mut f, 2, 3);
        settle(&mut store, &mut f, 3, 3); // MRU
        // device 1 is protected (parked), device 3 is MRU: only 2 goes
        let victims = store.enforce_budget(|d| d == 1);
        assert_eq!(victims, vec![2]);
        assert!(store.evicted_req(1).is_none(), "protected device evicted");
        assert_eq!(store.evicted_req(2), Some(1));
        assert!(store.evicted_req(3).is_none(), "MRU device evicted");
        // still over budget, but nothing evictable remains -> no livelock
        assert!(store.resident_bytes() > 1);
        assert!(store.enforce_budget(|d| d == 1).is_empty());
    }

    #[test]
    fn mirror_devices_are_preferred_eviction_victims() {
        let m = dims();
        let mut store = ContextStore::new(&m, Some(1), None); // absurd budget
        let mut f = factory();
        settle(&mut store, &mut f, 1, 3); // primary, least recently touched
        settle(&mut store, &mut f, 2, 3); // warm standby (marked below)
        settle(&mut store, &mut f, 3, 3); // MRU
        store.set_mirror(2, true);
        assert!(store.is_mirror(2) && !store.is_mirror(1));
        // device 1 is older, but the mirror goes first; the MRU stays
        let victims = store.enforce_budget(|_| false);
        assert_eq!(victims, vec![2, 1], "mirror must be the first victim");
        assert!(store.evicted_req(3).is_none());
        // promotion clears the preference; a full reset clears it too
        store.set_mirror(2, false);
        assert!(!store.is_mirror(2));
        store.set_mirror(3, true);
        store.reset_device(3);
        assert!(!store.is_mirror(3));
    }

    #[test]
    fn replay_upload_clears_the_eviction_mark_and_counts() {
        let m = dims();
        let mut store = ContextStore::new(&m, Some(1), None);
        let mut f = factory();
        settle(&mut store, &mut f, 1, 3);
        settle(&mut store, &mut f, 2, 3);
        store.enforce_budget(|_| false);
        assert_eq!(store.evicted_req(1), Some(1));
        // a mid-stream upload does NOT clear the mark (cannot rebuild
        // coverage from position 0 on its own)
        store.upload_owned(1, 1, 3, 3, vec![0.5; m.d_model]).unwrap();
        assert_eq!(store.evicted_req(1), Some(1));
        // the position-0 replay of the same request does, and counts
        store.upload_owned(1, 1, 0, 3, vec![0.5; 3 * m.d_model]).unwrap();
        assert!(store.evicted_req(1).is_none());
        assert_eq!(store.stats().replays, 1);
        // the rebuilt plan re-prefills from scratch
        let req = PlanReq { device: 1, req_id: 1, pos: 3, prompt_len: 3 };
        let plan = store.plan_batch(&[req], usize::MAX).remove(0).unwrap();
        assert!(plan.prefill.is_some());
        assert_eq!(plan.decode.len(), 1);
    }

    #[test]
    fn fenced_or_partial_uploads_do_not_clear_the_eviction_mark() {
        let m = dims();
        let d = m.d_model;
        let mut store = ContextStore::new(&m, Some(1), None);
        let mut f = factory();
        // request 1 of device 1 runs and ends (tombstoned at req 1)
        settle(&mut store, &mut f, 1, 3);
        store.end_request(1, 1);
        // request 2 runs and is evicted under pressure from device 9
        store.upload_owned(1, 2, 0, 3, vec![0.5; 3 * d]).unwrap();
        let req = PlanReq { device: 1, req_id: 2, pos: 2, prompt_len: 3 };
        store.plan_batch(&[req], usize::MAX).remove(0).unwrap();
        store.session(1, &mut f).unwrap();
        settle(&mut store, &mut f, 9, 3);
        store.enforce_budget(|_| false);
        assert_eq!(store.evicted_req(1), Some(2));
        // a tombstoned position-0 straggler (old request 1) builds no
        // coverage: the mark MUST survive, and no replay is counted
        store.upload_owned(1, 1, 0, 3, vec![0.5; 3 * d]).unwrap();
        assert_eq!(store.evicted_req(1), Some(2), "fenced upload cleared the mark");
        assert_eq!(store.stats().replays, 0);
        // the genuine replay of request 2 clears and counts
        store.upload_owned(1, 2, 0, 3, vec![0.5; 3 * d]).unwrap();
        assert!(store.evicted_req(1).is_none());
        assert_eq!(store.stats().replays, 1);
    }

    #[test]
    fn new_request_prompt_clears_the_mark_without_counting_a_replay() {
        let m = dims();
        let mut store = ContextStore::new(&m, Some(1), None);
        let mut f = factory();
        settle(&mut store, &mut f, 1, 3);
        settle(&mut store, &mut f, 2, 3);
        store.enforce_budget(|_| false);
        assert_eq!(store.evicted_req(1), Some(1));
        // request 2's prompt upload: the evicted request 1 context is moot
        store.upload_owned(1, 2, 0, 3, vec![0.5; 3 * m.d_model]).unwrap();
        assert!(store.evicted_req(1).is_none());
        assert_eq!(store.stats().replays, 0);
    }

    #[test]
    fn ttl_reaps_idle_devices_with_an_explicit_clock() {
        let m = dims();
        let mut store = ContextStore::new(&m, None, Some(10.0));
        let mut f = factory();
        settle(&mut store, &mut f, 1, 3);
        let armed =
            store.next_ttl_deadline(|_| false).expect("TTL armed while state is resident");
        // not idle long enough: nothing reaped
        assert!(store.reap_ttl(Instant::now(), |_| false).is_empty());
        // idle past the TTL: reaped (and recoverable)
        assert_eq!(store.reap_ttl(armed + Duration::from_secs(1), |_| false), vec![1]);
        assert_eq!(store.evicted_req(1), Some(1));
        assert_eq!(store.resident_bytes(), 0);
        let s = store.stats();
        assert_eq!((s.ttl_reaps, s.evictions), (1, 0), "TTL reaps are not budget evictions");
        assert!(
            store.next_ttl_deadline(|_| false).is_none(),
            "nothing resident, nothing to arm"
        );
        // a protected (parked) device survives even past the TTL...
        settle(&mut store, &mut f, 2, 3);
        let far = Instant::now() + Duration::from_secs(3600);
        assert!(store.reap_ttl(far, |d| d == 2).is_empty());
        // ...and never arms the wake-up deadline (the reaper would skip
        // it, so arming an expired deadline would spin the worker)
        assert!(store.next_ttl_deadline(|d| d == 2).is_none());
        assert!(store.next_ttl_deadline(|_| false).is_some());
    }

    #[test]
    fn running_resident_gauge_matches_recomputation() {
        let m = dims();
        let mut store = ContextStore::new(&m, Some(1), None);
        let mut f = factory();
        // a workload hitting every mutation path: settles, partial
        // uploads, evictions, replays, ends, resets
        settle(&mut store, &mut f, 1, 3);
        store.upload_owned(1, 1, 3, 3, vec![0.5; m.d_model]).unwrap();
        settle(&mut store, &mut f, 2, 3);
        store.enforce_budget(|_| false);
        store.upload_owned(1, 1, 0, 3, vec![0.5; 3 * m.d_model]).unwrap();
        store.end_request(2, 1);
        store.reset_device(3); // no-op reset of an unknown device
        assert_eq!(store.resident_bytes(), store.recompute_resident_bytes());
        assert!(store.resident_bytes() > 0);
        store.end_request(1, 1);
        assert_eq!(store.resident_bytes(), 0);
        assert_eq!(store.recompute_resident_bytes(), 0);
    }

    #[test]
    fn disabled_store_never_evicts() {
        let m = dims();
        let mut store = ContextStore::new(&m, None, None);
        let mut f = factory();
        for dev in 0..8u64 {
            settle(&mut store, &mut f, dev, 3);
        }
        assert!(store.enforce_budget(|_| false).is_empty());
        assert!(store
            .reap_ttl(Instant::now() + Duration::from_secs(3600), |_| false)
            .is_empty());
        assert!(store.next_ttl_deadline(|_| false).is_none());
        let s = store.stats();
        assert_eq!((s.evictions, s.ttl_reaps, s.replays), (0, 0, 0));
        assert_eq!(store.device_count(), 8);
    }

    #[test]
    fn suspend_drops_state_without_tombstones_or_replay_counts() {
        let m = dims();
        let mut store = ContextStore::new(&m, Some(1), None);
        let mut f = factory();
        settle(&mut store, &mut f, 1, 3);
        settle(&mut store, &mut f, 2, 3);
        store.enforce_budget(|_| false);
        assert_eq!(store.evicted_req(1), Some(1));
        // a resume supersedes the pending eviction bounce: the edge
        // replays proactively, no SessionEvicted round trip needed
        store.suspend_device(1);
        assert!(store.evicted_req(1).is_none());
        assert_eq!(store.device_resident_bytes(1), 0);
        // the proactive replay rebuilds coverage and re-prefills, and
        // is NOT an eviction replay
        store.upload_owned(1, 1, 0, 3, vec![0.5; 3 * m.d_model]).unwrap();
        assert_eq!(store.stats().replays, 0);
        let req = PlanReq { device: 1, req_id: 1, pos: 2, prompt_len: 3 };
        let plan = store.plan_batch(&[req], usize::MAX).remove(0).unwrap();
        assert!(plan.prefill.is_some());
        // end-request tombstones survive a suspend (old-connection
        // stragglers carry the same session nonce — only the tombstone
        // fences them)
        store.end_request(1, 1);
        store.suspend_device(1);
        store.upload_owned(1, 1, 0, 3, vec![0.5; 3 * m.d_model]).unwrap();
        assert_eq!(store.device_count(), 0, "tombstone must survive a suspend");
        assert_eq!(store.resident_bytes(), store.recompute_resident_bytes());
    }

    #[test]
    fn end_and_reset_clear_eviction_marks() {
        let m = dims();
        let mut store = ContextStore::new(&m, Some(1), None);
        let mut f = factory();
        settle(&mut store, &mut f, 1, 3);
        settle(&mut store, &mut f, 2, 3);
        store.enforce_budget(|_| false);
        assert_eq!(store.evicted_req(1), Some(1));
        store.end_request(1, 1);
        assert!(store.evicted_req(1).is_none());
        settle(&mut store, &mut f, 3, 3);
        store.enforce_budget(|_| false);
        let marked = store.evicted_req(2).is_some() || store.evicted_req(3).is_some();
        assert!(marked);
        for dev in [2u64, 3] {
            store.reset_device(dev);
            assert!(store.evicted_req(dev).is_none());
        }
    }
}
