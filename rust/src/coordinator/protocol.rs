//! Wire protocol between edge clients and the cloud server.
//!
//! The paper uses two Flask APIs — one receiving hidden-state uploads,
//! one serving inference requests (§4.2 "Dual API Handling").  We keep
//! the same dual-channel design over two TCP connections with a compact
//! little-endian binary encoding; hidden-state payloads are packed by
//! [`crate::quant`] (f16 by default, §4.3).
//!
//! Framing (length prefix) is the transport's job; this module encodes
//! message bodies.

use anyhow::{bail, ensure, Context, Result};

use crate::quant::Precision;

/// Channel roles announced in `Hello` (the paper's two APIs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Channel {
    Upload,
    Infer,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Opens a channel for `device_id`.  `session` is a nonce chosen
    /// once per [`CloudLink`](crate::coordinator::edge::CloudLink) and
    /// shared by both channels; the server uses it to fence out frames
    /// still in flight from a previous connection that reused the same
    /// device id (0 = untagged, accepted for backward compatibility).
    /// `resume = true` marks a reconnect Hello: the edge re-dialed after
    /// a broken link and is re-announcing the *same* session nonce.  A
    /// resume whose nonce matches the server's pinned session must NOT
    /// reset the device's cloud context (the edge replays only what the
    /// store reports missing); a stale resume (mismatched or unknown
    /// nonce — e.g. after a cloud restart or failover) is counted and
    /// degrades to a fresh session, which the edge's full-history replay
    /// then rebuilds.  On the wire the flag rides the high bit of the
    /// channel byte, so pre-resume Hellos decode as `resume = false`.
    /// `mirror = true` marks a warm-standby session: the edge is
    /// replicating its uploads to this endpoint so a future failover can
    /// promote it without any ring replay.  The cloud serves a mirror
    /// session exactly like a primary one, but bills its uploads
    /// separately (`uploads_mirrored`) and prefers it as an eviction
    /// victim so standbys never distort primary LRU accounting.  The
    /// flag rides bit 6 of the channel byte ([`CHANNEL_MIRROR_BIT`]);
    /// a fresh non-mirror Hello stays byte-identical to the pre-replica
    /// format.
    Hello { device_id: u64, session: u64, channel: Channel, resume: bool, mirror: bool },
    /// Hidden states for positions `start_pos .. start_pos + count`
    /// at `l_ee1` (`count * d_model` elements in `precision`).
    /// `prompt_len` lets the server distinguish prompt uploads from
    /// decode-step uploads and detect retransmissions.
    UploadHidden {
        device_id: u64,
        req_id: u32,
        start_pos: u32,
        count: u32,
        prompt_len: u32,
        precision: Precision,
        payload: Vec<u8>,
    },
    /// "Continue my inference from the uploaded states and give me the
    /// token at `pos`" (Algorithm 1, CloudInference).  `deadline_ms > 0`
    /// is the edge's per-token latency budget (§4.4): the scheduler fails
    /// the request instead of parking it past that long, because the edge
    /// has already fallen back to its best local exit by then.
    InferRequest { device_id: u64, req_id: u32, pos: u32, prompt_len: u32, deadline_ms: u32 },
    /// Single-token response (§4.2): the token, its confidence, and the
    /// server-side compute seconds (lets the edge split comm vs cloud
    /// time in its metrics, as the paper's tables do).  `pos` echoes the
    /// request so a deadline-abandoned response can be recognized as
    /// stale and skipped by the edge.
    TokenResponse { req_id: u32, pos: u32, token: i32, conf: f32, compute_s: f32 },
    /// Generation finished: release content-manager state (§4.4 step 6).
    EndSession { device_id: u64, req_id: u32 },
    /// Sent instead of a `TokenResponse` when the device's cloud context
    /// (engine KV session + buffered hidden states) was evicted by the
    /// context store (memory budget or idle TTL).  `req_id`/`pos` echo
    /// the request that hit the eviction, so a stale notice for an
    /// abandoned deferral can be recognized and skipped (like
    /// `TokenResponse`/`Error`).  Recovery: the edge re-uploads its
    /// retained exit-layer hidden states from position 0 under the same
    /// `req_id` and re-issues the `InferRequest`; the cloud re-prefills
    /// and serving resumes with bit-identical tokens, at the cost of one
    /// extra upload round trip.
    SessionEvicted { device_id: u64, req_id: u32, pos: u32 },
    Ack,
    /// Request failure.  `req_id`/`pos` echo the failed request so the
    /// edge can correlate (or skip) it; both are [`NO_REQ`] for
    /// connection-level errors not tied to any request.
    Error { req_id: u32, pos: u32, msg: String },
    /// Edge keepalive probe on an otherwise idle channel.  The server
    /// answers with a [`Message::Pong`] echoing `nonce` on the same
    /// connection; the edge measures the round trip and the traffic
    /// keeps quiet-but-alive links from tripping the reactor's
    /// `idle_timeout_s` reap (on by default now that the edge both
    /// pings and reconnects).
    Ping { nonce: u64 },
    /// Server's echo of a [`Message::Ping`].
    Pong { nonce: u64 },
}

/// Sentinel `req_id`/`pos` for errors not tied to a request.
pub const NO_REQ: u32 = u32::MAX;

/// Exact encoded size of an `UploadHidden` with an empty payload (tag +
/// device + req + start + count + prompt_len + precision + payload_len).
/// The edge's byte counters and the DES harness both price messages
/// from these constants, so simulated and measured wire bytes agree
/// exactly; guarded against `encode()` by a test.
pub const UPLOAD_HDR_LEN: usize = 30;
/// Exact encoded `InferRequest` size.
pub const INFER_REQ_LEN: usize = 25;
/// Exact encoded `TokenResponse` size.
pub const TOKEN_RESP_LEN: usize = 21;
/// Exact encoded `SessionEvicted` size (the DES prices the eviction
/// notice with it, matching the live edge's byte counters).
pub const EVICTED_LEN: usize = 17;
/// Exact encoded `Hello` size (the DES prices a reconnect's re-`Hello`
/// pair with it, matching the live edge's byte counters).
pub const HELLO_LEN: usize = 18;
/// Exact encoded `Ping`/`Pong` size (keepalive pricing).
pub const PING_LEN: usize = 9;

/// Borrowed view of an `UploadHidden` frame: identical fields to
/// [`Message::UploadHidden`], but the payload borrows from the frame
/// buffer instead of being copied into a fresh `Vec`.  The serve path
/// decodes one of these per uploaded token, so skipping that copy (and
/// unpacking straight out of the frame with
/// [`crate::quant::unpack_into`]) takes an allocation plus a memcpy off
/// the per-token hot loop.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UploadView<'a> {
    pub device_id: u64,
    pub req_id: u32,
    pub start_pos: u32,
    pub count: u32,
    pub prompt_len: u32,
    pub precision: Precision,
    pub payload: &'a [u8],
}

const TAG_HELLO: u8 = 1;
const TAG_UPLOAD: u8 = 2;
const TAG_INFER: u8 = 3;
const TAG_TOKEN: u8 = 4;
const TAG_END: u8 = 5;
const TAG_ACK: u8 = 6;
const TAG_ERROR: u8 = 7;
const TAG_EVICTED: u8 = 8;
const TAG_PING: u8 = 9;
const TAG_PONG: u8 = 10;

/// High bit of the `Hello` channel byte: set on a reconnect (resume)
/// Hello.  The low bits stay the channel role, so decoders that
/// predate resume reject the flag instead of misreading the channel.
const CHANNEL_RESUME_BIT: u8 = 0x80;

/// Bit 6 of the `Hello` channel byte: set on a warm-standby (mirror)
/// session's Hello.  Same compatibility story as the resume bit — a
/// decoder that predates replication rejects the flag rather than
/// misreading the channel, and a non-mirror Hello encodes exactly as
/// before the bit existed.
const CHANNEL_MIRROR_BIT: u8 = 0x40;

/// Both `Hello` channel-byte flags, masked off before the channel role
/// is interpreted.
const CHANNEL_FLAG_BITS: u8 = CHANNEL_RESUME_BIT | CHANNEL_MIRROR_BIT;

impl Message {
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(32);
        match self {
            Message::Hello { device_id, session, channel, resume, mirror } => {
                b.push(TAG_HELLO);
                b.extend_from_slice(&device_id.to_le_bytes());
                b.extend_from_slice(&session.to_le_bytes());
                // channel stays the last byte of the frame; resume and
                // mirror ride its high bits so a fresh non-mirror Hello
                // encodes exactly as before either flag existed
                let mut c = match channel {
                    Channel::Upload => 0,
                    Channel::Infer => 1,
                };
                if *resume {
                    c |= CHANNEL_RESUME_BIT;
                }
                if *mirror {
                    c |= CHANNEL_MIRROR_BIT;
                }
                b.push(c);
            }
            Message::UploadHidden {
                device_id,
                req_id,
                start_pos,
                count,
                prompt_len,
                precision,
                payload,
            } => {
                b.push(TAG_UPLOAD);
                b.extend_from_slice(&device_id.to_le_bytes());
                b.extend_from_slice(&req_id.to_le_bytes());
                b.extend_from_slice(&start_pos.to_le_bytes());
                b.extend_from_slice(&count.to_le_bytes());
                b.extend_from_slice(&prompt_len.to_le_bytes());
                b.push(match precision {
                    Precision::F16 => 0,
                    Precision::F32 => 1,
                });
                b.extend_from_slice(&(payload.len() as u32).to_le_bytes());
                b.extend_from_slice(payload);
            }
            Message::InferRequest { device_id, req_id, pos, prompt_len, deadline_ms } => {
                b.push(TAG_INFER);
                b.extend_from_slice(&device_id.to_le_bytes());
                b.extend_from_slice(&req_id.to_le_bytes());
                b.extend_from_slice(&pos.to_le_bytes());
                b.extend_from_slice(&prompt_len.to_le_bytes());
                b.extend_from_slice(&deadline_ms.to_le_bytes());
            }
            Message::TokenResponse { req_id, pos, token, conf, compute_s } => {
                b.push(TAG_TOKEN);
                b.extend_from_slice(&req_id.to_le_bytes());
                b.extend_from_slice(&pos.to_le_bytes());
                b.extend_from_slice(&token.to_le_bytes());
                b.extend_from_slice(&conf.to_le_bytes());
                b.extend_from_slice(&compute_s.to_le_bytes());
            }
            Message::EndSession { device_id, req_id } => {
                b.push(TAG_END);
                b.extend_from_slice(&device_id.to_le_bytes());
                b.extend_from_slice(&req_id.to_le_bytes());
            }
            Message::SessionEvicted { device_id, req_id, pos } => {
                b.push(TAG_EVICTED);
                b.extend_from_slice(&device_id.to_le_bytes());
                b.extend_from_slice(&req_id.to_le_bytes());
                b.extend_from_slice(&pos.to_le_bytes());
            }
            Message::Ack => b.push(TAG_ACK),
            Message::Ping { nonce } => {
                b.push(TAG_PING);
                b.extend_from_slice(&nonce.to_le_bytes());
            }
            Message::Pong { nonce } => {
                b.push(TAG_PONG);
                b.extend_from_slice(&nonce.to_le_bytes());
            }
            Message::Error { req_id, pos, msg } => {
                b.push(TAG_ERROR);
                b.extend_from_slice(&req_id.to_le_bytes());
                b.extend_from_slice(&pos.to_le_bytes());
                let bytes = msg.as_bytes();
                b.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
                b.extend_from_slice(bytes);
            }
        }
        b
    }

    pub fn decode(buf: &[u8]) -> Result<Message> {
        let mut r = Reader { buf, pos: 0 };
        let tag = r.u8()?;
        let msg = match tag {
            TAG_HELLO => {
                let device_id = r.u64()?;
                let session = r.u64()?;
                let c = r.u8()?;
                let resume = c & CHANNEL_RESUME_BIT != 0;
                let mirror = c & CHANNEL_MIRROR_BIT != 0;
                let channel = match c & !CHANNEL_FLAG_BITS {
                    0 => Channel::Upload,
                    1 => Channel::Infer,
                    _ => bail!("bad channel {c}"),
                };
                Message::Hello { device_id, session, channel, resume, mirror }
            }
            TAG_UPLOAD => {
                let v = read_upload(&mut r)?;
                Message::UploadHidden {
                    device_id: v.device_id,
                    req_id: v.req_id,
                    start_pos: v.start_pos,
                    count: v.count,
                    prompt_len: v.prompt_len,
                    precision: v.precision,
                    payload: v.payload.to_vec(),
                }
            }
            TAG_INFER => Message::InferRequest {
                device_id: r.u64()?,
                req_id: r.u32()?,
                pos: r.u32()?,
                prompt_len: r.u32()?,
                deadline_ms: r.u32()?,
            },
            TAG_TOKEN => Message::TokenResponse {
                req_id: r.u32()?,
                pos: r.u32()?,
                token: r.i32()?,
                conf: r.f32()?,
                compute_s: r.f32()?,
            },
            TAG_END => Message::EndSession { device_id: r.u64()?, req_id: r.u32()? },
            TAG_EVICTED => {
                Message::SessionEvicted { device_id: r.u64()?, req_id: r.u32()?, pos: r.u32()? }
            }
            TAG_ACK => Message::Ack,
            TAG_PING => Message::Ping { nonce: r.u64()? },
            TAG_PONG => Message::Pong { nonce: r.u64()? },
            TAG_ERROR => {
                let req_id = r.u32()?;
                let pos = r.u32()?;
                let n = r.u32()? as usize;
                let msg = String::from_utf8(r.bytes(n)?.to_vec()).context("error msg utf-8")?;
                Message::Error { req_id, pos, msg }
            }
            t => bail!("unknown message tag {t}"),
        };
        ensure!(r.pos == buf.len(), "{} trailing bytes", buf.len() - r.pos);
        Ok(msg)
    }

    /// Zero-copy fast path for the upload channel: decode an
    /// `UploadHidden` frame with the payload borrowed from `buf`.
    /// `Ok(None)` means the frame carries some other tag — fall through
    /// to the full [`Self::decode`].  Validation is identical to
    /// `decode` (shared parser).
    pub fn decode_upload(buf: &[u8]) -> Result<Option<UploadView<'_>>> {
        if buf.first() != Some(&TAG_UPLOAD) {
            return Ok(None);
        }
        let mut r = Reader { buf, pos: 1 };
        let view = read_upload(&mut r)?;
        ensure!(r.pos == buf.len(), "{} trailing bytes", buf.len() - r.pos);
        Ok(Some(view))
    }
}

/// Parse the body of an `UploadHidden` frame (tag already consumed),
/// borrowing the payload.  Shared by [`Message::decode`] and
/// [`Message::decode_upload`] so both paths validate identically.
fn read_upload<'a>(r: &mut Reader<'a>) -> Result<UploadView<'a>> {
    let device_id = r.u64()?;
    let req_id = r.u32()?;
    let start_pos = r.u32()?;
    let count = r.u32()?;
    let prompt_len = r.u32()?;
    let precision = match r.u8()? {
        0 => Precision::F16,
        1 => Precision::F32,
        p => bail!("bad precision {p}"),
    };
    let n = r.u32()? as usize;
    let payload = r.bytes(n)?;
    ensure!(
        payload.len() % (count.max(1) as usize * precision.bytes_per_elem()) == 0,
        "payload not a multiple of count*elem"
    );
    Ok(UploadView { device_id, req_id, start_pos, count, prompt_len, precision, payload })
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.pos + n <= self.buf.len(), "truncated message");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(m: Message) {
        let enc = m.encode();
        let dec = Message::decode(&enc).unwrap();
        assert_eq!(m, dec);
    }

    #[test]
    fn roundtrip_all_variants() {
        roundtrip(Message::Hello {
            device_id: 42,
            session: 7,
            channel: Channel::Upload,
            resume: false,
            mirror: false,
        });
        roundtrip(Message::Hello {
            device_id: 0,
            session: u64::MAX,
            channel: Channel::Infer,
            resume: false,
            mirror: false,
        });
        roundtrip(Message::Hello {
            device_id: 42,
            session: 7,
            channel: Channel::Upload,
            resume: true,
            mirror: false,
        });
        roundtrip(Message::Hello {
            device_id: 1,
            session: 2,
            channel: Channel::Infer,
            resume: true,
            mirror: false,
        });
        roundtrip(Message::Hello {
            device_id: 9,
            session: 3,
            channel: Channel::Upload,
            resume: false,
            mirror: true,
        });
        roundtrip(Message::Hello {
            device_id: 9,
            session: 3,
            channel: Channel::Infer,
            resume: true,
            mirror: true,
        });
        roundtrip(Message::UploadHidden {
            device_id: u64::MAX,
            req_id: 7,
            start_pos: 100,
            count: 2,
            prompt_len: 90,
            precision: Precision::F16,
            payload: vec![1, 2, 3, 4, 5, 6, 7, 8],
        });
        roundtrip(Message::InferRequest {
            device_id: 3,
            req_id: 9,
            pos: 55,
            prompt_len: 12,
            deadline_ms: 0,
        });
        roundtrip(Message::InferRequest {
            device_id: 3,
            req_id: 9,
            pos: 55,
            prompt_len: 12,
            deadline_ms: 1500,
        });
        roundtrip(Message::TokenResponse {
            req_id: 9,
            pos: 55,
            token: -1,
            conf: 0.25,
            compute_s: 1e-3,
        });
        roundtrip(Message::EndSession { device_id: 3, req_id: 9 });
        roundtrip(Message::SessionEvicted { device_id: 3, req_id: 9, pos: 55 });
        roundtrip(Message::SessionEvicted { device_id: u64::MAX, req_id: u32::MAX, pos: 0 });
        roundtrip(Message::Ack);
        roundtrip(Message::Error { req_id: 9, pos: 55, msg: "kaboom — ω".into() });
        roundtrip(Message::Error { req_id: super::NO_REQ, pos: super::NO_REQ, msg: "hello?".into() });
        roundtrip(Message::Ping { nonce: 0 });
        roundtrip(Message::Ping { nonce: u64::MAX });
        roundtrip(Message::Pong { nonce: 0xDEAD_BEEF });
    }

    #[test]
    fn fresh_hello_wire_format_is_unchanged() {
        // resume = mirror = false must encode byte-for-byte like the
        // pre-resume format: tag | device | session | channel, channel
        // ∈ {0, 1} as the last byte — old decoders keep accepting
        // fresh non-mirror Hellos.
        let enc = Message::Hello {
            device_id: 5,
            session: 11,
            channel: Channel::Infer,
            resume: false,
            mirror: false,
        }
        .encode();
        assert_eq!(enc.len(), HELLO_LEN);
        assert_eq!(*enc.last().unwrap(), 1);
        let up = Message::Hello {
            device_id: 5,
            session: 11,
            channel: Channel::Upload,
            resume: false,
            mirror: false,
        }
        .encode();
        assert_eq!(*up.last().unwrap(), 0);
        // ... and each flag only flips its own bit
        let res = Message::Hello {
            device_id: 5,
            session: 11,
            channel: Channel::Infer,
            resume: true,
            mirror: false,
        }
        .encode();
        assert_eq!(*res.last().unwrap(), 0x81);
        assert_eq!(enc[..enc.len() - 1], res[..res.len() - 1]);
        let mir = Message::Hello {
            device_id: 5,
            session: 11,
            channel: Channel::Infer,
            resume: false,
            mirror: true,
        }
        .encode();
        assert_eq!(*mir.last().unwrap(), 0x41);
        assert_eq!(enc[..enc.len() - 1], mir[..mir.len() - 1]);
        let both = Message::Hello {
            device_id: 5,
            session: 11,
            channel: Channel::Upload,
            resume: true,
            mirror: true,
        }
        .encode();
        assert_eq!(*both.last().unwrap(), 0xC0);
        assert_eq!(enc[..enc.len() - 1], both[..both.len() - 1]);
    }

    #[test]
    fn header_len_constants_match_encode() {
        let up = Message::UploadHidden {
            device_id: 1,
            req_id: 1,
            start_pos: 0,
            count: 1,
            prompt_len: 1,
            precision: Precision::F16,
            payload: vec![],
        };
        assert_eq!(up.encode().len(), UPLOAD_HDR_LEN);
        let rq =
            Message::InferRequest { device_id: 1, req_id: 1, pos: 0, prompt_len: 1, deadline_ms: 0 };
        assert_eq!(rq.encode().len(), INFER_REQ_LEN);
        let tk = Message::TokenResponse { req_id: 1, pos: 0, token: 0, conf: 0.0, compute_s: 0.0 };
        assert_eq!(tk.encode().len(), TOKEN_RESP_LEN);
        let ev = Message::SessionEvicted { device_id: 1, req_id: 1, pos: 0 };
        assert_eq!(ev.encode().len(), EVICTED_LEN);
        let hl = Message::Hello {
            device_id: 1,
            session: 1,
            channel: Channel::Upload,
            resume: true,
            mirror: true,
        };
        assert_eq!(hl.encode().len(), HELLO_LEN);
        assert_eq!(Message::Ping { nonce: 1 }.encode().len(), PING_LEN);
        assert_eq!(Message::Pong { nonce: 1 }.encode().len(), PING_LEN);
    }

    #[test]
    fn rejects_truncated() {
        let enc = Message::InferRequest {
            device_id: 3,
            req_id: 9,
            pos: 55,
            prompt_len: 2,
            deadline_ms: 40,
        }
        .encode();
        for cut in 1..enc.len() {
            assert!(Message::decode(&enc[..cut]).is_err(), "cut at {cut}");
        }
        let ev = Message::SessionEvicted { device_id: 3, req_id: 9, pos: 1 }.encode();
        for cut in 1..ev.len() {
            assert!(Message::decode(&ev[..cut]).is_err(), "evicted cut at {cut}");
        }
        let pg = Message::Ping { nonce: 77 }.encode();
        for cut in 1..pg.len() {
            assert!(Message::decode(&pg[..cut]).is_err(), "ping cut at {cut}");
        }
        let hl = Message::Hello {
            device_id: 3,
            session: 9,
            channel: Channel::Infer,
            resume: true,
            mirror: true,
        }
        .encode();
        for cut in 1..hl.len() {
            assert!(Message::decode(&hl[..cut]).is_err(), "hello cut at {cut}");
        }
    }

    #[test]
    fn rejects_trailing_bytes() {
        let mut enc = Message::Ack.encode();
        enc.push(0);
        assert!(Message::decode(&enc).is_err());
    }

    #[test]
    fn rejects_unknown_tag() {
        assert!(Message::decode(&[99]).is_err());
    }

    #[test]
    fn rejects_bad_precision_and_channel() {
        let mut enc = Message::Hello {
            device_id: 1,
            session: 3,
            channel: Channel::Infer,
            resume: false,
            mirror: false,
        }
        .encode();
        *enc.last_mut().unwrap() = 9;
        assert!(Message::decode(&enc).is_err());
        // a resume or mirror bit on a bad channel is still a bad channel
        *enc.last_mut().unwrap() = 0x80 | 9;
        assert!(Message::decode(&enc).is_err());
        *enc.last_mut().unwrap() = 0x40 | 9;
        assert!(Message::decode(&enc).is_err());
    }

    #[test]
    fn decode_upload_borrows_and_matches_decode() {
        let msg = Message::UploadHidden {
            device_id: 9,
            req_id: 4,
            start_pos: 17,
            count: 2,
            prompt_len: 12,
            precision: Precision::F16,
            payload: vec![1, 2, 3, 4, 5, 6, 7, 8],
        };
        let enc = msg.encode();
        let view = Message::decode_upload(&enc).unwrap().expect("upload frame");
        match &msg {
            Message::UploadHidden { device_id, req_id, start_pos, count, prompt_len, precision, payload } => {
                assert_eq!(view.device_id, *device_id);
                assert_eq!(view.req_id, *req_id);
                assert_eq!(view.start_pos, *start_pos);
                assert_eq!(view.count, *count);
                assert_eq!(view.prompt_len, *prompt_len);
                assert_eq!(view.precision, *precision);
                assert_eq!(view.payload, &payload[..]);
            }
            _ => unreachable!(),
        }
        // the payload really borrows the frame buffer (no copy)
        assert!(std::ptr::eq(view.payload.as_ptr(), enc[enc.len() - 8..].as_ptr()));
        // non-upload frames fall through cleanly
        assert!(Message::decode_upload(&Message::Ack.encode()).unwrap().is_none());
        assert!(Message::decode_upload(&[]).unwrap().is_none());
        // truncation is rejected just like the owned decode
        for cut in 1..enc.len() {
            assert!(Message::decode_upload(&enc[..cut]).is_err(), "cut at {cut}");
        }
        // trailing bytes rejected
        let mut bad = enc.clone();
        bad.push(0);
        assert!(Message::decode_upload(&bad).is_err());
    }

    #[test]
    fn upload_payload_f16_halves_bytes() {
        let h: Vec<f32> = (0..128).map(|i| i as f32 * 0.1).collect();
        let m16 = Message::UploadHidden {
            device_id: 1,
            req_id: 0,
            start_pos: 0,
            count: 1,
            prompt_len: 0,
            precision: Precision::F16,
            payload: crate::quant::pack(&h, Precision::F16),
        };
        let m32 = Message::UploadHidden {
            device_id: 1,
            req_id: 0,
            start_pos: 0,
            count: 1,
            prompt_len: 0,
            precision: Precision::F32,
            payload: crate::quant::pack(&h, Precision::F32),
        };
        assert!(m16.encode().len() < m32.encode().len());
        assert_eq!(m32.encode().len() - m16.encode().len(), 128 * 2);
    }
}
