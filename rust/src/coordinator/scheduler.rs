//! Event-driven serving core (paper §4.2, scaled out): a sharded worker
//! pool with dependency-tracked inference requests.
//!
//! The seed implementation serialized every device through one GPU thread
//! and resolved the upload-vs-infer race by re-queueing the request with a
//! bounded retry counter.  This module replaces that with a scheduler that
//! *parks* an infer request whose hidden states have not landed and wakes
//! it the moment the covering `Upload` arrives — the wait is purely
//! event-driven (a blocking channel receive), with no timers on the happy
//! path and no retry counters anywhere.
//!
//! Architecture:
//! * **Workers** (`CloudConfig::workers`): each worker thread owns a
//!   [`ContextStore`] shard holding its engine sessions and
//!   content-manager state.  PJRT handles are `!Send`, so the session
//!   factory is *built on the worker thread* via the [`FactoryBuilder`]
//!   and nothing engine-related ever crosses threads.
//! * **Bounded memory**: the store meters every device's resident bytes
//!   and, between passes, TTL-reaps idle sessions and LRU-evicts under
//!   `CloudConfig::memory_budget_bytes` pressure.  An infer request for
//!   an evicted device resolves with [`InferOutcome::Evicted`] instead of
//!   parking; the edge replays its history and the request completes
//!   with bit-identical tokens (see `coordinator::context_store`).
//! * **Sharding**: devices map to workers statically
//!   (`device_id % workers`), so all messages of one device are totally
//!   ordered by its worker's queue while independent devices are served
//!   concurrently.
//! * **Coalescing**: when an upload wakes several parked requests of one
//!   device, a single engine pass covers every pending decode position
//!   (the content manager's plan already batches catch-up positions) and
//!   each request is answered from that one pass.
//! * **Cross-device batching**: a worker drains its whole message queue
//!   before touching the engine, then serves *every* device whose
//!   coverage is `Ready` in one padded pass — each device's coalesced
//!   catch-up run enters the batch (via [`CloudEngine::decode_batch`]),
//!   padded to the widest run, and results fan back out to the parked
//!   requests.  Under load this turns N per-device passes into one
//!   per-worker pass; when idle a single request still runs immediately.
//!   [`CloudConfig::max_catchup_per_pass`] bounds how many positions one
//!   device may contribute per pass, so a device with a deep backlog
//!   spreads over several passes while everyone else rides along in each
//!   of them (fairness: no device starves the batch).
//! * **Deadlines**: an infer request may carry a deadline (the edge's
//!   per-token latency budget, §4.4), and every parked request is capped
//!   by [`CloudConfig::max_park_s`] regardless, so a request whose
//!   uploads never arrive resolves with an error instead of wedging its
//!   connection.  A parked request whose deadline passes before its
//!   uploads land is failed so the edge — which gave up at the same
//!   budget — finds its connection drained, not wedged.  The only timed
//!   wait in the loop is `recv_timeout` until the earliest parked
//!   deadline; with nothing parked the loop blocks on the next message.

use std::collections::HashMap;
use std::sync::atomic::{AtomicI64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Context, Result};

use crate::config::CloudConfig;
use crate::coordinator::content_manager::{Coverage, PlanReq, WorkPlan};
use crate::coordinator::context_store::{ContextStore, ContextStoreStats};
use crate::coordinator::protocol::UPLOAD_HDR_LEN;
use crate::metrics::{LatencyHist, MetricsRegistry};
use crate::model::manifest::ModelDims;
use crate::net::reactor::ReactorStats;
use crate::quant::{self, Precision};
use crate::runtime::traits::{BatchItem, CloudEngine};
use crate::trace::{Ev, TraceSink};
use crate::util::json::Json;

pub use crate::coordinator::context_store::SessionFactory;

/// Builds one [`SessionFactory`] per worker, invoked on that worker's own
/// thread (PJRT objects never cross threads).
pub type FactoryBuilder = Arc<dyn Fn() -> Result<SessionFactory> + Send + Sync>;

/// One served token: the cloud head's prediction plus the engine seconds
/// of the pass that produced it (a coalesced pass is attributed to every
/// request it answered).
#[derive(Debug, Clone, Copy)]
pub struct TokenOut {
    pub token: i32,
    pub conf: f32,
    pub compute_s: f64,
}

/// How an infer request resolved (successfully).
#[derive(Debug, Clone, Copy)]
pub enum InferOutcome {
    /// A served token.
    Token(TokenOut),
    /// The device's cloud context was evicted by the context store
    /// (memory budget or idle TTL) — there is nothing to serve the
    /// request from.  The connection layer turns this into a
    /// [`SessionEvicted`](crate::coordinator::protocol::Message::SessionEvicted)
    /// frame; the edge replays its hidden-state history from position 0
    /// and re-issues the request.
    Evicted,
}

/// Single-use completion sink for one infer request.  The blocking path
/// wraps an mpsc sender ([`Reply::channel`]); a reactor shard wraps a
/// closure that posts a completion record to *that shard's* completion
/// channel and wakes *that shard's* event loop ([`Reply::new`]) — the
/// sink resolves to the shard that created it, so a worker's answer can
/// never land on another shard.
/// Dropping a `Reply` without calling [`Reply::send`] signals "never
/// answered" to whoever holds the other end (a channel-backed reply makes
/// the receiver's `recv` fail, exactly like the old dropped sender did).
pub struct Reply(Box<dyn FnOnce(Result<InferOutcome>) + Send>);

impl Reply {
    pub fn new(f: impl FnOnce(Result<InferOutcome>) + Send + 'static) -> Self {
        Reply(Box::new(f))
    }

    /// The classic blocking shape: the caller parks on `rx.recv()`.
    pub fn channel(tx: Sender<Result<InferOutcome>>) -> Self {
        Self::new(move |out| {
            let _ = tx.send(out);
        })
    }

    pub fn send(self, out: Result<InferOutcome>) {
        (self.0)(out)
    }

    fn send_token(self, t: TokenOut) {
        self.send(Ok(InferOutcome::Token(t)))
    }
}

/// Payload of an upload message.  The reactor forwards the *packed* wire
/// payload and the owning worker unpacks it (f16→f32), so ingest CPU
/// scales with the worker pool instead of serializing on the one reactor
/// thread; in-process senders (tests, benches, harnesses) pass floats
/// directly.
pub enum UploadPayload {
    /// Already-unpacked hidden floats.
    Floats(Vec<f32>),
    /// Packed wire payload, unpacked on the owning worker thread.
    Packed { bytes: Vec<u8>, precision: Precision },
    /// Packed wire payload still sitting inside its frame buffer: the
    /// reactor moves the WHOLE `UploadHidden` frame (payload =
    /// `frame[UPLOAD_HDR_LEN..]`, guaranteed by the fixed-width header
    /// + the decoder's trailing-bytes check) instead of copying the
    /// payload out — for a large single-copy-ingested upload this keeps
    /// the reactor thread free of per-byte work entirely.
    PackedFrame { frame: Vec<u8>, precision: Precision },
}

impl UploadPayload {
    fn into_floats(self) -> Result<Vec<f32>> {
        match self {
            UploadPayload::Floats(v) => Ok(v),
            UploadPayload::Packed { bytes, precision } => quant::unpack(&bytes, precision),
            UploadPayload::PackedFrame { frame, precision } => {
                ensure!(frame.len() >= UPLOAD_HDR_LEN, "upload frame shorter than its header");
                quant::unpack(&frame[UPLOAD_HDR_LEN..], precision)
            }
        }
    }
}

/// Work items for the scheduler.
///
/// `session` is the connection-pair nonce from the `Hello` handshake
/// (0 = untagged, never fenced).  After a [`SchedMsg::Reset`] pins a
/// device to a session, messages tagged with a *different* session are
/// stragglers from a previous connection and are dropped (uploads,
/// ends) or failed (infers) instead of corrupting the fresh session.
pub enum SchedMsg {
    Upload {
        device: u64,
        session: u64,
        req_id: u32,
        start_pos: u32,
        prompt_len: u32,
        payload: UploadPayload,
    },
    Infer {
        device: u64,
        session: u64,
        req_id: u32,
        pos: u32,
        prompt_len: u32,
        /// Park no longer than this; `None` falls back to the worker's
        /// [`CloudConfig::max_park_s`] bound, so a request whose uploads
        /// never arrive (e.g. the upload connection died) fails with an
        /// error instead of wedging the connection.
        deadline: Option<Instant>,
        reply: Reply,
    },
    /// `EndSession` for one finished request.  Requests are ended by id:
    /// a newer request's uploads that raced ahead on the upload
    /// connection survive the teardown of the previous one.
    End { device: u64, session: u64, req_id: u32 },
    /// The device opened a new upload channel.
    ///
    /// `resume = false` (a fresh `Hello`): drop all of its state,
    /// including end-request tombstones (a fresh edge process restarts
    /// its request ids), fail anything still parked, and pin the device
    /// to `session`.
    ///
    /// `resume = true` (a reconnect re-announcing its session): when
    /// `session` matches the pinned nonce, the worker *suspends* the
    /// device instead — buffered state and the engine session are
    /// dropped (the edge replays its history from position 0 right
    /// after the handshake, so the rebuild is deterministic even when a
    /// served token died with the old socket), parked requests are
    /// failed (their reply sinks belong to the dead connection), but
    /// end-request tombstones survive: the old connection's stragglers
    /// carry the *same* nonce and only the tombstones fence them.  A
    /// resume whose nonce the worker cannot honor (unknown device or a
    /// different pinned session — e.g. after failover to a restarted
    /// cloud) is counted and degraded to the full reset.
    ///
    /// `mirror = true` (the Hello's mirror bit): this session is a
    /// warm standby — the edge replicates its uploads here so a future
    /// failover can promote the session without replay.  The worker
    /// marks the device in its store (separate upload billing,
    /// preferred eviction victim); the first infer on a mirror device
    /// clears the mark (promotion).
    Reset { device: u64, session: u64, resume: bool, mirror: bool },
    Stats { reply: Sender<CloudStats> },
    Shutdown,
}

/// Serving statistics — per worker, or summed across the pool.
#[derive(Debug, Clone, Default)]
pub struct CloudStats {
    pub requests_served: u64,
    pub uploads: u64,
    /// Uploads that landed on a warm-standby (mirror) session — a
    /// subset of `uploads`, billed separately so replication overhead
    /// stays visible next to primary traffic.
    pub uploads_mirrored: u64,
    /// Mirror sessions promoted to serving: an infer arrived on a
    /// device whose session Hello carried the mirror bit (the edge
    /// failed over to this standby, or hedged onto it).
    pub mirror_promotions: u64,
    pub busy_s: f64,
    pub active_devices: usize,
    pub pending_floats: usize,
    /// Infer requests currently parked waiting for their uploads.
    pub parked: usize,
    /// Parked requests failed because their deadline passed first.
    pub deadline_expired: u64,
    /// Resume `Hello`s honored: the nonce matched the pinned session,
    /// so the device was suspended (state dropped for the deterministic
    /// replay) instead of fully reset.
    pub sessions_resumed: u64,
    /// Resume `Hello`s the worker could not honor — unknown device or a
    /// mismatched session nonce (a restarted cloud, a failover target) —
    /// degraded to a full reset.
    pub stale_resumes: u64,
    /// Padded cross-device engine passes executed (one per batch, however
    /// many devices and catch-up positions it covered).
    pub engine_passes: u64,
    /// Decode catch-up items served through batched passes.
    pub batched_items: u64,
    /// Widest pass so far, in devices — how much cross-device batching
    /// the traffic actually yielded.
    pub batch_devices_max: usize,
    /// Context-store counters (resident bytes, evictions, TTL reaps,
    /// replays), summed over the pool's shards.
    pub context: ContextStoreStats,
    /// Workers contributing to this snapshot.
    pub workers: usize,
    /// Connection-layer counters aggregated across the reactor fleet.
    /// Worker-local snapshots leave this zeroed; the serving shell
    /// ([`crate::coordinator::cloud::CloudServer`]) fills it in.
    pub reactor: ReactorStats,
    /// The same counters per reactor shard (index = shard), so shard
    /// imbalance — a skewed `SO_REUSEPORT` hash, one hot shard — stays
    /// observable next to the aggregate.
    pub reactor_shards: Vec<ReactorStats>,
    /// Trace events the workers emitted into the [`TraceSink`] (0 when
    /// recording is off).
    pub trace_events: u64,
    /// Trace events dropped because the sink's bounded queue was full —
    /// a saturated recorder degrades visibly instead of ever blocking a
    /// worker.
    pub trace_dropped: u64,
}

impl CloudStats {
    /// The whole snapshot as one [`util::json`](crate::util::json) value.
    /// `Json`'s `Display` is compact and key-sorted, so the rendered
    /// string is a stable single line — the shape `CloudServer::shutdown`
    /// and the CLI print for scripts/CI to scrape without a parser for
    /// pretty output.
    pub fn to_json(&self) -> Json {
        let num = Json::Num;
        let mut o = std::collections::BTreeMap::new();
        o.insert("requests_served".into(), num(self.requests_served as f64));
        o.insert("uploads".into(), num(self.uploads as f64));
        o.insert("uploads_mirrored".into(), num(self.uploads_mirrored as f64));
        o.insert("mirror_promotions".into(), num(self.mirror_promotions as f64));
        o.insert("busy_s".into(), num(self.busy_s));
        o.insert("active_devices".into(), num(self.active_devices as f64));
        o.insert("pending_floats".into(), num(self.pending_floats as f64));
        o.insert("parked".into(), num(self.parked as f64));
        o.insert("deadline_expired".into(), num(self.deadline_expired as f64));
        o.insert("sessions_resumed".into(), num(self.sessions_resumed as f64));
        o.insert("stale_resumes".into(), num(self.stale_resumes as f64));
        o.insert("engine_passes".into(), num(self.engine_passes as f64));
        o.insert("batched_items".into(), num(self.batched_items as f64));
        o.insert("batch_devices_max".into(), num(self.batch_devices_max as f64));
        o.insert("workers".into(), num(self.workers as f64));
        o.insert("trace_events".into(), num(self.trace_events as f64));
        o.insert("trace_dropped".into(), num(self.trace_dropped as f64));
        let mut ctx = std::collections::BTreeMap::new();
        ctx.insert("resident_bytes".into(), num(self.context.resident_bytes as f64));
        ctx.insert("evictions".into(), num(self.context.evictions as f64));
        ctx.insert("ttl_reaps".into(), num(self.context.ttl_reaps as f64));
        ctx.insert("replays".into(), num(self.context.replays as f64));
        o.insert("context".into(), Json::Obj(ctx));
        let mut r = std::collections::BTreeMap::new();
        r.insert("backend".into(), Json::Str(self.reactor.backend.to_string()));
        r.insert("accept_mode".into(), Json::Str(self.reactor.accept_mode.to_string()));
        r.insert("shards".into(), num(self.reactor_shards.len() as f64));
        r.insert("accepts".into(), num(self.reactor.accepts as f64));
        r.insert("conns_opened".into(), num(self.reactor.conns_opened as f64));
        r.insert("conns_closed".into(), num(self.reactor.conns_closed as f64));
        r.insert("conns_rejected".into(), num(self.reactor.conns_rejected as f64));
        r.insert("evicted_slow".into(), num(self.reactor.evicted_slow as f64));
        r.insert("frames_in".into(), num(self.reactor.frames_in as f64));
        r.insert("frames_out".into(), num(self.reactor.frames_out as f64));
        r.insert("read_pauses".into(), num(self.reactor.read_pauses as f64));
        r.insert("hello_timeouts".into(), num(self.reactor.hello_timeouts as f64));
        r.insert("idle_timeouts".into(), num(self.reactor.idle_timeouts as f64));
        r.insert("open_conns".into(), num(self.reactor.open_conns as f64));
        r.insert("wakes".into(), num(self.reactor.wakes as f64));
        o.insert("reactor".into(), Json::Obj(r));
        Json::Obj(o)
    }

    fn merge(&mut self, o: &CloudStats) {
        self.requests_served += o.requests_served;
        self.uploads += o.uploads;
        self.uploads_mirrored += o.uploads_mirrored;
        self.mirror_promotions += o.mirror_promotions;
        self.busy_s += o.busy_s;
        self.active_devices += o.active_devices;
        self.pending_floats += o.pending_floats;
        self.parked += o.parked;
        self.deadline_expired += o.deadline_expired;
        self.sessions_resumed += o.sessions_resumed;
        self.stale_resumes += o.stale_resumes;
        self.engine_passes += o.engine_passes;
        self.batched_items += o.batched_items;
        self.batch_devices_max = self.batch_devices_max.max(o.batch_devices_max);
        self.context.merge(&o.context);
        self.workers += o.workers;
        self.reactor.merge(&o.reactor);
        self.reactor_shards.extend(o.reactor_shards.iter().cloned());
        self.trace_events += o.trace_events;
        self.trace_dropped += o.trace_dropped;
    }
}

/// A scheduler message plus its optional enqueue timestamp (stamped by
/// the [`Router`] only when metrics are on, so the off path never calls
/// `Instant::now`): the worker's queue-wait histogram is the delta
/// between this stamp and the dequeue.
type Queued = (Option<Instant>, SchedMsg);

/// Cheap cloneable handle routing device-addressed messages to the worker
/// that owns the device.  The reactor (and any connection-side code)
/// holds its own clone.
#[derive(Clone)]
pub struct Router {
    txs: Vec<Sender<Queued>>,
    /// Messages sent but not yet taken off each worker's queue — the
    /// reactor's backpressure signal (it pauses reading from sockets
    /// whose owning worker has fallen too far behind, instead of
    /// buffering unboundedly).
    depths: Vec<Arc<AtomicUsize>>,
    /// Stamp enqueue times onto messages (metrics on).
    stamp: bool,
}

impl Router {
    pub fn workers(&self) -> usize {
        self.txs.len()
    }

    /// Worker index owning `device` (static shard).
    pub fn worker_for(&self, device: u64) -> usize {
        (device % self.txs.len() as u64) as usize
    }

    /// Route one message to the worker owning `device`.
    pub fn send(&self, device: u64, msg: SchedMsg) -> Result<()> {
        self.send_to(self.worker_for(device), msg)
    }

    /// Route one message to worker `w` directly, keeping the queue-depth
    /// gauge consistent (every enqueue counted; workers decrement on
    /// dequeue).  Also carries the scheduler's own control traffic.
    fn send_to(&self, w: usize, msg: SchedMsg) -> Result<()> {
        let at = if self.stamp { Some(Instant::now()) } else { None };
        self.depths[w].fetch_add(1, Ordering::Relaxed);
        if self.txs[w].send((at, msg)).is_err() {
            self.depths[w].fetch_sub(1, Ordering::Relaxed);
            return Err(anyhow!("scheduler worker gone"));
        }
        Ok(())
    }

    /// Messages queued to worker `w` and not yet dequeued by it.  A
    /// transient gauge: exactness only matters at the extremes (0 =
    /// drained, large = the worker is drowning), which is what the
    /// reactor's read-pause threshold consumes.
    pub fn queue_depth(&self, w: usize) -> usize {
        self.depths[w].load(Ordering::Relaxed)
    }
}

/// The worker pool.  Owns the threads; hand out [`Router`]s for senders.
pub struct Scheduler {
    router: Router,
    handles: Vec<JoinHandle<CloudStats>>,
    sink: Option<Arc<TraceSink>>,
}

impl Scheduler {
    /// Spawn `cfg.workers` threads (at least one).  `builder` runs once
    /// on each worker thread to construct that worker's session factory.
    pub fn spawn(dims: ModelDims, cfg: CloudConfig, builder: FactoryBuilder) -> Result<Scheduler> {
        let workers = cfg.workers.max(1);
        // Trace recording resolves once, here: `run_meta` is the first
        // event of every recording (sequence 0), pinning everything the
        // replayer needs to rebuild this deployment.  The budget is the
        // GLOBAL bound — the replayer re-splits it exactly like the loop
        // below does.
        let sink = TraceSink::resolve(cfg.trace);
        // Same resolve discipline for histograms: explicit config wins,
        // CE_METRICS enables ambiently, and `None` keeps every record
        // site a single `Option` check.
        let metrics = MetricsRegistry::resolve(cfg.metrics);
        if let Some(s) = &sink {
            let mut ev = Ev::new("run_meta")
                .u("workers", workers as u64)
                .u("d_model", dims.d_model as u64)
                .u("max_catchup", cfg.max_catchup_per_pass.max(1) as u64);
            if let Some(b) = cfg.memory_budget_bytes {
                ev = ev.u("budget", b);
            }
            if let Some(t) = cfg.session_ttl_s {
                ev = ev.f("ttl_s", t);
            }
            s.emit(ev);
        }
        // the global memory budget splits into even per-worker shares:
        // static device sharding makes each shard's enforcement
        // independent, and the shares sum back to the global bound
        let mut wcfg = cfg;
        wcfg.memory_budget_bytes =
            cfg.memory_budget_bytes.map(|b| (b / workers as u64).max(1));
        let mut txs = Vec::with_capacity(workers);
        let mut depths = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = channel::<Queued>();
            let depth = Arc::new(AtomicUsize::new(0));
            let builder = Arc::clone(&builder);
            let dims = dims.clone();
            let wdepth = Arc::clone(&depth);
            let wsink = sink.clone();
            let wmetrics = metrics.clone();
            let handle = std::thread::Builder::new()
                .name(format!("cloud-worker-{w}"))
                .spawn(move || {
                    let factory = match builder() {
                        Ok(f) => f,
                        Err(e) => {
                            log::error!("worker {w}: engine builder failed: {e:#}");
                            return CloudStats::default();
                        }
                    };
                    Worker::new(dims, factory, &wcfg, wdepth, w as u64, wsink, wmetrics).run(rx)
                })?;
            txs.push(tx);
            depths.push(depth);
            handles.push(handle);
        }
        let stamp = metrics.is_some();
        Ok(Scheduler { router: Router { txs, depths, stamp }, handles, sink })
    }

    pub fn router(&self) -> Router {
        self.router.clone()
    }

    /// The trace sink this pool records into, if recording is on — the
    /// serving shell hands the same sink to the reactor fleet so frame
    /// and scheduler events interleave in one sequence.
    pub fn trace_sink(&self) -> Option<Arc<TraceSink>> {
        self.sink.clone()
    }

    /// Aggregate statistics across the pool.
    pub fn stats(&self) -> Result<CloudStats> {
        let mut total = CloudStats::default();
        for w in 0..self.router.workers() {
            let (reply, rx) = channel();
            self.router.send_to(w, SchedMsg::Stats { reply })?;
            total.merge(&rx.recv().context("worker stats reply")?);
        }
        Ok(total)
    }

    /// Stop every worker and return the summed final statistics.
    pub fn shutdown(mut self) -> CloudStats {
        for w in 0..self.router.workers() {
            let _ = self.router.send_to(w, SchedMsg::Shutdown);
        }
        let mut total = CloudStats::default();
        for handle in self.handles.drain(..) {
            total.merge(&handle.join().unwrap_or_default());
        }
        total
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        // idempotent: workers already gone just drop the message
        for w in 0..self.router.workers() {
            let _ = self.router.send_to(w, SchedMsg::Shutdown);
        }
    }
}

/// Tokens produced by one device's share of a pass, keyed by position.
type PassTokens = HashMap<u32, (i32, f32)>;

/// A device's outcome within one padded pass: its ready requests plus
/// either the served tokens and post-plan frontier, or the error that
/// fails them all.
type DeviceOutcome = (u64, Vec<Parked>, Result<(PassTokens, u32)>);

/// An infer request waiting for its uploads.
struct Parked {
    req_id: u32,
    pos: u32,
    prompt_len: u32,
    /// Effective expiry: the client's deadline capped by the worker's
    /// max-park bound, so every parked request eventually resolves.
    deadline: Instant,
    /// When the request entered the parking lot; the park-wait histogram
    /// records the delta when its token fans out.
    parked_at: Instant,
    reply: Reply,
}

/// Cached registry handles for one worker's hot-path record sites — each
/// a single pre-resolved `Arc`, so recording is one atomic add with no
/// name lookup anywhere near the engine.
struct WorkerMetrics {
    park_wait: Arc<LatencyHist>,
    queue_wait: Arc<LatencyHist>,
    batch_pass: Arc<LatencyHist>,
    pass_items: Arc<LatencyHist>,
    gauges: Vec<(Arc<AtomicI64>, fn(&CloudStats) -> i64)>,
}

impl WorkerMetrics {
    fn new(reg: &MetricsRegistry, w: u64) -> WorkerMetrics {
        let g = |name: &str| reg.gauge(&format!("{name}{{worker=\"{w}\"}}"));
        let gauges: Vec<(Arc<AtomicI64>, fn(&CloudStats) -> i64)> = vec![
            (g("ce_sched_requests_served"), |s| s.requests_served as i64),
            (g("ce_sched_uploads"), |s| s.uploads as i64),
            (g("ce_sched_parked"), |s| s.parked as i64),
            (g("ce_sched_engine_passes"), |s| s.engine_passes as i64),
            (g("ce_sched_batched_items"), |s| s.batched_items as i64),
            (g("ce_sched_busy_us"), |s| (s.busy_s * 1e6) as i64),
            (g("ce_store_resident_bytes"), |s| s.context.resident_bytes as i64),
            (g("ce_store_evictions"), |s| s.context.evictions as i64),
            (g("ce_store_ttl_reaps"), |s| s.context.ttl_reaps as i64),
            (g("ce_store_replays"), |s| s.context.replays as i64),
        ];
        WorkerMetrics {
            park_wait: reg.hist(&format!("ce_sched_park_wait_ns{{worker=\"{w}\"}}")),
            queue_wait: reg.hist(&format!("ce_sched_queue_wait_ns{{worker=\"{w}\"}}")),
            batch_pass: reg.hist(&format!("ce_sched_batch_pass_ns{{worker=\"{w}\"}}")),
            pass_items: reg.hist(&format!("ce_sched_pass_items{{worker=\"{w}\"}}")),
            gauges,
        }
    }

    /// Publish the worker's counter snapshot into the registry gauges so
    /// a `/metrics` scrape never needs a blocking stats round trip into
    /// the worker (the reactor renders from these atomics directly).
    fn publish(&self, stats: &CloudStats) {
        for (gauge, read) in &self.gauges {
            gauge.store(read(stats), Ordering::Relaxed);
        }
    }
}

/// Most messages one greedy drain takes off the queue before the worker
/// runs its padded batch pass — bounds the latency a full queue can add
/// in front of already-ready work.
const MAX_DRAIN: usize = 256;

/// One worker: a context-store shard (which owns the engine sessions and
/// hidden-state buffers — the bytes) plus the parking lot and pass logic
/// (the compute) for the devices assigned to it.
struct Worker {
    store: ContextStore,
    factory: SessionFactory,
    parked: HashMap<u64, Vec<Parked>>,
    /// Connection-pair nonce each device is pinned to (set by `Reset`).
    session_of: HashMap<u64, u64>,
    max_park: Duration,
    /// Fairness bound: catch-up positions one device may put into a
    /// single padded pass ([`CloudConfig::max_catchup_per_pass`]).
    max_catchup: usize,
    /// Shared with [`Router::queue_depth`]: decremented once per message
    /// this worker takes off its queue.
    depth: Arc<AtomicUsize>,
    /// This worker's index, stamped into every trace event it emits.
    windex: u64,
    /// Trace recorder; `None` (the default) keeps the hot path at one
    /// `Option` check per tap site.
    sink: Option<Arc<TraceSink>>,
    /// Pre-resolved histogram/gauge handles; `None` (the default) keeps
    /// every record site at one `Option` check, same as `sink`.
    metrics: Option<WorkerMetrics>,
    stats: CloudStats,
}

impl Worker {
    fn new(
        dims: ModelDims,
        factory: SessionFactory,
        cfg: &CloudConfig,
        depth: Arc<AtomicUsize>,
        windex: u64,
        sink: Option<Arc<TraceSink>>,
        metrics: Option<Arc<MetricsRegistry>>,
    ) -> Worker {
        Worker {
            store: ContextStore::new(&dims, cfg.memory_budget_bytes, cfg.session_ttl_s),
            factory,
            parked: HashMap::new(),
            session_of: HashMap::new(),
            max_park: Duration::from_secs_f64(cfg.max_park_s.max(0.001)),
            max_catchup: cfg.max_catchup_per_pass.max(1),
            depth,
            windex,
            sink,
            metrics: metrics.map(|reg| WorkerMetrics::new(&reg, windex)),
            stats: CloudStats { workers: 1, ..CloudStats::default() },
        }
    }

    /// Emit one trace event when recording is on.  Event construction
    /// (the closure) only runs behind the `Option` check, and a
    /// saturated sink drops the event and counts it — a worker never
    /// blocks on the recorder.
    fn trace_with(&mut self, build: impl FnOnce(u64) -> Ev) {
        if let Some(sink) = &self.sink {
            if sink.emit(build(self.windex)) {
                self.stats.trace_events += 1;
            } else {
                self.stats.trace_dropped += 1;
            }
        }
    }

    /// A tagged message from a connection the device has moved past.
    fn stale_session(&self, device: u64, session: u64) -> bool {
        session != 0 && self.session_of.get(&device).is_some_and(|&cur| cur != session)
    }

    /// One message dequeued: keep [`Router::queue_depth`] honest and
    /// record how long it sat on the queue (when the router stamped it).
    fn dequeued(&self, at: Option<Instant>) {
        self.depth.fetch_sub(1, Ordering::Relaxed);
        if let (Some(m), Some(at)) = (&self.metrics, at) {
            m.queue_wait.record_duration(at.elapsed());
        }
    }

    /// Refresh the gauges and mirror them into the registry so a live
    /// scrape reads fresh values without a round trip into this thread.
    fn publish_metrics(&mut self) {
        if self.metrics.is_none() {
            return;
        }
        self.refresh_gauges();
        if let Some(m) = &self.metrics {
            m.publish(&self.stats);
        }
    }

    fn run(mut self, rx: Receiver<Queued>) -> CloudStats {
        'serve: loop {
            // Block for the next message; with parked deadlines armed,
            // wake at the earliest one to expire it, and with a session
            // TTL configured, wake when the oldest idle context crosses
            // it so the reaper needs no polling.
            let msg = match self.next_deadline() {
                Some(deadline) => {
                    match rx.recv_timeout(deadline.saturating_duration_since(Instant::now())) {
                        Ok((at, m)) => {
                            self.dequeued(at);
                            Some(m)
                        }
                        Err(RecvTimeoutError::Timeout) => None,
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }
                None => match rx.recv() {
                    Ok((at, m)) => {
                        self.dequeued(at);
                        Some(m)
                    }
                    Err(_) => break,
                },
            };
            match msg {
                None => {
                    self.expire_overdue(Instant::now());
                    self.sweep_store();
                    self.publish_metrics();
                }
                Some(first) => {
                    // Greedy drain: fold every already-queued message
                    // into this wake before touching the engine, so the
                    // pass below batches across devices exactly when the
                    // queue is deep (i.e. when load is highest).
                    let mut msg = first;
                    let mut drained = 1;
                    loop {
                        if !self.handle(msg) {
                            break 'serve;
                        }
                        if drained >= MAX_DRAIN {
                            break;
                        }
                        match rx.try_recv() {
                            Ok((at, m)) => {
                                self.dequeued(at);
                                msg = m;
                                drained += 1;
                            }
                            Err(_) => break,
                        }
                    }
                    // One padded cross-device pass per iteration; capped
                    // leftovers (fairness bound) keep the loop going.
                    // Between passes, fold in whatever arrived while the
                    // engine was busy, so mid-drain traffic joins the
                    // very next pass instead of waiting out a deep
                    // backlog behind the whole leftover loop.
                    // Store housekeeping runs strictly BETWEEN passes
                    // (never inside one), so a device being served in a
                    // batch pass can never be evicted mid-pass.
                    loop {
                        let leftover = self.batch_pass();
                        self.expire_overdue(Instant::now());
                        self.sweep_store();
                        if !leftover {
                            break;
                        }
                        let mut extra = 0;
                        while extra < MAX_DRAIN {
                            match rx.try_recv() {
                                Ok((at, m)) => {
                                    self.dequeued(at);
                                    if !self.handle(m) {
                                        break 'serve;
                                    }
                                    extra += 1;
                                }
                                Err(_) => break,
                            }
                        }
                    }
                    self.publish_metrics();
                }
            }
        }
        self.refresh_gauges();
        if let Some(m) = &self.metrics {
            m.publish(&self.stats);
        }
        // final per-worker counters: the replayer checks its own end
        // state against the sum of these
        let s = self.stats.clone();
        self.trace_with(|w| {
            Ev::new("worker_stats")
                .u("worker", w)
                .u("served", s.requests_served)
                .u("uploads", s.uploads)
                .u("resumed", s.sessions_resumed)
                .u("stale_resumes", s.stale_resumes)
                .u("evictions", s.context.evictions)
                .u("ttl_reaps", s.context.ttl_reaps)
                .u("replays", s.context.replays)
        });
        self.stats
    }

    /// Apply one message's state transition — park, buffer, end, reset —
    /// without running any engine work (that happens in the batched pass
    /// after the queue drain).  Returns `false` on `Shutdown`.
    fn handle(&mut self, msg: SchedMsg) -> bool {
        match msg {
            SchedMsg::Upload { device, session, req_id, start_pos, prompt_len, payload } => {
                if self.stale_session(device, session) {
                    log::debug!("dropping stale-session upload from device {device}");
                    return true;
                }
                self.stats.uploads += 1;
                if self.store.is_mirror(device) {
                    self.stats.uploads_mirrored += 1;
                }
                // packed payloads unpack HERE, on the owning worker —
                // the reactor thread never pays the f16→f32 conversion
                let hiddens = match payload.into_floats() {
                    Ok(h) => h,
                    Err(e) => {
                        log::warn!("upload from device {device} rejected: {e:#}");
                        return true;
                    }
                };
                // recorded post-unpack: the trace carries the canonical
                // f32 payload whatever precision rode the wire
                self.trace_with(|w| {
                    Ev::new("upload")
                        .u("worker", w)
                        .u("device", device)
                        .hex("session", session)
                        .u("req", req_id as u64)
                        .u("start", start_pos as u64)
                        .u("plen", prompt_len as u64)
                        .hex_f32s("data", &hiddens)
                });
                if let Err(e) =
                    self.store.upload_owned(device, req_id, start_pos, prompt_len, hiddens)
                {
                    log::warn!("upload from device {device} rejected: {e:#}");
                }
            }
            SchedMsg::Infer { device, session, req_id, pos, prompt_len, deadline, reply } => {
                self.trace_with(|w| {
                    Ev::new("infer")
                        .u("worker", w)
                        .u("device", device)
                        .hex("session", session)
                        .u("req", req_id as u64)
                        .u("pos", pos as u64)
                        .u("plen", prompt_len as u64)
                });
                if self.stale_session(device, session) {
                    self.stats.requests_served += 1;
                    self.trace_with(|w| {
                        Ev::new("infer_error")
                            .u("worker", w)
                            .u("device", device)
                            .u("req", req_id as u64)
                            .u("pos", pos as u64)
                            .s("kind", "stale")
                    });
                    let _ = reply.send(Err(anyhow!(
                        "infer request {req_id} from a stale connection of device {device}"
                    )));
                    return true;
                }
                if self.store.is_mirror(device) {
                    // first infer on a warm-standby session: the edge
                    // promoted it after a primary failure, or hedged
                    // onto it under a tight deadline — either way the
                    // session is serving now, so it stops being a
                    // preferred eviction victim
                    self.store.set_mirror(device, false);
                    self.stats.mirror_promotions += 1;
                    self.trace_with(|w| {
                        Ev::new("mirror_promote").u("worker", w).u("device", device)
                    });
                }
                if self.store.evicted_req(device).is_some() {
                    // the device's context is gone: parking would wait
                    // forever for uploads the edge believes have already
                    // landed.  Tell it to replay instead; the position-0
                    // re-upload clears the mark and the re-issued
                    // request parks and serves normally.  Not counted in
                    // requests_served — the same logical request comes
                    // back and is served (or fails) exactly once; the
                    // bounce is visible as `context.replays`.
                    self.trace_with(|w| {
                        Ev::new("evicted_notice")
                            .u("worker", w)
                            .u("device", device)
                            .u("req", req_id as u64)
                            .u("pos", pos as u64)
                    });
                    reply.send(Ok(InferOutcome::Evicted));
                    return true;
                }
                let now = Instant::now();
                let cap = now + self.max_park;
                let deadline = deadline.map_or(cap, |d| d.min(cap));
                self.trace_with(|w| {
                    Ev::new("park")
                        .u("worker", w)
                        .u("device", device)
                        .u("req", req_id as u64)
                        .u("pos", pos as u64)
                });
                self.parked
                    .entry(device)
                    .or_default()
                    .push(Parked { req_id, pos, prompt_len, deadline, parked_at: now, reply });
            }
            SchedMsg::End { device, session, req_id } => {
                self.trace_with(|w| {
                    Ev::new("end")
                        .u("worker", w)
                        .u("device", device)
                        .hex("session", session)
                        .u("req", req_id as u64)
                });
                if self.stale_session(device, session) {
                    log::debug!("ignoring stale-session EndSession from device {device}");
                    return true;
                }
                self.store.end_request(device, req_id);
                if let Some(mut queue) = self.parked.remove(&device) {
                    // fail parked requests of the ended (or older)
                    // request; later ones keep waiting for coverage
                    let mut i = 0;
                    while i < queue.len() {
                        if queue[i].req_id <= req_id {
                            let p = queue.remove(i);
                            self.stats.requests_served += 1;
                            self.trace_with(|w| {
                                Ev::new("infer_error")
                                    .u("worker", w)
                                    .u("device", device)
                                    .u("req", p.req_id as u64)
                                    .u("pos", p.pos as u64)
                                    .s("kind", "end")
                            });
                            let _ = p.reply.send(Err(anyhow!(
                                "request {} for device {device} ended",
                                p.req_id
                            )));
                        } else {
                            i += 1;
                        }
                    }
                    if !queue.is_empty() {
                        self.parked.insert(device, queue);
                    }
                }
            }
            SchedMsg::Reset { device, session, resume, mirror } => {
                let honored = resume
                    && session != 0
                    && self.session_of.get(&device) == Some(&session);
                self.trace_with(|w| {
                    Ev::new("reset")
                        .u("worker", w)
                        .u("device", device)
                        .hex("session", session)
                        .b("resume", resume)
                        .b("honored", honored)
                        .b("mirror", mirror)
                });
                if honored {
                    self.store.suspend_device(device);
                    self.stats.sessions_resumed += 1;
                } else {
                    if resume {
                        self.stats.stale_resumes += 1;
                    }
                    self.store.reset_device(device);
                    if session != 0 {
                        self.session_of.insert(device, session);
                    }
                }
                // the Hello's mirror bit re-stamps the device either
                // way: a reconnecting standby stays a standby, a
                // non-mirror Hello on a previously mirrored device is
                // a promotion-by-reconnect
                self.store.set_mirror(device, mirror);
                // parked replies belong to the dead connection either
                // way: fail them so the slots free up immediately
                if let Some(queue) = self.parked.remove(&device) {
                    for p in queue {
                        self.stats.requests_served += 1;
                        self.trace_with(|w| {
                            Ev::new("infer_error")
                                .u("worker", w)
                                .u("device", device)
                                .u("req", p.req_id as u64)
                                .u("pos", p.pos as u64)
                                .s("kind", "reset")
                        });
                        let _ = p.reply.send(Err(anyhow!(
                            "device {device} reconnected; request {} dropped",
                            p.req_id
                        )));
                    }
                }
            }
            SchedMsg::Stats { reply } => {
                // enforce before reporting, so a stats reader never sees
                // a transiently over-budget gauge for state a sweep
                // would have already released
                self.sweep_store();
                self.refresh_gauges();
                if let Some(m) = &self.metrics {
                    m.publish(&self.stats);
                }
                let _ = reply.send(self.stats.clone());
            }
            SchedMsg::Shutdown => return false,
        }
        true
    }

    /// Store housekeeping between passes: TTL-reap idle devices, then
    /// enforce the memory budget.  Devices with parked requests are
    /// protected — they are either waiting on in-flight uploads or about
    /// to be served by the next pass.
    fn sweep_store(&mut self) {
        let parked = &self.parked;
        let reaped = self.store.reap_ttl(Instant::now(), |d| parked.contains_key(&d));
        let evicted = self.store.enforce_budget(|d| parked.contains_key(&d));
        for d in reaped {
            self.trace_with(|w| Ev::new("ttl_reap").u("worker", w).u("device", d));
        }
        for d in evicted {
            self.trace_with(|w| Ev::new("evict").u("worker", w).u("device", d));
        }
    }

    fn refresh_gauges(&mut self) {
        self.stats.active_devices = self.store.device_count();
        self.stats.pending_floats = self.store.pending_floats();
        self.stats.parked = self.parked.values().map(Vec::len).sum();
        self.stats.context = self.store.stats();
    }

    fn next_deadline(&self) -> Option<Instant> {
        let parked = self.parked.values().flatten().map(|p| p.deadline).min();
        // parked (protected) devices are excluded from the TTL deadline —
        // the reaper skips them, so arming their expired deadline would
        // spin this wait at zero timeout; their own park deadline bounds
        // the wake instead
        let ttl = self.store.next_ttl_deadline(|d| self.parked.contains_key(&d));
        match (parked, ttl) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    /// Fail every parked request whose deadline has passed.  The edge
    /// that set the deadline has already emitted its local fallback; the
    /// error reply keeps its infer connection drained and releases the
    /// parking slot.
    fn expire_overdue(&mut self, now: Instant) {
        let mut expired: Vec<(u64, Parked)> = Vec::new();
        for (&device, queue) in self.parked.iter_mut() {
            let mut i = 0;
            while i < queue.len() {
                if queue[i].deadline <= now {
                    expired.push((device, queue.remove(i)));
                } else {
                    i += 1;
                }
            }
        }
        self.parked.retain(|_, queue| !queue.is_empty());
        for (device, p) in expired {
            self.stats.requests_served += 1;
            self.stats.deadline_expired += 1;
            self.trace_with(|w| {
                Ev::new("infer_error")
                    .u("worker", w)
                    .u("device", device)
                    .u("req", p.req_id as u64)
                    .u("pos", p.pos as u64)
                    .s("kind", "deadline")
            });
            let _ = p.reply.send(Err(anyhow!(
                "deadline expired waiting for uploads from device {device} (pos {})",
                p.pos
            )));
        }
    }

    /// Serve every parked request the current upload state covers —
    /// across ALL of this worker's devices — in one padded engine pass:
    /// sweep the parking lot for `Ready` heads (failing `Stale` ones),
    /// plan every device through a single content-manager call, run the
    /// pass (per-device prefill + each device's coalesced catch-up run
    /// via [`CloudEngine::decode_batch`], runs padded to the widest one),
    /// then fan the tokens back out.  Engine seconds of the whole pass
    /// are attributed to every request it answered, the same way
    /// coalesced single-device passes always were.
    ///
    /// Returns `true` when the fairness cap left ready work behind (the
    /// caller immediately runs another pass).
    fn batch_pass(&mut self) -> bool {
        // --- sweep the parking lot for ready heads ------------------------
        let mut batch: Vec<(u64, Vec<Parked>)> = Vec::new();
        let mut devices: Vec<u64> = self.parked.keys().copied().collect();
        devices.sort_unstable();
        for device in devices {
            let Some(mut queue) = self.parked.remove(&device) else { continue };
            let mut ready: Vec<Parked> = Vec::new();
            let mut i = 0;
            while i < queue.len() {
                let p = &queue[i];
                match self.store.coverage(device, p.req_id, p.pos, p.prompt_len) {
                    Coverage::Ready => ready.push(queue.remove(i)),
                    Coverage::Stale => {
                        let p = queue.remove(i);
                        self.stats.requests_served += 1;
                        self.trace_with(|w| {
                            Ev::new("infer_error")
                                .u("worker", w)
                                .u("device", device)
                                .u("req", p.req_id as u64)
                                .u("pos", p.pos as u64)
                                .s("kind", "stale")
                        });
                        let _ = p.reply.send(Err(anyhow!(
                            "request {} from device {device} superseded by a newer request",
                            p.req_id
                        )));
                    }
                    Coverage::Waiting => i += 1,
                }
            }
            if !queue.is_empty() {
                self.parked.insert(device, queue);
            }
            if !ready.is_empty() {
                batch.push((device, ready));
            }
        }
        if batch.is_empty() {
            return false;
        }

        // --- plan the whole batch in one manager sweep --------------------
        // Ready implies the request id matches the manager's current
        // request for the device, so each device's ready set shares one id
        // and its highest position's plan covers every lower one.
        let reqs: Vec<PlanReq> = batch
            .iter()
            .map(|(device, ready)| {
                let top = ready.iter().max_by_key(|p| p.pos).expect("non-empty ready set");
                PlanReq {
                    device: *device,
                    req_id: top.req_id,
                    pos: top.pos,
                    prompt_len: top.prompt_len,
                }
            })
            .collect();
        let plans = self.store.plan_batch(&reqs, self.max_catchup);

        // --- one padded engine pass over every planned device -------------
        let t0 = Instant::now();
        let mut served: Vec<DeviceOutcome> = Vec::with_capacity(batch.len());
        let mut pass_devices = 0usize;
        let mut pass_items = 0u64;
        for ((device, ready), plan) in batch.into_iter().zip(plans) {
            let outcome = match plan {
                Err(e) => Err(e),
                Ok(plan) => {
                    let frontier = plan.frontier;
                    let n_items = plan.decode.len() as u64;
                    let session = match self.store.session(device, &mut self.factory) {
                        Ok(s) => s,
                        Err(e) => {
                            served.push((device, ready, Err(e)));
                            continue;
                        }
                    };
                    // counted only once a session actually runs the work,
                    // so failed devices don't inflate batching stats
                    pass_devices += 1;
                    pass_items += n_items;
                    run_device_pass(session, plan).map(|tokens| (tokens, frontier))
                }
            };
            served.push((device, ready, outcome));
        }
        let pass_dur = t0.elapsed();
        let elapsed = pass_dur.as_secs_f64();
        if pass_devices > 0 {
            self.stats.busy_s += elapsed;
            self.stats.engine_passes += 1;
            self.stats.batched_items += pass_items;
            self.stats.batch_devices_max = self.stats.batch_devices_max.max(pass_devices);
            if let Some(m) = &self.metrics {
                m.batch_pass.record_duration(pass_dur);
                m.pass_items.record_value(pass_items);
            }
            self.trace_with(|w| {
                Ev::new("pass")
                    .u("worker", w)
                    .u("devices", pass_devices as u64)
                    .u("items", pass_items)
            });
        }

        // --- fan results back out to the parked requests ------------------
        let mut leftover = false;
        for (device, ready, outcome) in served {
            match outcome {
                Ok((tokens, frontier)) => {
                    for p in ready {
                        if let Some(&(token, conf)) = tokens.get(&p.pos) {
                            self.stats.requests_served += 1;
                            if let Some(m) = &self.metrics {
                                m.park_wait.record_duration(p.parked_at.elapsed());
                            }
                            // conf recorded as its exact f32 bit pattern:
                            // "bit-identical" is checkable, not aspirational
                            self.trace_with(|w| {
                                Ev::new("token")
                                    .u("worker", w)
                                    .u("device", device)
                                    .u("req", p.req_id as u64)
                                    .u("pos", p.pos as u64)
                                    .i("token", token as i64)
                                    .u("conf_bits", conf.to_bits() as u64)
                            });
                            p.reply.send_token(TokenOut { token, conf, compute_s: elapsed });
                        } else if p.pos < frontier {
                            // position consumed by an earlier pass and
                            // never re-requested: nothing left to compute
                            self.stats.requests_served += 1;
                            self.trace_with(|w| {
                                Ev::new("infer_error")
                                    .u("worker", w)
                                    .u("device", device)
                                    .u("req", p.req_id as u64)
                                    .u("pos", p.pos as u64)
                                    .s("kind", "frontier")
                            });
                            let _ = p
                                .reply
                                .send(Err(anyhow!("nothing to compute for pos {}", p.pos)));
                        } else {
                            // fairness cap stopped short of this position:
                            // stays parked, next pass continues the run
                            leftover = true;
                            self.parked.entry(device).or_default().push(p);
                        }
                    }
                }
                Err(e) => {
                    for p in ready {
                        self.stats.requests_served += 1;
                        self.trace_with(|w| {
                            Ev::new("infer_error")
                                .u("worker", w)
                                .u("device", device)
                                .u("req", p.req_id as u64)
                                .u("pos", p.pos as u64)
                                .s("kind", "engine")
                        });
                        let _ = p.reply.send(Err(anyhow!("{e:#}")));
                    }
                }
            }
        }
        leftover
    }
}

/// One device's share of a padded pass: optional prompt prefill, then the
/// coalesced catch-up run as a single [`CloudEngine::decode_batch`] call.
fn run_device_pass(session: &mut dyn CloudEngine, plan: WorkPlan) -> Result<PassTokens> {
    let mut tokens = HashMap::new();
    if let Some((h, len)) = &plan.prefill {
        session.reset();
        let out = session.prefill(h, *len)?;
        tokens.insert(*len as u32 - 1, (out.exit.token, out.exit.conf));
    }
    if !plan.decode.is_empty() {
        let items: Vec<BatchItem> = plan
            .decode
            .into_iter()
            .map(|(p, h)| BatchItem { h1: h, pos: p as usize })
            .collect();
        let outs = session.decode_batch(&items)?;
        anyhow::ensure!(outs.len() == items.len(), "decode_batch returned a short batch");
        for (item, out) in items.iter().zip(outs) {
            tokens.insert(item.pos as u32, (out.exit.token, out.exit.conf));
        }
    }
    Ok(tokens)
}
