//! Event-driven serving core (paper §4.2, scaled out): a sharded worker
//! pool with dependency-tracked inference requests.
//!
//! The seed implementation serialized every device through one GPU thread
//! and resolved the upload-vs-infer race by re-queueing the request with a
//! bounded retry counter.  This module replaces that with a scheduler that
//! *parks* an infer request whose hidden states have not landed and wakes
//! it the moment the covering `Upload` arrives — the wait is purely
//! event-driven (a blocking channel receive), with no timers on the happy
//! path and no retry counters anywhere.
//!
//! Architecture:
//! * **Workers** (`CloudConfig::workers`): each worker thread owns its own
//!   engine sessions and content-manager shard.  PJRT handles are `!Send`,
//!   so the session factory is *built on the worker thread* via the
//!   [`FactoryBuilder`] and nothing engine-related ever crosses threads.
//! * **Sharding**: devices map to workers statically
//!   (`device_id % workers`), so all messages of one device are totally
//!   ordered by its worker's queue while independent devices are served
//!   concurrently.
//! * **Coalescing**: when an upload wakes several parked requests of one
//!   device, a single engine pass covers every pending decode position
//!   (the content manager's plan already batches catch-up positions) and
//!   each request is answered from that one pass.
//! * **Deadlines**: an infer request may carry a deadline (the edge's
//!   per-token latency budget, §4.4), and every parked request is capped
//!   by [`CloudConfig::max_park_s`] regardless, so a request whose
//!   uploads never arrive resolves with an error instead of wedging its
//!   connection.  A parked request whose deadline passes before its
//!   uploads land is failed so the edge — which gave up at the same
//!   budget — finds its connection drained, not wedged.  The only timed
//!   wait in the loop is `recv_timeout` until the earliest parked
//!   deadline; with nothing parked the loop blocks on the next message.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::config::CloudConfig;
use crate::coordinator::content_manager::{ContentManager, Coverage};
use crate::model::manifest::ModelDims;
use crate::runtime::traits::CloudEngine;

/// Session factory living on a worker thread.
pub type SessionFactory = Box<dyn FnMut(u64) -> Result<Box<dyn CloudEngine>>>;

/// Builds one [`SessionFactory`] per worker, invoked on that worker's own
/// thread (PJRT objects never cross threads).
pub type FactoryBuilder = Arc<dyn Fn() -> Result<SessionFactory> + Send + Sync>;

/// One served token: the cloud head's prediction plus the engine seconds
/// of the pass that produced it (a coalesced pass is attributed to every
/// request it answered).
#[derive(Debug, Clone, Copy)]
pub struct TokenOut {
    pub token: i32,
    pub conf: f32,
    pub compute_s: f64,
}

/// Work items for the scheduler.
///
/// `session` is the connection-pair nonce from the `Hello` handshake
/// (0 = untagged, never fenced).  After a [`SchedMsg::Reset`] pins a
/// device to a session, messages tagged with a *different* session are
/// stragglers from a previous connection and are dropped (uploads,
/// ends) or failed (infers) instead of corrupting the fresh session.
pub enum SchedMsg {
    Upload {
        device: u64,
        session: u64,
        req_id: u32,
        start_pos: u32,
        prompt_len: u32,
        hiddens: Vec<f32>,
    },
    Infer {
        device: u64,
        session: u64,
        req_id: u32,
        pos: u32,
        prompt_len: u32,
        /// Park no longer than this; `None` falls back to the worker's
        /// [`CloudConfig::max_park_s`] bound, so a request whose uploads
        /// never arrive (e.g. the upload connection died) fails with an
        /// error instead of wedging the connection.
        deadline: Option<Instant>,
        reply: Sender<Result<TokenOut>>,
    },
    /// `EndSession` for one finished request.  Requests are ended by id:
    /// a newer request's uploads that raced ahead on the upload
    /// connection survive the teardown of the previous one.
    End { device: u64, session: u64, req_id: u32 },
    /// The device opened a fresh upload channel: drop all of its state,
    /// including end-request tombstones (a reconnecting edge process
    /// restarts its request ids), fail anything still parked, and pin
    /// the device to `session`.
    Reset { device: u64, session: u64 },
    Stats { reply: Sender<CloudStats> },
    Shutdown,
}

/// Serving statistics — per worker, or summed across the pool.
#[derive(Debug, Clone, Default)]
pub struct CloudStats {
    pub requests_served: u64,
    pub uploads: u64,
    pub busy_s: f64,
    pub active_devices: usize,
    pub pending_floats: usize,
    /// Infer requests currently parked waiting for their uploads.
    pub parked: usize,
    /// Parked requests failed because their deadline passed first.
    pub deadline_expired: u64,
    /// Workers contributing to this snapshot.
    pub workers: usize,
}

impl CloudStats {
    fn merge(&mut self, o: &CloudStats) {
        self.requests_served += o.requests_served;
        self.uploads += o.uploads;
        self.busy_s += o.busy_s;
        self.active_devices += o.active_devices;
        self.pending_floats += o.pending_floats;
        self.parked += o.parked;
        self.deadline_expired += o.deadline_expired;
        self.workers += o.workers;
    }
}

/// Cheap cloneable handle routing device-addressed messages to the worker
/// that owns the device.  Connection threads each hold their own clone.
#[derive(Clone)]
pub struct Router {
    txs: Vec<Sender<SchedMsg>>,
}

impl Router {
    pub fn workers(&self) -> usize {
        self.txs.len()
    }

    /// Worker index owning `device` (static shard).
    pub fn worker_for(&self, device: u64) -> usize {
        (device % self.txs.len() as u64) as usize
    }

    /// Route one message to the worker owning `device`.
    pub fn send(&self, device: u64, msg: SchedMsg) -> Result<()> {
        self.txs[self.worker_for(device)].send(msg).map_err(|_| anyhow!("scheduler worker gone"))
    }
}

/// The worker pool.  Owns the threads; hand out [`Router`]s for senders.
pub struct Scheduler {
    router: Router,
    handles: Vec<JoinHandle<CloudStats>>,
}

impl Scheduler {
    /// Spawn `cfg.workers` threads (at least one).  `builder` runs once
    /// on each worker thread to construct that worker's session factory.
    pub fn spawn(dims: ModelDims, cfg: CloudConfig, builder: FactoryBuilder) -> Result<Scheduler> {
        let workers = cfg.workers.max(1);
        let max_park = Duration::from_secs_f64(cfg.max_park_s.max(0.001));
        let mut txs = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = channel::<SchedMsg>();
            let builder = Arc::clone(&builder);
            let dims = dims.clone();
            let handle = std::thread::Builder::new()
                .name(format!("cloud-worker-{w}"))
                .spawn(move || {
                    let factory = match builder() {
                        Ok(f) => f,
                        Err(e) => {
                            log::error!("worker {w}: engine builder failed: {e:#}");
                            return CloudStats::default();
                        }
                    };
                    Worker::new(dims, factory, max_park).run(rx)
                })?;
            txs.push(tx);
            handles.push(handle);
        }
        Ok(Scheduler { router: Router { txs }, handles })
    }

    pub fn router(&self) -> Router {
        self.router.clone()
    }

    /// Aggregate statistics across the pool.
    pub fn stats(&self) -> Result<CloudStats> {
        let mut total = CloudStats::default();
        for tx in &self.router.txs {
            let (reply, rx) = channel();
            tx.send(SchedMsg::Stats { reply }).map_err(|_| anyhow!("scheduler worker gone"))?;
            total.merge(&rx.recv().context("worker stats reply")?);
        }
        Ok(total)
    }

    /// Stop every worker and return the summed final statistics.
    pub fn shutdown(mut self) -> CloudStats {
        for tx in &self.router.txs {
            let _ = tx.send(SchedMsg::Shutdown);
        }
        let mut total = CloudStats::default();
        for handle in self.handles.drain(..) {
            total.merge(&handle.join().unwrap_or_default());
        }
        total
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        // idempotent: workers already gone just drop the message
        for tx in &self.router.txs {
            let _ = tx.send(SchedMsg::Shutdown);
        }
    }
}

/// An infer request waiting for its uploads.
struct Parked {
    req_id: u32,
    pos: u32,
    prompt_len: u32,
    /// Effective expiry: the client's deadline capped by the worker's
    /// max-park bound, so every parked request eventually resolves.
    deadline: Instant,
    reply: Sender<Result<TokenOut>>,
}

/// One worker: engine sessions + content-manager shard + parking lot for
/// the devices assigned to it.
struct Worker {
    cm: ContentManager,
    factory: SessionFactory,
    sessions: HashMap<u64, Box<dyn CloudEngine>>,
    parked: HashMap<u64, Vec<Parked>>,
    /// Connection-pair nonce each device is pinned to (set by `Reset`).
    session_of: HashMap<u64, u64>,
    max_park: Duration,
    stats: CloudStats,
}

impl Worker {
    fn new(dims: ModelDims, factory: SessionFactory, max_park: Duration) -> Worker {
        Worker {
            cm: ContentManager::new(dims.d_model),
            factory,
            sessions: HashMap::new(),
            parked: HashMap::new(),
            session_of: HashMap::new(),
            max_park,
            stats: CloudStats { workers: 1, ..CloudStats::default() },
        }
    }

    /// A tagged message from a connection the device has moved past.
    fn stale_session(&self, device: u64, session: u64) -> bool {
        session != 0 && self.session_of.get(&device).is_some_and(|&cur| cur != session)
    }

    fn run(mut self, rx: Receiver<SchedMsg>) -> CloudStats {
        loop {
            // Block for the next message; with parked deadlines armed,
            // wake at the earliest one to expire it.
            let msg = match self.next_deadline() {
                Some(deadline) => {
                    match rx.recv_timeout(deadline.saturating_duration_since(Instant::now())) {
                        Ok(m) => Some(m),
                        Err(RecvTimeoutError::Timeout) => None,
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }
                None => match rx.recv() {
                    Ok(m) => Some(m),
                    Err(_) => break,
                },
            };
            match msg {
                None => self.expire_overdue(Instant::now()),
                Some(SchedMsg::Upload { device, session, req_id, start_pos, prompt_len, hiddens }) => {
                    if self.stale_session(device, session) {
                        log::debug!("dropping stale-session upload from device {device}");
                        continue;
                    }
                    self.stats.uploads += 1;
                    if let Err(e) = self.cm.upload(device, req_id, start_pos, prompt_len, &hiddens)
                    {
                        log::warn!("upload from device {device} rejected: {e:#}");
                    }
                    self.drain(device);
                }
                Some(SchedMsg::Infer { device, session, req_id, pos, prompt_len, deadline, reply }) => {
                    if self.stale_session(device, session) {
                        self.stats.requests_served += 1;
                        let _ = reply.send(Err(anyhow!(
                            "infer request {req_id} from a stale connection of device {device}"
                        )));
                        continue;
                    }
                    let cap = Instant::now() + self.max_park;
                    let deadline = deadline.map_or(cap, |d| d.min(cap));
                    self.parked
                        .entry(device)
                        .or_default()
                        .push(Parked { req_id, pos, prompt_len, deadline, reply });
                    self.drain(device);
                }
                Some(SchedMsg::End { device, session, req_id }) => {
                    if self.stale_session(device, session) {
                        log::debug!("ignoring stale-session EndSession from device {device}");
                        continue;
                    }
                    self.cm.end_request(device, req_id);
                    self.sessions.remove(&device);
                    if let Some(queue) = self.parked.get_mut(&device) {
                        // fail parked requests of the ended (or older)
                        // request; later ones keep waiting for coverage
                        let mut i = 0;
                        while i < queue.len() {
                            if queue[i].req_id <= req_id {
                                let p = queue.remove(i);
                                self.stats.requests_served += 1;
                                let _ = p.reply.send(Err(anyhow!(
                                    "request {} for device {device} ended",
                                    p.req_id
                                )));
                            } else {
                                i += 1;
                            }
                        }
                        if queue.is_empty() {
                            self.parked.remove(&device);
                        }
                    }
                }
                Some(SchedMsg::Reset { device, session }) => {
                    self.cm.reset_device(device);
                    self.sessions.remove(&device);
                    if session != 0 {
                        self.session_of.insert(device, session);
                    }
                    if let Some(queue) = self.parked.remove(&device) {
                        for p in queue {
                            self.stats.requests_served += 1;
                            let _ = p.reply.send(Err(anyhow!(
                                "device {device} reconnected; request {} dropped",
                                p.req_id
                            )));
                        }
                    }
                }
                Some(SchedMsg::Stats { reply }) => {
                    self.refresh_gauges();
                    let _ = reply.send(self.stats.clone());
                }
                Some(SchedMsg::Shutdown) => break,
            }
        }
        self.refresh_gauges();
        self.stats
    }

    fn refresh_gauges(&mut self) {
        self.stats.active_devices = self.cm.device_count();
        self.stats.pending_floats = self.cm.pending_floats();
        self.stats.parked = self.parked.values().map(Vec::len).sum();
    }

    fn next_deadline(&self) -> Option<Instant> {
        self.parked.values().flatten().map(|p| p.deadline).min()
    }

    /// Fail every parked request whose deadline has passed.  The edge
    /// that set the deadline has already emitted its local fallback; the
    /// error reply keeps its infer connection drained and releases the
    /// parking slot.
    fn expire_overdue(&mut self, now: Instant) {
        for (device, queue) in self.parked.iter_mut() {
            let mut i = 0;
            while i < queue.len() {
                if queue[i].deadline <= now {
                    let p = queue.remove(i);
                    self.stats.requests_served += 1;
                    self.stats.deadline_expired += 1;
                    let _ = p.reply.send(Err(anyhow!(
                        "deadline expired waiting for uploads from device {device} (pos {})",
                        p.pos
                    )));
                } else {
                    i += 1;
                }
            }
        }
        self.parked.retain(|_, queue| !queue.is_empty());
    }

    /// Serve every parked request of `device` that the current upload
    /// state covers, all in one engine pass; fail superseded ones.
    fn drain(&mut self, device: u64) {
        let Some(queue) = self.parked.get_mut(&device) else { return };
        let mut batch: Vec<Parked> = Vec::new();
        let mut i = 0;
        while i < queue.len() {
            let p = &queue[i];
            match self.cm.coverage(device, p.req_id, p.pos, p.prompt_len) {
                Coverage::Ready => batch.push(queue.remove(i)),
                Coverage::Stale => {
                    let p = queue.remove(i);
                    self.stats.requests_served += 1;
                    let _ = p.reply.send(Err(anyhow!(
                        "request {} from device {device} superseded by a newer request",
                        p.req_id
                    )));
                }
                Coverage::Waiting => i += 1,
            }
        }
        if queue.is_empty() {
            self.parked.remove(&device);
        }
        if batch.is_empty() {
            return;
        }
        batch.sort_by_key(|p| p.pos);
        // Ready implies the request id matches the manager's current
        // request for the device, so the whole batch shares one id and the
        // highest position's plan covers every lower one.
        let top = batch.last().expect("non-empty batch");
        let t0 = Instant::now();
        let served = self.engine_pass(device, top.req_id, top.pos, top.prompt_len);
        let elapsed = t0.elapsed().as_secs_f64();
        self.stats.busy_s += elapsed;
        match served {
            Ok(tokens) => {
                for p in batch {
                    self.stats.requests_served += 1;
                    let out = tokens
                        .get(&p.pos)
                        .map(|&(token, conf)| TokenOut { token, conf, compute_s: elapsed })
                        .ok_or_else(|| anyhow!("nothing to compute for pos {}", p.pos));
                    let _ = p.reply.send(out);
                }
            }
            Err(e) => {
                for p in batch {
                    self.stats.requests_served += 1;
                    let _ = p.reply.send(Err(anyhow!("{e:#}")));
                }
            }
        }
    }

    /// One engine pass answering every position up to `pos`: optional
    /// prompt prefill, then per-position decode catch-up.
    fn engine_pass(
        &mut self,
        device: u64,
        req_id: u32,
        pos: u32,
        prompt_len: u32,
    ) -> Result<HashMap<u32, (i32, f32)>> {
        let plan = self.cm.plan(device, req_id, pos, prompt_len)?;
        let session = match self.sessions.entry(device) {
            std::collections::hash_map::Entry::Occupied(o) => o.into_mut(),
            std::collections::hash_map::Entry::Vacant(v) => v.insert((self.factory)(device)?),
        };
        let mut tokens = HashMap::new();
        if let Some((h, len)) = &plan.prefill {
            session.reset();
            let out = session.prefill(h, *len)?;
            tokens.insert(*len as u32 - 1, (out.exit.token, out.exit.conf));
        }
        for (p, h) in &plan.decode {
            let out = session.decode(h, *p as usize)?;
            tokens.insert(*p, (out.exit.token, out.exit.conf));
        }
        Ok(tokens)
    }
}
