//! Cloud-side content manager (paper §4.2).
//!
//! Responsibilities, per edge device:
//! * buffer uploaded exit-1 hidden states until the cloud partition
//!   consumes them into its KV caches;
//! * deduplicate retransmissions (the "Without Content Manager" ablation
//!   resends the full history every request — the manager makes the
//!   redundant copies harmless to the compute path);
//! * hand the inference loop exactly the contiguous positions it needs
//!   (prompt prefill, then per-position decode catch-up);
//! * release consumed state eagerly and everything at end-of-session
//!   ("continuously releases unused hidden states to optimize resource
//!   usage and separately manages cache data for each edge device").

use std::collections::{BTreeMap, HashMap};

use anyhow::{ensure, Result};

/// Hidden-state buffers for one (device, request) session.
#[derive(Debug, Default)]
struct DeviceState {
    req_id: u32,
    prompt_len: Option<u32>,
    /// Uploaded, not yet consumed hidden states keyed by position.
    pending: BTreeMap<u32, Vec<f32>>,
    /// Running float count of `pending` (the context store meters
    /// resident bytes per upload/plan, so this must be O(1)).
    pending_floats: usize,
    /// Positions `< consumed_upto` have been folded into the KV cache.
    consumed_upto: u32,
    bytes_received: u64,
    duplicates_dropped: u64,
}

impl DeviceState {
    /// Upload watermark: every position `< watermark` is either consumed
    /// or pending, i.e. the contiguous coverage frontier for this request.
    fn watermark(&self) -> u32 {
        let mut w = self.consumed_upto;
        while self.pending.contains_key(&w) {
            w += 1;
        }
        w
    }
}

/// Whether an inference request is serviceable against the current
/// upload state (the scheduler's park/wake decision).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Coverage {
    /// Every hidden state the request needs has landed; `plan` will
    /// succeed structurally.
    Ready,
    /// Uploads are still in flight; park the request and re-check when
    /// the next upload for this device arrives.
    Waiting,
    /// A newer request from this device has superseded the buffered
    /// state; the request can never complete and must be failed.
    Stale,
}

/// What the inference loop must run to answer a request at `pos`.
#[derive(Debug, PartialEq)]
pub struct WorkPlan {
    /// `Some((h1_concat, len))` if cloud prefill must run first.
    pub prefill: Option<(Vec<f32>, usize)>,
    /// Per-position hidden states for decode catch-up, in order.  With a
    /// catch-up cap the run may stop short of the requested position; the
    /// request then stays parked and the next pass continues from
    /// [`Self::frontier`].
    pub decode: Vec<(u32, Vec<f32>)>,
    /// `consumed_upto` after this plan: every position `< frontier` has
    /// been handed to the engine (by this plan or an earlier one).
    pub frontier: u32,
}

/// One (device, request) head for [`ContentManager::plan_batch`].
#[derive(Debug, Clone, Copy)]
pub struct PlanReq {
    pub device: u64,
    pub req_id: u32,
    pub pos: u32,
    pub prompt_len: u32,
}

#[derive(Debug, Default)]
pub struct ContentManager {
    devices: HashMap<u64, DeviceState>,
    /// Highest request id explicitly ended per device.  The upload and
    /// infer channels are independent connections, so a straggling upload
    /// can arrive *after* its request's `EndSession`; the tombstone keeps
    /// it from resurrecting released state.  One entry per device ever
    /// seen (device identities are long-lived).
    ended: HashMap<u64, u32>,
    d_model: usize,
}

impl ContentManager {
    pub fn new(d_model: usize) -> Self {
        Self { devices: HashMap::new(), ended: HashMap::new(), d_model }
    }

    /// Ingest an upload of `count` hidden vectors starting at `start_pos`.
    /// Retransmitted positions (already pending or already consumed) are
    /// counted and dropped.
    pub fn upload(
        &mut self,
        device: u64,
        req_id: u32,
        start_pos: u32,
        prompt_len: u32,
        hiddens: &[f32],
    ) -> Result<()> {
        let d = self.d_model;
        let st = match self.upload_state(device, req_id, prompt_len, hiddens.len())? {
            Some(st) => st,
            None => return Ok(()),
        };
        for (i, chunk) in hiddens.chunks_exact(d).enumerate() {
            Self::insert_position(st, start_pos + i as u32, || chunk.to_vec());
        }
        Ok(())
    }

    /// [`Self::upload`] taking ownership of the payload: the dominant
    /// per-token case (`count == 1`) moves the vector straight into the
    /// pending buffer instead of copying it — the serving path's
    /// per-upload copy disappears (see the hotpath bench).
    pub fn upload_owned(
        &mut self,
        device: u64,
        req_id: u32,
        start_pos: u32,
        prompt_len: u32,
        hiddens: Vec<f32>,
    ) -> Result<()> {
        let d = self.d_model;
        if hiddens.len() != d {
            // multi-position payload: same chunked copy as the borrowed path
            return self.upload(device, req_id, start_pos, prompt_len, &hiddens);
        }
        let st = match self.upload_state(device, req_id, prompt_len, hiddens.len())? {
            Some(st) => st,
            None => return Ok(()),
        };
        Self::insert_position(st, start_pos, || hiddens);
        Ok(())
    }

    /// Shared upload bookkeeping: validation, tombstone check, request
    /// rollover, byte accounting.  `Ok(None)` means a fenced straggler.
    fn upload_state(
        &mut self,
        device: u64,
        req_id: u32,
        prompt_len: u32,
        payload_len: usize,
    ) -> Result<Option<&mut DeviceState>> {
        ensure!(self.d_model > 0, "content manager d_model not set");
        ensure!(payload_len % self.d_model == 0, "ragged hidden payload");
        if self.ended.get(&device).is_some_and(|&r| req_id <= r) {
            // straggler from an already-ended request: ignore, do not
            // resurrect released state
            return Ok(None);
        }
        let st = self.devices.entry(device).or_default();
        if st.req_id != req_id {
            // new request from this device: drop stale state
            *st = DeviceState { req_id, ..Default::default() };
        }
        if st.prompt_len.is_none() && prompt_len > 0 {
            st.prompt_len = Some(prompt_len);
        }
        st.bytes_received += (payload_len * 4) as u64;
        Ok(Some(st))
    }

    /// Insert one position, deduplicating retransmissions.  The payload
    /// closure is only invoked for fresh positions, so the owned fast
    /// path never copies and duplicates never allocate.
    fn insert_position(st: &mut DeviceState, pos: u32, payload: impl FnOnce() -> Vec<f32>) {
        if pos < st.consumed_upto || st.pending.contains_key(&pos) {
            st.duplicates_dropped += 1;
            return;
        }
        let v = payload();
        st.pending_floats += v.len();
        st.pending.insert(pos, v);
    }

    /// Build the work plan to answer an inference request at `pos`.
    ///
    /// Errors if required positions have not been uploaded (protocol
    /// violation: with parallel upload the edge always uploads at
    /// `l_ee1` *before* it can know it needs the cloud).
    pub fn plan(&mut self, device: u64, req_id: u32, pos: u32, prompt_len: u32) -> Result<WorkPlan> {
        self.plan_capped(device, req_id, pos, prompt_len, usize::MAX)
    }

    /// [`Self::plan`] with a fairness cap: consume at most `max_decode`
    /// catch-up positions.  A capped plan's [`WorkPlan::frontier`] stops
    /// short of `pos + 1`; the scheduler keeps the request parked and
    /// continues from the frontier in its next pass.
    pub fn plan_capped(
        &mut self,
        device: u64,
        req_id: u32,
        pos: u32,
        prompt_len: u32,
        max_decode: usize,
    ) -> Result<WorkPlan> {
        let d = self.d_model;
        let st = self
            .devices
            .get_mut(&device)
            .ok_or_else(|| anyhow::anyhow!("no uploads from device {device}"))?;
        ensure!(st.req_id == req_id, "request id mismatch: {} vs {}", st.req_id, req_id);
        let plen = st.prompt_len.unwrap_or(prompt_len).max(prompt_len);
        ensure!(plen > 0, "unknown prompt length");

        let mut prefill = None;
        if st.consumed_upto == 0 {
            // prompt positions 0..plen must all be pending
            let mut h = Vec::with_capacity(plen as usize * d);
            for p in 0..plen {
                let v = st
                    .pending
                    .remove(&p)
                    .ok_or_else(|| anyhow::anyhow!("missing prompt hidden at pos {p}"))?;
                st.pending_floats -= v.len();
                h.extend_from_slice(&v);
            }
            st.consumed_upto = plen;
            prefill = Some((h, plen as usize));
        }

        let mut decode = Vec::new();
        while st.consumed_upto <= pos && decode.len() < max_decode {
            let p = st.consumed_upto;
            let v = st
                .pending
                .remove(&p)
                .ok_or_else(|| anyhow::anyhow!("missing hidden at pos {p} (requested {pos})"))?;
            st.pending_floats -= v.len();
            decode.push((p, v));
            st.consumed_upto += 1;
        }
        Ok(WorkPlan { prefill, decode, frontier: st.consumed_upto })
    }

    /// Build capped work plans for several (device, request) heads in one
    /// call — the shape the scheduler's cross-device pass consumes.
    /// Results are index-aligned with `reqs`.
    pub fn plan_batch(
        &mut self,
        reqs: &[PlanReq],
        max_decode_per_device: usize,
    ) -> Vec<Result<WorkPlan>> {
        reqs.iter()
            .map(|r| self.plan_capped(r.device, r.req_id, r.pos, r.prompt_len, max_decode_per_device))
            .collect()
    }

    /// Classify an inference request at `pos` against the current upload
    /// state.  [`Coverage::Ready`] guarantees the matching [`Self::plan`]
    /// call finds every hidden state it needs; this is the pure check the
    /// scheduler uses to park or wake requests without consuming anything.
    pub fn coverage(&self, device: u64, req_id: u32, pos: u32, prompt_len: u32) -> Coverage {
        if self.ended.get(&device).is_some_and(|&r| req_id <= r) {
            return Coverage::Stale;
        }
        let Some(st) = self.devices.get(&device) else {
            // no uploads from this device yet — they are on the wire
            return Coverage::Waiting;
        };
        if st.req_id != req_id {
            // the manager keeps exactly one request per device; a smaller
            // id means the device has already moved on to a newer request
            return if req_id < st.req_id { Coverage::Stale } else { Coverage::Waiting };
        }
        let plen = st.prompt_len.unwrap_or(prompt_len).max(prompt_len);
        if plen == 0 {
            return Coverage::Waiting;
        }
        // the plan consumes the full prompt first (when not yet prefilled),
        // then every position up to and including `pos`
        let mut need = pos + 1;
        if st.consumed_upto == 0 {
            need = need.max(plen);
        }
        if st.watermark() >= need {
            Coverage::Ready
        } else {
            Coverage::Waiting
        }
    }

    /// Contiguous upload coverage frontier for the device's current
    /// request (0 for unknown devices).
    pub fn watermark(&self, device: u64) -> u32 {
        self.devices.get(&device).map(DeviceState::watermark).unwrap_or(0)
    }

    /// Release state for a finished request (§4.4 step 6) and tombstone
    /// its id so straggling uploads cannot resurrect it.  State belonging
    /// to a *newer* request (whose uploads raced ahead of this
    /// `EndSession` on the other connection) is left untouched.
    pub fn end_request(&mut self, device: u64, req_id: u32) {
        let t = self.ended.entry(device).or_insert(req_id);
        *t = (*t).max(req_id);
        if self.devices.get(&device).is_some_and(|st| st.req_id <= req_id) {
            self.devices.remove(&device);
        }
    }

    /// Release everything buffered for a device unconditionally (local
    /// harness teardown; the serving path uses [`Self::end_request`]).
    pub fn end_session(&mut self, device: u64) {
        self.devices.remove(&device);
    }

    /// Drop a device's buffered state *without* tombstoning its request:
    /// the context-store eviction path.  The request is still live on the
    /// edge — a replayed upload with the same request id must be accepted
    /// and rebuild the state (which `end_request`'s tombstone would
    /// block).  Returns the request id the dropped state belonged to, so
    /// the store can tell a genuine replay from a new request's uploads.
    pub fn evict_device(&mut self, device: u64) -> Option<u32> {
        self.devices.remove(&device).map(|st| st.req_id)
    }

    /// Forget a device entirely, including its end-request tombstones.
    /// Used when the device opens a fresh upload channel: a reconnecting
    /// edge process restarts its request ids from 1, so tombstones from
    /// its previous session must not outlive the connection.
    pub fn reset_device(&mut self, device: u64) {
        self.devices.remove(&device);
        self.ended.remove(&device);
    }

    pub fn device_count(&self) -> usize {
        self.devices.len()
    }

    /// Whether the manager holds any state for `device` (tombstones do
    /// not count — they are metadata, not resident bytes).
    pub fn has_device(&self, device: u64) -> bool {
        self.devices.contains_key(&device)
    }

    /// Request id of the state currently held for `device`, if any.
    pub fn current_req(&self, device: u64) -> Option<u32> {
        self.devices.get(&device).map(|s| s.req_id)
    }

    /// Devices with resident state, for the context store's metering
    /// sweep.
    pub fn device_ids(&self) -> Vec<u64> {
        self.devices.keys().copied().collect()
    }

    /// Resident hidden-state floats (for the resource-release invariant).
    pub fn pending_floats(&self) -> usize {
        self.devices.values().map(|s| s.pending_floats).sum()
    }

    /// Resident hidden-state floats buffered for one device (O(1): the
    /// context store meters every upload and plan against this).
    pub fn pending_floats_of(&self, device: u64) -> usize {
        self.devices.get(&device).map(|s| s.pending_floats).unwrap_or(0)
    }

    /// Positions of `device`'s current request already folded into the
    /// engine KV cache — what a resident session's KV footprint scales
    /// with (0 for unknown devices).
    pub fn consumed_upto(&self, device: u64) -> u32 {
        self.devices.get(&device).map(|s| s.consumed_upto).unwrap_or(0)
    }

    pub fn duplicates_dropped(&self, device: u64) -> u64 {
        self.devices.get(&device).map(|s| s.duplicates_dropped).unwrap_or(0)
    }

    pub fn bytes_received(&self, device: u64) -> u64 {
        self.devices.get(&device).map(|s| s.bytes_received).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const D: usize = 4;

    fn h(pos: u32) -> Vec<f32> {
        vec![pos as f32; D]
    }

    fn cm() -> ContentManager {
        ContentManager::new(D)
    }

    #[test]
    fn prompt_then_decode_plan() {
        let mut m = cm();
        // prompt of 3 positions uploaded as one batch
        let prompt: Vec<f32> = (0..3).flat_map(h).collect();
        m.upload(1, 0, 0, 3, &prompt).unwrap();
        // decode uploads for positions 3 and 4
        m.upload(1, 0, 3, 3, &h(3)).unwrap();
        m.upload(1, 0, 4, 3, &h(4)).unwrap();

        let plan = m.plan(1, 0, 4, 3).unwrap();
        let (pre, len) = plan.prefill.unwrap();
        assert_eq!(len, 3);
        assert_eq!(pre.len(), 3 * D);
        assert_eq!(plan.decode.iter().map(|(p, _)| *p).collect::<Vec<_>>(), vec![3, 4]);
        // consumed state is released
        assert_eq!(m.pending_floats(), 0);
    }

    #[test]
    fn second_request_skips_prefill() {
        let mut m = cm();
        let prompt: Vec<f32> = (0..2).flat_map(h).collect();
        m.upload(1, 0, 0, 2, &prompt).unwrap();
        m.plan(1, 0, 1, 2).unwrap(); // prefill only (pos = plen-1)
        m.upload(1, 0, 2, 2, &h(2)).unwrap();
        let plan = m.plan(1, 0, 2, 2).unwrap();
        assert!(plan.prefill.is_none());
        assert_eq!(plan.decode.len(), 1);
    }

    #[test]
    fn missing_position_is_an_error() {
        let mut m = cm();
        m.upload(1, 0, 0, 2, &[0.0; 2 * D]).unwrap();
        // position 2 never uploaded
        assert!(m.plan(1, 0, 2, 2).is_err());
    }

    #[test]
    fn duplicates_are_dropped_not_duplicated() {
        let mut m = cm();
        let prompt: Vec<f32> = (0..2).flat_map(h).collect();
        m.upload(1, 0, 0, 2, &prompt).unwrap();
        m.upload(1, 0, 0, 2, &prompt).unwrap(); // retransmit (no-CM edge)
        assert_eq!(m.duplicates_dropped(1), 2);
        let plan = m.plan(1, 0, 1, 2).unwrap();
        assert_eq!(plan.prefill.unwrap().1, 2);
        assert_eq!(m.pending_floats(), 0);
    }

    #[test]
    fn retransmit_after_consumption_is_dropped() {
        let mut m = cm();
        m.upload(1, 0, 0, 2, &[0.0; 2 * D]).unwrap();
        m.plan(1, 0, 1, 2).unwrap();
        m.upload(1, 0, 0, 2, &[0.0; 2 * D]).unwrap();
        assert_eq!(m.duplicates_dropped(1), 2);
        assert_eq!(m.pending_floats(), 0);
    }

    #[test]
    fn devices_are_isolated() {
        let mut m = cm();
        m.upload(1, 0, 0, 1, &h(0)).unwrap();
        m.upload(2, 0, 0, 1, &[9.0; D]).unwrap();
        let p1 = m.plan(1, 0, 0, 1).unwrap();
        assert_eq!(p1.prefill.unwrap().0, h(0));
        let p2 = m.plan(2, 0, 0, 1).unwrap();
        assert_eq!(p2.prefill.unwrap().0, vec![9.0; D]);
        assert_eq!(m.device_count(), 2);
    }

    #[test]
    fn new_request_id_resets_device_state() {
        let mut m = cm();
        m.upload(1, 0, 0, 1, &h(0)).unwrap();
        m.upload(1, 1, 0, 1, &h(0)).unwrap(); // new request
        // old request's plan must fail (state belongs to req 1 now)
        assert!(m.plan(1, 0, 0, 1).is_err());
        assert!(m.plan(1, 1, 0, 1).is_ok());
    }

    #[test]
    fn end_session_releases_everything() {
        let mut m = cm();
        m.upload(1, 0, 0, 2, &[0.0; 2 * D]).unwrap();
        m.end_session(1);
        assert_eq!(m.device_count(), 0);
        assert_eq!(m.pending_floats(), 0);
        assert!(m.plan(1, 0, 0, 2).is_err());
    }

    #[test]
    fn ragged_payload_rejected() {
        let mut m = cm();
        assert!(m.upload(1, 0, 0, 1, &[0.0; D + 1]).is_err());
    }

    #[test]
    fn ended_request_tombstone_blocks_stragglers() {
        let mut m = cm();
        m.upload(1, 1, 0, 2, &[0.0; 2 * D]).unwrap();
        m.end_request(1, 1);
        assert_eq!(m.device_count(), 0);
        // a straggling upload for the ended request is ignored
        m.upload(1, 1, 0, 2, &[0.0; 2 * D]).unwrap();
        assert_eq!(m.device_count(), 0);
        assert_eq!(m.pending_floats(), 0);
        assert_eq!(m.coverage(1, 1, 1, 2), Coverage::Stale);
        // the next request is unaffected
        m.upload(1, 2, 0, 2, &[0.0; 2 * D]).unwrap();
        assert_eq!(m.coverage(1, 2, 1, 2), Coverage::Ready);
    }

    #[test]
    fn evicted_device_accepts_a_replay_of_the_same_request() {
        let mut m = cm();
        let prompt: Vec<f32> = (0..2).flat_map(h).collect();
        m.upload(1, 3, 0, 2, &prompt).unwrap();
        m.upload(1, 3, 2, 2, &h(2)).unwrap();
        m.plan(1, 3, 2, 2).unwrap(); // positions 0..=2 consumed
        assert_eq!(m.consumed_upto(1), 3);
        // eviction drops the state but leaves NO tombstone
        assert_eq!(m.evict_device(1), Some(3));
        assert!(!m.has_device(1));
        assert_eq!(m.consumed_upto(1), 0);
        // the edge replays the SAME request from position 0: accepted,
        // and the rebuilt plan re-prefills from scratch
        let replay: Vec<f32> = (0..3).flat_map(h).collect();
        m.upload(1, 3, 0, 2, &replay).unwrap();
        assert_eq!(m.coverage(1, 3, 2, 2), Coverage::Ready);
        let plan = m.plan(1, 3, 2, 2).unwrap();
        assert_eq!(plan.prefill.as_ref().unwrap().1, 2);
        assert_eq!(plan.decode.len(), 1);
    }

    #[test]
    fn per_device_metering_accessors() {
        let mut m = cm();
        m.upload(1, 0, 0, 2, &[0.0; 2 * D]).unwrap();
        m.upload(2, 0, 0, 1, &h(0)).unwrap();
        assert_eq!(m.pending_floats_of(1), 2 * D);
        assert_eq!(m.pending_floats_of(2), D);
        assert_eq!(m.pending_floats_of(9), 0);
        let mut ids = m.device_ids();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2]);
        m.plan(1, 0, 1, 2).unwrap();
        assert_eq!(m.pending_floats_of(1), 0);
        assert_eq!(m.consumed_upto(1), 2);
        // the O(1) counter tracks the map exactly, duplicates included
        m.upload(2, 0, 0, 1, &h(0)).unwrap(); // dropped duplicate
        m.upload(2, 0, 1, 1, &h(1)).unwrap();
        let by_map: usize =
            m.devices.get(&2).unwrap().pending.values().map(Vec::len).sum();
        assert_eq!(m.pending_floats_of(2), by_map);
    }

    #[test]
    fn reset_device_clears_tombstones_for_a_reconnecting_client() {
        let mut m = cm();
        m.upload(1, 1, 0, 1, &h(0)).unwrap();
        m.end_request(1, 1);
        // a fresh client process reuses device 1 and restarts at req 1
        m.reset_device(1);
        m.upload(1, 1, 0, 1, &h(0)).unwrap();
        assert_eq!(m.coverage(1, 1, 0, 1), Coverage::Ready);
        assert!(m.plan(1, 1, 0, 1).is_ok());
    }

    #[test]
    fn end_request_spares_a_newer_requests_state() {
        let mut m = cm();
        // request 2's uploads raced ahead of request 1's EndSession
        m.upload(1, 2, 0, 2, &[0.0; 2 * D]).unwrap();
        m.end_request(1, 1);
        assert_eq!(m.device_count(), 1, "request 2 state must survive");
        assert_eq!(m.coverage(1, 2, 1, 2), Coverage::Ready);
        assert!(m.plan(1, 2, 1, 2).is_ok());
    }

    #[test]
    fn upload_owned_matches_borrowed_semantics() {
        let mut borrowed = cm();
        let mut owned = cm();
        let prompt: Vec<f32> = (0..2).flat_map(h).collect();
        borrowed.upload(1, 0, 0, 2, &prompt).unwrap();
        owned.upload_owned(1, 0, 0, 2, prompt).unwrap();
        for p in 2..5u32 {
            borrowed.upload(1, 0, p, 2, &h(p)).unwrap();
            owned.upload_owned(1, 0, p, 2, h(p)).unwrap();
            // duplicate per-token upload is dropped on both paths
            borrowed.upload(1, 0, p, 2, &h(p)).unwrap();
            owned.upload_owned(1, 0, p, 2, h(p)).unwrap();
        }
        assert_eq!(borrowed.duplicates_dropped(1), owned.duplicates_dropped(1));
        assert_eq!(borrowed.bytes_received(1), owned.bytes_received(1));
        let a = borrowed.plan(1, 0, 4, 2).unwrap();
        let b = owned.plan(1, 0, 4, 2).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn capped_plan_stops_at_the_bound_and_resumes() {
        let mut m = cm();
        let prompt: Vec<f32> = (0..2).flat_map(h).collect();
        m.upload(1, 0, 0, 2, &prompt).unwrap();
        for p in 2..10u32 {
            m.upload(1, 0, p, 2, &h(p)).unwrap();
        }
        // request at pos 9 with a cap of 3: prefill plus three positions
        let plan = m.plan_capped(1, 0, 9, 2, 3).unwrap();
        assert!(plan.prefill.is_some());
        assert_eq!(plan.decode.iter().map(|(p, _)| *p).collect::<Vec<_>>(), vec![2, 3, 4]);
        assert_eq!(plan.frontier, 5, "frontier short of the requested pos");
        // the request is still serviceable; the next pass continues
        assert_eq!(m.coverage(1, 0, 9, 2), Coverage::Ready);
        let plan = m.plan_capped(1, 0, 9, 2, 3).unwrap();
        assert!(plan.prefill.is_none());
        assert_eq!(plan.decode.iter().map(|(p, _)| *p).collect::<Vec<_>>(), vec![5, 6, 7]);
        let plan = m.plan_capped(1, 0, 9, 2, 3).unwrap();
        assert_eq!(plan.decode.iter().map(|(p, _)| *p).collect::<Vec<_>>(), vec![8, 9]);
        assert_eq!(plan.frontier, 10, "request position reached");
        assert_eq!(m.pending_floats(), 0);
    }

    #[test]
    fn plan_batch_plans_every_device_in_one_sweep() {
        let mut m = cm();
        for dev in 1..=3u64 {
            let prompt: Vec<f32> = (0..2).flat_map(h).collect();
            m.upload(dev, 0, 0, 2, &prompt).unwrap();
            m.upload(dev, 0, 2, 2, &h(2)).unwrap();
        }
        let reqs: Vec<PlanReq> = (1..=3u64)
            .map(|device| PlanReq { device, req_id: 0, pos: 2, prompt_len: 2 })
            .collect();
        let plans = m.plan_batch(&reqs, usize::MAX);
        assert_eq!(plans.len(), 3);
        for plan in &plans {
            let plan = plan.as_ref().unwrap();
            assert!(plan.prefill.is_some());
            assert_eq!(plan.decode.len(), 1);
            assert_eq!(plan.frontier, 3);
        }
        assert_eq!(m.pending_floats(), 0, "every device's state consumed");
    }

    #[test]
    fn coverage_tracks_contiguous_uploads() {
        let mut m = cm();
        // nothing uploaded yet: wait
        assert_eq!(m.coverage(1, 0, 2, 3), Coverage::Waiting);
        assert_eq!(m.watermark(1), 0);
        let prompt: Vec<f32> = (0..3).flat_map(h).collect();
        m.upload(1, 0, 0, 3, &prompt).unwrap();
        assert_eq!(m.watermark(1), 3);
        // request at the last prompt position is now serviceable
        assert_eq!(m.coverage(1, 0, 2, 3), Coverage::Ready);
        // ... but a decode position past the watermark is not
        assert_eq!(m.coverage(1, 0, 3, 3), Coverage::Waiting);
        m.upload(1, 0, 3, 3, &h(3)).unwrap();
        assert_eq!(m.coverage(1, 0, 3, 3), Coverage::Ready);
        // Ready implies plan succeeds
        assert!(m.plan(1, 0, 3, 3).is_ok());
    }

    #[test]
    fn coverage_requires_gap_free_prompt() {
        let mut m = cm();
        m.upload(1, 0, 0, 3, &h(0)).unwrap();
        m.upload(1, 0, 2, 3, &h(2)).unwrap(); // gap at position 1
        assert_eq!(m.watermark(1), 1);
        assert_eq!(m.coverage(1, 0, 2, 3), Coverage::Waiting);
        m.upload(1, 0, 1, 3, &h(1)).unwrap();
        assert_eq!(m.watermark(1), 3);
        assert_eq!(m.coverage(1, 0, 2, 3), Coverage::Ready);
    }

    #[test]
    fn coverage_request_id_transitions() {
        let mut m = cm();
        m.upload(1, 4, 0, 1, &h(0)).unwrap();
        // older request: superseded, can never complete
        assert_eq!(m.coverage(1, 3, 0, 1), Coverage::Stale);
        // newer request: its uploads have not arrived yet
        assert_eq!(m.coverage(1, 5, 0, 1), Coverage::Waiting);
        assert_eq!(m.coverage(1, 4, 0, 1), Coverage::Ready);
    }

    #[test]
    fn coverage_after_consumption_stays_ready() {
        let mut m = cm();
        m.upload(1, 0, 0, 2, &[0.0; 2 * D]).unwrap();
        m.plan(1, 0, 1, 2).unwrap();
        // an already-served position stays Ready (plan then reports
        // "nothing to compute" — the scheduler surfaces that error)
        assert_eq!(m.coverage(1, 0, 1, 2), Coverage::Ready);
        assert_eq!(m.coverage(1, 0, 2, 2), Coverage::Waiting);
    }
}
