//! Engine interfaces the coordinator is written against.
//!
//! Real implementations ([`super::engines`]) execute PJRT artifacts;
//! [`super::mock`] provides scripted engines so every coordinator policy
//! and protocol path is testable without artifacts.  Neither is `Send`
//! (PJRT handles are `Rc`-based); engines are owned by their thread.

use anyhow::Result;

use crate::model::manifest::ModelDims;

/// Result of evaluating one exit head (paper §4.4 step 2): the argmax
/// token, its confidence (max softmax probability, produced by the fused
/// Pallas kernel), and the full logits for optional resampling.
#[derive(Debug, Clone)]
pub struct ExitEval {
    pub token: i32,
    pub conf: f32,
    pub logits: Vec<f32>,
}

/// Edge prefill output: exit evaluations at the last prompt position plus
/// the exit-1 hidden states for the whole prompt (the upload payload).
#[derive(Debug, Clone)]
pub struct EdgePrefillOut {
    /// `[len * d_model]` hidden states at `l_ee1`, valid positions only.
    pub h1: Vec<f32>,
    pub exit1: ExitEval,
    pub exit2: ExitEval,
}

/// Edge segment-1 decode output (layers `0..l_ee1` + exit head 1).
#[derive(Debug, Clone)]
pub struct Seg1Out {
    /// `[d_model]` hidden state at `l_ee1` — uploaded to the cloud.
    pub h1: Vec<f32>,
    pub exit1: ExitEval,
}

/// Edge segment-2 decode output (layers `l_ee1..l_ee2` + exit head 2).
#[derive(Debug, Clone)]
pub struct Seg2Out {
    pub exit2: ExitEval,
}

/// Cloud partition output (layers `l_ee1..n_layers` + final head).
#[derive(Debug, Clone)]
pub struct CloudOut {
    pub exit: ExitEval,
}

/// One lane of a batched cloud-decode pass: the uploaded `[d_model]`
/// hidden state for `pos`.  A run of items within one session must be
/// position-contiguous (each step extends the KV cache the next one
/// reads); across sessions lanes are independent and the scheduler pads
/// every session's run to the widest one in the pass.
#[derive(Debug, Clone)]
pub struct BatchItem {
    pub h1: Vec<f32>,
    pub pos: usize,
}

/// The edge device's model partition (paper §4.1).
pub trait EdgeEngine {
    fn dims(&self) -> &ModelDims;

    /// Process a full prompt (already tokenized, `BOS`-prefixed,
    /// unpadded).  Fills the edge KV caches.
    fn prefill(&mut self, prompt: &[i32]) -> Result<EdgePrefillOut>;

    /// Layers `0..l_ee1` for one token at `pos`; evaluates exit 1.
    fn seg1(&mut self, token: i32, pos: usize) -> Result<Seg1Out>;

    /// Layers `l_ee1..l_ee2` from the exit-1 hidden; evaluates exit 2.
    fn seg2(&mut self, h1: &[f32], pos: usize) -> Result<Seg2Out>;

    /// Clear KV state for a new request (paper §4.4 step 6).
    fn reset(&mut self);
}

/// The cloud's model partition (paper §4.2), one session per edge device.
pub trait CloudEngine {
    fn dims(&self) -> &ModelDims;

    /// Build the cloud KV caches from uploaded prompt hidden states
    /// (`[len * d_model]`) and return the final-head evaluation at the
    /// last prompt position.
    fn prefill(&mut self, h1: &[f32], len: usize) -> Result<CloudOut>;

    /// One decode step from an uploaded `[d_model]` hidden at `pos`.
    fn decode(&mut self, h1: &[f32], pos: usize) -> Result<CloudOut>;

    /// Decode a position-contiguous run of catch-up items in one engine
    /// pass, returning one output per item in order.
    ///
    /// The default implementation is the per-position [`Self::decode`]
    /// loop, so every engine is correct by construction.  Batch-aware
    /// engines override it with a fused pass (one program execution over
    /// the padded run) and MUST return outputs bit-identical to the
    /// sequential loop — the scheduler relies on that equivalence when it
    /// merges many devices' runs into one cross-device pass.
    fn decode_batch(&mut self, items: &[BatchItem]) -> Result<Vec<CloudOut>> {
        items.iter().map(|b| self.decode(&b.h1, b.pos)).collect()
    }

    /// Fused passes executed by [`Self::decode_batch`] overrides (0 for
    /// engines using the sequential default) — observability for tests
    /// and stats, not a correctness contract.
    fn batch_passes(&self) -> u64 {
        0
    }

    /// Whether `prefill` has been run for the current session.
    fn is_prefilled(&self) -> bool;

    fn reset(&mut self);
}
