//! XLA/PJRT binding shim.
//!
//! With the `pjrt` feature enabled this module re-exports the real `xla`
//! bindings (add the crate to `[dependencies]`; see `Cargo.toml`).  The
//! default build ships this compile-complete stub instead so the whole
//! crate — coordinator, harnesses, mock engines, benches — builds and
//! tests in environments without the XLA extension library.
//!
//! Stub semantics: [`Literal`] is a real host-side container (the
//! `runtime::literal` helpers and their tests work against it);
//! client/executable/buffer types are uninhabited — [`PjRtClient::cpu`]
//! returns an error, so no code path can ever reach their methods.

#[cfg(feature = "pjrt")]
pub use ::xla::*;

#[cfg(not(feature = "pjrt"))]
pub use stub::*;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::fmt;

    /// Error type mirroring `xla::Error` closely enough for the crate's
    /// `map_err(|e| anyhow!("{e:?}"))` and `?` conversions.
    #[derive(Debug)]
    pub struct Error(pub String);

    impl fmt::Display for Error {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "{}", self.0)
        }
    }

    impl std::error::Error for Error {}

    fn unsupported() -> Error {
        Error("built without the `pjrt` feature — real PJRT execution unavailable".into())
    }

    #[derive(Clone, Debug)]
    enum Never {}

    /// Typed storage for the stub [`Literal`].
    #[doc(hidden)]
    #[derive(Debug, Clone, PartialEq)]
    pub enum Data {
        F32(Vec<f32>),
        I32(Vec<i32>),
    }

    /// Element types the stub literal can hold.
    pub trait NativeType: Copy {
        #[doc(hidden)]
        fn wrap(v: Vec<Self>) -> Data
        where
            Self: Sized;
        #[doc(hidden)]
        fn slice(d: &Data) -> Option<&[Self]>
        where
            Self: Sized;
    }

    impl NativeType for f32 {
        fn wrap(v: Vec<Self>) -> Data {
            Data::F32(v)
        }
        fn slice(d: &Data) -> Option<&[Self]> {
            match d {
                Data::F32(v) => Some(v),
                _ => None,
            }
        }
    }

    impl NativeType for i32 {
        fn wrap(v: Vec<Self>) -> Data {
            Data::I32(v)
        }
        fn slice(d: &Data) -> Option<&[Self]> {
            match d {
                Data::I32(v) => Some(v),
                _ => None,
            }
        }
    }

    /// Host-side literal: shape + typed buffer.  Fully functional (the
    /// `runtime::literal` helpers and tests run against it).
    #[derive(Debug, Clone, PartialEq)]
    pub struct Literal {
        data: Data,
        dims: Vec<i64>,
    }

    impl Literal {
        pub fn scalar<T: NativeType>(v: T) -> Literal {
            Literal { data: T::wrap(vec![v]), dims: vec![] }
        }

        pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
            Literal { data: T::wrap(v.to_vec()), dims: vec![v.len() as i64] }
        }

        pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
            let n: i64 = dims.iter().product();
            if n as usize != self.element_count() {
                return Err(Error(format!(
                    "reshape {:?} -> {:?}: element count mismatch",
                    self.dims, dims
                )));
            }
            Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
        }

        pub fn element_count(&self) -> usize {
            match &self.data {
                Data::F32(v) => v.len(),
                Data::I32(v) => v.len(),
            }
        }

        pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
            T::slice(&self.data)
                .map(<[T]>::to_vec)
                .ok_or_else(|| Error("literal element type mismatch".into()))
        }

        pub fn get_first_element<T: NativeType>(&self) -> Result<T, Error> {
            T::slice(&self.data)
                .and_then(|s| s.first().copied())
                .ok_or_else(|| Error("empty literal or element type mismatch".into()))
        }

        pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>, Error> {
            Err(Error("stub literal is never a tuple".into()))
        }
    }

    /// Uninhabited: [`PjRtClient::cpu`] always errors in the stub build.
    #[derive(Clone)]
    pub struct PjRtClient(Never);

    impl PjRtClient {
        pub fn cpu() -> Result<PjRtClient, Error> {
            Err(unsupported())
        }

        pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
            match self.0 {}
        }

        pub fn buffer_from_host_buffer<T: NativeType>(
            &self,
            _data: &[T],
            _dims: &[usize],
            _device: Option<usize>,
        ) -> Result<PjRtBuffer, Error> {
            match self.0 {}
        }

        pub fn buffer_from_host_literal(
            &self,
            _device: Option<usize>,
            _lit: &Literal,
        ) -> Result<PjRtBuffer, Error> {
            match self.0 {}
        }
    }

    pub struct PjRtBuffer(Never);

    impl PjRtBuffer {
        pub fn to_literal_sync(&self) -> Result<Literal, Error> {
            match self.0 {}
        }
    }

    pub struct PjRtLoadedExecutable(Never);

    impl PjRtLoadedExecutable {
        pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
            match self.0 {}
        }
    }

    pub struct HloModuleProto(Never);

    impl HloModuleProto {
        pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
            Err(unsupported())
        }
    }

    pub struct XlaComputation(Never);

    impl XlaComputation {
        pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
            match proto.0 {}
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn stub_client_reports_missing_feature() {
            let err = PjRtClient::cpu().err().expect("stub cpu() must fail");
            assert!(format!("{err}").contains("pjrt"));
        }

        #[test]
        fn stub_literal_is_functional() {
            let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
            let r = lit.reshape(&[2, 2]).unwrap();
            assert_eq!(r.element_count(), 4);
            assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
            assert!(r.to_vec::<i32>().is_err());
            assert!(lit.reshape(&[3, 2]).is_err());
        }
    }
}
