//! PJRT runtime: loads the AOT HLO artifacts and executes them on the CPU
//! PJRT client.  Python never runs here — the artifacts are self-contained.
//!
//! Thread model: the `xla` crate's `PjRtClient` is `Rc`-based (`!Send`),
//! so every PJRT object lives on the thread that created it.  The
//! coordinator talks to engines through the [`traits`] interfaces; the
//! cloud server hosts its engine on a dedicated "GPU thread" actor
//! ([`crate::coordinator::cloud`]), which also gives the paper's
//! single-GPU FIFO semantics for free.

pub mod artifact;
pub mod engines;
pub mod literal;
pub mod mock;
pub mod stack;
pub mod traits;

pub use artifact::{Artifact, Outputs};
pub use stack::LocalStack;
pub use traits::{CloudEngine, CloudOut, EdgeEngine, EdgePrefillOut, ExitEval, Seg1Out, Seg2Out};
