//! PJRT runtime: loads the AOT HLO artifacts and executes them on the CPU
//! PJRT client.  Python never runs here — the artifacts are self-contained.
//!
//! Thread model: the `xla` crate's `PjRtClient` is `Rc`-based (`!Send`),
//! so every PJRT object lives on the thread that created it.  The
//! coordinator talks to engines through the [`traits`] interfaces; the
//! cloud side hosts engines on scheduler worker threads
//! ([`crate::coordinator::scheduler`]), each of which builds its own
//! sessions via a factory invoked on that thread — with `workers = 1`
//! this reproduces the paper's single-GPU FIFO semantics.
//!
//! The `pjrt` cargo feature selects the real `xla` bindings; the default
//! build uses the compile-complete stub in [`xla`](self::xla).

pub mod artifact;
pub mod engines;
pub mod literal;
pub mod mock;
pub mod stack;
pub mod traits;
pub mod xla;

pub use artifact::{Artifact, Outputs};
pub use stack::LocalStack;
pub use traits::{
    BatchItem, CloudEngine, CloudOut, EdgeEngine, EdgePrefillOut, ExitEval, Seg1Out, Seg2Out,
};
