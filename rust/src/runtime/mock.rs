//! Deterministic scripted engines for coordinator/protocol tests.
//!
//! A [`MockOracle`] derives per-position confidences and tokens from a
//! seed via splitmix64, with the structural properties the real model
//! has: exit-2 confidence is (usually) higher than exit-1, and exit
//! tokens agree with the cloud token exactly when their confidence is
//! high (so threshold sweeps change outputs the way the paper describes).

use anyhow::Result;

use crate::model::manifest::ModelDims;
use crate::runtime::traits::{
    BatchItem, CloudEngine, CloudOut, EdgeEngine, EdgePrefillOut, ExitEval, Seg1Out, Seg2Out,
};

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

fn unit(x: u64) -> f32 {
    (x >> 11) as f32 / (1u64 << 53) as f32
}

/// Deterministic pseudo-model shared by a mock edge/cloud pair.
#[derive(Debug, Clone, Copy)]
pub struct MockOracle {
    pub seed: u64,
    /// EOS emitted by the *cloud/final* head at this generated position.
    pub eos_at: Option<usize>,
    pub eos_id: i32,
}

impl MockOracle {
    pub fn new(seed: u64) -> Self {
        Self { seed, eos_at: None, eos_id: 257 }
    }

    pub fn conf1(&self, pos: usize) -> f32 {
        unit(splitmix64(self.seed ^ (pos as u64) << 1))
    }

    pub fn conf2(&self, pos: usize) -> f32 {
        // exit 2 sees more layers: confidence no lower than exit 1 (usually)
        let c1 = self.conf1(pos);
        let bump = unit(splitmix64(self.seed ^ 0xABCD ^ (pos as u64) << 3));
        (c1 + 0.3 * bump).min(0.999)
    }

    pub fn cloud_token(&self, pos: usize) -> i32 {
        if self.eos_at == Some(pos) {
            return self.eos_id;
        }
        97 + (splitmix64(self.seed ^ 0x77 ^ pos as u64) % 26) as i32
    }

    /// Exit tokens agree with the final token iff confidence ≥ 0.5 —
    /// mirrors the paper's Table 1 (high-confidence predictions are
    /// consistent across exits).
    pub fn exit_token(&self, pos: usize, conf: f32) -> i32 {
        if conf >= 0.5 {
            self.cloud_token(pos)
        } else {
            97 + (splitmix64(self.seed ^ 0x1111 ^ pos as u64) % 26) as i32
        }
    }

    fn h1(&self, pos: usize) -> Vec<f32> {
        vec![pos as f32; 128]
    }
}

pub struct MockEdge {
    pub oracle: MockOracle,
    dims: ModelDims,
    pub prefilled: bool,
    pub seg1_calls: usize,
    pub seg2_calls: usize,
}

impl MockEdge {
    pub fn new(oracle: MockOracle, dims: ModelDims) -> Self {
        Self { oracle, dims, prefilled: false, seg1_calls: 0, seg2_calls: 0 }
    }
}

fn eval(token: i32, conf: f32) -> ExitEval {
    // logits consistent with argmax=token: one-hot-ish vector
    let mut logits = vec![0f32; 384];
    logits[token.clamp(0, 383) as usize] = 10.0;
    ExitEval { token, conf, logits }
}

impl EdgeEngine for MockEdge {
    fn dims(&self) -> &ModelDims {
        &self.dims
    }

    fn prefill(&mut self, prompt: &[i32]) -> Result<EdgePrefillOut> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        self.prefilled = true;
        let pos = prompt.len() - 1;
        let (c1, c2) = (self.oracle.conf1(pos), self.oracle.conf2(pos));
        Ok(EdgePrefillOut {
            h1: (0..prompt.len()).flat_map(|p| self.oracle.h1(p)).collect(),
            exit1: eval(self.oracle.exit_token(pos, c1), c1),
            exit2: eval(self.oracle.exit_token(pos, c2), c2),
        })
    }

    fn seg1(&mut self, _token: i32, pos: usize) -> Result<Seg1Out> {
        anyhow::ensure!(self.prefilled, "seg1 before prefill");
        self.seg1_calls += 1;
        let c1 = self.oracle.conf1(pos);
        Ok(Seg1Out { h1: self.oracle.h1(pos), exit1: eval(self.oracle.exit_token(pos, c1), c1) })
    }

    fn seg2(&mut self, _h1: &[f32], pos: usize) -> Result<Seg2Out> {
        anyhow::ensure!(self.prefilled, "seg2 before prefill");
        self.seg2_calls += 1;
        let c2 = self.oracle.conf2(pos);
        Ok(Seg2Out { exit2: eval(self.oracle.exit_token(pos, c2), c2) })
    }

    fn reset(&mut self) {
        self.prefilled = false;
    }
}

pub struct MockCloud {
    pub oracle: MockOracle,
    dims: ModelDims,
    prefilled: bool,
    pub prefill_calls: usize,
    pub decode_calls: usize,
    /// Fused `decode_batch` passes executed (one per call, any width).
    pub fused_passes: u64,
    /// Items decoded through fused passes.
    pub batched_items: u64,
    /// Positions decoded, for catch-up/content-manager assertions.
    pub decoded_positions: Vec<usize>,
}

impl MockCloud {
    pub fn new(oracle: MockOracle, dims: ModelDims) -> Self {
        Self {
            oracle,
            dims,
            prefilled: false,
            prefill_calls: 0,
            decode_calls: 0,
            fused_passes: 0,
            batched_items: 0,
            decoded_positions: vec![],
        }
    }
}

impl CloudEngine for MockCloud {
    fn dims(&self) -> &ModelDims {
        &self.dims
    }

    fn prefill(&mut self, h1: &[f32], len: usize) -> Result<CloudOut> {
        anyhow::ensure!(h1.len() == len * self.dims.d_model, "h1/len mismatch");
        self.prefilled = true;
        self.prefill_calls += 1;
        let pos = len - 1;
        Ok(CloudOut { exit: eval(self.oracle.cloud_token(pos), 0.95) })
    }

    fn decode(&mut self, h1: &[f32], pos: usize) -> Result<CloudOut> {
        anyhow::ensure!(self.prefilled, "cloud decode before prefill");
        anyhow::ensure!(h1.len() == self.dims.d_model, "h1 wrong length");
        self.decode_calls += 1;
        self.decoded_positions.push(pos);
        Ok(CloudOut { exit: eval(self.oracle.cloud_token(pos), 0.95) })
    }

    /// Fused catch-up pass: validates the whole run up front, then
    /// produces every output in one sweep.  Output values come from the
    /// same oracle as [`Self::decode`], so the batch is bit-identical to
    /// the sequential loop by construction.
    fn decode_batch(&mut self, items: &[BatchItem]) -> Result<Vec<CloudOut>> {
        anyhow::ensure!(self.prefilled, "cloud decode before prefill");
        for b in items {
            anyhow::ensure!(b.h1.len() == self.dims.d_model, "h1 wrong length");
        }
        self.fused_passes += 1;
        self.batched_items += items.len() as u64;
        let mut out = Vec::with_capacity(items.len());
        for b in items {
            self.decoded_positions.push(b.pos);
            out.push(CloudOut { exit: eval(self.oracle.cloud_token(b.pos), 0.95) });
        }
        Ok(out)
    }

    fn batch_passes(&self) -> u64 {
        self.fused_passes
    }

    fn is_prefilled(&self) -> bool {
        self.prefilled
    }

    fn reset(&mut self) {
        self.prefilled = false;
        self.decoded_positions.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::test_manifest;

    #[test]
    fn oracle_deterministic_and_bounded() {
        let o = MockOracle::new(7);
        for pos in 0..100 {
            let c1 = o.conf1(pos);
            assert!((0.0..=1.0).contains(&c1));
            assert!(o.conf2(pos) >= c1 - 1e-6);
            assert_eq!(o.cloud_token(pos), o.cloud_token(pos));
        }
    }

    #[test]
    fn high_conf_exit_tokens_agree_with_cloud() {
        let o = MockOracle::new(3);
        for pos in 0..200 {
            let c = o.conf1(pos);
            if c >= 0.5 {
                assert_eq!(o.exit_token(pos, c), o.cloud_token(pos));
            }
        }
    }

    #[test]
    fn engine_ordering_enforced() {
        let dims = test_manifest().model;
        let o = MockOracle::new(1);
        let mut e = MockEdge::new(o, dims.clone());
        assert!(e.seg1(0, 0).is_err());
        e.prefill(&[256, 97]).unwrap();
        assert!(e.seg1(0, 2).is_ok());

        let mut c = MockCloud::new(o, dims);
        assert!(c.decode(&vec![0.0; 128], 2).is_err());
        c.prefill(&vec![0.0; 2 * 128], 2).unwrap();
        assert!(c.decode(&vec![0.0; 128], 2).is_ok());
    }

    #[test]
    fn fused_decode_batch_matches_sequential_decode() {
        let dims = test_manifest().model;
        let d = dims.d_model;
        let o = MockOracle::new(9);
        let mut fused = MockCloud::new(o, dims.clone());
        let mut seq = MockCloud::new(o, dims);
        fused.prefill(&vec![0.0; 2 * d], 2).unwrap();
        seq.prefill(&vec![0.0; 2 * d], 2).unwrap();

        let items: Vec<BatchItem> =
            (2..7).map(|pos| BatchItem { h1: vec![0.5; d], pos }).collect();
        let a = fused.decode_batch(&items).unwrap();
        let b: Vec<CloudOut> =
            items.iter().map(|it| seq.decode(&it.h1, it.pos).unwrap()).collect();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.exit.token, y.exit.token);
            assert_eq!(x.exit.conf.to_bits(), y.exit.conf.to_bits());
            assert_eq!(x.exit.logits, y.exit.logits);
        }
        assert_eq!(fused.batch_passes(), 1, "one fused pass for the whole run");
        assert_eq!(fused.batched_items, 5);
        assert_eq!(fused.decoded_positions, seq.decoded_positions);
        // the sequential engine never took a fused pass
        assert_eq!(seq.batch_passes(), 0);
    }

    #[test]
    fn eos_scripting() {
        let mut o = MockOracle::new(1);
        o.eos_at = Some(5);
        assert_eq!(o.cloud_token(5), o.eos_id);
        assert_ne!(o.cloud_token(4), o.eos_id);
    }
}
