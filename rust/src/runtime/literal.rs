//! Conversion helpers between rust slices and `xla::Literal`s.

use anyhow::{Context, Result};

use crate::runtime::xla::Literal;

/// Build an f32 literal of the given shape.
pub fn f32_literal(data: &[f32], dims: &[usize]) -> Result<Literal> {
    let n: usize = dims.iter().product::<usize>().max(1);
    anyhow::ensure!(n == data.len(), "shape {:?} wants {} elems, got {}", dims, n, data.len());
    if dims.is_empty() {
        return Ok(Literal::scalar(data[0]));
    }
    let v = Literal::vec1(data);
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(v.reshape(&dims_i64)?)
}

/// Build an i32 literal of the given shape.
pub fn i32_literal(data: &[i32], dims: &[usize]) -> Result<Literal> {
    let n: usize = dims.iter().product::<usize>().max(1);
    anyhow::ensure!(n == data.len(), "shape {:?} wants {} elems, got {}", dims, n, data.len());
    if dims.is_empty() {
        return Ok(Literal::scalar(data[0]));
    }
    let v = Literal::vec1(data);
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(v.reshape(&dims_i64)?)
}

pub fn scalar_i32(v: i32) -> Literal {
    Literal::scalar(v)
}

/// Read an f32 literal to a host vector.
pub fn to_f32_vec(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().context("literal -> Vec<f32>")
}

/// Read a scalar f32 literal.
pub fn to_f32_scalar(lit: &Literal) -> Result<f32> {
    lit.get_first_element::<f32>().context("literal -> f32 scalar")
}

/// Read a scalar i32 literal.
pub fn to_i32_scalar(lit: &Literal) -> Result<i32> {
    lit.get_first_element::<i32>().context("literal -> i32 scalar")
}

/// Read an i32 literal to a host vector.
pub fn to_i32_vec(lit: &Literal) -> Result<Vec<i32>> {
    lit.to_vec::<i32>().context("literal -> Vec<i32>")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip_shaped() {
        let lit = f32_literal(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        assert_eq!(to_f32_vec(&lit).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(lit.element_count(), 6);
    }

    #[test]
    fn scalar_roundtrip() {
        let lit = f32_literal(&[7.5], &[]).unwrap();
        assert_eq!(to_f32_scalar(&lit).unwrap(), 7.5);
        let lit = scalar_i32(-3);
        assert_eq!(to_i32_scalar(&lit).unwrap(), -3);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(f32_literal(&[1.0, 2.0], &[3]).is_err());
        assert!(i32_literal(&[1, 2, 3], &[2]).is_err());
    }
}
