//! Real PJRT-backed engine sessions.
//!
//! Sessions hold their KV caches as host `Literal`s between steps (the
//! `xla` crate returns execution outputs as one tuple buffer, so caches
//! round-trip through the host; see EXPERIMENTS.md §Perf for the measured
//! cost and the mitigations applied).

use std::rc::Rc;

use anyhow::Result;

use crate::runtime::xla::{Literal, PjRtBuffer};

use crate::model::manifest::ModelDims;
use crate::runtime::literal::{f32_literal, i32_literal, scalar_i32};
use crate::runtime::stack::LoadedArtifacts;
use crate::runtime::traits::{
    BatchItem, CloudEngine, CloudOut, EdgeEngine, EdgePrefillOut, ExitEval, Seg1Out, Seg2Out,
};

/// Positions per fused catch-up execution: the `cloud_decode_catchup`
/// artifact is AOT-compiled for a fixed `[CATCHUP_BUCKET, d_model]` input
/// (padded with zeros, real count passed as a scalar), so longer runs are
/// chunked into bucket-sized executions.
pub const CATCHUP_BUCKET: usize = 8;

pub struct EdgeSession {
    dims: ModelDims,
    arts: Rc<LoadedArtifacts>,
    params: Rc<Vec<PjRtBuffer>>,
    kv1: Option<(Literal, Literal)>,
    kv2: Option<(Literal, Literal)>,
}

impl EdgeSession {
    pub fn new(dims: ModelDims, arts: Rc<LoadedArtifacts>, params: Rc<Vec<PjRtBuffer>>) -> Self {
        Self { dims, arts, params, kv1: None, kv2: None }
    }

    fn exit_eval(out: &mut super::artifact::Outputs, prefix: &str) -> Result<ExitEval> {
        Ok(ExitEval {
            token: out.i32_scalar(&format!("{prefix}_tok"))?,
            conf: out.f32_scalar(&format!("{prefix}_conf"))?,
            logits: out.f32_vec(&format!("{prefix}_logits"))?,
        })
    }
}

impl EdgeEngine for EdgeSession {
    fn dims(&self) -> &ModelDims {
        &self.dims
    }

    fn prefill(&mut self, prompt: &[i32]) -> Result<EdgePrefillOut> {
        let p_max = self.dims.max_prompt;
        anyhow::ensure!(
            !prompt.is_empty() && prompt.len() <= p_max,
            "prompt length {} out of range 1..={p_max}",
            prompt.len()
        );
        // pick the smallest prefill bucket that fits (perf: short prompts
        // skip 3/4 of the pad; EXPERIMENTS.md §Perf)
        let (artifact, p) = match &self.arts.edge_prefill_64 {
            Some(a) if prompt.len() <= 64 => (a, 64),
            _ => (&self.arts.edge_prefill, p_max),
        };
        let mut tokens = prompt.to_vec();
        tokens.resize(p, self.dims.pad_id);
        let mut out = artifact.execute(
            &self.params,
            &[i32_literal(&tokens, &[p])?, scalar_i32(prompt.len() as i32)],
        )?;
        self.kv1 = Some((out.take("kv1_k")?, out.take("kv1_v")?));
        self.kv2 = Some((out.take("kv2_k")?, out.take("kv2_v")?));
        let h1_full = out.f32_vec("h1")?; // [max_prompt * d]
        let h1 = h1_full[..prompt.len() * self.dims.d_model].to_vec();
        Ok(EdgePrefillOut {
            h1,
            exit1: Self::exit_eval(&mut out, "e1")?,
            exit2: Self::exit_eval(&mut out, "e2")?,
        })
    }

    fn seg1(&mut self, token: i32, pos: usize) -> Result<Seg1Out> {
        let (kv_k, kv_v) = self.kv1.take().ok_or_else(|| anyhow::anyhow!("seg1 before prefill"))?;
        anyhow::ensure!(pos < self.dims.max_seq, "pos {pos} >= max_seq");
        let mut out = self.arts.edge_seg1_decode.execute(
            &self.params,
            &[kv_k, kv_v, scalar_i32(token), scalar_i32(pos as i32)],
        )?;
        self.kv1 = Some((out.take("kv1_k")?, out.take("kv1_v")?));
        Ok(Seg1Out { h1: out.f32_vec("h1")?, exit1: Self::exit_eval(&mut out, "e1")? })
    }

    fn seg2(&mut self, h1: &[f32], pos: usize) -> Result<Seg2Out> {
        let (kv_k, kv_v) = self.kv2.take().ok_or_else(|| anyhow::anyhow!("seg2 before prefill"))?;
        let d = self.dims.d_model;
        anyhow::ensure!(h1.len() == d, "h1 length {} != d_model {d}", h1.len());
        let mut out = self.arts.edge_seg2_decode.execute(
            &self.params,
            &[kv_k, kv_v, f32_literal(h1, &[1, d])?, scalar_i32(pos as i32)],
        )?;
        self.kv2 = Some((out.take("kv2_k")?, out.take("kv2_v")?));
        Ok(Seg2Out { exit2: Self::exit_eval(&mut out, "e2")? })
    }

    fn reset(&mut self) {
        self.kv1 = None;
        self.kv2 = None;
    }
}

pub struct CloudSession {
    dims: ModelDims,
    arts: Rc<LoadedArtifacts>,
    params: Rc<Vec<PjRtBuffer>>,
    kvc: Option<(Literal, Literal)>,
    fused_passes: u64,
}

impl CloudSession {
    pub fn new(dims: ModelDims, arts: Rc<LoadedArtifacts>, params: Rc<Vec<PjRtBuffer>>) -> Self {
        Self { dims, arts, params, kvc: None, fused_passes: 0 }
    }

    fn exit_eval(out: &mut super::artifact::Outputs) -> Result<ExitEval> {
        Ok(ExitEval {
            token: out.i32_scalar("tok")?,
            conf: out.f32_scalar("conf")?,
            logits: out.f32_vec("logits")?,
        })
    }

    /// One fused execution over up to [`CATCHUP_BUCKET`] contiguous
    /// positions: hiddens padded to the bucket, one KV round trip for the
    /// whole chunk instead of one per position.
    ///
    /// Artifact contract (`cloud_decode_catchup`): inputs
    /// `kv_k, kv_v, h1 [CATCHUP_BUCKET, d], start_pos, count`; outputs
    /// `kvc_k, kvc_v, toks [B] i32, confs [B] f32, logits [B * vocab]`.
    fn decode_chunk_fused(&mut self, chunk: &[BatchItem]) -> Result<Vec<CloudOut>> {
        let arts = Rc::clone(&self.arts);
        let artifact =
            arts.cloud_decode_catchup.as_ref().expect("fused path requires the artifact");
        let (kv_k, kv_v) =
            self.kvc.take().ok_or_else(|| anyhow::anyhow!("cloud decode before prefill"))?;
        let d = self.dims.d_model;
        let start = chunk[0].pos;
        let mut padded = vec![0f32; CATCHUP_BUCKET * d];
        for (i, b) in chunk.iter().enumerate() {
            padded[i * d..(i + 1) * d].copy_from_slice(&b.h1);
        }
        let mut out = artifact.execute(
            &self.params,
            &[
                kv_k,
                kv_v,
                f32_literal(&padded, &[CATCHUP_BUCKET, d])?,
                scalar_i32(start as i32),
                scalar_i32(chunk.len() as i32),
            ],
        )?;
        self.kvc = Some((out.take("kvc_k")?, out.take("kvc_v")?));
        self.fused_passes += 1;
        let toks = out.i32_vec("toks")?;
        let confs = out.f32_vec("confs")?;
        let logits = out.f32_vec("logits")?;
        anyhow::ensure!(
            toks.len() >= chunk.len() && confs.len() >= chunk.len(),
            "fused outputs shorter than the chunk"
        );
        let vocab = logits.len() / toks.len().max(1);
        Ok(chunk
            .iter()
            .enumerate()
            .map(|(i, _)| CloudOut {
                exit: ExitEval {
                    token: toks[i],
                    conf: confs[i],
                    logits: logits[i * vocab..(i + 1) * vocab].to_vec(),
                },
            })
            .collect())
    }
}

impl CloudEngine for CloudSession {
    fn dims(&self) -> &ModelDims {
        &self.dims
    }

    fn prefill(&mut self, h1: &[f32], len: usize) -> Result<CloudOut> {
        let (p_max, d) = (self.dims.max_prompt, self.dims.d_model);
        anyhow::ensure!(len >= 1 && len <= p_max, "prompt length {len} out of range");
        anyhow::ensure!(h1.len() == len * d, "h1 len {} != {len}*{d}", h1.len());
        let (artifact, p) = match &self.arts.cloud_prefill_64 {
            Some(a) if len <= 64 => (a, 64),
            _ => (&self.arts.cloud_prefill, p_max),
        };
        let mut padded = vec![0f32; p * d];
        padded[..h1.len()].copy_from_slice(h1);
        let mut out = artifact.execute(
            &self.params,
            &[f32_literal(&padded, &[p, d])?, scalar_i32(len as i32)],
        )?;
        self.kvc = Some((out.take("kvc_k")?, out.take("kvc_v")?));
        Ok(CloudOut { exit: Self::exit_eval(&mut out)? })
    }

    fn decode(&mut self, h1: &[f32], pos: usize) -> Result<CloudOut> {
        let (kv_k, kv_v) =
            self.kvc.take().ok_or_else(|| anyhow::anyhow!("cloud decode before prefill"))?;
        let d = self.dims.d_model;
        anyhow::ensure!(h1.len() == d, "h1 length {} != d_model {d}", h1.len());
        anyhow::ensure!(pos < self.dims.max_seq, "pos {pos} >= max_seq");
        let mut out = self.arts.cloud_decode.execute(
            &self.params,
            &[kv_k, kv_v, f32_literal(h1, &[1, d])?, scalar_i32(pos as i32)],
        )?;
        self.kvc = Some((out.take("kvc_k")?, out.take("kvc_v")?));
        Ok(CloudOut { exit: Self::exit_eval(&mut out)? })
    }

    fn decode_batch(&mut self, items: &[BatchItem]) -> Result<Vec<CloudOut>> {
        if items.is_empty() {
            return Ok(Vec::new());
        }
        let d = self.dims.d_model;
        for (i, b) in items.iter().enumerate() {
            anyhow::ensure!(b.h1.len() == d, "h1 length {} != d_model {d}", b.h1.len());
            anyhow::ensure!(b.pos < self.dims.max_seq, "pos {} >= max_seq", b.pos);
            anyhow::ensure!(
                i == 0 || b.pos == items[i - 1].pos + 1,
                "catch-up run must be position-contiguous"
            );
        }
        if self.arts.cloud_decode_catchup.is_none() {
            // stack compiled without the fused artifact: per-position loop
            // (one KV round trip per position; see EXPERIMENTS.md §Perf)
            return items.iter().map(|b| self.decode(&b.h1, b.pos)).collect();
        }
        let mut out = Vec::with_capacity(items.len());
        for chunk in items.chunks(CATCHUP_BUCKET) {
            out.extend(self.decode_chunk_fused(chunk)?);
        }
        Ok(out)
    }

    fn batch_passes(&self) -> u64 {
        self.fused_passes
    }

    fn is_prefilled(&self) -> bool {
        self.kvc.is_some()
    }

    fn reset(&mut self) {
        self.kvc = None;
    }
}
