//! `LocalStack`: one-stop loader for the artifact directory.
//!
//! Owns the PJRT client, the compiled artifacts, and the parameter
//! buffers (staged to the device once — the request path never re-uploads
//! weights).  Hands out per-request edge/cloud sessions that share them.

use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::runtime::xla::{PjRtBuffer, PjRtClient};

use crate::model::manifest::Manifest;
use crate::model::tokenizer::Tokenizer;
use crate::model::weights::Weights;
use crate::runtime::artifact::Artifact;
use crate::runtime::engines::{EdgeSession, CloudSession};

pub struct LoadedArtifacts {
    pub edge_prefill: Artifact,
    pub edge_seg1_decode: Artifact,
    pub edge_seg2_decode: Artifact,
    pub cloud_prefill: Artifact,
    pub cloud_decode: Artifact,
    /// Short-prompt prefill buckets (P=64) — optional perf artifacts that
    /// skip ~3/4 of the prefill pad for Alpaca-length prompts.
    pub edge_prefill_64: Option<Artifact>,
    pub cloud_prefill_64: Option<Artifact>,
    /// Fused catch-up decode over a `[CATCHUP_BUCKET, d_model]` padded
    /// run (see [`crate::runtime::engines::CATCHUP_BUCKET`]) — optional
    /// batching artifact; stacks without it fall back to the sequential
    /// per-position decode loop.
    pub cloud_decode_catchup: Option<Artifact>,
}

pub struct LocalStack {
    pub client: PjRtClient,
    pub manifest: Manifest,
    pub artifacts: Rc<LoadedArtifacts>,
    /// Edge-partition parameters, staged on device in manifest order.
    pub edge_params: Rc<Vec<PjRtBuffer>>,
    /// Cloud-partition parameters, staged on device in manifest order.
    pub cloud_params: Rc<Vec<PjRtBuffer>>,
    pub dir: PathBuf,
}

impl LocalStack {
    /// Load manifest, weights and all five artifacts from `dir`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let weights = Weights::load(&dir.join("weights.bin"))?;
        let client = PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;

        let stage = |partition: &str| -> Result<Vec<PjRtBuffer>> {
            let sigs = manifest
                .partitions
                .get(partition)
                .with_context(|| format!("partition '{partition}'"))?;
            let mut bufs = Vec::with_capacity(sigs.len());
            for sig in sigs {
                let t = weights.get(&sig.name)?;
                anyhow::ensure!(
                    t.shape == sig.shape,
                    "weight '{}' shape {:?} != manifest {:?}",
                    sig.name,
                    t.shape,
                    sig.shape
                );
                let buf = client
                    .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
                    .map_err(|e| anyhow::anyhow!("staging '{}': {e:?}", sig.name))?;
                bufs.push(buf);
            }
            Ok(bufs)
        };
        let edge_params = Rc::new(stage("edge")?);
        let cloud_params = Rc::new(stage("cloud")?);

        let load = |name: &str| -> Result<Artifact> {
            Artifact::load(&client, &dir, name, manifest.artifact(name)?)
        };
        let load_opt = |name: &str| -> Result<Option<Artifact>> {
            match manifest.artifacts.get(name) {
                Some(sig) => Ok(Some(Artifact::load(&client, &dir, name, sig)?)),
                None => Ok(None),
            }
        };
        let artifacts = Rc::new(LoadedArtifacts {
            edge_prefill: load("edge_prefill")?,
            edge_seg1_decode: load("edge_seg1_decode")?,
            edge_seg2_decode: load("edge_seg2_decode")?,
            cloud_prefill: load("cloud_prefill")?,
            cloud_decode: load("cloud_decode")?,
            edge_prefill_64: load_opt("edge_prefill_64")?,
            cloud_prefill_64: load_opt("cloud_prefill_64")?,
            cloud_decode_catchup: load_opt("cloud_decode_catchup")?,
        });

        Ok(Self { client, manifest, artifacts, edge_params, cloud_params, dir })
    }

    pub fn tokenizer(&self) -> Tokenizer {
        Tokenizer::from_dims(&self.manifest.model)
    }

    /// A fresh edge session (empty KV caches) sharing this stack.
    pub fn edge_session(&self) -> EdgeSession {
        EdgeSession::new(
            self.manifest.model.clone(),
            Rc::clone(&self.artifacts),
            Rc::clone(&self.edge_params),
        )
    }

    /// A fresh cloud session (empty KV caches) sharing this stack.
    pub fn cloud_session(&self) -> CloudSession {
        CloudSession::new(
            self.manifest.model.clone(),
            Rc::clone(&self.artifacts),
            Rc::clone(&self.cloud_params),
        )
    }
}
