//! A loaded AOT artifact: HLO text compiled to a PJRT executable, plus its
//! typed signature from the manifest.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::model::manifest::ArtifactSig;
use crate::runtime::xla::{
    HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation,
};

pub struct Artifact {
    pub name: String,
    pub sig: ArtifactSig,
    exe: PjRtLoadedExecutable,
    client: PjRtClient,
    out_idx: HashMap<String, usize>,
}

impl Artifact {
    /// Load `<dir>/<sig.file>` (HLO text) and compile it.
    pub fn load(client: &PjRtClient, dir: &Path, name: &str, sig: &ArtifactSig) -> Result<Self> {
        let path = dir.join(&sig.file);
        let proto = HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
        let out_idx =
            sig.outputs.iter().enumerate().map(|(i, t)| (t.name.clone(), i)).collect();
        Ok(Self { name: name.to_string(), sig: sig.clone(), exe, client: client.clone(), out_idx })
    }

    /// Execute with pre-staged parameter buffers followed by runtime
    /// literals (converted to device buffers here).  Returns the
    /// decomposed output tuple.
    pub fn execute(&self, params: &[PjRtBuffer], runtime: &[Literal]) -> Result<Outputs> {
        anyhow::ensure!(
            runtime.len() == self.sig.inputs.len(),
            "{}: expected {} runtime inputs, got {}",
            self.name,
            self.sig.inputs.len(),
            runtime.len()
        );
        let mut staged: Vec<PjRtBuffer> = Vec::with_capacity(runtime.len());
        for lit in runtime {
            staged.push(
                self.client
                    .buffer_from_host_literal(None, lit)
                    .map_err(|e| anyhow::anyhow!("{}: staging input: {e:?}", self.name))?,
            );
        }
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(params.len() + staged.len());
        args.extend(params.iter());
        args.extend(staged.iter());

        let result = self
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow::anyhow!("{}: execute: {e:?}", self.name))?;
        let tuple = result
            .first()
            .and_then(|r| r.first())
            .with_context(|| format!("{}: empty execution result", self.name))?;
        let mut lit = tuple
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{}: output to host: {e:?}", self.name))?;
        let parts = lit
            .decompose_tuple()
            .map_err(|e| anyhow::anyhow!("{}: decompose tuple: {e:?}", self.name))?;
        anyhow::ensure!(
            parts.len() == self.sig.outputs.len(),
            "{}: {} outputs, manifest says {}",
            self.name,
            parts.len(),
            self.sig.outputs.len()
        );
        Ok(Outputs { lits: parts.into_iter().map(Some).collect(), idx: self.out_idx.clone() })
    }
}

/// Decomposed outputs of one execution, addressable by manifest name.
pub struct Outputs {
    lits: Vec<Option<Literal>>,
    idx: HashMap<String, usize>,
}

impl Outputs {
    fn slot(&mut self, name: &str) -> Result<&mut Option<Literal>> {
        let i = *self
            .idx
            .get(name)
            .with_context(|| format!("output '{name}' not in artifact signature"))?;
        Ok(&mut self.lits[i])
    }

    /// Move an output literal out (for KV caches fed back next step).
    pub fn take(&mut self, name: &str) -> Result<Literal> {
        self.slot(name)?
            .take()
            .with_context(|| format!("output '{name}' already taken"))
    }

    pub fn f32_vec(&mut self, name: &str) -> Result<Vec<f32>> {
        let lit = self.slot(name)?.as_ref().context("output already taken")?;
        super::literal::to_f32_vec(lit)
    }

    pub fn f32_scalar(&mut self, name: &str) -> Result<f32> {
        let lit = self.slot(name)?.as_ref().context("output already taken")?;
        super::literal::to_f32_scalar(lit)
    }

    pub fn i32_scalar(&mut self, name: &str) -> Result<i32> {
        let lit = self.slot(name)?.as_ref().context("output already taken")?;
        super::literal::to_i32_scalar(lit)
    }

    pub fn i32_vec(&mut self, name: &str) -> Result<Vec<i32>> {
        let lit = self.slot(name)?.as_ref().context("output already taken")?;
        super::literal::to_i32_vec(lit)
    }
}
