//! Replay a recorded trace through a live scheduler and assert the
//! outputs bit-identical, plus the DES cross-validation report.
//!
//! The replayer is a deterministic driver: recorded *input* events
//! (`reset`, `upload`, `infer`, `end`) are re-sent through the
//! [`Router`] in recorded sequence order, and recorded *output* events
//! (`token`, `evicted_notice`, `infer_error`) act as wait-points — the
//! replay blocks until the live scheduler produces the outcome for that
//! `(device, req, pos)` and compares it bit-for-bit (token value and
//! the confidence's exact f32 bit pattern).  Because inputs after a
//! wait-point are not sent until the wait-point is satisfied, the
//! replay reproduces the linearization the recording captured, which is
//! what makes budget evictions and session resumes land on the same
//! protocol steps.  Final counters are then compared against the
//! recorded `worker_stats` events.
//!
//! [`Router`]: crate::coordinator::scheduler::Router

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::config::{AblationFlags, CloudConfig};
use crate::coordinator::policy::ExitPoint;
use crate::coordinator::scheduler::{
    FactoryBuilder, InferOutcome, Reply, SchedMsg, Scheduler, UploadPayload,
};
use crate::harness::cost::CostModel;
use crate::harness::des::{simulate, SimConfig, Strategy};
use crate::harness::trace::{Trace, TraceStep};
use crate::metrics::{HistSnapshot, LatencyHist};
use crate::model::manifest::ModelDims;
use crate::net::profiles::LinkProfile;

use super::TraceEvent;

/// How long a wait-point may block before the replay declares the
/// recorded outcome unreachable.  Generous: a healthy replay satisfies
/// each wait-point in microseconds.
const WAIT_POINT_TIMEOUT: Duration = Duration::from_secs(10);

/// Result of a replay: how much was driven and every divergence found.
#[derive(Debug, Default)]
pub struct ReplayReport {
    /// Total events consumed from the trace.
    pub events: usize,
    /// Input events re-driven through the router.
    pub inputs_sent: usize,
    /// Output wait-points checked bit-for-bit.
    pub outputs_checked: usize,
    /// Every divergence between recording and replay (empty = identical).
    pub mismatches: Vec<String>,
}

impl ReplayReport {
    /// True when the replay reproduced the recording exactly.
    pub fn ok(&self) -> bool {
        self.mismatches.is_empty()
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "replayed {} events ({} inputs, {} outputs checked): {}",
            self.events,
            self.inputs_sent,
            self.outputs_checked,
            if self.ok() { "bit-identical" } else { "DIVERGED" },
        );
        for m in &self.mismatches {
            s.push_str("\n  mismatch: ");
            s.push_str(m);
        }
        s
    }
}

#[derive(Debug, Clone, PartialEq)]
enum Outcome {
    Token { token: i32, conf_bits: u32 },
    Evicted,
    Error(String),
}

type Key = (u64, u64, u64); // (device, req, pos)

/// Outcomes flowing back from the live scheduler, queued per key (a key
/// can legitimately recur: an evicted-then-replayed request answers the
/// same `(device, req, pos)` twice — first `Evicted`, then the token).
struct Mailbox {
    map: Mutex<HashMap<Key, VecDeque<Outcome>>>,
    cv: Condvar,
}

impl Mailbox {
    fn post(&self, key: Key, out: Outcome) {
        let mut map = self.map.lock().unwrap();
        map.entry(key).or_default().push_back(out);
        self.cv.notify_all();
    }

    fn wait(&self, key: Key, timeout: Duration) -> Option<Outcome> {
        let deadline = Instant::now() + timeout;
        let mut map = self.map.lock().unwrap();
        loop {
            if let Some(o) = map.get_mut(&key).and_then(|q| q.pop_front()) {
                return Some(o);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (g, _) = self.cv.wait_timeout(map, deadline - now).unwrap();
            map = g;
        }
    }
}

fn key_of(e: &TraceEvent) -> Result<Key> {
    Ok((e.u("device")?, e.u("req")?, e.u("pos")?))
}

/// Replay a parsed trace through a freshly spawned scheduler.
///
/// `dims` and `builder` recreate the engine the recording ran against
/// (for mock-backed recordings: the same oracle seed).  The scheduler
/// configuration is rebuilt from the trace's `run_meta`, with the idle
/// TTL forced off (wall-clock reaps are not part of the recorded
/// order) and tracing off (a replay is not itself a recording).
pub fn replay(
    events: &[TraceEvent],
    dims: &ModelDims,
    builder: FactoryBuilder,
) -> Result<ReplayReport> {
    let meta = events
        .iter()
        .find(|e| e.ev == "run_meta")
        .context("trace has no run_meta event — not a cloud-side recording")?;
    ensure!(
        meta.u("d_model")? as usize == dims.d_model,
        "trace was recorded at d_model {} but the replayer's dims have {}",
        meta.u("d_model")?,
        dims.d_model
    );
    let cfg = CloudConfig {
        workers: meta.u("workers")?.max(1) as usize,
        max_catchup_per_pass: meta.u("max_catchup")?.max(1) as usize,
        memory_budget_bytes: meta.u_opt("budget"),
        session_ttl_s: None,
        trace: None,
        ..CloudConfig::default()
    };

    // Pre-scan: the recorded outcome kinds per key, in order.  Consumed
    // one per `infer` so a request whose recording expired at its
    // deadline is re-sent with an already-expired deadline (the park
    // would otherwise wait out the full max_park_s).
    let mut expected: HashMap<Key, VecDeque<&str>> = HashMap::new();
    for e in events {
        let kind = match e.ev.as_str() {
            "token" => "token",
            "evicted_notice" => "evicted",
            "infer_error" => {
                if e.s("kind").unwrap_or("") == "deadline" {
                    "deadline"
                } else {
                    "error"
                }
            }
            _ => continue,
        };
        expected.entry(key_of(e)?).or_default().push_back(kind);
    }

    let sched = Scheduler::spawn(dims.clone(), cfg, builder)?;
    let router = sched.router();
    let mailbox = Arc::new(Mailbox { map: Mutex::new(HashMap::new()), cv: Condvar::new() });
    let mut report = ReplayReport { events: events.len(), ..ReplayReport::default() };
    let mut recorded_stats: BTreeMap<u64, RecordedWorkerStats> = BTreeMap::new();

    'drive: for e in events {
        match e.ev.as_str() {
            "run_meta" => {}
            "reset" => {
                let device = e.u("device")?;
                router.send(device, SchedMsg::Reset {
                    device,
                    session: e.hex_u64("session")?,
                    resume: e.b("resume")?,
                    // absent in pre-replication recordings: not a mirror
                    mirror: e.b("mirror").unwrap_or(false),
                })?;
                report.inputs_sent += 1;
            }
            "upload" => {
                let device = e.u("device")?;
                router.send(device, SchedMsg::Upload {
                    device,
                    session: e.hex_u64("session")?,
                    req_id: e.u("req")? as u32,
                    start_pos: e.u("start")? as u32,
                    prompt_len: e.u("plen")? as u32,
                    payload: UploadPayload::Floats(e.f32s("data")?),
                })?;
                report.inputs_sent += 1;
            }
            "infer" => {
                let key = key_of(e)?;
                let expires_now = expected
                    .get_mut(&key)
                    .and_then(|q| q.pop_front())
                    .map(|k| k == "deadline")
                    .unwrap_or(false);
                let mb = Arc::clone(&mailbox);
                let reply = Reply::new(move |out: Result<InferOutcome>| {
                    mb.post(key, match out {
                        Ok(InferOutcome::Token(t)) => {
                            Outcome::Token { token: t.token, conf_bits: t.conf.to_bits() }
                        }
                        Ok(InferOutcome::Evicted) => Outcome::Evicted,
                        Err(err) => Outcome::Error(format!("{err:#}")),
                    });
                });
                router.send(key.0, SchedMsg::Infer {
                    device: key.0,
                    session: e.hex_u64("session")?,
                    req_id: key.1 as u32,
                    pos: key.2 as u32,
                    prompt_len: e.u("plen")? as u32,
                    deadline: if expires_now { Some(Instant::now()) } else { None },
                    reply,
                })?;
                report.inputs_sent += 1;
            }
            "end" => {
                let device = e.u("device")?;
                router.send(device, SchedMsg::End {
                    device,
                    session: e.hex_u64("session")?,
                    req_id: e.u("req")? as u32,
                })?;
                report.inputs_sent += 1;
            }
            "token" | "evicted_notice" | "infer_error" => {
                let key = key_of(e)?;
                let got = match mailbox.wait(key, WAIT_POINT_TIMEOUT) {
                    Some(o) => o,
                    None => {
                        report.mismatches.push(format!(
                            "seq {}: no outcome arrived for device {} req {} pos {} \
                             (recorded '{}')",
                            e.seq, key.0, key.1, key.2, e.ev
                        ));
                        break 'drive;
                    }
                };
                report.outputs_checked += 1;
                let want = match e.ev.as_str() {
                    "token" => Outcome::Token {
                        token: e.i("token")? as i32,
                        conf_bits: e.u("conf_bits")? as u32,
                    },
                    "evicted_notice" => Outcome::Evicted,
                    _ => Outcome::Error(String::new()),
                };
                let matches = match (&want, &got) {
                    (Outcome::Error(_), Outcome::Error(_)) => true,
                    (w, g) => w == g,
                };
                if !matches {
                    report.mismatches.push(format!(
                        "seq {}: device {} req {} pos {} recorded {:?} but replay produced {:?}",
                        e.seq, key.0, key.1, key.2, want, got
                    ));
                }
            }
            "worker_stats" => {
                recorded_stats.insert(e.u("worker")?, RecordedWorkerStats::from_event(e)?);
            }
            // observational events: recorded for reporting/anchoring,
            // nothing to re-drive at the scheduler level
            // (mirror_promote is implied by the replayed infer on a
            // mirror-reset session; edge_promote/edge_hedge live on the
            // edge side of the wire)
            "conn_open" | "conn_close" | "frame_in" | "frame_out" | "fault" | "park" | "pass"
            | "evict" | "ttl_reap" | "mirror_promote" | "edge_send" | "edge_recv"
            | "edge_reconnect" | "edge_promote" | "edge_hedge" => {}
            other => bail!(
                "unknown trace event type '{other}' at seq {} — refusing to replay \
                 (TRACE v1 rule: an unrecognized event is an error, not a skip)",
                e.seq
            ),
        }
    }

    let stats = sched.shutdown();
    if !recorded_stats.is_empty() {
        let rec = recorded_stats.values().fold(RecordedWorkerStats::default(), |a, b| a.add(b));
        let pairs: [(&str, u64, u64); 7] = [
            ("requests_served", rec.served, stats.requests_served),
            ("uploads", rec.uploads, stats.uploads),
            ("sessions_resumed", rec.resumed, stats.sessions_resumed),
            ("stale_resumes", rec.stale_resumes, stats.stale_resumes),
            ("evictions", rec.evictions, stats.context.evictions),
            ("ttl_reaps", rec.ttl_reaps, stats.context.ttl_reaps),
            ("replays", rec.replays, stats.context.replays),
        ];
        for (name, recorded, replayed) in pairs {
            if recorded != replayed {
                report
                    .mismatches
                    .push(format!("counter {name}: recorded {recorded}, replay {replayed}"));
            }
        }
    }
    Ok(report)
}

/// [`replay`] over a trace file on disk.
pub fn replay_file(path: &str, dims: &ModelDims, builder: FactoryBuilder) -> Result<ReplayReport> {
    let events = super::parse_trace_file(path)?;
    replay(&events, dims, builder)
}

#[derive(Debug, Default, Clone, Copy)]
struct RecordedWorkerStats {
    served: u64,
    uploads: u64,
    resumed: u64,
    stale_resumes: u64,
    evictions: u64,
    ttl_reaps: u64,
    replays: u64,
}

impl RecordedWorkerStats {
    fn from_event(e: &TraceEvent) -> Result<Self> {
        Ok(Self {
            served: e.u("served")?,
            uploads: e.u("uploads")?,
            resumed: e.u("resumed")?,
            stale_resumes: e.u("stale_resumes")?,
            evictions: e.u("evictions")?,
            ttl_reaps: e.u("ttl_reaps")?,
            replays: e.u("replays")?,
        })
    }

    fn add(self, o: &Self) -> Self {
        Self {
            served: self.served + o.served,
            uploads: self.uploads + o.uploads,
            resumed: self.resumed + o.resumed,
            stale_resumes: self.stale_resumes + o.stale_resumes,
            evictions: self.evictions + o.evictions,
            ttl_reaps: self.ttl_reaps + o.ttl_reaps,
            replays: self.replays + o.replays,
        }
    }
}

// ---------------------------------------------------------------------------
// DES cross-validation

/// Simulated-vs-measured deltas from feeding a recorded trace's request
/// timeline into the discrete-event harness ([`simulate`]) — the
/// cheapest cross-validation of the live stack and the DES: both
/// consume the same per-device token/position sequence, so their pass,
/// eviction, and byte counters should track each other.
#[derive(Debug)]
pub struct DesReport {
    pub devices: usize,
    pub tokens: u64,
    /// Engine passes: counted `pass` events vs the DES pool's passes.
    pub measured_passes: u64,
    pub sim_passes: u64,
    /// Budget evictions: counted `evict` events vs the DES's LRU law.
    pub measured_evictions: u64,
    pub sim_evictions: u64,
    pub sim_replays: u64,
    /// Upload payload bytes: recorded f32 payload bytes vs the DES's
    /// priced uplink bytes (which include wire headers and the
    /// deployment's wire precision, so this pair brackets rather than
    /// matches — the deltas are the report).
    pub measured_upload_bytes: u64,
    pub sim_upload_bytes: u64,
    pub sim_makespan_s: f64,
    /// Park-wait distribution rebuilt from the recording's `t_us`
    /// timeline (each `park` resolved at the next same-`(device, req,
    /// pos)` outcome event) vs the DES's simulated park-wait histogram.
    /// Same bucket schema on both sides, so the percentile deltas
    /// compare distribution shape, not just totals.
    pub measured_park: HistSnapshot,
    pub sim_park: HistSnapshot,
}

impl DesReport {
    pub fn summary(&self) -> String {
        let us = |snap: &HistSnapshot, q: f64| snap.quantile(q) / 1_000.0;
        format!(
            "des check over {} devices / {} tokens: passes measured {} vs simulated {} \
             (delta {:+}), evictions measured {} vs simulated {} (delta {:+}), \
             upload bytes measured {} vs simulated {}, sim replays {}, sim makespan {:.3}s; \
             park-wait p50/p90/p99 measured {:.0}/{:.0}/{:.0}us ({} waits) \
             vs simulated {:.0}/{:.0}/{:.0}us ({} waits)",
            self.devices,
            self.tokens,
            self.measured_passes,
            self.sim_passes,
            self.sim_passes as i64 - self.measured_passes as i64,
            self.measured_evictions,
            self.sim_evictions,
            self.sim_evictions as i64 - self.measured_evictions as i64,
            self.measured_upload_bytes,
            self.sim_upload_bytes,
            self.sim_replays,
            self.sim_makespan_s,
            us(&self.measured_park, 0.50),
            us(&self.measured_park, 0.90),
            us(&self.measured_park, 0.99),
            self.measured_park.count(),
            us(&self.sim_park, 0.50),
            us(&self.sim_park, 0.90),
            us(&self.sim_park, 0.99),
            self.sim_park.count(),
        )
    }
}

/// Rebuild per-device request traces from a recording and replay them
/// through the DES under the recorded deployment shape (workers,
/// budget, cross-device batching), reporting simulated-vs-measured
/// counter deltas.
pub fn des_check(events: &[TraceEvent], dims: &ModelDims) -> Result<DesReport> {
    let meta = events
        .iter()
        .find(|e| e.ev == "run_meta")
        .context("trace has no run_meta event — not a cloud-side recording")?;
    let workers = meta.u("workers")?.max(1) as usize;
    let budget = meta.u_opt("budget");

    // prompt lengths per (device, req) from the recorded inputs
    let mut plen: BTreeMap<(u64, u64), usize> = BTreeMap::new();
    for e in events {
        if e.ev == "upload" || e.ev == "infer" {
            plen.insert((e.u("device")?, e.u("req")?), e.u("plen")? as usize);
        }
    }

    // every served token, grouped per (device, req) in seq order
    let mut toks: BTreeMap<(u64, u64), Vec<(usize, i32, u32)>> = BTreeMap::new();
    for e in events {
        if e.ev == "token" {
            toks.entry((e.u("device")?, e.u("req")?)).or_default().push((
                e.u("pos")? as usize,
                e.i("token")? as i32,
                e.u("conf_bits")? as u32,
            ));
        }
    }

    let mut per_device: BTreeMap<u64, Vec<Trace>> = BTreeMap::new();
    let mut tokens_total = 0u64;
    for ((device, req), steps_in) in &toks {
        let prompt_len =
            plen.get(&(*device, *req)).copied().unwrap_or_else(|| steps_in[0].0 + 1);
        let mut steps = Vec::with_capacity(steps_in.len());
        let mut prev_pos: Option<usize> = None;
        for (pos, token, conf_bits) in steps_in {
            steps.push(TraceStep {
                pos: *pos,
                token: *token,
                exit: ExitPoint::Cloud,
                conf1: 0.0,
                conf2: None,
                tok1: *token,
                tok2: None,
                cloud_conf: Some(f32::from_bits(*conf_bits)),
                cloud_catchup: prev_pos.map(|p| pos.saturating_sub(p)).unwrap_or(0),
                cloud_prefill: prev_pos.is_none(),
            });
            prev_pos = Some(*pos);
        }
        tokens_total += steps.len() as u64;
        let tokens: Vec<i32> = steps.iter().map(|s| s.token).collect();
        per_device.entry(*device).or_default().push(Trace {
            prompt_len,
            tokens,
            text: String::new(),
            steps,
        });
    }
    ensure!(!per_device.is_empty(), "trace contains no served tokens to cross-validate");

    let traces: Vec<Vec<Trace>> = per_device.into_values().collect();
    let devices = traces.len();
    let cost = CostModel::synthetic(dims);
    let sim = simulate(&traces, dims, &cost, &SimConfig {
        strategy: Strategy::CeCollm(AblationFlags::default()),
        link: LinkProfile::paper_scaled(),
        seed: 0,
        workers,
        cross_device_batch: true,
        memory_budget_bytes: budget,
        session_ttl_s: None,
        link_fault: None,
        replication: None,
    });
    let (_, counters) = sim.summed();

    // measured park-wait: each `park` resolves at the first later
    // same-(device, req, pos) outcome event — the token it was waiting
    // to serve, or the error/eviction that retired it.  `t_us` is the
    // sink-relative timestamp every recorded line carries.
    let measured_park = LatencyHist::new();
    let mut pending_parks: Vec<((u64, u64, u64), u64)> = Vec::new();
    for e in events {
        match e.ev.as_str() {
            "park" => {
                if let (Ok(d), Ok(r), Ok(p), Ok(t)) =
                    (e.u("device"), e.u("req"), e.u("pos"), e.u("t_us"))
                {
                    pending_parks.push(((d, r, p), t));
                }
            }
            "token" | "infer_error" | "evicted_notice" => {
                if let (Ok(d), Ok(r), Ok(p), Ok(t)) =
                    (e.u("device"), e.u("req"), e.u("pos"), e.u("t_us"))
                {
                    if let Some(i) = pending_parks.iter().position(|(k, _)| *k == (d, r, p)) {
                        let (_, t0) = pending_parks.swap_remove(i);
                        measured_park.record(t.saturating_sub(t0).saturating_mul(1_000));
                    }
                }
            }
            _ => {}
        }
    }

    let measured_passes = events.iter().filter(|e| e.ev == "pass").count() as u64;
    let measured_evictions = events.iter().filter(|e| e.ev == "evict").count() as u64;
    let measured_upload_bytes: u64 = events
        .iter()
        .filter(|e| e.ev == "upload")
        .map(|e| e.s("data").map(|d| d.len() as u64 / 2).unwrap_or(0))
        .sum();

    Ok(DesReport {
        devices,
        tokens: tokens_total,
        measured_passes,
        sim_passes: sim.cloud_passes,
        measured_evictions,
        sim_evictions: sim.cloud_evictions,
        sim_replays: sim.cloud_replays,
        measured_upload_bytes,
        sim_upload_bytes: counters.bytes_up,
        sim_makespan_s: sim.makespan_s,
        measured_park: measured_park.snapshot(),
        sim_park: sim.hist_park_wait,
    })
}
