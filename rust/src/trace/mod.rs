//! Deterministic trace subsystem: record every wire frame and scheduler
//! event as a versioned JSONL stream, replay the scheduler from a
//! recording and assert the token stream bit-identical, and anchor
//! fault-injection schedules to recorded points.
//!
//! The contract is the observed-trace one: *if it wasn't emitted by the
//! runtime, it didn't happen*.  Every event the serving stack can take
//! or produce — frames in and out of the reactor, uploads, parks,
//! batch passes, evictions, TTL reaps, session resets/resumes, faults
//! injected — is tapped into a [`TraceSink`] carrying a process-global
//! monotonic sequence number, so a recording is a total order over the
//! run that [`replay`](crate::trace::replay::replay) can re-drive and
//! [`anchored_fault`] can address ("sever after the frame with seq N").
//!
//! # Enabling
//!
//! Off by default.  [`CloudConfig::trace`](crate::config::CloudConfig)
//! (explicit, wins) or the `CE_TRACE=path.jsonl` env var turn the
//! cloud-side recorder on; `CE_TRACE_EDGE=path.jsonl` turns on the
//! edge-side tap in [`CloudLink`](crate::coordinator::edge::CloudLink)
//! (a separate file — edge and cloud may be separate processes).  When
//! off, every tap site is a single `Option` check: no event is built,
//! no allocation happens.  When on, emission never blocks the hot path:
//! events go through a bounded queue to a dedicated writer thread, and
//! a saturated queue *drops* the event and bumps the emitter's
//! `trace_dropped` counter (`ReactorStats`/`CloudStats`) instead of
//! stalling the reactor or a worker.
//!
//! # Event schema (TRACE v1)
//!
//! One JSON object per line.  Common fields on every event:
//!
//! | field | type | meaning |
//! |-------|------|---------|
//! | `v`   | int  | schema version, currently `1` |
//! | `seq` | int  | process-global monotonic sequence number |
//! | `t_us`| int  | microseconds since the sink opened (observational) |
//! | `ev`  | str  | event type, one of the names below |
//!
//! Identity fields reuse the serving stack's names: `shard`/`conn`
//! (reactor; `conn` is the shard-local 56-bit counter — the shard tag is
//! its own field so values stay exact in JSON doubles), `worker`,
//! `device`, `req`, `pos`.  Session nonces are full u64s and therefore
//! serialized as `"0x…"` hex strings.
//!
//! Reactor events: `conn_open {shard, conn}` · `conn_close {shard,
//! conn, reason}` · `frame_in {shard, conn, ordinal, tag, len}` (the
//! per-connection inbound ordinal is the unit fault schedules key on) ·
//! `frame_out {shard, conn, tag, len}` · `fault {shard, conn, kind,
//! ordinal}` with `kind` in `sever_in | drop_in | delay_in |
//! reorder_hold | reorder_release` (the reorder pair brackets a held
//! frame: stashed at ordinal `n`, released after ordinal `n+k`).
//!
//! Scheduler input events (these *drive* a replay): `run_meta {workers,
//! d_model, max_catchup, budget?, ttl_s?}` (first event of a cloud
//! recording) · `upload {worker, device, session, req, start, plen,
//! data}` (`data` = hex of the unpacked f32 little-endian payload — the
//! canonical form whatever the wire precision was) · `infer {worker,
//! device, session, req, pos, plen}` · `end {worker, device, session,
//! req}` · `reset {worker, device, session, resume, honored, mirror}`
//! (`mirror` marks the session as a warm-standby copy; absent in
//! pre-replication recordings, which read as `false`).
//!
//! Scheduler output events (these are replay *assertions*): `token
//! {worker, device, req, pos, token, conf_bits}` (`conf_bits` is the
//! f32 confidence's exact bit pattern — bit-identical means bits, not
//! "close floats") · `evicted_notice {worker, device, req, pos}` ·
//! `infer_error {worker, device, req, pos, kind}` with `kind` in
//! `deadline | stale | frontier | reset | end | engine`.
//!
//! Scheduler observational events (recorded, reported, not re-driven):
//! `park {worker, device, req, pos}` · `pass {worker, devices, items}`
//! · `evict {worker, device}` · `ttl_reap {worker, device}` ·
//! `mirror_promote {worker, device}` (first infer on a mirror session
//! converted it to a live one — the cloud half of a warm failover) ·
//! `worker_stats {worker, served, uploads, resumed, stale_resumes,
//! evictions, ttl_reaps, replays}` (final counters at shutdown; replay
//! compares its own final counters against the sum of these).
//!
//! Edge events: `edge_send {device, chan, n, tag, len}` · `edge_recv
//! {device, chan, n, tag, len}` (`n` = per-device per-channel ordinal,
//! the unit [`anchored_plan`] keys client-side [`FaultPlan`]s on) ·
//! `edge_reconnect {device, round}` · `edge_promote {device,
//! standbys_left}` (warm failover: a mirror standby became the primary
//! link) · `edge_hedge {device, req, pos}` (deferral duplicated to the
//! best standby; first valid echo wins).
//!
//! # Versioning rules
//!
//! The version is per *trace line* (`v`).  A reader encountering a line
//! with `v != 1` MUST fail parsing.  A replayer encountering an event
//! type it does not know MUST fail the replay — an unknown event is a
//! recorded action the replayer cannot reproduce, so skipping it would
//! silently turn "bit-identical" into "bit-identical except the parts
//! we ignored".  New event types therefore require a version bump (or a
//! replayer that learned them first).  Adding a *field* to an existing
//! event is backward compatible (readers take what they know).
//!
//! # Replay scope (v1)
//!
//! [`replay`](crate::trace::replay) re-drives the **scheduler** (the
//! component all correctness claims reduce to) through its [`Router`]:
//! recorded inputs are fed in seq order, recorded outputs are
//! wait-points checked bit-for-bit, and final counters are compared
//! against the recorded `worker_stats`.  The idle TTL is forced off
//! during replay (wall-clock reaps are not part of the recorded order),
//! so traces recorded with `session_ttl_s` replay only up to TTL-driven
//! divergence; budget evictions, resumes, and eviction replays are
//! fully deterministic under the lockstep order the trace captures.
//! Driving the full reactor from `frame_in` events over
//! `InProcTransport` is the ROADMAP remainder, alongside a TLA+ spec
//! check over observed traces.
//!
//! [`Router`]: crate::coordinator::scheduler::Router
//! [`FaultPlan`]: crate::net::fault::FaultPlan

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};
use log::warn;

use crate::net::fault::{FaultPlan, ReactorFault};
use crate::util::json::Json;

pub mod replay;

pub use replay::{des_check, replay, replay_file, DesReport, ReplayReport};

/// Schema version stamped on every emitted line (`v`).
pub const TRACE_VERSION: u64 = 1;
/// Cloud-side recorder env toggle (`CloudConfig::trace` wins over it).
pub const TRACE_ENV: &str = "CE_TRACE";
/// Edge-side recorder env toggle (separate file: edge and cloud may be
/// different processes).
pub const EDGE_TRACE_ENV: &str = "CE_TRACE_EDGE";

/// Bounded depth of the sink's line queue.  A full queue means the
/// writer can't keep up; emitters then drop-and-count rather than
/// block (see `trace_dropped`).
const QUEUE_CAP: usize = 8192;

// ---------------------------------------------------------------------------
// event builder

/// Builder for one trace event.  Constructed only when a sink is
/// actually attached (tap sites guard with `if let Some(sink)`), so the
/// disabled path never allocates.
#[derive(Debug)]
pub struct Ev {
    map: BTreeMap<String, Json>,
}

impl Ev {
    pub fn new(ev: &str) -> Self {
        let mut map = BTreeMap::new();
        map.insert("ev".to_string(), Json::Str(ev.to_string()));
        Ev { map }
    }

    /// Small unsigned field.  JSON numbers are doubles; values must stay
    /// under 2^53 to round-trip exactly (all protocol counters do —
    /// u64-wide identities like sessions use [`Ev::hex`] instead).
    pub fn u(mut self, k: &str, v: u64) -> Self {
        debug_assert!(v < (1 << 53), "field {k}={v} would lose precision in JSON");
        self.map.insert(k.to_string(), Json::Num(v as f64));
        self
    }

    pub fn i(mut self, k: &str, v: i64) -> Self {
        self.map.insert(k.to_string(), Json::Num(v as f64));
        self
    }

    pub fn f(mut self, k: &str, v: f64) -> Self {
        self.map.insert(k.to_string(), Json::Num(v));
        self
    }

    pub fn s(mut self, k: &str, v: &str) -> Self {
        self.map.insert(k.to_string(), Json::Str(v.to_string()));
        self
    }

    pub fn b(mut self, k: &str, v: bool) -> Self {
        self.map.insert(k.to_string(), Json::Bool(v));
        self
    }

    /// Full-width u64 (session nonces): serialized as a `"0x…"` string
    /// because doubles only carry 53 mantissa bits.
    pub fn hex(mut self, k: &str, v: u64) -> Self {
        self.map.insert(k.to_string(), Json::Str(format!("{v:#x}")));
        self
    }

    /// f32 payload as little-endian hex (8 chars per element) — exact
    /// bit patterns, byte order pinned.
    pub fn hex_f32s(mut self, k: &str, v: &[f32]) -> Self {
        let mut s = String::with_capacity(v.len() * 8);
        for x in v {
            for b in x.to_le_bytes() {
                let _ = write!(s, "{b:02x}");
            }
        }
        self.map.insert(k.to_string(), Json::Str(s));
        self
    }

    fn into_line(mut self, seq: u64, t_us: u64) -> String {
        self.map.insert("v".to_string(), Json::Num(TRACE_VERSION as f64));
        self.map.insert("seq".to_string(), Json::Num(seq as f64));
        self.map.insert("t_us".to_string(), Json::Num(t_us.min((1 << 53) - 1) as f64));
        Json::Obj(self.map).to_string()
    }
}

// ---------------------------------------------------------------------------
// sink

enum SinkMsg {
    Line(String),
    Flush(SyncSender<()>),
    Shutdown,
}

/// Bounded, non-blocking JSONL event sink.  Emitters assign sequence
/// numbers atomically and hand finished lines to a dedicated writer
/// thread; the writer flushes per line so a killed process (the CI
/// record job SIGTERMs the server) still leaves a readable prefix.
pub struct TraceSink {
    seq: AtomicU64,
    tx: SyncSender<SinkMsg>,
    writer: Mutex<Option<JoinHandle<()>>>,
    t0: Instant,
}

impl std::fmt::Debug for TraceSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceSink").field("seq", &self.seq.load(Ordering::Relaxed)).finish()
    }
}

impl TraceSink {
    /// Open a sink writing to `path` (truncating any existing file).
    pub fn to_file(path: &str) -> Result<Arc<TraceSink>> {
        let file = File::create(path).with_context(|| format!("create trace file {path}"))?;
        let (tx, rx) = mpsc::sync_channel(QUEUE_CAP);
        let writer = std::thread::Builder::new()
            .name("ce-trace-writer".into())
            .spawn(move || writer_loop(rx, BufWriter::new(file)))
            .context("spawn trace writer")?;
        Ok(Arc::new(TraceSink {
            seq: AtomicU64::new(0),
            tx,
            writer: Mutex::new(Some(writer)),
            t0: Instant::now(),
        }))
    }

    /// Resolve the cloud-side recorder: an explicit config path wins,
    /// else the `CE_TRACE` env var, else off.  A path that cannot be
    /// opened logs a warning and disables tracing rather than killing
    /// the server.
    pub fn resolve(explicit: Option<&str>) -> Option<Arc<TraceSink>> {
        let owned;
        let path = match explicit {
            Some(p) => p,
            None => match std::env::var(TRACE_ENV) {
                Ok(p) if !p.trim().is_empty() => {
                    owned = p;
                    owned.as_str()
                }
                _ => return None,
            },
        };
        match Self::to_file(path) {
            Ok(s) => Some(s),
            Err(e) => {
                warn!("trace disabled: {e:#}");
                None
            }
        }
    }

    /// Emit one event.  Returns `true` when the event was queued,
    /// `false` when the queue was saturated and the event dropped —
    /// callers count the outcome into their `trace_events` /
    /// `trace_dropped` stats.  Never blocks.
    pub fn emit(&self, ev: Ev) -> bool {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let t_us = self.t0.elapsed().as_micros() as u64;
        match self.tx.try_send(SinkMsg::Line(ev.into_line(seq, t_us))) {
            Ok(()) => true,
            Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => false,
        }
    }

    /// Block until every event queued so far has reached the file.
    pub fn flush(&self) {
        let (tx, rx) = mpsc::sync_channel(1);
        if self.tx.send(SinkMsg::Flush(tx)).is_ok() {
            let _ = rx.recv();
        }
    }

    /// Events emitted so far (== the next sequence number).
    pub fn emitted(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }
}

impl Drop for TraceSink {
    fn drop(&mut self) {
        // writer drains the queue, sees Shutdown, flushes, exits
        let _ = self.tx.send(SinkMsg::Shutdown);
        if let Ok(mut guard) = self.writer.lock() {
            if let Some(h) = guard.take() {
                let _ = h.join();
            }
        }
    }
}

fn writer_loop(rx: Receiver<SinkMsg>, mut out: BufWriter<File>) {
    while let Ok(msg) = rx.recv() {
        match msg {
            SinkMsg::Line(l) => {
                // per-line flush: a SIGTERM'd recording is still a
                // readable prefix (the CI record job relies on it)
                let _ = out.write_all(l.as_bytes());
                let _ = out.write_all(b"\n");
                let _ = out.flush();
            }
            SinkMsg::Flush(ack) => {
                let _ = out.flush();
                let _ = ack.send(());
            }
            SinkMsg::Shutdown => break,
        }
    }
    let _ = out.flush();
}

// ---------------------------------------------------------------------------
// parsing

/// One parsed trace line.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub seq: u64,
    pub ev: String,
    pub fields: Json,
}

impl TraceEvent {
    pub fn u(&self, k: &str) -> Result<u64> {
        self.fields
            .get(k)
            .and_then(|v| v.as_i64())
            .filter(|&v| v >= 0)
            .map(|v| v as u64)
            .with_context(|| format!("event '{}' seq {}: missing field '{k}'", self.ev, self.seq))
    }

    pub fn u_opt(&self, k: &str) -> Option<u64> {
        self.fields.get(k).and_then(|v| v.as_i64()).filter(|&v| v >= 0).map(|v| v as u64)
    }

    pub fn i(&self, k: &str) -> Result<i64> {
        self.fields
            .get(k)
            .and_then(|v| v.as_i64())
            .with_context(|| format!("event '{}' seq {}: missing field '{k}'", self.ev, self.seq))
    }

    pub fn s(&self, k: &str) -> Result<&str> {
        self.fields
            .get(k)
            .and_then(|v| v.as_str())
            .with_context(|| format!("event '{}' seq {}: missing field '{k}'", self.ev, self.seq))
    }

    pub fn b(&self, k: &str) -> Result<bool> {
        match self.fields.get(k) {
            Some(Json::Bool(b)) => Ok(*b),
            _ => bail!("event '{}' seq {}: missing bool field '{k}'", self.ev, self.seq),
        }
    }

    /// Full-width u64 stored as a `"0x…"` string (see [`Ev::hex`]).
    pub fn hex_u64(&self, k: &str) -> Result<u64> {
        let s = self.s(k)?;
        let digits = s.strip_prefix("0x").unwrap_or(s);
        u64::from_str_radix(digits, 16)
            .with_context(|| format!("event '{}' seq {}: bad hex field '{k}'", self.ev, self.seq))
    }

    /// f32 payload recorded by [`Ev::hex_f32s`].
    pub fn f32s(&self, k: &str) -> Result<Vec<f32>> {
        let s = self.s(k)?;
        ensure!(s.len() % 8 == 0, "hex f32 field '{k}' has odd length {}", s.len());
        let mut out = Vec::with_capacity(s.len() / 8);
        let bytes = s.as_bytes();
        let nib = |c: u8| -> Result<u8> {
            match c {
                b'0'..=b'9' => Ok(c - b'0'),
                b'a'..=b'f' => Ok(c - b'a' + 10),
                b'A'..=b'F' => Ok(c - b'A' + 10),
                _ => bail!("bad hex digit {c:#x} in field '{k}'"),
            }
        };
        for chunk in bytes.chunks_exact(8) {
            let mut le = [0u8; 4];
            for (i, pair) in chunk.chunks_exact(2).enumerate() {
                le[i] = (nib(pair[0])? << 4) | nib(pair[1])?;
            }
            out.push(f32::from_le_bytes(le));
        }
        Ok(out)
    }
}

/// Parse a JSONL trace into events sorted by `seq`.  Rejects unknown
/// schema versions (the versioning rule); unknown *event types* are
/// deferred to the replayer, which must error on them.
pub fn parse_trace(text: &str) -> Result<Vec<TraceEvent>> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line).map_err(|e| anyhow::anyhow!("trace line {}: {e}", i + 1))?;
        let v = j
            .get("v")
            .and_then(|v| v.as_i64())
            .with_context(|| format!("trace line {}: missing version", i + 1))?;
        ensure!(
            v == TRACE_VERSION as i64,
            "trace line {}: unsupported trace version {v} (reader supports v{TRACE_VERSION})",
            i + 1
        );
        let seq = j
            .get("seq")
            .and_then(|v| v.as_i64())
            .filter(|&s| s >= 0)
            .with_context(|| format!("trace line {}: missing seq", i + 1))? as u64;
        let ev = j
            .get("ev")
            .and_then(|v| v.as_str())
            .with_context(|| format!("trace line {}: missing ev", i + 1))?
            .to_string();
        out.push(TraceEvent { seq, ev, fields: j });
    }
    out.sort_by_key(|e| e.seq);
    Ok(out)
}

/// Read and parse a trace file.
pub fn parse_trace_file(path: &str) -> Result<Vec<TraceEvent>> {
    let text = std::fs::read_to_string(path).with_context(|| format!("read trace {path}"))?;
    parse_trace(&text)
}

// ---------------------------------------------------------------------------
// trace-anchored fault schedules

/// What to do at an anchored trace point (reactor side).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AnchorKind {
    /// Sever the connection right after the anchored frame is routed.
    Sever,
    /// Drop the anchored frame (the ordinal still counts).
    Drop,
    /// Stall the connection this long before routing the anchored frame.
    DelayMs(u64),
}

/// Build a [`ReactorFault`] that fires at a recorded reactor frame:
/// `seq` must name a `frame_in` event, whose per-connection inbound
/// ordinal becomes the schedule's trigger — "sever after the frame with
/// seq N" expressed in the reactor's own unit, so re-running the same
/// deterministic workload hits the same protocol step.
pub fn anchored_fault(events: &[TraceEvent], seq: u64, kind: AnchorKind) -> Result<ReactorFault> {
    let e = events
        .iter()
        .find(|e| e.seq == seq)
        .with_context(|| format!("no trace event with seq {seq}"))?;
    ensure!(
        e.ev == "frame_in",
        "seq {seq} is a '{}' event; reactor faults anchor to 'frame_in'",
        e.ev
    );
    let ordinal = e.u("ordinal")?;
    let mut f = ReactorFault::default();
    match kind {
        AnchorKind::Sever => f.sever_in_at = Some(ordinal),
        AnchorKind::Drop => f.drop_in_at = Some(ordinal),
        AnchorKind::DelayMs(ms) => {
            f.delay_in_at = Some(ordinal);
            f.delay_in_ms = ms;
        }
    }
    Ok(f)
}

/// Build a client-side [`FaultPlan`] anchored at a recorded edge frame:
/// `seq` must name an `edge_send` or `edge_recv` event; its per-channel
/// ordinal `n` keys the plan on the matching direction.
pub fn anchored_plan(events: &[TraceEvent], seq: u64, kind: AnchorKind) -> Result<FaultPlan> {
    let e = events
        .iter()
        .find(|e| e.seq == seq)
        .with_context(|| format!("no trace event with seq {seq}"))?;
    let n = e.u("n")?;
    let send_side = match e.ev.as_str() {
        "edge_send" => true,
        "edge_recv" => false,
        other => bail!("seq {seq} is a '{other}' event; plans anchor to edge_send/edge_recv"),
    };
    let plan = FaultPlan::new();
    Ok(match (send_side, kind) {
        (true, AnchorKind::Sever) => plan.sever_send_at(n),
        (true, AnchorKind::Drop) => plan.drop_send_at(n),
        (true, AnchorKind::DelayMs(ms)) => plan.delay_send_at(n, ms),
        (false, AnchorKind::Sever) => plan.sever_recv_at(n),
        (false, AnchorKind::Drop) => plan.drop_recv_at(n),
        (false, AnchorKind::DelayMs(ms)) => plan.delay_recv_at(n, ms),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_path(tag: &str) -> String {
        let d = std::env::temp_dir();
        d.join(format!("ce_trace_{tag}_{}.jsonl", std::process::id())).display().to_string()
    }

    #[test]
    fn sink_writes_versioned_lines_with_monotonic_seq() {
        let path = tmp_path("sink");
        let sink = TraceSink::to_file(&path).unwrap();
        assert!(sink.emit(Ev::new("conn_open").u("shard", 0).u("conn", 1)));
        assert!(sink.emit(Ev::new("token").u("device", 3).u("req", 1).u("pos", 7).i("token", 99)));
        sink.flush();
        drop(sink);
        let events = parse_trace_file(&path).unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].seq, 0);
        assert_eq!(events[1].seq, 1);
        assert_eq!(events[0].ev, "conn_open");
        assert_eq!(events[1].u("device").unwrap(), 3);
        assert_eq!(events[1].i("token").unwrap(), 99);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn hex_f32_roundtrip_is_bit_exact() {
        let path = tmp_path("hex");
        let sink = TraceSink::to_file(&path).unwrap();
        let data = vec![0.5f32, -1.25, f32::MIN_POSITIVE, 0.95, 1e30];
        sink.emit(Ev::new("upload").u("device", 1).hex_f32s("data", &data).hex("session", u64::MAX));
        sink.flush();
        drop(sink);
        let events = parse_trace_file(&path).unwrap();
        let got = events[0].f32s("data").unwrap();
        assert_eq!(got.len(), data.len());
        for (a, b) in got.iter().zip(&data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(events[0].hex_u64("session").unwrap(), u64::MAX);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn parser_rejects_unknown_version() {
        let line = r#"{"ev":"token","seq":0,"v":2}"#;
        let err = parse_trace(line).unwrap_err().to_string();
        assert!(err.contains("unsupported trace version"), "{err}");
    }

    #[test]
    fn parser_sorts_by_seq_and_skips_blank_lines() {
        let text = "\n{\"ev\":\"b\",\"seq\":1,\"v\":1}\n\n{\"ev\":\"a\",\"seq\":0,\"v\":1}\n";
        let events = parse_trace(text).unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].ev, "a");
        assert_eq!(events[1].ev, "b");
    }

    #[test]
    fn resolve_is_off_without_config_or_env() {
        // test processes never set CE_TRACE; explicit None must be off
        if std::env::var(TRACE_ENV).is_err() {
            assert!(TraceSink::resolve(None).is_none());
        }
    }

    #[test]
    fn anchored_fault_maps_seq_to_conn_ordinal() {
        let text = concat!(
            "{\"ev\":\"frame_in\",\"seq\":4,\"v\":1,\"shard\":0,\"conn\":2,",
            "\"ordinal\":7,\"tag\":2,\"len\":30}\n",
            "{\"ev\":\"token\",\"seq\":5,\"v\":1}\n",
        );
        let events = parse_trace(text).unwrap();
        let f = anchored_fault(&events, 4, AnchorKind::Sever).unwrap();
        assert_eq!(f.sever_in_at, Some(7));
        let f = anchored_fault(&events, 4, AnchorKind::DelayMs(25)).unwrap();
        assert_eq!(f.delay_in_at, Some(7));
        assert_eq!(f.delay_in_ms, 25);
        let f = anchored_fault(&events, 4, AnchorKind::Drop).unwrap();
        assert_eq!(f.drop_in_at, Some(7));
        // a non-frame event is not an anchor
        assert!(anchored_fault(&events, 5, AnchorKind::Sever).is_err());
        assert!(anchored_fault(&events, 99, AnchorKind::Sever).is_err());
    }

    #[test]
    fn anchored_plan_maps_edge_events_to_plan_ordinals() {
        let text = concat!(
            "{\"ev\":\"edge_send\",\"seq\":0,\"v\":1,\"device\":1,\"chan\":\"upload\",",
            "\"n\":3,\"tag\":2,\"len\":30}\n",
            "{\"ev\":\"edge_recv\",\"seq\":1,\"v\":1,\"device\":1,\"chan\":\"infer\",",
            "\"n\":5,\"tag\":4,\"len\":21}\n",
        );
        let events = parse_trace(text).unwrap();
        assert!(!anchored_plan(&events, 0, AnchorKind::Sever).unwrap().is_empty());
        assert!(!anchored_plan(&events, 1, AnchorKind::DelayMs(10)).unwrap().is_empty());
    }
}
