//! `ce-collm` CLI — leader entrypoint.
//!
//! Subcommands:
//!   table 1|2|3|4        regenerate a paper table (real engines + DES)
//!   fig4                 regenerate Figure 4 series
//!   all                  every table + figure
//!   standalone <prompt>  edge standalone generation (low-latency mode)
//!   generate <prompt>    collaborative generation, local engines
//!   serve-cloud          run the cloud server (TCP)
//!   run-edge <prompt>    run an edge client against a cloud server
//!   trace-record <file>  record a short mock e2e run (TCP, CE_TRACE twin)
//!   trace-replay <file>  replay a recorded trace, assert bit-identical
//!   calibrate            measure per-call costs and print the cost model
//!
//! Common flags: --artifacts DIR (default "artifacts"), --prompts N,
//! --repeats N, --max-new N, --link wifi|lte|fiber|lan|ideal,
//! --threshold T, --clients N, --addr HOST:PORT, --seed N.

use std::sync::Arc;

use anyhow::{Context, Result};

use ce_collm::config::{CloudConfig, DeploymentConfig};
use ce_collm::coordinator::cloud::{CloudServer, SessionFactory};
use ce_collm::coordinator::edge::{CloudLink, EdgeClient};
use ce_collm::harness::runner::{record_main_experiments, ExperimentConfig};
use ce_collm::harness::tables;
use ce_collm::harness::trace::CallTimings;
use ce_collm::net::profiles::LinkProfile;
use ce_collm::runtime::stack::LocalStack;
use ce_collm::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn experiment_config(args: &Args) -> ExperimentConfig {
    ExperimentConfig {
        n_prompts: args.get_parse("prompts", 25usize),
        repeats: args.get_parse("repeats", 5usize),
        max_new_tokens: args.get_parse("max-new", 96usize),
        seed: args.get_parse("seed", 42u64),
    }
}

fn link(args: &Args) -> Result<LinkProfile> {
    let name = args.get_or("link", "wifi");
    LinkProfile::by_name(&name).with_context(|| format!("unknown link profile '{name}'"))
}

fn run() -> Result<()> {
    let args = Args::parse_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let artifacts = args.get_or("artifacts", "artifacts");

    match cmd {
        "table" => {
            let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("2");
            let stack = LocalStack::load(&artifacts)?;
            let cfg = experiment_config(&args);
            let mut edge = stack.edge_session();
            let mut cloud = stack.cloud_session();
            match which {
                "1" => {
                    let prompt = args.get_or("prompt", "the machine is a");
                    println!(
                        "{}",
                        tables::table1(&mut edge, &mut cloud, &prompt,
                                       args.get_parse("max-new", 24usize))?
                    );
                }
                "2" => {
                    let rec = record_main_experiments(&mut edge, &mut cloud, &cfg)?;
                    println!("{}", tables::table2(&rec, &stack.manifest.model, link(&args)?, &cfg));
                }
                "3" => {
                    println!("{}", tables::table3(&mut edge, &mut cloud, &cfg)?);
                }
                "4" => {
                    let rec = record_main_experiments(&mut edge, &mut cloud, &cfg)?;
                    println!("{}", tables::table4(&rec, &stack.manifest.model, link(&args)?, &cfg));
                }
                other => anyhow::bail!("unknown table '{other}' (1-4)"),
            }
        }
        "fig4" => {
            let stack = LocalStack::load(&artifacts)?;
            let cfg = experiment_config(&args);
            let mut edge = stack.edge_session();
            let mut cloud = stack.cloud_session();
            let rec = record_main_experiments(&mut edge, &mut cloud, &cfg)?;
            println!(
                "{}",
                tables::fig4(&rec, &stack.manifest.model, link(&args)?, &cfg,
                             args.get_parse("clients", 5usize))
            );
        }
        "all" => {
            let stack = LocalStack::load(&artifacts)?;
            let cfg = experiment_config(&args);
            let l = link(&args)?;
            let mut edge = stack.edge_session();
            let mut cloud = stack.cloud_session();
            println!("=== Table 1 ===");
            println!("{}", tables::table1(&mut edge, &mut cloud, "the machine is a", 24)?);
            let rec = record_main_experiments(&mut edge, &mut cloud, &cfg)?;
            println!("\n=== Table 2 ===");
            println!("{}", tables::table2(&rec, &stack.manifest.model, l, &cfg));
            println!("\n=== Table 3 ===");
            println!("{}", tables::table3(&mut edge, &mut cloud, &cfg)?);
            println!("\n=== Table 4 ===");
            println!("{}", tables::table4(&rec, &stack.manifest.model, l, &cfg));
            println!("\n=== Figure 4 ===");
            println!(
                "{}",
                tables::fig4(&rec, &stack.manifest.model, l, &cfg,
                             args.get_parse("clients", 5usize))
            );
        }
        "standalone" | "generate" => {
            let prompt = args
                .positional
                .get(1)
                .cloned()
                .unwrap_or_else(|| "the machine is a".to_string());
            let stack = LocalStack::load(&artifacts)?;
            let mut cfg = if cmd == "standalone" {
                DeploymentConfig::standalone()
            } else {
                DeploymentConfig::with_threshold(args.get_parse("threshold", 0.8f32))
            };
            cfg.max_new_tokens = args.get_parse("max-new", 64usize);
            if cmd == "generate" {
                // local in-process generation via the trace recorder
                let mut edge = stack.edge_session();
                let mut cloud = stack.cloud_session();
                let mut timings = CallTimings::default();
                let tr = ce_collm::harness::trace::record(
                    &mut edge,
                    &mut cloud,
                    cfg.policy,
                    ce_collm::quant::Precision::F16,
                    &prompt,
                    cfg.max_new_tokens,
                    &mut timings,
                )?;
                println!("{}", tr.text);
                eprintln!(
                    "[{} tokens: {} exit1, {} exit2, {} cloud]",
                    tr.tokens.len(),
                    tr.count(ce_collm::coordinator::policy::ExitPoint::Exit1),
                    tr.count(ce_collm::coordinator::policy::ExitPoint::Exit2),
                    tr.count(ce_collm::coordinator::policy::ExitPoint::Cloud),
                );
            } else {
                let mut client = EdgeClient::standalone(stack.edge_session(), cfg);
                let out = client.generate(&prompt)?;
                println!("{}", out.text);
                eprintln!("[{} tokens, {}]", out.tokens.len(), out.cost);
            }
        }
        "serve-cloud" => {
            let addr = args.get_or("addr", "127.0.0.1:7433");
            let workers: usize = args.get_parse("workers", 1);
            let dims = ce_collm::model::manifest::Manifest::load(
                std::path::Path::new(&artifacts),
            )?
            .model;
            let mut cfg = CloudConfig::with_workers(workers);
            cfg.reactor.shards = args.get_parse("shards", 0usize); // 0 = auto
            if let Some(path) = args.get("trace") {
                // config wants &'static str; the path lives for the whole
                // process anyway (serve-cloud never returns)
                cfg.trace = Some(Box::leak(path.to_string().into_boxed_str()));
            }
            let art2 = artifacts.clone();
            // each worker loads its own stack on its own thread (PJRT is
            // thread-local); the builder runs once per worker.  bind()
            // gives the reactor fleet per-shard SO_REUSEPORT listeners
            // on Linux (kernel-balanced accepts)
            let server = CloudServer::bind(&addr, dims, cfg, move || {
                let stack = LocalStack::load(&art2)?;
                let f: SessionFactory =
                    Box::new(move |_| Ok(Box::new(stack.cloud_session()) as _));
                Ok(f)
            })?;
            println!(
                "cloud server listening on {addr} ({workers} workers, {} reactor shards, \
                 artifacts: {artifacts})",
                server.shards()
            );
            println!("ready; Ctrl-C to stop");
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
                let _ = server.stats();
            }
        }
        "run-edge" => {
            let prompt = args
                .positional
                .get(1)
                .cloned()
                .unwrap_or_else(|| "the machine is a".to_string());
            // --addrs takes an ordered failover list; --addr stays as the
            // single-endpoint spelling (both feed the same reconnect path)
            let endpoints: Vec<String> = match args.get("addrs") {
                Some(list) => list.split(',').map(|s| s.trim().to_string()).collect(),
                None => vec![args.get_or("addr", "127.0.0.1:7433")],
            };
            let stack = LocalStack::load(&artifacts)?;
            let mut cfg = DeploymentConfig::with_threshold(args.get_parse("threshold", 0.8f32));
            cfg.max_new_tokens = args.get_parse("max-new", 64usize);
            cfg.device_id = args.get_parse("device-id", 1u64);
            let budget_ms: u64 = args.get_parse("budget-ms", 0);
            if budget_ms > 0 {
                cfg.cloud_token_budget_s = Some(budget_ms as f64 / 1e3);
            }
            let link = CloudLink::connect(cfg.device_id, &endpoints, cfg.reconnect)?;
            let mut client = EdgeClient::with_cloud(stack.edge_session(), cfg, link);
            let out = client.generate(&prompt)?;
            println!("{}", out.text);
            eprintln!(
                "[{} tokens; cloud rate {:.1}%; {} deadline fallbacks; {} reconnects \
                 ({} failovers); {}]",
                out.tokens.len(),
                out.counters.request_cloud_rate() * 100.0,
                out.counters.cloud_fallbacks,
                out.counters.reconnects,
                out.counters.failovers,
                out.cost
            );
        }
        "trace-record" => {
            // a short mock-backed e2e serving run over real TCP with
            // recording on — the CI twin of `serve-cloud --trace` (no
            // artifacts needed); replay it with `trace-replay --seed N`
            let out = args.positional.get(1).context(
                "usage: trace-record <out.jsonl> [--seed N] [--max-new N] [--workers N]",
            )?;
            let seed: u64 = args.get_parse("seed", 1u64);
            let workers: usize = args.get_parse("workers", 1);
            let dims = ce_collm::model::manifest::test_manifest().model;
            let mut cfg = CloudConfig::with_workers(workers);
            cfg.trace = Some(Box::leak(out.to_string().into_boxed_str()));
            let sdims = dims.clone();
            let server = CloudServer::bind("127.0.0.1:0", dims.clone(), cfg, move || {
                let sdims = sdims.clone();
                let f: SessionFactory = Box::new(move |_device| {
                    Ok(Box::new(ce_collm::runtime::mock::MockCloud::new(
                        ce_collm::runtime::mock::MockOracle::new(seed),
                        sdims.clone(),
                    )) as _)
                });
                Ok(f)
            })?;
            // θ = 1.0 defers every token to the cloud, so the recording
            // exercises the full upload/infer/park/pass cycle per token
            let mut dcfg = DeploymentConfig::with_threshold(1.0);
            dcfg.device_id = 0;
            dcfg.max_new_tokens = args.get_parse("max-new", 12usize);
            let link = CloudLink::connect(0, &[server.addr.to_string()], dcfg.reconnect)?;
            let mut client = EdgeClient::with_cloud(
                ce_collm::runtime::mock::MockEdge::new(
                    ce_collm::runtime::mock::MockOracle::new(seed),
                    dims,
                ),
                dcfg,
                link,
            );
            let gen = client.generate(&args.get_or("prompt", "a ci trace prompt"))?;
            let stats = server.shutdown();
            println!(
                "recorded {} scheduler events ({} dropped) over {} served tokens -> {out}",
                stats.trace_events,
                stats.trace_dropped,
                gen.tokens.len()
            );
        }
        "trace-replay" => {
            // replays drive mock engines (--seed must match the recorded
            // run); the real-engine replay path goes through the library
            let path = args
                .positional
                .get(1)
                .context("usage: trace-replay <trace.jsonl> [--seed N] [--des]")?;
            let seed: u64 = args.get_parse("seed", 1u64);
            let dims = ce_collm::model::manifest::test_manifest().model;
            let events = ce_collm::trace::parse_trace_file(path)?;
            let sdims = dims.clone();
            let builder: ce_collm::coordinator::scheduler::FactoryBuilder = Arc::new(move || {
                let sdims = sdims.clone();
                let f: SessionFactory = Box::new(move |_device| {
                    Ok(Box::new(ce_collm::runtime::mock::MockCloud::new(
                        ce_collm::runtime::mock::MockOracle::new(seed),
                        sdims.clone(),
                    )) as _)
                });
                Ok(f)
            });
            let report = ce_collm::trace::replay(&events, &dims, builder)?;
            println!("{}", report.summary());
            if args.has("des") {
                match ce_collm::trace::des_check(&events, &dims) {
                    Ok(des) => println!("{}", des.summary()),
                    Err(e) => println!("des check skipped: {e:#}"),
                }
            }
            if !report.ok() {
                std::process::exit(1);
            }
        }
        "calibrate" => {
            let stack = LocalStack::load(&artifacts)?;
            let cfg = ExperimentConfig {
                n_prompts: args.get_parse("prompts", 5usize),
                ..experiment_config(&args)
            };
            let mut edge = stack.edge_session();
            let mut cloud = stack.cloud_session();
            let rec = record_main_experiments(&mut edge, &mut cloud, &cfg)?;
            println!("calibrated cost model (seconds):");
            println!("  edge_prefill : {:?}", rec.cost.edge_prefill);
            println!("  seg1         : {:?}", rec.cost.seg1);
            println!("  seg2         : {:?}", rec.cost.seg2);
            println!("  cloud_prefill: {:?}", rec.cost.cloud_prefill);
            println!("  cloud_decode : {:?}", rec.cost.cloud_decode);
        }
        _ => {
            println!(
                "ce-collm — CE-CoLLM reproduction (cloud-edge collaborative LLM inference)\n\n\
                 usage: ce-collm <command> [flags]\n\n\
                 commands:\n\
                 \x20 table 1|2|3|4      regenerate a paper table\n\
                 \x20 fig4               regenerate Figure 4\n\
                 \x20 all                everything\n\
                 \x20 standalone <p>     edge standalone generation\n\
                 \x20 generate <p>       collaborative generation (local)\n\
                 \x20 serve-cloud        start the cloud server\n\
                 \x20 run-edge <p>       edge client against a server\n\
                 \x20 trace-record <f>   record a short mock e2e run (TCP)\n\
                 \x20 trace-replay <f>   replay a recorded trace (mock engines)\n\
                 \x20 calibrate          print the measured cost model\n\n\
                 flags: --artifacts DIR --prompts N --repeats N --max-new N\n\
                 \x20      --link wifi|lte|fiber|lan|ideal --threshold T\n\
                 \x20      --clients N --addr HOST:PORT --seed N\n\
                 \x20      --workers N (serve-cloud scheduler pool)\n\
                 \x20      --trace PATH (serve-cloud: record a TRACE v1 JSONL)\n\
                 \x20      --budget-ms N (run-edge per-token cloud latency budget)\n\
                 \x20      --addrs A,B,... (run-edge ordered failover endpoints)\n\
                 \x20      --des (trace-replay: cross-validate against the DES)"
            );
        }
    }
    Ok(())
}
