//! `ce-collm` CLI — leader entrypoint.
//!
//! Subcommands:
//!   table 1|2|3|4        regenerate a paper table (real engines + DES)
//!   fig4                 regenerate Figure 4 series
//!   all                  every table + figure
//!   standalone <prompt>  edge standalone generation (low-latency mode)
//!   generate <prompt>    collaborative generation, local engines
//!   serve-cloud          run the cloud server (TCP)
//!   run-edge <prompt>    run an edge client against a cloud server
//!   trace-record <file>  record a short mock e2e run (TCP, CE_TRACE twin)
//!   trace-replay <file>  replay a recorded trace, assert bit-identical
//!   stats                scrape a running server's /metrics, pretty-print
//!   calibrate            measure per-call costs and print the cost model
//!
//! Common flags: --artifacts DIR (default "artifacts"), --prompts N,
//! --repeats N, --max-new N, --link wifi|lte|fiber|lan|ideal,
//! --threshold T, --clients N, --addr HOST:PORT, --seed N.

use std::sync::Arc;

use anyhow::{Context, Result};

use ce_collm::config::{CloudConfig, DeploymentConfig, ReplicationConfig};
use ce_collm::coordinator::cloud::{CloudServer, SessionFactory};
use ce_collm::coordinator::edge::{CloudLink, EdgeClient, ReplicaSet};
use ce_collm::harness::runner::{record_main_experiments, ExperimentConfig};
use ce_collm::harness::tables;
use ce_collm::harness::trace::CallTimings;
use ce_collm::net::profiles::LinkProfile;
use ce_collm::runtime::stack::LocalStack;
use ce_collm::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

#[cfg(unix)]
mod sigint {
    //! Minimal SIGINT latch over libc's `signal(2)` (already linked by
    //! std) — the handler only flips an atomic, the serve loop polls it.
    use std::sync::atomic::{AtomicBool, Ordering};

    static HIT: AtomicBool = AtomicBool::new(false);
    const SIGINT: i32 = 2;

    extern "C" fn on_sigint(_sig: i32) {
        HIT.store(true, Ordering::SeqCst);
    }

    pub fn install() {
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        unsafe {
            signal(SIGINT, on_sigint as usize);
        }
    }

    pub fn hit() -> bool {
        HIT.load(Ordering::SeqCst)
    }
}

/// One-shot scrape of the reactor's in-band `/metrics` endpoint: any
/// shard sniffs the `GET ` prefix on a fresh connection, answers one
/// HTTP/1.0 response, and closes — so read-to-EOF is the protocol.
fn scrape_metrics(addr: &str) -> Result<String> {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    s.write_all(b"GET /metrics HTTP/1.0\r\n\r\n")?;
    let mut raw = Vec::new();
    s.read_to_end(&mut raw)?;
    let text = String::from_utf8_lossy(&raw);
    text.split_once("\r\n\r\n")
        .map(|(_, body)| body.to_string())
        .context("metrics response has no header/body split")
}

/// Render a parsed exposition for humans: histograms as percentile
/// lines (ns families shown in microseconds), scalars verbatim.
fn render_stats(body: &str) -> Result<String> {
    use std::fmt::Write as _;
    let exp = ce_collm::metrics::parse_exposition(body)
        .map_err(|e| anyhow::anyhow!("bad exposition: {e}"))?;
    let fmt_labels = |labels: &[(String, String)]| -> String {
        if labels.is_empty() {
            String::new()
        } else {
            let inner: Vec<String> =
                labels.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect();
            format!("{{{}}}", inner.join(","))
        }
    };
    let mut out = String::new();
    for (base, ty) in &exp.types {
        if ty != "histogram" {
            continue;
        }
        for s in exp.samples_named(&format!("{base}_count")) {
            let labels: Vec<(&str, &str)> =
                s.labels.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
            let q = |qv: f64| exp.hist_quantile(base, &labels, qv).unwrap_or(0.0);
            let lbl = fmt_labels(&s.labels);
            if base.ends_with("_ns") {
                let _ = writeln!(
                    out,
                    "  {base}{lbl}: n={} p50={:.0}us p90={:.0}us p99={:.0}us",
                    s.value as u64,
                    q(0.50) / 1e3,
                    q(0.90) / 1e3,
                    q(0.99) / 1e3,
                );
            } else {
                let _ = writeln!(
                    out,
                    "  {base}{lbl}: n={} p50={:.0} p90={:.0} p99={:.0}",
                    s.value as u64,
                    q(0.50),
                    q(0.90),
                    q(0.99),
                );
            }
        }
    }
    let hist_part = |name: &str| {
        ["_bucket", "_sum", "_count"].iter().any(|suf| {
            name.strip_suffix(suf)
                .is_some_and(|b| exp.types.get(b).is_some_and(|t| t == "histogram"))
        })
    };
    for s in &exp.samples {
        if hist_part(&s.name) {
            continue;
        }
        let _ = writeln!(out, "  {}{}  {}", s.name, fmt_labels(&s.labels), s.value);
    }
    Ok(out)
}

fn experiment_config(args: &Args) -> ExperimentConfig {
    ExperimentConfig {
        n_prompts: args.get_parse("prompts", 25usize),
        repeats: args.get_parse("repeats", 5usize),
        max_new_tokens: args.get_parse("max-new", 96usize),
        seed: args.get_parse("seed", 42u64),
    }
}

fn link(args: &Args) -> Result<LinkProfile> {
    let name = args.get_or("link", "wifi");
    LinkProfile::by_name(&name).with_context(|| format!("unknown link profile '{name}'"))
}

fn run() -> Result<()> {
    let args = Args::parse_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let artifacts = args.get_or("artifacts", "artifacts");

    match cmd {
        "table" => {
            let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("2");
            let stack = LocalStack::load(&artifacts)?;
            let cfg = experiment_config(&args);
            let mut edge = stack.edge_session();
            let mut cloud = stack.cloud_session();
            match which {
                "1" => {
                    let prompt = args.get_or("prompt", "the machine is a");
                    println!(
                        "{}",
                        tables::table1(&mut edge, &mut cloud, &prompt,
                                       args.get_parse("max-new", 24usize))?
                    );
                }
                "2" => {
                    let rec = record_main_experiments(&mut edge, &mut cloud, &cfg)?;
                    println!("{}", tables::table2(&rec, &stack.manifest.model, link(&args)?, &cfg));
                }
                "3" => {
                    println!("{}", tables::table3(&mut edge, &mut cloud, &cfg)?);
                }
                "4" => {
                    let rec = record_main_experiments(&mut edge, &mut cloud, &cfg)?;
                    println!("{}", tables::table4(&rec, &stack.manifest.model, link(&args)?, &cfg));
                }
                other => anyhow::bail!("unknown table '{other}' (1-4)"),
            }
        }
        "fig4" => {
            let stack = LocalStack::load(&artifacts)?;
            let cfg = experiment_config(&args);
            let mut edge = stack.edge_session();
            let mut cloud = stack.cloud_session();
            let rec = record_main_experiments(&mut edge, &mut cloud, &cfg)?;
            println!(
                "{}",
                tables::fig4(&rec, &stack.manifest.model, link(&args)?, &cfg,
                             args.get_parse("clients", 5usize))
            );
        }
        "all" => {
            let stack = LocalStack::load(&artifacts)?;
            let cfg = experiment_config(&args);
            let l = link(&args)?;
            let mut edge = stack.edge_session();
            let mut cloud = stack.cloud_session();
            println!("=== Table 1 ===");
            println!("{}", tables::table1(&mut edge, &mut cloud, "the machine is a", 24)?);
            let rec = record_main_experiments(&mut edge, &mut cloud, &cfg)?;
            println!("\n=== Table 2 ===");
            println!("{}", tables::table2(&rec, &stack.manifest.model, l, &cfg));
            println!("\n=== Table 3 ===");
            println!("{}", tables::table3(&mut edge, &mut cloud, &cfg)?);
            println!("\n=== Table 4 ===");
            println!("{}", tables::table4(&rec, &stack.manifest.model, l, &cfg));
            println!("\n=== Figure 4 ===");
            println!(
                "{}",
                tables::fig4(&rec, &stack.manifest.model, l, &cfg,
                             args.get_parse("clients", 5usize))
            );
        }
        "standalone" | "generate" => {
            let prompt = args
                .positional
                .get(1)
                .cloned()
                .unwrap_or_else(|| "the machine is a".to_string());
            let stack = LocalStack::load(&artifacts)?;
            let mut cfg = if cmd == "standalone" {
                DeploymentConfig::standalone()
            } else {
                DeploymentConfig::with_threshold(args.get_parse("threshold", 0.8f32))
            };
            cfg.max_new_tokens = args.get_parse("max-new", 64usize);
            if cmd == "generate" {
                // local in-process generation via the trace recorder
                let mut edge = stack.edge_session();
                let mut cloud = stack.cloud_session();
                let mut timings = CallTimings::default();
                let tr = ce_collm::harness::trace::record(
                    &mut edge,
                    &mut cloud,
                    cfg.policy,
                    ce_collm::quant::Precision::F16,
                    &prompt,
                    cfg.max_new_tokens,
                    &mut timings,
                )?;
                println!("{}", tr.text);
                eprintln!(
                    "[{} tokens: {} exit1, {} exit2, {} cloud]",
                    tr.tokens.len(),
                    tr.count(ce_collm::coordinator::policy::ExitPoint::Exit1),
                    tr.count(ce_collm::coordinator::policy::ExitPoint::Exit2),
                    tr.count(ce_collm::coordinator::policy::ExitPoint::Cloud),
                );
            } else {
                let mut client = EdgeClient::standalone(stack.edge_session(), cfg);
                let out = client.generate(&prompt)?;
                println!("{}", out.text);
                eprintln!("[{} tokens, {}]", out.tokens.len(), out.cost);
            }
        }
        "serve-cloud" => {
            let addr = args.get_or("addr", "127.0.0.1:7433");
            let workers: usize = args.get_parse("workers", 1);
            let dims = ce_collm::model::manifest::Manifest::load(
                std::path::Path::new(&artifacts),
            )?
            .model;
            let mut cfg = CloudConfig::with_workers(workers);
            cfg.reactor.shards = args.get_parse("shards", 0usize); // 0 = auto
            cfg.metrics = args.has("metrics");
            if let Some(path) = args.get("trace") {
                // config wants &'static str; the path lives for the whole
                // process anyway (serve-cloud never returns)
                cfg.trace = Some(Box::leak(path.to_string().into_boxed_str()));
            }
            let art2 = artifacts.clone();
            // each worker loads its own stack on its own thread (PJRT is
            // thread-local); the builder runs once per worker.  bind()
            // gives the reactor fleet per-shard SO_REUSEPORT listeners
            // on Linux (kernel-balanced accepts)
            let server = CloudServer::bind(&addr, dims, cfg, move || {
                let stack = LocalStack::load(&art2)?;
                let f: SessionFactory =
                    Box::new(move |_| Ok(Box::new(stack.cloud_session()) as _));
                Ok(f)
            })?;
            println!(
                "cloud server listening on {addr} ({workers} workers, {} reactor shards, \
                 artifacts: {artifacts})",
                server.shards()
            );
            if cfg.metrics {
                println!("metrics: GET /metrics on {addr} (or `ce-collm stats --addr {addr}`)");
            }
            println!("ready; Ctrl-C to stop");
            #[cfg(unix)]
            sigint::install();
            loop {
                std::thread::sleep(std::time::Duration::from_millis(250));
                #[cfg(unix)]
                if sigint::hit() {
                    eprintln!("SIGINT: shutting down");
                    // shutdown() folds the fleet's final counters in; the
                    // one-line JSON is the stable machine-readable record
                    let stats = server.shutdown();
                    println!("{}", stats.to_json());
                    return Ok(());
                }
            }
        }
        "stats" => {
            // scrape a running server's /metrics and pretty-print it;
            // --watch re-scrapes every 2s until interrupted
            let addr = args.get_or("addr", "127.0.0.1:7433");
            let watch = args.has("watch");
            loop {
                let body = scrape_metrics(&addr)?;
                if watch {
                    println!("--- {addr} ---");
                }
                print!("{}", render_stats(&body)?);
                if !watch {
                    break;
                }
                std::thread::sleep(std::time::Duration::from_secs(2));
            }
        }
        "run-edge" => {
            let prompt = args
                .positional
                .get(1)
                .cloned()
                .unwrap_or_else(|| "the machine is a".to_string());
            // --addrs takes an ordered failover list; --addr stays as the
            // single-endpoint spelling (both feed the same reconnect path)
            let endpoints: Vec<String> = match args.get("addrs") {
                Some(list) => list.split(',').map(|s| s.trim().to_string()).collect(),
                None => vec![args.get_or("addr", "127.0.0.1:7433")],
            };
            let stack = LocalStack::load(&artifacts)?;
            let mut cfg = DeploymentConfig::with_threshold(args.get_parse("threshold", 0.8f32));
            cfg.max_new_tokens = args.get_parse("max-new", 64usize);
            cfg.device_id = args.get_parse("device-id", 1u64);
            let budget_ms: u64 = args.get_parse("budget-ms", 0);
            if budget_ms > 0 {
                cfg.cloud_token_budget_s = Some(budget_ms as f64 / 1e3);
            }
            // --replicas opens N warm-standby sessions (mirror-bit
            // handshakes) against the same endpoint list, rotated so
            // each standby prefers a different endpoint; --hedge
            // additionally duplicates deadline-budgeted infers to the
            // best-scored standby
            let replicas: usize = args.get_parse("replicas", 0usize);
            let hedge = args.has("hedge");
            if replicas > 0 {
                cfg.replication = Some(ReplicationConfig { replicas, hedge });
            }
            let link = CloudLink::connect(cfg.device_id, &endpoints, cfg.reconnect)?;
            let mut client = if replicas > 0 {
                let mut set = ReplicaSet::new(hedge);
                for i in 0..replicas {
                    let mut rotated = endpoints.clone();
                    rotated.rotate_left((i + 1) % rotated.len().max(1));
                    set.add_standby(CloudLink::connect_mirror(
                        cfg.device_id,
                        &rotated,
                        cfg.reconnect,
                    )?);
                }
                EdgeClient::with_cloud_replicas(stack.edge_session(), cfg, link, set)
            } else {
                EdgeClient::with_cloud(stack.edge_session(), cfg, link)
            };
            let out = client.generate(&prompt)?;
            println!("{}", out.text);
            eprintln!(
                "[{} tokens; cloud rate {:.1}%; {} deadline fallbacks; {} reconnects \
                 ({} failovers, {} warm, {} cold); {}]",
                out.tokens.len(),
                out.counters.request_cloud_rate() * 100.0,
                out.counters.cloud_fallbacks,
                out.counters.reconnects,
                out.counters.failovers,
                out.counters.failovers_warm,
                out.counters.failovers_cold,
                out.cost
            );
            if let Some(set) = client.replicas() {
                eprintln!(
                    "[replicas: {} standby(s) live; health scores (ms) {:?}; \
                     {} hedged requests; {:.1} KiB mirrored]",
                    set.len(),
                    set.health_scores(),
                    out.counters.hedged_requests,
                    out.counters.bytes_mirrored as f64 / 1024.0
                );
            }
        }
        "trace-record" => {
            // a short mock-backed e2e serving run over real TCP with
            // recording on — the CI twin of `serve-cloud --trace` (no
            // artifacts needed); replay it with `trace-replay --seed N`
            let out = args.positional.get(1).context(
                "usage: trace-record <out.jsonl> [--seed N] [--max-new N] [--workers N] \
                 [--metrics OUT.prom]",
            )?;
            let seed: u64 = args.get_parse("seed", 1u64);
            let workers: usize = args.get_parse("workers", 1);
            let metrics_out = args.get("metrics").map(|p| p.to_string());
            let dims = ce_collm::model::manifest::test_manifest().model;
            let mut cfg = CloudConfig::with_workers(workers);
            cfg.trace = Some(Box::leak(out.to_string().into_boxed_str()));
            cfg.metrics = metrics_out.is_some();
            let sdims = dims.clone();
            let server = CloudServer::bind("127.0.0.1:0", dims.clone(), cfg, move || {
                let sdims = sdims.clone();
                let f: SessionFactory = Box::new(move |_device| {
                    Ok(Box::new(ce_collm::runtime::mock::MockCloud::new(
                        ce_collm::runtime::mock::MockOracle::new(seed),
                        sdims.clone(),
                    )) as _)
                });
                Ok(f)
            })?;
            // θ = 1.0 defers every token to the cloud, so the recording
            // exercises the full upload/infer/park/pass cycle per token
            let mut dcfg = DeploymentConfig::with_threshold(1.0);
            dcfg.device_id = 0;
            dcfg.max_new_tokens = args.get_parse("max-new", 12usize);
            let link = CloudLink::connect(0, &[server.addr.to_string()], dcfg.reconnect)?;
            let mut client = EdgeClient::with_cloud(
                ce_collm::runtime::mock::MockEdge::new(
                    ce_collm::runtime::mock::MockOracle::new(seed),
                    dims,
                ),
                dcfg,
                link,
            );
            let gen = client.generate(&args.get_or("prompt", "a ci trace prompt"))?;
            if let Some(path) = &metrics_out {
                // scrape while the server is still up, refuse to write a
                // bad artifact: empty or unparseable fails the run
                let body = scrape_metrics(&server.addr.to_string())?;
                let exp = ce_collm::metrics::parse_exposition(&body)
                    .map_err(|e| anyhow::anyhow!("scraped metrics unparseable: {e}"))?;
                anyhow::ensure!(!exp.samples.is_empty(), "scraped metrics are empty");
                std::fs::write(path, &body)
                    .with_context(|| format!("write metrics to {path}"))?;
                println!("scraped {} metric samples -> {path}", exp.samples.len());
            }
            let stats = server.shutdown();
            println!(
                "recorded {} scheduler events ({} dropped) over {} served tokens -> {out}",
                stats.trace_events,
                stats.trace_dropped,
                gen.tokens.len()
            );
        }
        "trace-replay" => {
            // replays drive mock engines (--seed must match the recorded
            // run); the real-engine replay path goes through the library
            let path = args
                .positional
                .get(1)
                .context("usage: trace-replay <trace.jsonl> [--seed N] [--des]")?;
            let seed: u64 = args.get_parse("seed", 1u64);
            let dims = ce_collm::model::manifest::test_manifest().model;
            let events = ce_collm::trace::parse_trace_file(path)?;
            let sdims = dims.clone();
            let builder: ce_collm::coordinator::scheduler::FactoryBuilder = Arc::new(move || {
                let sdims = sdims.clone();
                let f: SessionFactory = Box::new(move |_device| {
                    Ok(Box::new(ce_collm::runtime::mock::MockCloud::new(
                        ce_collm::runtime::mock::MockOracle::new(seed),
                        sdims.clone(),
                    )) as _)
                });
                Ok(f)
            });
            let report = ce_collm::trace::replay(&events, &dims, builder)?;
            println!("{}", report.summary());
            if args.has("des") {
                match ce_collm::trace::des_check(&events, &dims) {
                    Ok(des) => println!("{}", des.summary()),
                    Err(e) => println!("des check skipped: {e:#}"),
                }
            }
            if !report.ok() {
                std::process::exit(1);
            }
        }
        "calibrate" => {
            let stack = LocalStack::load(&artifacts)?;
            let cfg = ExperimentConfig {
                n_prompts: args.get_parse("prompts", 5usize),
                ..experiment_config(&args)
            };
            let mut edge = stack.edge_session();
            let mut cloud = stack.cloud_session();
            let rec = record_main_experiments(&mut edge, &mut cloud, &cfg)?;
            println!("calibrated cost model (seconds):");
            println!("  edge_prefill : {:?}", rec.cost.edge_prefill);
            println!("  seg1         : {:?}", rec.cost.seg1);
            println!("  seg2         : {:?}", rec.cost.seg2);
            println!("  cloud_prefill: {:?}", rec.cost.cloud_prefill);
            println!("  cloud_decode : {:?}", rec.cost.cloud_decode);
        }
        _ => {
            println!(
                "ce-collm — CE-CoLLM reproduction (cloud-edge collaborative LLM inference)\n\n\
                 usage: ce-collm <command> [flags]\n\n\
                 commands:\n\
                 \x20 table 1|2|3|4      regenerate a paper table\n\
                 \x20 fig4               regenerate Figure 4\n\
                 \x20 all                everything\n\
                 \x20 standalone <p>     edge standalone generation\n\
                 \x20 generate <p>       collaborative generation (local)\n\
                 \x20 serve-cloud        start the cloud server\n\
                 \x20 run-edge <p>       edge client against a server\n\
                 \x20 trace-record <f>   record a short mock e2e run (TCP)\n\
                 \x20 trace-replay <f>   replay a recorded trace (mock engines)\n\
                 \x20 stats              scrape and pretty-print a server's /metrics\n\
                 \x20 calibrate          print the measured cost model\n\n\
                 flags: --artifacts DIR --prompts N --repeats N --max-new N\n\
                 \x20      --link wifi|lte|fiber|lan|ideal --threshold T\n\
                 \x20      --clients N --addr HOST:PORT --seed N\n\
                 \x20      --workers N (serve-cloud scheduler pool)\n\
                 \x20      --trace PATH (serve-cloud: record a TRACE v1 JSONL)\n\
                 \x20      --metrics (serve-cloud: enable the /metrics endpoint;\n\
                 \x20                 trace-record: scrape to the given .prom PATH)\n\
                 \x20      --watch (stats: re-scrape every 2s)\n\
                 \x20      --budget-ms N (run-edge per-token cloud latency budget)\n\
                 \x20      --addrs A,B,... (run-edge ordered failover endpoints)\n\
                 \x20      --replicas N (run-edge warm-standby sessions)\n\
                 \x20      --hedge (run-edge: duplicate budgeted infers to a standby)\n\
                 \x20      --des (trace-replay: cross-validate against the DES)"
            );
        }
    }
    Ok(())
}
