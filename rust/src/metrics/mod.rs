//! Cost accounting, latency histograms, and table rendering.
//!
//! The stack has three observability layers; pick by question:
//!
//! 1. **Counters** ([`RunCounters`], `CloudStats`, `ReactorStats`,
//!    `ContextStoreStats`) — monotonic totals and gauges, always on, the
//!    cheapest possible accounting.  Add here when the question is "how
//!    many / how much, ever".
//! 2. **Histograms + registry** ([`hist::LatencyHist`],
//!    [`hist::MetricsRegistry`]) — per-stage latency/size *distributions*
//!    (p50/p90/p99/max), off by default (`CloudConfig::metrics` /
//!    `CE_METRICS`), one relaxed atomic add per observation when on,
//!    scrapeable live from the reactor's `GET /metrics` path.  Add here
//!    when the question is "how long does this stage take, and for whom"
//!    — the tail, not the total.
//! 3. **Trace** (`trace::TraceSink`) — the full per-event timeline,
//!    replayable bit-identically.  Add here when the question is "what
//!    exactly happened, in what order" and a distribution is too lossy.
//!
//! This module also carries the paper-facing accounting: four time
//! columns per run (total / edge / cloud / comm — Table 2, Table 4) plus
//! a request-cloud rate, transmitted bytes (Fig 4c) and ROUGE-L.
//! [`CostBreakdown`] accumulates one request; [`Aggregate`] folds many
//! runs into `mean ± std` exactly as the paper's tables present them
//! (5 repeats).

pub mod hist;

pub use hist::{
    parse_exposition, render_hist, Exposition, HistSnapshot, LatencyHist, MetricsRegistry,
    METRICS_ENV,
};

use std::fmt;

/// Time/cost breakdown of one inference request or one whole run.
///
/// All values in seconds.  `total` is wall-clock makespan and is *not*
/// necessarily the sum of the parts: with parallel upload, communication
/// overlaps edge compute (paper §4.1), and with multiple clients cloud
/// busy time overlaps other clients' edge time.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CostBreakdown {
    pub total_s: f64,
    pub edge_s: f64,
    pub cloud_s: f64,
    pub comm_s: f64,
}

impl CostBreakdown {
    pub fn add(&mut self, other: &CostBreakdown) {
        self.total_s += other.total_s;
        self.edge_s += other.edge_s;
        self.cloud_s += other.cloud_s;
        self.comm_s += other.comm_s;
    }
}

impl fmt::Display for CostBreakdown {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "total {:.3}s (edge {:.3}s, cloud {:.3}s, comm {:.3}s)",
            self.total_s, self.edge_s, self.cloud_s, self.comm_s
        )
    }
}

/// Counters for one generation request (paper Table 2 right-hand columns).
#[derive(Debug, Clone, Default)]
pub struct RunCounters {
    pub tokens_generated: usize,
    pub tokens_exit1: usize,
    pub tokens_exit2: usize,
    pub tokens_cloud: usize,
    /// Bytes sent edge→cloud (hidden states + requests).
    pub bytes_up: u64,
    /// Bytes sent cloud→edge (token responses).
    pub bytes_down: u64,
    /// Cloud inference requests issued.
    pub cloud_requests: usize,
    /// Tokens that wanted the cloud but were emitted from a local exit
    /// because the latency budget expired or the link failed (§4.4).
    pub cloud_fallbacks: usize,
    /// Times the cloud evicted this device's context (memory budget or
    /// idle TTL) and the edge recovered by replaying its hidden-state
    /// history from position 0 — each one costs an extra upload round
    /// trip but zero token differences.
    pub context_replays: usize,
    /// Times a severed cloud link was re-established with session resume
    /// during this run (reconnect policy).  Each one costs a re-dial,
    /// re-`Hello`, and one history replay round trip — zero token
    /// differences.  Distinct from `context_replays`: a resumed session
    /// was suspended cooperatively, not evicted.
    pub reconnects: u64,
    /// Reconnects that landed on a *different* endpoint than the one
    /// that failed (multi-endpoint failover).  Always <= `reconnects`.
    pub failovers: u64,
    /// Failovers resolved by promoting a *warm standby* whose mirrored
    /// `ContextStore` coverage already spanned the watermark: the edge
    /// swaps links and re-issues the pending `InferRequest` with **zero**
    /// history replay and zero token differences.
    pub failovers_warm: u64,
    /// Failovers resolved the cold way: re-dial, resume `Hello`, and one
    /// full history replay round trip (same recovery as `reconnects`
    /// before replication existed).  Strictly more expensive than warm.
    pub failovers_cold: u64,
    /// Bytes of hidden-state uploads duplicated to warm standby replicas.
    /// Disjoint from `bytes_up` (primary traffic only) so the paper's
    /// Fig 4c transmission column is unchanged by replication.
    pub bytes_mirrored: u64,
    /// Cloud inference requests that were hedged: duplicated to the
    /// best-scored standby because the deadline budget was tight.  The
    /// first valid `(req_id, pos)` echo wins; the loser is fenced by the
    /// stale-response skip, so this never inflates `cloud_requests`.
    pub hedged_requests: usize,
    /// Round-trip time of the most recent keepalive `Ping` on the infer
    /// channel, in milliseconds (`0.0` when no ping was issued).  A
    /// gauge, not a counter: `add` keeps the last non-zero observation.
    pub ping_rtt_last_ms: f64,
    /// Last keepalive `Ping` round trip per warm standby replica, in
    /// milliseconds, in replica order (`0.0` until the first ping lands).
    /// A gauge vector: `add` keeps the longer list and overwrites
    /// element-wise with non-zero observations.
    pub replica_ping_rtt_ms: Vec<f64>,
}

impl RunCounters {
    pub fn add(&mut self, o: &RunCounters) {
        self.tokens_generated += o.tokens_generated;
        self.tokens_exit1 += o.tokens_exit1;
        self.tokens_exit2 += o.tokens_exit2;
        self.tokens_cloud += o.tokens_cloud;
        self.bytes_up += o.bytes_up;
        self.bytes_down += o.bytes_down;
        self.cloud_requests += o.cloud_requests;
        self.cloud_fallbacks += o.cloud_fallbacks;
        self.context_replays += o.context_replays;
        self.reconnects += o.reconnects;
        self.failovers += o.failovers;
        self.failovers_warm += o.failovers_warm;
        self.failovers_cold += o.failovers_cold;
        self.bytes_mirrored += o.bytes_mirrored;
        self.hedged_requests += o.hedged_requests;
        if o.ping_rtt_last_ms != 0.0 {
            self.ping_rtt_last_ms = o.ping_rtt_last_ms;
        }
        if o.replica_ping_rtt_ms.len() > self.replica_ping_rtt_ms.len() {
            self.replica_ping_rtt_ms.resize(o.replica_ping_rtt_ms.len(), 0.0);
        }
        for (i, &rtt) in o.replica_ping_rtt_ms.iter().enumerate() {
            if rtt != 0.0 {
                self.replica_ping_rtt_ms[i] = rtt;
            }
        }
    }

    /// "Request Cloud Rate" — fraction of generated tokens that required a
    /// cloud round trip.
    pub fn request_cloud_rate(&self) -> f64 {
        if self.tokens_generated == 0 {
            return 0.0;
        }
        self.tokens_cloud as f64 / self.tokens_generated as f64
    }

    pub fn transmitted_mb(&self) -> f64 {
        (self.bytes_up + self.bytes_down) as f64 / 1e6
    }
}

/// `mean ± std` over repeated runs of a scalar metric.
#[derive(Debug, Clone, Default)]
pub struct MeanStd {
    values: Vec<f64>,
}

impl MeanStd {
    pub fn push(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    /// Sample standard deviation (n−1), matching the paper's ± columns.
    pub fn std(&self) -> f64 {
        let n = self.values.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.values.iter().map(|v| (v - m).powi(2)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    pub fn n(&self) -> usize {
        self.values.len()
    }

    pub fn fmt_pm(&self, digits: usize) -> String {
        format!("{:.d$} ± {:.d$}", self.mean(), self.std(), d = digits)
    }
}

/// Aggregate of repeated runs of one (strategy, dataset) cell.
#[derive(Debug, Default)]
pub struct Aggregate {
    pub total_s: MeanStd,
    pub edge_s: MeanStd,
    pub cloud_s: MeanStd,
    pub comm_s: MeanStd,
    pub rouge_l: MeanStd,
    pub request_rate: MeanStd,
    pub transmitted_mb: MeanStd,
}

impl Aggregate {
    pub fn push(&mut self, cost: &CostBreakdown, counters: &RunCounters, rouge_l: Option<f64>) {
        self.total_s.push(cost.total_s);
        self.edge_s.push(cost.edge_s);
        self.cloud_s.push(cost.cloud_s);
        self.comm_s.push(cost.comm_s);
        self.request_rate.push(counters.request_cloud_rate() * 100.0);
        self.transmitted_mb.push(counters.transmitted_mb());
        if let Some(r) = rouge_l {
            self.rouge_l.push(r);
        }
    }
}

/// Minimal fixed-width table renderer for harness output (markdown-ish,
/// matches the layout of the paper's tables).
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            s
        };
        let mut out = line(&self.header);
        out.push('\n');
        out.push('|');
        for wi in &w {
            out.push_str(&format!("{:-<width$}|", "", width = wi + 2));
        }
        for r in &self.rows {
            out.push('\n');
            out.push_str(&line(r));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meanstd_matches_hand_computation() {
        let mut m = MeanStd::default();
        for v in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            m.push(v);
        }
        assert!((m.mean() - 5.0).abs() < 1e-12);
        // sample std of that classic set is ~2.138
        assert!((m.std() - 2.13809).abs() < 1e-4);
    }

    #[test]
    fn meanstd_degenerate_cases() {
        let m = MeanStd::default();
        assert_eq!(m.mean(), 0.0);
        assert_eq!(m.std(), 0.0);
        let mut one = MeanStd::default();
        one.push(3.0);
        assert_eq!(one.mean(), 3.0);
        assert_eq!(one.std(), 0.0);
    }

    #[test]
    fn counters_rates() {
        let c = RunCounters {
            tokens_generated: 100,
            tokens_cloud: 42,
            bytes_up: 1_500_000,
            bytes_down: 500_000,
            ..Default::default()
        };
        assert!((c.request_cloud_rate() - 0.42).abs() < 1e-12);
        assert!((c.transmitted_mb() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn counters_add_merges_replica_gauges() {
        let mut a = RunCounters {
            failovers_warm: 1,
            bytes_mirrored: 100,
            replica_ping_rtt_ms: vec![2.0],
            ..Default::default()
        };
        let b = RunCounters {
            failovers_warm: 2,
            failovers_cold: 1,
            bytes_mirrored: 50,
            hedged_requests: 3,
            replica_ping_rtt_ms: vec![0.0, 7.5],
            ..Default::default()
        };
        a.add(&b);
        assert_eq!(a.failovers_warm, 3);
        assert_eq!(a.failovers_cold, 1);
        assert_eq!(a.bytes_mirrored, 150);
        assert_eq!(a.hedged_requests, 3);
        // gauge vector: zero in `b` keeps `a`'s observation, longer wins
        assert_eq!(a.replica_ping_rtt_ms, vec![2.0, 7.5]);
    }

    #[test]
    fn cost_add_accumulates() {
        let mut a = CostBreakdown { total_s: 1.0, edge_s: 0.5, cloud_s: 0.3, comm_s: 0.2 };
        a.add(&CostBreakdown { total_s: 2.0, edge_s: 1.0, cloud_s: 0.6, comm_s: 0.4 });
        assert_eq!(a.total_s, 3.0);
        assert_eq!(a.comm_s, 0.6000000000000001);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Strategy", "Total (s)"]);
        t.row(vec!["CE-CoLLM".into(), "319.057".into()]);
        t.row(vec!["Cloud".into(), "370.166".into()]);
        let s = t.render();
        assert!(s.contains("| CE-CoLLM | 319.057   |"));
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
