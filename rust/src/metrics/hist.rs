//! Lock-free log-bucketed latency histograms and the process-wide
//! [`MetricsRegistry`] behind the live `/metrics` scrape endpoint.
//!
//! Design, in the same hand-rolled spirit as `util::json`:
//!
//! - [`LatencyHist`] is a fixed array of 64 `AtomicU64` buckets on a
//!   power-of-√2 grid covering ~724ns .. ~1555s (everything below the first
//!   edge lands in bucket 0, everything above the last finite edge in the
//!   overflow bucket).  `record()` is wait-free: one `fetch_add` on the
//!   bucket, one on the sum, one `fetch_max` on the max — all `Relaxed`.
//!   Count is derived as the sum of buckets, so a snapshot's `_count` always
//!   equals its last cumulative bucket by construction, even when read
//!   concurrently with writers.
//! - [`HistSnapshot`] is a plain copy that merges (`merge`) and answers
//!   quantile queries (`quantile`) by cumulative walk with linear
//!   interpolation inside the winning bucket, clamped to the observed max.
//! - [`MetricsRegistry`] maps name → histogram/gauge/counter.  Labels are
//!   encoded in the name (`ce_sched_park_wait_ns{worker="0"}`); the
//!   Prometheus renderer groups series by base name and additionally emits a
//!   merged unlabeled aggregate per family.
//! - The registry is resolved like `TraceSink::resolve`: explicitly via
//!   `CloudConfig::metrics`, or ambiently via the `CE_METRICS` env var.
//!   Once enabled it latches on process-wide so every subsystem (scheduler
//!   workers, reactor shards, edge link, DES consumers) shares one registry.
//!
//! Value-shaped histograms (batch widths, frame sizes) reuse the ns grid by
//! scaling each value by [`VALUE_SCALE`]; the renderer un-scales the bucket
//! bounds and sum for any family whose base name does not end in `_ns`, so
//! exposition units are always the native ones.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

/// Number of histogram buckets (including the bucket-0 underflow catch-all
/// and the top overflow bucket).
pub const BUCKETS: usize = 64;

/// Scale factor applied by [`LatencyHist::record_value`] so count/size
/// histograms get sub-√2 resolution starting at 1 unit.  The Prometheus
/// renderer divides bounds and `_sum` back down for non-`_ns` families.
pub const VALUE_SCALE: u64 = 1000;

/// Env var that ambiently enables the global metrics registry (any
/// non-empty value other than `"0"`), mirroring `CE_TRACE`.
pub const METRICS_ENV: &str = "CE_METRICS";

/// Map a nanosecond value onto the √2 grid.
///
/// For `ns >= 512` the index is derived from `2*floor(log2 ns)` plus the
/// second-highest significant bit (the "half step"), shifted so the first
/// grid edge above bucket 0 is ~724ns; everything smaller shares bucket 0,
/// everything at or above the top edge shares the overflow bucket 63.
#[inline]
pub fn bucket_of(ns: u64) -> usize {
    if ns < 512 {
        return 0;
    }
    let lz = 63 - ns.leading_zeros() as u64; // floor(log2 ns), >= 9
    let half = 2 * lz + ((ns >> (lz - 1)) & 1);
    ((half - 18) as usize).min(BUCKETS - 1)
}

/// Inclusive lower bound of bucket `i`, in nanoseconds.
pub fn lower_bound(i: usize) -> f64 {
    if i == 0 {
        return 0.0;
    }
    let half = i as u32 + 18;
    let base = (half / 2) as f64;
    if half % 2 == 0 {
        base.exp2()
    } else {
        base.exp2() * std::f64::consts::SQRT_2
    }
}

/// A fixed-size, lock-free, log-bucketed histogram.  See the module doc.
#[derive(Debug)]
pub struct LatencyHist {
    buckets: [AtomicU64; BUCKETS],
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    pub fn new() -> Self {
        LatencyHist {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one observation in nanoseconds.  Wait-free.
    #[inline]
    pub fn record(&self, ns: u64) {
        self.buckets[bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(ns, Ordering::Relaxed);
        self.max.fetch_max(ns, Ordering::Relaxed);
    }

    /// Record a wall-clock duration.
    #[inline]
    pub fn record_duration(&self, d: Duration) {
        self.record(u64::try_from(d.as_nanos()).unwrap_or(u64::MAX));
    }

    /// Record a dimensionless value (batch width, byte count) with
    /// [`VALUE_SCALE`] applied so small integers spread across buckets.
    #[inline]
    pub fn record_value(&self, v: u64) {
        self.record(v.saturating_mul(VALUE_SCALE));
    }

    /// Take a consistent-enough copy for rendering: buckets are read once
    /// each; count is derived from the copied buckets so `_count` always
    /// matches the cumulative total.
    pub fn snapshot(&self) -> HistSnapshot {
        let mut buckets = [0u64; BUCKETS];
        for (i, b) in self.buckets.iter().enumerate() {
            buckets[i] = b.load(Ordering::Relaxed);
        }
        HistSnapshot {
            buckets,
            sum: self.sum.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time copy of a [`LatencyHist`].  Plain data: mergeable,
/// serializable, and the unit the DES emits directly.
#[derive(Debug, Clone, Copy)]
pub struct HistSnapshot {
    pub buckets: [u64; BUCKETS],
    pub sum: u64,
    pub max: u64,
}

impl Default for HistSnapshot {
    fn default() -> Self {
        HistSnapshot { buckets: [0; BUCKETS], sum: 0, max: 0 }
    }
}

impl HistSnapshot {
    /// Total number of recorded observations.
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Fold another snapshot into this one (bucket-wise add, sum add,
    /// max of maxes).
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += *b;
        }
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }

    /// Estimate the q-quantile (0.0..=1.0) in nanoseconds by cumulative
    /// walk with linear interpolation inside the winning bucket, clamped
    /// to the recorded max.  Returns 0.0 on an empty histogram.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let rank = (q.clamp(0.0, 1.0) * total as f64).max(1.0);
        let mut cum = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            if n == 0 {
                continue;
            }
            let next = cum + n;
            if (next as f64) >= rank {
                let lo = lower_bound(i);
                let hi = if i + 1 < BUCKETS { lower_bound(i + 1) } else { self.max as f64 };
                let hi = hi.min(self.max as f64).max(lo);
                let frac = (rank - cum as f64) / n as f64;
                return lo + (hi - lo) * frac;
            }
            cum = next;
        }
        self.max as f64
    }
}

/// Process-wide registry of named histograms, gauges, and counters.
///
/// Names carry their labels inline (`ce_reactor_conn_lifetime_ns{shard="3"}`)
/// so registration stays a single map lookup; the renderer re-groups series
/// into Prometheus families.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    hists: Mutex<BTreeMap<String, Arc<LatencyHist>>>,
    gauges: Mutex<BTreeMap<String, Arc<AtomicI64>>>,
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static GLOBAL: OnceLock<Arc<MetricsRegistry>> = OnceLock::new();

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// The process-wide registry (created on first use).
    pub fn global() -> Arc<MetricsRegistry> {
        GLOBAL.get_or_init(|| Arc::new(MetricsRegistry::new())).clone()
    }

    /// Resolve the registry the way `TraceSink::resolve` resolves the trace
    /// sink: an explicit `CloudConfig::metrics = true` wins, else the
    /// [`METRICS_ENV`] env var enables it ambiently.  Either path latches
    /// metrics on for the rest of the process so all subsystems share one
    /// registry; when neither applies, `None` keeps every instrumentation
    /// site a single branch on an `Option`.
    pub fn resolve(explicit: bool) -> Option<Arc<MetricsRegistry>> {
        if explicit {
            ENABLED.store(true, Ordering::Relaxed);
            return Some(Self::global());
        }
        if ENABLED.load(Ordering::Relaxed) {
            return Some(Self::global());
        }
        match std::env::var(METRICS_ENV) {
            Ok(v) if !v.is_empty() && v != "0" => {
                ENABLED.store(true, Ordering::Relaxed);
                Some(Self::global())
            }
            _ => None,
        }
    }

    /// Get or create the histogram with this (label-qualified) name.
    pub fn hist(&self, name: &str) -> Arc<LatencyHist> {
        let mut map = self.hists.lock().unwrap();
        map.entry(name.to_string()).or_insert_with(|| Arc::new(LatencyHist::new())).clone()
    }

    /// Get or create the gauge with this (label-qualified) name.
    pub fn gauge(&self, name: &str) -> Arc<AtomicI64> {
        let mut map = self.gauges.lock().unwrap();
        map.entry(name.to_string()).or_insert_with(|| Arc::new(AtomicI64::new(0))).clone()
    }

    /// Get or create the counter with this (label-qualified) name.
    pub fn counter(&self, name: &str) -> Arc<AtomicU64> {
        let mut map = self.counters.lock().unwrap();
        map.entry(name.to_string()).or_insert_with(|| Arc::new(AtomicU64::new(0))).clone()
    }

    /// Render every registered series as Prometheus text exposition
    /// (format 0.0.4): per-series histograms/gauges/counters plus one
    /// merged unlabeled aggregate per family.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();

        // Histograms: group label-qualified series under their base name.
        let hists = self.hists.lock().unwrap();
        let mut families: BTreeMap<String, Vec<(String, HistSnapshot)>> = BTreeMap::new();
        for (name, h) in hists.iter() {
            let (base, labels) = split_name(name);
            families.entry(base).or_default().push((labels, h.snapshot()));
        }
        drop(hists);
        for (base, series) in &families {
            out.push_str(&format!("# TYPE {base} histogram\n"));
            for (labels, snap) in series {
                out.push_str(&render_hist(base, labels, snap));
            }
            // Merged aggregate, unless the only series is already unlabeled.
            if !(series.len() == 1 && series[0].0.is_empty()) {
                let mut agg = HistSnapshot::default();
                for (_, snap) in series {
                    agg.merge(snap);
                }
                out.push_str(&render_hist(base, "", &agg));
            }
        }

        // Counters and gauges: per-series line plus an unlabeled sum.
        let counters = self.counters.lock().unwrap();
        let counter_vals: Vec<(String, f64)> = counters
            .iter()
            .map(|(n, c)| (n.clone(), c.load(Ordering::Relaxed) as f64))
            .collect();
        drop(counters);
        render_scalar_families(&mut out, "counter", &counter_vals);

        let gauges = self.gauges.lock().unwrap();
        let gauge_vals: Vec<(String, f64)> = gauges
            .iter()
            .map(|(n, g)| (n.clone(), g.load(Ordering::Relaxed) as f64))
            .collect();
        drop(gauges);
        render_scalar_families(&mut out, "gauge", &gauge_vals);

        out
    }
}

/// Split `base{labels}` into `(base, labels)`; labels exclude the braces.
fn split_name(name: &str) -> (String, String) {
    match name.find('{') {
        Some(i) => {
            let labels = name[i + 1..].trim_end_matches('}');
            (name[..i].to_string(), labels.to_string())
        }
        None => (name.to_string(), String::new()),
    }
}

/// Format a float the way Prometheus expects: integers stay integral.
fn fmt_num(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn label_set(labels: &str, le: Option<&str>) -> String {
    let mut parts: Vec<&str> = Vec::new();
    if !labels.is_empty() {
        parts.push(labels);
    }
    let le_part;
    if let Some(le) = le {
        le_part = format!("le=\"{le}\"");
        parts.push(&le_part);
    }
    if parts.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", parts.join(","))
    }
}

/// Render one histogram series (`<base>_bucket`/`_sum`/`_count` lines).
///
/// This helper is the single source of the exposition schema: the live
/// registry renderer and the DES's `SimOutcome` both call it, so the two
/// sides emit provably identical shapes.  Families whose base name ends in
/// `_ns` expose raw nanosecond bounds; all others are value-scaled
/// histograms whose bounds and sum are divided back by [`VALUE_SCALE`].
pub fn render_hist(base: &str, labels: &str, snap: &HistSnapshot) -> String {
    let scale = if base.ends_with("_ns") { 1.0 } else { VALUE_SCALE as f64 };
    let mut out = String::new();
    let last_nonzero = snap
        .buckets
        .iter()
        .rposition(|&n| n > 0)
        .unwrap_or(0)
        .min(BUCKETS - 2);
    let mut cum = 0u64;
    for i in 0..=last_nonzero {
        cum += snap.buckets[i];
        // Bucket i's upper edge is bucket i+1's lower edge.
        let le = fmt_num(lower_bound(i + 1) / scale);
        out.push_str(&format!("{base}_bucket{} {cum}\n", label_set(labels, Some(&le))));
    }
    let total = snap.count();
    out.push_str(&format!("{base}_bucket{} {total}\n", label_set(labels, Some("+Inf"))));
    out.push_str(&format!(
        "{base}_sum{} {}\n",
        label_set(labels, None),
        fmt_num(snap.sum as f64 / scale)
    ));
    out.push_str(&format!("{base}_count{} {total}\n", label_set(labels, None)));
    out
}

fn render_scalar_families(out: &mut String, kind: &str, vals: &[(String, f64)]) {
    let mut families: BTreeMap<String, Vec<(String, f64)>> = BTreeMap::new();
    for (name, v) in vals {
        let (base, labels) = split_name(name);
        families.entry(base).or_default().push((labels, *v));
    }
    for (base, series) in &families {
        out.push_str(&format!("# TYPE {base} {kind}\n"));
        for (labels, v) in series {
            out.push_str(&format!("{base}{} {}\n", label_set(labels, None), fmt_num(*v)));
        }
        if !(series.len() == 1 && series[0].0.is_empty()) {
            let total: f64 = series.iter().map(|(_, v)| v).sum();
            out.push_str(&format!("{base} {}\n", fmt_num(total)));
        }
    }
}

/// One parsed exposition sample.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

impl Sample {
    /// The value of label `key`, if present.
    pub fn label(&self, key: &str) -> Option<&str> {
        self.labels.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    /// True when the sample's non-`le` labels equal `want` exactly
    /// (order-insensitive; `want` is `k=v` pairs).
    pub fn labels_match(&self, want: &[(&str, &str)]) -> bool {
        let mine: Vec<(&str, &str)> = self
            .labels
            .iter()
            .filter(|(k, _)| k != "le")
            .map(|(k, v)| (k.as_str(), v.as_str()))
            .collect();
        mine.len() == want.len() && want.iter().all(|w| mine.contains(w))
    }
}

/// A parsed Prometheus text exposition.
#[derive(Debug, Default)]
pub struct Exposition {
    pub samples: Vec<Sample>,
    pub types: BTreeMap<String, String>,
}

impl Exposition {
    /// All samples with this exact metric name.
    pub fn samples_named<'a>(&'a self, name: &str) -> impl Iterator<Item = &'a Sample> {
        let name = name.to_string();
        self.samples.iter().filter(move |s| s.name == name)
    }

    /// The value of the sample with this name and exact label set.
    pub fn value(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        self.samples_named(name).find(|s| s.labels_match(labels)).map(|s| s.value)
    }

    /// Compute a quantile for histogram family `base` (with the given
    /// non-`le` labels) generically from its (le, cumulative) bucket pairs,
    /// linear interpolation between adjacent bounds.
    pub fn hist_quantile(&self, base: &str, labels: &[(&str, &str)], q: f64) -> Option<f64> {
        let bucket_name = format!("{base}_bucket");
        let mut pairs: Vec<(f64, f64)> = self
            .samples_named(&bucket_name)
            .filter(|s| s.labels_match(labels))
            .filter_map(|s| {
                let le = s.label("le")?;
                let le = if le == "+Inf" { f64::INFINITY } else { le.parse().ok()? };
                Some((le, s.value))
            })
            .collect();
        if pairs.is_empty() {
            return None;
        }
        pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let total = pairs.last().unwrap().1;
        if total == 0.0 {
            return Some(0.0);
        }
        let rank = (q.clamp(0.0, 1.0) * total).max(1.0);
        let mut prev_le = 0.0;
        let mut prev_cum = 0.0;
        for &(le, cum) in &pairs {
            if cum >= rank {
                if le.is_infinite() {
                    return Some(prev_le);
                }
                let frac = if cum > prev_cum { (rank - prev_cum) / (cum - prev_cum) } else { 1.0 };
                return Some(prev_le + (le - prev_le) * frac);
            }
            prev_le = le;
            prev_cum = cum;
        }
        Some(prev_le)
    }
}

/// Parse and validate a Prometheus text exposition.
///
/// Beyond the line grammar, every histogram family is checked for internal
/// consistency: `le` bounds strictly ascending, cumulative counts monotone
/// non-decreasing, a `+Inf` bucket present and equal to the series'
/// `_count`, and a `_sum` sample present.  An empty exposition is an error
/// (this is the CI fail condition for the scrape artifact).
pub fn parse_exposition(text: &str) -> Result<Exposition, String> {
    let mut exp = Exposition::default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().ok_or_else(|| format!("line {}: bare TYPE", lineno + 1))?;
            let kind = it.next().ok_or_else(|| format!("line {}: TYPE without kind", lineno + 1))?;
            exp.types.insert(name.to_string(), kind.to_string());
            continue;
        }
        if line.starts_with('#') {
            continue;
        }
        exp.samples.push(parse_sample(line).map_err(|e| format!("line {}: {e}", lineno + 1))?);
    }
    if exp.samples.is_empty() {
        return Err("empty exposition".into());
    }
    validate_histograms(&exp)?;
    Ok(exp)
}

fn parse_sample(line: &str) -> Result<Sample, String> {
    let (name_part, value_part) = match line.find('{') {
        Some(open) => {
            let close = line.rfind('}').ok_or("unclosed label braces")?;
            (line[..close + 1].to_string(), line[close + 1..].trim())
        }
        None => {
            let sp = line.find(' ').ok_or("sample without value")?;
            (line[..sp].to_string(), line[sp..].trim())
        }
    };
    let value: f64 = if value_part == "+Inf" {
        f64::INFINITY
    } else {
        value_part
            .split_whitespace()
            .next()
            .ok_or("missing value")?
            .parse()
            .map_err(|_| format!("bad value {value_part:?}"))?
    };
    let (name, labels) = match name_part.find('{') {
        Some(i) => {
            let body = name_part[i + 1..].trim_end_matches('}');
            let mut labels = Vec::new();
            for pair in split_label_pairs(body)? {
                labels.push(pair);
            }
            (name_part[..i].to_string(), labels)
        }
        None => (name_part, Vec::new()),
    };
    if name.is_empty() {
        return Err("empty metric name".into());
    }
    Ok(Sample { name, labels, value })
}

fn split_label_pairs(body: &str) -> Result<Vec<(String, String)>, String> {
    let mut out = Vec::new();
    let mut rest = body.trim();
    while !rest.is_empty() {
        let eq = rest.find('=').ok_or("label without '='")?;
        let key = rest[..eq].trim().to_string();
        let after = &rest[eq + 1..];
        let after = after.strip_prefix('"').ok_or("unquoted label value")?;
        let endq = after.find('"').ok_or("unterminated label value")?;
        let val = after[..endq].to_string();
        out.push((key, val));
        rest = after[endq + 1..].trim_start_matches(',').trim();
    }
    Ok(out)
}

fn validate_histograms(exp: &Exposition) -> Result<(), String> {
    // Collect every histogram series: (base, non-le labels) -> bucket pairs.
    let mut series: BTreeMap<(String, String), Vec<(f64, f64)>> = BTreeMap::new();
    for s in &exp.samples {
        if let Some(base) = s.name.strip_suffix("_bucket") {
            let le = s.label("le").ok_or_else(|| format!("{}: bucket without le", s.name))?;
            let le = if le == "+Inf" {
                f64::INFINITY
            } else {
                le.parse().map_err(|_| format!("{}: bad le {le:?}", s.name))?
            };
            let key = (base.to_string(), non_le_key(s));
            series.entry(key).or_default().push((le, s.value));
        }
    }
    for ((base, labels), buckets) in &series {
        let mut prev_le = f64::NEG_INFINITY;
        let mut prev_cum = -1.0;
        for &(le, cum) in buckets {
            if le <= prev_le {
                return Err(format!("{base}{{{labels}}}: le bounds not ascending"));
            }
            if cum < prev_cum {
                return Err(format!("{base}{{{labels}}}: cumulative buckets decrease"));
            }
            prev_le = le;
            prev_cum = cum;
        }
        let (last_le, last_cum) = *buckets.last().unwrap();
        if !last_le.is_infinite() {
            return Err(format!("{base}{{{labels}}}: missing +Inf bucket"));
        }
        let count = lookup_scalar(exp, &format!("{base}_count"), labels)
            .ok_or_else(|| format!("{base}{{{labels}}}: missing _count"))?;
        if count != last_cum {
            return Err(format!(
                "{base}{{{labels}}}: _count {count} != +Inf bucket {last_cum}"
            ));
        }
        if lookup_scalar(exp, &format!("{base}_sum"), labels).is_none() {
            return Err(format!("{base}{{{labels}}}: missing _sum"));
        }
    }
    Ok(())
}

/// Canonical sorted `k=v,...` key of a sample's non-`le` labels.
fn non_le_key(s: &Sample) -> String {
    let mut pairs: Vec<String> = s
        .labels
        .iter()
        .filter(|(k, _)| k != "le")
        .map(|(k, v)| format!("{k}={v}"))
        .collect();
    pairs.sort();
    pairs.join(",")
}

fn lookup_scalar(exp: &Exposition, name: &str, labels_key: &str) -> Option<f64> {
    exp.samples
        .iter()
        .find(|s| s.name == name && non_le_key(s) == labels_key)
        .map(|s| s.value)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic LCG so the reference tests need no external deps.
    struct Lcg(u64);
    impl Lcg {
        fn next(&mut self) -> u64 {
            self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            self.0 >> 11
        }
    }

    #[test]
    fn bucket_grid_shape() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(511), 0);
        assert_eq!(bucket_of(512), 0); // 512 = 2^9, half=18 -> idx 0
        assert_eq!(bucket_of(1000), 1);
        assert_eq!(bucket_of(1024), 2);
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
        // Grid edges: lower(2) = 1024 exactly, lower(1) = 512*sqrt(2).
        assert_eq!(lower_bound(0), 0.0);
        assert!((lower_bound(1) - 724.077).abs() < 0.1);
        assert_eq!(lower_bound(2), 1024.0);
        // Every value lands in the bucket whose bounds contain it.
        let mut rng = Lcg(7);
        for _ in 0..10_000 {
            let ns = rng.next() % 80_000_000_000; // up to 80s
            let i = bucket_of(ns);
            assert!(ns as f64 >= lower_bound(i), "ns={ns} below bucket {i}");
            if i + 1 < BUCKETS {
                assert!((ns as f64) < lower_bound(i + 1), "ns={ns} above bucket {i}");
            }
        }
        // 60s is representable below the overflow bucket.
        assert!(bucket_of(60_000_000_000) < BUCKETS - 1);
    }

    #[test]
    fn record_count_sum_max() {
        let h = LatencyHist::new();
        h.record(1_000);
        h.record(2_000);
        h.record(3_000_000);
        let s = h.snapshot();
        assert_eq!(s.count(), 3);
        assert_eq!(s.sum, 3_003_000);
        assert_eq!(s.max, 3_000_000);
        h.record_duration(Duration::from_micros(5));
        assert_eq!(h.snapshot().count(), 4);
    }

    #[test]
    fn quantiles_bound_sorted_reference() {
        // Percentile estimates must land within one bucket of the exact
        // sorted-vec answer: between the true value's bucket lower bound
        // and its upper bound.
        let h = LatencyHist::new();
        let mut vals = Vec::new();
        let mut rng = Lcg(42);
        for _ in 0..5_000 {
            let ns = 600 + rng.next() % 10_000_000; // 600ns .. 10ms
            h.record(ns);
            vals.push(ns);
        }
        vals.sort_unstable();
        let s = h.snapshot();
        for &q in &[0.5, 0.9, 0.99] {
            let est = s.quantile(q);
            let exact = vals[((q * vals.len() as f64) as usize).min(vals.len() - 1)];
            let b = bucket_of(exact);
            let lo = lower_bound(b);
            let hi = if b + 1 < BUCKETS { lower_bound(b + 1) } else { s.max as f64 };
            // Interpolation can cross at most one bucket edge near ties.
            assert!(
                est >= lo / std::f64::consts::SQRT_2 && est <= hi * std::f64::consts::SQRT_2,
                "q={q}: est {est} outside [{lo}, {hi}]±√2 (exact {exact})"
            );
        }
        assert!(s.quantile(1.0) <= s.max as f64 + 1.0);
    }

    #[test]
    fn merge_equals_combined_recording() {
        let a = LatencyHist::new();
        let b = LatencyHist::new();
        let combined = LatencyHist::new();
        let mut rng = Lcg(9);
        for i in 0..2_000 {
            let ns = 500 + rng.next() % 1_000_000;
            if i % 2 == 0 { a.record(ns) } else { b.record(ns) }
            combined.record(ns);
        }
        let mut merged = a.snapshot();
        merged.merge(&b.snapshot());
        let want = combined.snapshot();
        assert_eq!(merged.buckets, want.buckets);
        assert_eq!(merged.sum, want.sum);
        assert_eq!(merged.max, want.max);
        assert!((merged.quantile(0.9) - want.quantile(0.9)).abs() < 1e-9);
    }

    #[test]
    fn empty_hist_is_quiet() {
        let s = LatencyHist::new().snapshot();
        assert_eq!(s.count(), 0);
        assert_eq!(s.quantile(0.99), 0.0);
    }

    #[test]
    fn registry_get_or_create_and_render() {
        let r = MetricsRegistry::new();
        let h0 = r.hist("t_wait_ns{worker=\"0\"}");
        let h0b = r.hist("t_wait_ns{worker=\"0\"}");
        assert!(Arc::ptr_eq(&h0, &h0b));
        h0.record(1_500);
        r.hist("t_wait_ns{worker=\"1\"}").record(3_000);
        r.gauge("t_parked{worker=\"0\"}").store(4, Ordering::Relaxed);
        r.counter("t_requests").fetch_add(7, Ordering::Relaxed);
        let text = r.render_prometheus();
        let exp = parse_exposition(&text).expect("render must parse");
        assert_eq!(exp.types.get("t_wait_ns").map(String::as_str), Some("histogram"));
        // Per-series and merged aggregate both present.
        assert_eq!(exp.value("t_wait_ns_count", &[("worker", "0")]), Some(1.0));
        assert_eq!(exp.value("t_wait_ns_count", &[]), Some(2.0));
        assert_eq!(exp.value("t_parked", &[("worker", "0")]), Some(4.0));
        assert_eq!(exp.value("t_requests", &[]), Some(7.0));
    }

    #[test]
    fn value_scaled_families_unscale_in_exposition() {
        let r = MetricsRegistry::new();
        r.hist("t_pass_items").record_value(3);
        r.hist("t_pass_items").record_value(12);
        let text = r.render_prometheus();
        let exp = parse_exposition(&text).expect("parse");
        // _sum is back in native units.
        assert_eq!(exp.value("t_pass_items_sum", &[]), Some(15.0));
        // The quantile derived from exposition bounds is near the native values.
        let p99 = exp.hist_quantile("t_pass_items", &[], 0.99).unwrap();
        assert!(p99 > 8.0 && p99 < 18.0, "p99={p99}");
    }

    #[test]
    fn render_hist_schema_is_shared() {
        // The standalone helper emits exactly what the registry emits for a
        // single series: this is the DES-vs-live schema contract.
        let h = LatencyHist::new();
        h.record(2_000);
        let direct = render_hist("t_solo_ns", "", &h.snapshot());
        let exp = parse_exposition(&format!("# TYPE t_solo_ns histogram\n{direct}")).unwrap();
        assert_eq!(exp.value("t_solo_ns_count", &[]), Some(1.0));
    }

    #[test]
    fn parser_rejects_broken_expositions() {
        assert!(parse_exposition("").is_err());
        assert!(parse_exposition("   \n# just a comment\n").is_err());
        // Decreasing cumulative buckets.
        let bad = "# TYPE x histogram\n\
                   x_bucket{le=\"1\"} 5\nx_bucket{le=\"2\"} 3\nx_bucket{le=\"+Inf\"} 5\n\
                   x_sum 9\nx_count 5\n";
        assert!(parse_exposition(bad).is_err());
        // _count disagreeing with +Inf.
        let bad2 = "# TYPE x histogram\n\
                    x_bucket{le=\"1\"} 5\nx_bucket{le=\"+Inf\"} 5\nx_sum 9\nx_count 6\n";
        assert!(parse_exposition(bad2).is_err());
        // Missing +Inf.
        let bad3 = "# TYPE x histogram\nx_bucket{le=\"1\"} 5\nx_sum 9\nx_count 5\n";
        assert!(parse_exposition(bad3).is_err());
    }

    #[test]
    fn quantile_from_exposition_matches_snapshot() {
        let h = LatencyHist::new();
        let mut rng = Lcg(3);
        for _ in 0..3_000 {
            h.record(1_000 + rng.next() % 5_000_000);
        }
        let snap = h.snapshot();
        let text = format!("# TYPE q_ns histogram\n{}", render_hist("q_ns", "", &snap));
        let exp = parse_exposition(&text).unwrap();
        let from_exp = exp.hist_quantile("q_ns", &[], 0.9).unwrap();
        let from_snap = snap.quantile(0.9);
        let ratio = from_exp / from_snap;
        assert!(ratio > 0.6 && ratio < 1.7, "exposition p90 {from_exp} vs snapshot {from_snap}");
    }
}
