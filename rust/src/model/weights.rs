//! Reader for `artifacts/weights.bin` — the tensor container written by
//! `python/compile/aot.py::write_weights`.
//!
//! Format (little-endian):
//! ```text
//! magic "CECW" | u32 version | u32 n_tensors
//! per tensor: u16 name_len | name utf-8 | u8 dtype | u8 ndim |
//!             u32 dims[ndim] | u64 byte_len | raw f32 data
//! ```

use std::collections::HashMap;
use std::io::Read;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

const MAGIC: &[u8; 4] = b"CECW";
const DTYPE_F32: u8 = 0;

/// One loaded tensor.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn elem_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// All tensors from a weights file, indexed by name.
#[derive(Debug, Default)]
pub struct Weights {
    tensors: HashMap<String, Tensor>,
}

impl Weights {
    pub fn load(path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&bytes)
    }

    pub fn parse(bytes: &[u8]) -> Result<Self> {
        let mut r = bytes;
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic).context("truncated header")?;
        ensure!(&magic == MAGIC, "bad magic {:?}", magic);
        let version = read_u32(&mut r)?;
        ensure!(version == 1, "unsupported weights version {version}");
        let n = read_u32(&mut r)? as usize;

        let mut tensors = HashMap::with_capacity(n);
        for i in 0..n {
            let name_len = read_u16(&mut r)? as usize;
            let mut name_buf = vec![0u8; name_len];
            r.read_exact(&mut name_buf).with_context(|| format!("tensor {i} name"))?;
            let name = String::from_utf8(name_buf).context("tensor name not utf-8")?;
            let dtype = read_u8(&mut r)?;
            if dtype != DTYPE_F32 {
                bail!("tensor '{name}': unsupported dtype {dtype}");
            }
            let ndim = read_u8(&mut r)? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u32(&mut r)? as usize);
            }
            let byte_len = read_u64(&mut r)? as usize;
            let expect = shape.iter().product::<usize>().max(1) * 4;
            ensure!(
                byte_len == expect,
                "tensor '{name}': byte_len {byte_len} != shape-implied {expect}"
            );
            ensure!(r.len() >= byte_len, "tensor '{name}': truncated data");
            let (raw, rest) = r.split_at(byte_len);
            r = rest;
            let data = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.insert(name.clone(), Tensor { name, shape, data });
        }
        ensure!(r.is_empty(), "{} trailing bytes after last tensor", r.len());
        Ok(Self { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name).with_context(|| format!("weight tensor '{name}' not found"))
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.tensors.keys().map(|s| s.as_str())
    }

    #[cfg(test)]
    pub fn insert_for_test(&mut self, t: Tensor) {
        self.tensors.insert(t.name.clone(), t);
    }
}

fn read_u8(r: &mut &[u8]) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

fn read_u16(r: &mut &[u8]) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32(r: &mut &[u8]) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut &[u8]) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn encode(tensors: &[(&str, &[usize], &[f32])]) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
        for (name, shape, data) in tensors {
            out.extend_from_slice(&(name.len() as u16).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.push(DTYPE_F32);
            out.push(shape.len() as u8);
            for d in *shape {
                out.extend_from_slice(&(*d as u32).to_le_bytes());
            }
            out.extend_from_slice(&((data.len() * 4) as u64).to_le_bytes());
            for v in *data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    #[test]
    fn roundtrip_two_tensors() {
        let bytes = encode(&[
            ("a", &[2, 2], &[1.0, 2.0, 3.0, 4.0]),
            ("b['x']", &[3], &[-1.0, 0.5, 9.0]),
        ]);
        let w = Weights::parse(&bytes).unwrap();
        assert_eq!(w.len(), 2);
        assert_eq!(w.get("a").unwrap().data, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(w.get("b['x']").unwrap().shape, vec![3]);
        assert!(w.get("missing").is_err());
    }

    #[test]
    fn scalar_tensor() {
        let bytes = encode(&[("s", &[], &[42.0])]);
        let w = Weights::parse(&bytes).unwrap();
        assert_eq!(w.get("s").unwrap().elem_count(), 1);
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = encode(&[("a", &[1], &[1.0])]);
        bytes[0] = b'X';
        assert!(Weights::parse(&bytes).is_err());
    }

    #[test]
    fn truncated_data_rejected() {
        let bytes = encode(&[("a", &[4], &[1.0, 2.0, 3.0, 4.0])]);
        assert!(Weights::parse(&bytes[..bytes.len() - 3]).is_err());
    }

    #[test]
    fn trailing_garbage_rejected() {
        let mut bytes = encode(&[("a", &[1], &[1.0])]);
        bytes.push(0);
        assert!(Weights::parse(&bytes).is_err());
    }

    #[test]
    fn shape_bytelen_mismatch_rejected() {
        let mut bytes = encode(&[("a", &[2], &[1.0, 2.0])]);
        // corrupt the byte_len field (8 bytes before the 8 bytes of data)
        let n = bytes.len();
        bytes[n - 16..n - 8].copy_from_slice(&4u64.to_le_bytes());
        assert!(Weights::parse(&bytes).is_err());
    }
}
