//! Byte-level tokenizer.
//!
//! The reproduction model is a byte-level LM (vocab = 256 byte values +
//! BOS/EOS/PAD specials), so tokenization is UTF-8 bytes.  This keeps the
//! tokenizer exactly consistent between the build-time trainer
//! (python/compile/data.py) and the request path with zero vocabulary
//! files to ship.

use crate::model::manifest::ModelDims;

#[derive(Debug, Clone)]
pub struct Tokenizer {
    pub bos_id: i32,
    pub eos_id: i32,
    pub pad_id: i32,
    pub vocab_size: usize,
}

impl Tokenizer {
    pub fn from_dims(dims: &ModelDims) -> Self {
        Self {
            bos_id: dims.bos_id,
            eos_id: dims.eos_id,
            pad_id: dims.pad_id,
            vocab_size: dims.vocab_size,
        }
    }

    /// Encode text as `BOS <bytes>`.
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut out = Vec::with_capacity(text.len() + 1);
        out.push(self.bos_id);
        out.extend(text.as_bytes().iter().map(|&b| b as i32));
        out
    }

    /// Encode and right-pad with PAD to `target_len`.  Errors if the
    /// prompt does not fit.
    pub fn encode_padded(&self, text: &str, target_len: usize) -> anyhow::Result<Vec<i32>> {
        let mut ids = self.encode(text);
        anyhow::ensure!(
            ids.len() <= target_len,
            "prompt of {} tokens exceeds max_prompt {}",
            ids.len(),
            target_len
        );
        ids.resize(target_len, self.pad_id);
        Ok(ids)
    }

    /// Decode generated ids back to text, stopping at EOS and skipping
    /// all non-byte specials.  Invalid UTF-8 is replaced.
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut bytes = Vec::with_capacity(ids.len());
        for &id in ids {
            if id == self.eos_id {
                break;
            }
            if (0..256).contains(&id) {
                bytes.push(id as u8);
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    pub fn is_eos(&self, id: i32) -> bool {
        id == self.eos_id
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::test_manifest;

    fn tok() -> Tokenizer {
        Tokenizer::from_dims(&test_manifest().model)
    }

    #[test]
    fn encode_prepends_bos() {
        let t = tok();
        let ids = t.encode("ab");
        assert_eq!(ids, vec![256, 97, 98]);
    }

    #[test]
    fn roundtrip_ascii() {
        let t = tok();
        let ids = t.encode("the machine works");
        assert_eq!(t.decode(&ids[1..]), "the machine works");
    }

    #[test]
    fn decode_stops_at_eos() {
        let t = tok();
        let ids = vec![104, 105, 257, 120, 121];
        assert_eq!(t.decode(&ids), "hi");
    }

    #[test]
    fn decode_skips_pad_and_bos() {
        let t = tok();
        assert_eq!(t.decode(&[256, 97, 258, 98]), "ab");
    }

    #[test]
    fn padded_encoding() {
        let t = tok();
        let ids = t.encode_padded("xy", 8).unwrap();
        assert_eq!(ids.len(), 8);
        assert_eq!(&ids[..3], &[256, 120, 121]);
        assert!(ids[3..].iter().all(|&i| i == 258));
        assert!(t.encode_padded("way too long", 3).is_err());
    }

    #[test]
    fn utf8_multibyte_roundtrip() {
        let t = tok();
        let ids = t.encode("héllo");
        assert_eq!(t.decode(&ids[1..]), "héllo");
    }
}
