//! Model-side substrate: manifest parsing, weight container, byte-level
//! tokenizer, and sampling utilities.

pub mod manifest;
pub mod sampling;
pub mod tokenizer;
pub mod weights;

pub use manifest::{ArtifactSig, Manifest, ModelDims, TensorSig};
pub use tokenizer::Tokenizer;
pub use weights::Weights;
