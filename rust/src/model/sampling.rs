//! Token sampling.  The paper's experiments use greedy decoding (ROUGE-L
//! of 1.0 at θ=1.0 requires determinism); temperature/top-k are provided
//! for the examples and downstream users.

use crate::util::rng::Rng;

/// Greedy argmax over logits, with first-occurrence tie-breaking (matches
/// the fused exit-head kernel and `jnp.argmax`).
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as i32
}

/// Softmax in-place, numerically stable.  Returns the max probability
/// (the confidence measure used by the early-exit policy).
pub fn softmax(logits: &mut [f32]) -> f32 {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0f32;
    for v in logits.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    let mut maxp = 0f32;
    for v in logits.iter_mut() {
        *v /= sum;
        maxp = maxp.max(*v);
    }
    maxp
}

#[derive(Debug, Clone, Copy)]
pub enum SamplingMode {
    Greedy,
    /// Temperature softmax sampling with optional top-k truncation.
    Temperature { temperature: f32, top_k: Option<usize> },
}

pub fn sample(logits: &[f32], mode: SamplingMode, rng: &mut Rng) -> i32 {
    match mode {
        SamplingMode::Greedy => argmax(logits),
        SamplingMode::Temperature { temperature, top_k } => {
            let mut scaled: Vec<(usize, f32)> = logits
                .iter()
                .enumerate()
                .map(|(i, &v)| (i, v / temperature.max(1e-6)))
                .collect();
            if let Some(k) = top_k {
                scaled.sort_by(|a, b| b.1.total_cmp(&a.1));
                scaled.truncate(k.max(1));
            }
            let m = scaled.iter().map(|x| x.1).fold(f32::NEG_INFINITY, f32::max);
            let weights: Vec<f32> = scaled.iter().map(|x| (x.1 - m).exp()).collect();
            let total: f32 = weights.iter().sum();
            let mut u = rng.gen_f32() * total;
            for ((i, _), w) in scaled.iter().zip(&weights) {
                if u <= *w {
                    return *i as i32;
                }
                u -= w;
            }
            scaled.last().map(|x| x.0 as i32).unwrap_or(0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    
    #[test]
    fn argmax_first_occurrence_on_ties() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn softmax_sums_to_one_and_returns_max() {
        let mut l = vec![1.0, 2.0, 3.0];
        let maxp = softmax(&mut l);
        assert!((l.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!((maxp - l[2]).abs() < 1e-7);
    }

    #[test]
    fn softmax_stable_for_large_logits() {
        let mut l = vec![1000.0, 1001.0];
        let maxp = softmax(&mut l);
        assert!(maxp.is_finite() && maxp > 0.7);
    }

    #[test]
    fn greedy_sample_matches_argmax() {
        let mut rng = Rng::seed_from_u64(0);
        let logits = vec![0.1, 5.0, -2.0];
        assert_eq!(sample(&logits, SamplingMode::Greedy, &mut rng), 1);
    }

    #[test]
    fn temperature_zero_ish_is_greedy() {
        let mut rng = Rng::seed_from_u64(0);
        let logits = vec![0.1, 5.0, -2.0, 4.9];
        for _ in 0..20 {
            let t = sample(
                &logits,
                SamplingMode::Temperature { temperature: 0.01, top_k: None },
                &mut rng,
            );
            assert_eq!(t, 1);
        }
    }

    #[test]
    fn top_k_restricts_support() {
        let mut rng = Rng::seed_from_u64(7);
        let logits = vec![10.0, 9.9, -50.0, -50.0];
        for _ in 0..50 {
            let t = sample(
                &logits,
                SamplingMode::Temperature { temperature: 1.0, top_k: Some(2) },
                &mut rng,
            );
            assert!(t == 0 || t == 1);
        }
    }
}
