//! `artifacts/manifest.json` — the contract between the AOT exporter
//! (python/compile/aot.py) and the rust runtime.
//!
//! The manifest pins, per artifact: the HLO file, which parameter
//! partition its leading inputs come from (in jax pytree flatten order),
//! the runtime inputs that follow, and the flattened output order.
//! Parsed with the in-tree JSON parser ([`crate::util::json`]).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Model architecture constants, mirrored from python/compile/config.py.
#[derive(Debug, Clone)]
pub struct ModelDims {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub ffn_hidden: usize,
    pub l_ee1: usize,
    pub l_ee2: usize,
    pub max_prompt: usize,
    pub max_seq: usize,
    pub head_dim: usize,
    pub bos_id: i32,
    pub eos_id: i32,
    pub pad_id: i32,
    pub rope_theta: f64,
    pub norm_eps: f64,
}

impl ModelDims {
    /// Bytes of one hidden-state vector on the wire at the given element size.
    pub fn hidden_bytes(&self, bytes_per_elem: usize) -> usize {
        self.d_model * bytes_per_elem
    }

    /// Bytes of cloud-partition KV cache one resident position costs: K
    /// and V (f32) for every layer the cloud runs (`l_ee1..n_layers`).
    /// The context store meters per-device residency against
    /// `CloudConfig::memory_budget_bytes` with this rate, and the DES
    /// prices the same law, so simulated and enforced budgets agree.
    pub fn cloud_kv_bytes_per_pos(&self) -> usize {
        2 * self.n_layers.saturating_sub(self.l_ee1) * self.n_heads * self.head_dim * 4
    }

    fn from_json(j: &Json) -> Result<Self> {
        let u = |k: &str| -> Result<usize> {
            j.req(k)?.as_usize().with_context(|| format!("model.{k} not a usize"))
        };
        let i = |k: &str| -> Result<i32> {
            Ok(j.req(k)?.as_i64().with_context(|| format!("model.{k} not an int"))? as i32)
        };
        let f = |k: &str| -> Result<f64> {
            j.req(k)?.as_f64().with_context(|| format!("model.{k} not a number"))
        };
        Ok(Self {
            vocab_size: u("vocab_size")?,
            d_model: u("d_model")?,
            n_layers: u("n_layers")?,
            n_heads: u("n_heads")?,
            ffn_hidden: u("ffn_hidden")?,
            l_ee1: u("l_ee1")?,
            l_ee2: u("l_ee2")?,
            max_prompt: u("max_prompt")?,
            max_seq: u("max_seq")?,
            head_dim: u("head_dim")?,
            bos_id: i("bos_id")?,
            eos_id: i("eos_id")?,
            pad_id: i("pad_id")?,
            rope_theta: f("rope_theta")?,
            norm_eps: f("norm_eps")?,
        })
    }
}

/// Shape+dtype of one named tensor (parameter, input, or output).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSig {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSig {
    pub fn elem_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(j: &Json) -> Result<Self> {
        let name = j.req("name")?.as_str().context("tensor name")?.to_string();
        let shape = j
            .req("shape")?
            .as_arr()
            .context("tensor shape")?
            .iter()
            .map(|d| d.as_usize().context("dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j.req("dtype")?.as_str().context("tensor dtype")?.to_string();
        Ok(Self { name, shape, dtype })
    }
}

/// One AOT-lowered segment function.
#[derive(Debug, Clone)]
pub struct ArtifactSig {
    pub file: String,
    /// Runtime inputs, in call order (params come first, then these).
    pub inputs: Vec<TensorSig>,
    /// Flattened outputs, in tuple order.
    pub outputs: Vec<TensorSig>,
}

impl ArtifactSig {
    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|t| t.name == name)
            .with_context(|| format!("artifact output '{name}' not found"))
    }

    fn from_json(j: &Json) -> Result<Self> {
        let sigs = |key: &str| -> Result<Vec<TensorSig>> {
            j.req(key)?
                .as_arr()
                .with_context(|| format!("artifact.{key}"))?
                .iter()
                .map(TensorSig::from_json)
                .collect()
        };
        Ok(Self {
            file: j.req("file")?.as_str().context("artifact.file")?.to_string(),
            inputs: sigs("inputs")?,
            outputs: sigs("outputs")?,
        })
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: ModelDims,
    /// Parameter tensors per partition, in jax flatten (= argument) order.
    pub partitions: HashMap<String, Vec<TensorSig>>,
    /// artifact name -> partition name.
    pub artifact_params: HashMap<String, String>,
    pub artifacts: HashMap<String, ArtifactSig>,
    pub final_train_loss: Option<f64>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let model = ModelDims::from_json(j.req("model")?)?;

        let mut partitions = HashMap::new();
        for (name, arr) in j.req("partitions")?.as_obj().context("partitions")? {
            let sigs = arr
                .as_arr()
                .context("partition list")?
                .iter()
                .map(TensorSig::from_json)
                .collect::<Result<Vec<_>>>()?;
            partitions.insert(name.clone(), sigs);
        }

        let mut artifact_params = HashMap::new();
        for (name, v) in j.req("artifact_params")?.as_obj().context("artifact_params")? {
            artifact_params
                .insert(name.clone(), v.as_str().context("partition name")?.to_string());
        }

        let mut artifacts = HashMap::new();
        for (name, v) in j.req("artifacts")?.as_obj().context("artifacts")? {
            artifacts.insert(name.clone(), ArtifactSig::from_json(v)?);
        }

        let final_train_loss =
            j.get("final_train_loss").and_then(|v| v.as_f64());

        let m = Manifest { model, partitions, artifact_params, artifacts, final_train_loss };
        m.validate()?;
        Ok(m)
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSig> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' missing from manifest"))
    }

    pub fn partition_for(&self, artifact: &str) -> Result<&[TensorSig]> {
        let pname = self
            .artifact_params
            .get(artifact)
            .with_context(|| format!("no partition mapping for artifact '{artifact}'"))?;
        Ok(self
            .partitions
            .get(pname)
            .with_context(|| format!("partition '{pname}' missing"))?)
    }

    /// Structural sanity checks run at load time, so a stale or truncated
    /// artifact directory fails fast with a readable error.
    pub fn validate(&self) -> Result<()> {
        let m = &self.model;
        anyhow::ensure!(m.l_ee1 < m.l_ee2 && m.l_ee2 <= m.n_layers, "exit points out of order");
        anyhow::ensure!(m.d_model == m.n_heads * m.head_dim, "d_model != heads*head_dim");
        anyhow::ensure!(m.max_prompt <= m.max_seq, "max_prompt exceeds cache capacity");
        for name in [
            "edge_prefill",
            "edge_seg1_decode",
            "edge_seg2_decode",
            "cloud_prefill",
            "cloud_decode",
        ] {
            let a = self.artifact(name)?;
            anyhow::ensure!(!a.outputs.is_empty(), "artifact '{name}' has no outputs");
            self.partition_for(name)?;
        }
        Ok(())
    }
}

/// A minimal, structurally valid manifest for unit tests that don't touch
/// real artifacts (also used by the mock engines).
pub fn test_manifest() -> Manifest {
    let dims = ModelDims {
        vocab_size: 384,
        d_model: 128,
        n_layers: 8,
        n_heads: 4,
        ffn_hidden: 512,
        l_ee1: 3,
        l_ee2: 5,
        max_prompt: 256,
        max_seq: 384,
        head_dim: 32,
        bos_id: 256,
        eos_id: 257,
        pad_id: 258,
        rope_theta: 10000.0,
        norm_eps: 1e-5,
    };
    let sig = |name: &str| ArtifactSig {
        file: format!("{name}.hlo.txt"),
        inputs: vec![],
        outputs: vec![TensorSig { name: "tok".into(), shape: vec![], dtype: "int32".into() }],
    };
    let mut artifacts = HashMap::new();
    let mut artifact_params = HashMap::new();
    for n in
        ["edge_prefill", "edge_seg1_decode", "edge_seg2_decode", "cloud_prefill", "cloud_decode"]
    {
        artifacts.insert(n.to_string(), sig(n));
        let part = if n.starts_with("edge") { "edge" } else { "cloud" };
        artifact_params.insert(n.to_string(), part.to_string());
    }
    let mut partitions = HashMap::new();
    partitions.insert("edge".to_string(), vec![]);
    partitions.insert("cloud".to_string(), vec![]);
    Manifest { model: dims, partitions, artifact_params, artifacts, final_train_loss: None }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_manifest_validates() {
        test_manifest().validate().unwrap();
    }

    #[test]
    fn cloud_kv_bytes_per_pos_counts_cloud_layers_only() {
        let m = test_manifest().model;
        // K + V, f32, for the 5 cloud layers (l_ee1=3 .. n_layers=8)
        assert_eq!(m.cloud_kv_bytes_per_pos(), 2 * 5 * 4 * 32 * 4);
    }

    #[test]
    fn bad_exit_order_rejected() {
        let mut m = test_manifest();
        m.model.l_ee1 = 6; // > l_ee2
        assert!(m.validate().is_err());
    }

    #[test]
    fn inconsistent_heads_rejected() {
        let mut m = test_manifest();
        m.model.head_dim = 31;
        assert!(m.validate().is_err());
    }

    #[test]
    fn missing_artifact_rejected() {
        let mut m = test_manifest();
        m.artifacts.remove("cloud_decode");
        assert!(m.validate().is_err());
    }

    #[test]
    fn output_index_lookup() {
        let m = test_manifest();
        let a = m.artifact("cloud_decode").unwrap();
        assert_eq!(a.output_index("tok").unwrap(), 0);
        assert!(a.output_index("nope").is_err());
    }

    #[test]
    fn parse_minimal_manifest_json() {
        let text = r#"{
          "model": {"vocab_size":384,"d_model":128,"n_layers":8,"n_heads":4,
                    "ffn_hidden":512,"l_ee1":3,"l_ee2":5,"max_prompt":256,
                    "max_seq":384,"head_dim":32,"bos_id":256,"eos_id":257,
                    "pad_id":258,"rope_theta":10000.0,"norm_eps":1e-05},
          "partitions": {"edge": [{"name":"w","shape":[2,3],"dtype":"float32"}],
                         "cloud": []},
          "artifact_params": {"edge_prefill":"edge","edge_seg1_decode":"edge",
                              "edge_seg2_decode":"edge","cloud_prefill":"cloud",
                              "cloud_decode":"cloud"},
          "artifacts": {
            "edge_prefill": {"file":"edge_prefill.hlo.txt","inputs":[],
              "outputs":[{"name":"h1","shape":[256,128],"dtype":"float32"}]},
            "edge_seg1_decode": {"file":"a","inputs":[],"outputs":[{"name":"x","shape":[],"dtype":"int32"}]},
            "edge_seg2_decode": {"file":"b","inputs":[],"outputs":[{"name":"x","shape":[],"dtype":"int32"}]},
            "cloud_prefill": {"file":"c","inputs":[],"outputs":[{"name":"x","shape":[],"dtype":"int32"}]},
            "cloud_decode": {"file":"d","inputs":[],"outputs":[{"name":"x","shape":[],"dtype":"int32"}]}
          },
          "final_train_loss": 0.43
        }"#;
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.model.vocab_size, 384);
        assert_eq!(m.partitions["edge"][0].shape, vec![2, 3]);
        assert_eq!(m.artifact_params["cloud_decode"], "cloud");
        assert_eq!(m.final_train_loss, Some(0.43));
        assert_eq!(m.artifact("edge_prefill").unwrap().outputs[0].name, "h1");
    }

    #[test]
    fn tensor_sig_elem_count() {
        let t = TensorSig { name: "x".into(), shape: vec![3, 4, 2], dtype: "float32".into() };
        assert_eq!(t.elem_count(), 24);
        let s = TensorSig { name: "s".into(), shape: vec![], dtype: "int32".into() };
        assert_eq!(s.elem_count(), 1);
    }
}
