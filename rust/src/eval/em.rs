//! Exact Match (SQuAD-style), the paper's TruthfulQA metric (Table 3).

/// Normalize: lowercase, strip punctuation, collapse whitespace, drop
/// English articles — the standard SQuAD normalization.
pub fn normalize(s: &str) -> String {
    let lower = s.to_lowercase();
    let no_punct: String = lower
        .chars()
        .map(|c| if c.is_alphanumeric() || c.is_whitespace() { c } else { ' ' })
        .collect();
    no_punct
        .split_whitespace()
        .filter(|w| !matches!(*w, "a" | "an" | "the"))
        .collect::<Vec<_>>()
        .join(" ")
}

/// 1.0 if the normalized candidate equals the normalized reference.
pub fn exact_match(candidate: &str, reference: &str) -> f64 {
    if normalize(candidate) == normalize(reference) {
        1.0
    } else {
        0.0
    }
}

/// Template-validity exact match for the synthetic QA grammar.
///
/// The grammar answers "what is D N?" with "a `<ADJ>` `<NOUN>`", but WHICH
/// adjective/noun is genuinely random — no model can match a freshly
/// sampled reference string, so string-EM would be ~0 by construction
/// (unlike TruthfulQA, where the reference is determined by the
/// question).  The faithful analogue of the paper's EM column is
/// whether the model produces a *well-formed* answer: article + known
/// adjective + known noun.  Like the paper's EM (0.18 at every θ), this
/// is insensitive to the exit threshold.
pub fn template_match(candidate: &str) -> f64 {
    let first = candidate.split(['.', ',']).next().unwrap_or("");
    let words: Vec<String> =
        normalize(first).split_whitespace().map(|w| w.to_string()).collect();
    // normalize() drops articles, so a well-formed "a ADJ NOUN" reduces
    // to [ADJ, NOUN]
    if words.len() != 2 {
        return 0.0;
    }
    let adj_ok = crate::eval::datasets::ADJS.contains(&words[0].as_str());
    let noun_ok = crate::eval::datasets::NOUNS.contains(&words[1].as_str());
    if adj_ok && noun_ok {
        1.0
    } else {
        0.0
    }
}

/// Mean EM over a set of (candidate, reference) pairs.
pub fn exact_match_set(pairs: &[(String, String)]) -> f64 {
    if pairs.is_empty() {
        return 0.0;
    }
    pairs.iter().map(|(c, r)| exact_match(c, r)).sum::<f64>() / pairs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_strings_match() {
        assert_eq!(exact_match("machine", "machine"), 1.0);
    }

    #[test]
    fn articles_and_case_ignored() {
        assert_eq!(exact_match("The Machine", "machine"), 1.0);
        assert_eq!(exact_match("an answer.", "answer"), 1.0);
    }

    #[test]
    fn different_content_fails() {
        assert_eq!(exact_match("machine", "computer"), 0.0);
    }

    #[test]
    fn set_mean() {
        let pairs = vec![
            ("a".to_string(), "a".to_string()),
            ("b".to_string(), "c".to_string()),
        ];
        assert_eq!(exact_match_set(&pairs), 0.5);
        assert_eq!(exact_match_set(&[]), 0.0);
    }

    #[test]
    fn normalize_collapses_whitespace_and_punct() {
        assert_eq!(normalize("  The  cat,   sat! "), "cat sat");
    }

    #[test]
    fn template_match_accepts_wellformed_answers() {
        assert_eq!(template_match(" a reliable system. more text"), 1.0);
        assert_eq!(template_match("an efficient network"), 1.0);
    }

    #[test]
    fn template_match_rejects_malformed() {
        assert_eq!(template_match("banana banana banana"), 0.0);
        assert_eq!(template_match("a reliable"), 0.0);
        assert_eq!(template_match(""), 0.0);
        assert_eq!(template_match("a system reliable"), 0.0); // order matters
    }
}
