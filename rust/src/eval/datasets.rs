//! Synthetic prompt sets with the paper's workload shapes.
//!
//! The paper evaluates on Alpaca (short prompts, 13–43 tokens), XSum (long
//! prompts, 200–500 tokens), TruthfulQA, and CNN/DailyMail.  We have none
//! of those licenses baked into a testbed, and — more importantly — the
//! model is a byte-level LM trained on a synthetic grammar, so evaluation
//! prompts must come from the *same grammar* to elicit the paper's
//! confidence structure.  The word lists and templates below mirror
//! `python/compile/data.py` exactly (KEEP IN SYNC).
//!
//! Length shapes are preserved at byte granularity: "alpaca" prompts are
//! 16–48 bytes, "xsum" documents 150–250 bytes (our `max_prompt` is 256).

use crate::util::rng::Rng;

// --- mirrored from python/compile/data.py ---------------------------------
pub const NOUNS: &[&str] = &[
    "machine", "test", "system", "model", "network", "computer", "data",
    "cloud", "edge", "device", "server", "intelligence", "behaviour",
    "ability", "language", "token", "layer", "cache", "latency", "result",
    "question", "answer", "document", "summary", "article", "story",
    "report", "sentence", "paragraph", "response", "request", "signal",
];
pub const VERBS: &[&str] = &[
    "exhibit", "generate", "process", "predict", "transmit", "compute",
    "evaluate", "measure", "produce", "describe", "summarize", "explain",
    "analyze", "compare", "reduce", "improve", "accelerate", "support",
];
pub const ADJS: &[&str] = &[
    "intelligent", "efficient", "adaptive", "large", "small", "fast",
    "slow", "accurate", "reliable", "local", "remote", "collaborative",
    "early", "final", "hidden", "confident",
];
pub const DETS: &[&str] = &["the", "a", "this", "that", "every", "each"];

const TEMPLATES: &[&[&str]] = &[
    &["D", "N", "is", "a", "N", "of", "a", "N's", "ability", "to", "V", "A", "N"],
    &["D", "A", "N", "can", "V", "D", "N"],
    &["D", "N", "must", "V", "D", "A", "N", "quickly"],
    &["what", "is", "D", "N", "?", "it", "is", "a", "A", "N"],
    &["D", "N", "of", "D", "N", "is", "A"],
    &["to", "V", "is", "to", "V", "D", "A", "N"],
    &["D", "N", "and", "D", "N", "V", "together"],
    &["when", "D", "N", "is", "A", ",", "D", "N", "can", "V"],
];
// ---------------------------------------------------------------------------

/// Which paper dataset a prompt set stands in for.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataset {
    /// Short instruction prompts (paper: Alpaca, 13–43 tokens).
    Alpaca,
    /// Long documents (paper: XSum, 200–500 tokens).
    Xsum,
    /// Short QA with a reference answer (paper: TruthfulQA, EM metric).
    TruthfulQa,
    /// Long documents with reference summaries (paper: CNN/DailyMail).
    CnnDailyMail,
}

impl Dataset {
    pub fn name(&self) -> &'static str {
        match self {
            Dataset::Alpaca => "Alpaca",
            Dataset::Xsum => "XSum",
            Dataset::TruthfulQa => "TruthfulQA",
            Dataset::CnnDailyMail => "CNN/DailyMail",
        }
    }
}

/// One evaluation case: a prompt, and (for QA/summarization sets) a
/// grammar-derived reference answer.
#[derive(Debug, Clone)]
pub struct PromptCase {
    pub prompt: String,
    pub reference: Option<String>,
}

/// A generated prompt set.
#[derive(Debug, Clone)]
pub struct PromptSet {
    pub dataset: Dataset,
    pub cases: Vec<PromptCase>,
}

pub fn sample_sentence(rng: &mut Rng) -> String {
    let tpl = TEMPLATES[rng.gen_range(TEMPLATES.len())];
    let mut out: Vec<String> = Vec::with_capacity(tpl.len());
    for tok in tpl {
        let w = match *tok {
            "N" => NOUNS[rng.gen_range(NOUNS.len())].to_string(),
            "N's" => format!("{}'s", NOUNS[rng.gen_range(NOUNS.len())]),
            "V" => VERBS[rng.gen_range(VERBS.len())].to_string(),
            "A" => ADJS[rng.gen_range(ADJS.len())].to_string(),
            "D" => DETS[rng.gen_range(DETS.len())].to_string(),
            other => other.to_string(),
        };
        out.push(w);
    }
    let s = out.join(" ").replace(" ?", "?").replace(" ,", ",");
    format!("{s}.")
}

/// Make a prompt open-ended: the training corpus is `BOS sentence . EOS`,
/// so a prompt ending in "." makes the model emit EOS immediately.
/// Stripping the final period (and cutting back to a word boundary)
/// leaves the model mid-sentence with real tokens left to generate.
fn open_ended(mut s: String) -> String {
    while s.ends_with('.') || s.ends_with(' ') {
        s.pop();
    }
    // drop the final word so the continuation is non-trivial
    if let Some(i) = s.rfind(' ') {
        if i >= 10 {
            s.truncate(i);
        }
    }
    s
}

fn sentence_with_len(rng: &mut Rng, min: usize, max: usize) -> String {
    // rejection-sample a sentence whose byte length fits [min, max],
    // truncating at word boundaries as a fallback
    for _ in 0..64 {
        let s = sample_sentence(rng);
        if s.len() >= min && s.len() <= max {
            return s;
        }
    }
    let mut s = sample_sentence(rng);
    while s.len() > max {
        match s.rfind(' ') {
            Some(i) => s.truncate(i),
            None => {
                s.truncate(max);
                break;
            }
        }
    }
    s
}

fn document_with_len(rng: &mut Rng, min: usize, max: usize) -> String {
    let mut doc = String::new();
    while doc.len() < min {
        if !doc.is_empty() {
            doc.push(' ');
        }
        doc.push_str(&sample_sentence(rng));
    }
    while doc.len() > max {
        match doc.rfind(' ') {
            Some(i) => doc.truncate(i),
            None => {
                doc.truncate(max);
                break;
            }
        }
    }
    doc
}

/// Generate a deterministic prompt set.
///
/// * `Alpaca` — 16–48 byte instruction-style sentences (paper 13–43 tok).
/// * `Xsum` — 150–250 byte documents (paper 200–500 tok, scaled to our
///   `max_prompt = 256`).
/// * `TruthfulQa` — "what is D N?" questions, reference = grammar answer.
/// * `CnnDailyMail` — documents with a leading "summary" sentence as the
///   reference (lead-1, the standard news-summarization heuristic).
pub fn generate(dataset: Dataset, n: usize, seed: u64) -> PromptSet {
    let mut rng = Rng::seed_from_u64(seed.wrapping_mul(0x9E37) ^ dataset as u64);
    let mut cases = Vec::with_capacity(n);
    for _ in 0..n {
        let case = match dataset {
            Dataset::Alpaca => PromptCase {
                prompt: open_ended(sentence_with_len(&mut rng, 22, 48)),
                reference: None,
            },
            Dataset::Xsum => {
                let doc = document_with_len(&mut rng, 160, 250);
                // lead-1 reference: the standard extreme-summarization
                // heuristic (the XSum task is one-sentence summaries)
                let lead = doc.split('.').next().unwrap_or("").trim().to_string();
                PromptCase { prompt: open_ended(doc), reference: Some(lead) }
            }
            Dataset::TruthfulQa => {
                let noun = NOUNS[rng.gen_range(NOUNS.len())];
                let adj = ADJS[rng.gen_range(ADJS.len())];
                let obj = NOUNS[rng.gen_range(NOUNS.len())];
                PromptCase {
                    prompt: format!("what is the {noun}? it is"),
                    reference: Some(format!("a {adj} {obj}")),
                }
            }
            Dataset::CnnDailyMail => {
                let lead = sentence_with_len(&mut rng, 20, 80);
                let body = document_with_len(&mut rng, 100, 170);
                PromptCase {
                    prompt: open_ended(format!("{lead} {body}")),
                    reference: Some(lead),
                }
            }
        };
        cases.push(case);
    }
    PromptSet { dataset, cases }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpaca_lengths_in_band() {
        let set = generate(Dataset::Alpaca, 50, 7);
        for c in &set.cases {
            assert!(
                c.prompt.len() >= 8 && c.prompt.len() <= 48,
                "len {} out of band: {}",
                c.prompt.len(),
                c.prompt
            );
        }
    }

    #[test]
    fn xsum_lengths_in_band() {
        let set = generate(Dataset::Xsum, 30, 7);
        for c in &set.cases {
            assert!(c.prompt.len() >= 100 && c.prompt.len() <= 250);
            assert!(!c.prompt.ends_with('.'), "prompt must be open-ended");
        }
    }

    #[test]
    fn xsum_is_much_longer_than_alpaca() {
        let a = generate(Dataset::Alpaca, 20, 1);
        let x = generate(Dataset::Xsum, 20, 1);
        let mean = |s: &PromptSet| {
            s.cases.iter().map(|c| c.prompt.len()).sum::<usize>() as f64 / s.cases.len() as f64
        };
        assert!(mean(&x) > 3.0 * mean(&a), "paper needs a strong short/long contrast");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(Dataset::Alpaca, 10, 42);
        let b = generate(Dataset::Alpaca, 10, 42);
        for (x, y) in a.cases.iter().zip(&b.cases) {
            assert_eq!(x.prompt, y.prompt);
        }
        let c = generate(Dataset::Alpaca, 10, 43);
        assert!(a.cases.iter().zip(&c.cases).any(|(x, y)| x.prompt != y.prompt));
    }

    #[test]
    fn qa_sets_have_references() {
        for ds in [Dataset::TruthfulQa, Dataset::CnnDailyMail] {
            let set = generate(ds, 10, 0);
            assert!(set.cases.iter().all(|c| c.reference.is_some()));
        }
    }

    #[test]
    fn prompts_are_ascii_bytes() {
        // byte-level model: prompts must stay in single-byte range
        for ds in [Dataset::Alpaca, Dataset::Xsum] {
            for c in &generate(ds, 20, 3).cases {
                assert!(c.prompt.is_ascii());
            }
        }
    }

    #[test]
    fn prompts_fit_max_prompt() {
        for ds in [Dataset::Alpaca, Dataset::Xsum, Dataset::CnnDailyMail] {
            for c in &generate(ds, 30, 9).cases {
                assert!(c.prompt.len() + 1 <= 256, "prompt + BOS must fit max_prompt");
            }
        }
    }
}
