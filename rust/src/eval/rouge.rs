//! ROUGE-L (Lin & Och 2004): LCS-based F-measure over token sequences.
//!
//! The paper uses ROUGE-L twice: (1) Table 2, similarity between CE-CoLLM
//! output and the cloud-deployment output (θ=1.0 must give exactly 1.0);
//! (2) Table 3, summarization quality on XSum/CNN-DM-like tasks.

/// Longest common subsequence length between two token slices.
///
/// O(n·m) time, O(min(n,m)) memory (two rolling rows).
pub fn lcs_len<T: PartialEq>(a: &[T], b: &[T]) -> usize {
    let (short, long) = if a.len() <= b.len() { (a, b) } else { (b, a) };
    if short.is_empty() {
        return 0;
    }
    let mut prev = vec![0usize; short.len() + 1];
    let mut curr = vec![0usize; short.len() + 1];
    for x in long {
        for (j, y) in short.iter().enumerate() {
            curr[j + 1] = if x == y {
                prev[j] + 1
            } else {
                prev[j + 1].max(curr[j])
            };
        }
        std::mem::swap(&mut prev, &mut curr);
    }
    prev[short.len()]
}

/// ROUGE-L F1 between candidate and reference token sequences.
///
/// `beta` is fixed at 1 (harmonic mean), matching HELM's rouge_l scorer.
pub fn rouge_l_tokens<T: PartialEq>(candidate: &[T], reference: &[T]) -> f64 {
    if candidate.is_empty() && reference.is_empty() {
        return 1.0;
    }
    if candidate.is_empty() || reference.is_empty() {
        return 0.0;
    }
    let lcs = lcs_len(candidate, reference) as f64;
    if lcs == 0.0 {
        return 0.0;
    }
    let p = lcs / candidate.len() as f64;
    let r = lcs / reference.len() as f64;
    2.0 * p * r / (p + r)
}

/// ROUGE-L F1 over whitespace-tokenized, lowercased words.
pub fn rouge_l(candidate: &str, reference: &str) -> f64 {
    let c: Vec<String> = tokenize(candidate);
    let r: Vec<String> = tokenize(reference);
    rouge_l_tokens(&c, &r)
}

fn tokenize(s: &str) -> Vec<String> {
    s.split(|c: char| !c.is_alphanumeric() && c != '\'')
        .filter(|w| !w.is_empty())
        .map(|w| w.to_lowercase())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_strings_score_one() {
        assert_eq!(rouge_l("a test of a machine", "a test of a machine"), 1.0);
    }

    #[test]
    fn disjoint_strings_score_zero() {
        assert_eq!(rouge_l("alpha beta", "gamma delta"), 0.0);
    }

    #[test]
    fn empty_cases() {
        assert_eq!(rouge_l("", ""), 1.0);
        assert_eq!(rouge_l("a", ""), 0.0);
        assert_eq!(rouge_l("", "a"), 0.0);
    }

    #[test]
    fn lcs_known_value() {
        // LCS("ABCBDAB", "BDCABA") = 4 ("BCBA" / "BDAB")
        let a: Vec<char> = "ABCBDAB".chars().collect();
        let b: Vec<char> = "BDCABA".chars().collect();
        assert_eq!(lcs_len(&a, &b), 4);
    }

    #[test]
    fn f1_hand_computed() {
        // cand = "the cat sat", ref = "the cat sat down": LCS=3, P=1, R=3/4
        let got = rouge_l("the cat sat", "the cat sat down");
        let expect = 2.0 * 1.0 * 0.75 / 1.75;
        assert!((got - expect).abs() < 1e-12);
    }

    #[test]
    fn case_and_punctuation_insensitive() {
        assert_eq!(rouge_l("The Machine, works.", "the machine works"), 1.0);
    }

    #[test]
    fn order_sensitivity() {
        // same bag of words, scrambled order -> LCS < n
        let s = rouge_l("a b c d", "d c b a");
        assert!(s < 1.0 && s > 0.0);
    }

    #[test]
    fn symmetric_f1() {
        let x = "the edge device can predict tokens";
        let y = "the cloud must predict every token";
        assert!((rouge_l(x, y) - rouge_l(y, x)).abs() < 1e-12);
    }
}
