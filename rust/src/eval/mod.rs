//! Evaluation substrate: metrics (ROUGE-L, Exact Match) and the synthetic
//! prompt sets standing in for Alpaca / XSum / TruthfulQA / CNN-DailyMail
//! (DESIGN.md §Hardware-Adaptation explains the substitution).

pub mod datasets;
pub mod em;
pub mod rouge;

pub use em::exact_match;
pub use rouge::rouge_l;
