//! Naïve cloud-edge deployment (paper Fig 1b): the model is split at
//! `l_ee1`, and for *every* token the edge synchronously re-transmits the
//! full fp32 hidden-state history before the cloud can continue — no
//! early exits, no content manager, no parallel upload.
//!
//! This is the strawman whose communication cost the paper measures at
//! 10.9 GB (Alpaca) / 65.8 GB (XSum) for 100 prompts: transmitted bytes
//! grow **quadratically** in sequence length.  Token outputs are
//! identical to the cloud-only baseline (same full model).

use anyhow::Result;

use crate::metrics::RunCounters;
use crate::model::tokenizer::Tokenizer;
use crate::quant::{self, Precision};
use crate::runtime::traits::{CloudEngine, EdgeEngine};

pub struct NaiveSplitRunner<E: EdgeEngine, C: CloudEngine> {
    edge: E,
    cloud: C,
    pub tokenizer: Tokenizer,
}

#[derive(Debug, Clone)]
pub struct NaiveOutput {
    pub text: String,
    pub tokens: Vec<i32>,
    pub counters: RunCounters,
}

impl<E: EdgeEngine, C: CloudEngine> NaiveSplitRunner<E, C> {
    pub fn new(edge: E, cloud: C) -> Self {
        let tokenizer = Tokenizer::from_dims(edge.dims());
        Self { edge, cloud, tokenizer }
    }

    pub fn generate(&mut self, prompt: &str, max_new_tokens: usize) -> Result<NaiveOutput> {
        let dims = self.edge.dims().clone();
        let ids = self.tokenizer.encode(prompt);
        let prompt_len = ids.len();

        self.edge.reset();
        self.cloud.reset();
        let mut counters = RunCounters::default();

        // history of fp32 hidden states the edge re-sends every token
        let mut h1_history: Vec<f32> = Vec::with_capacity(prompt_len * dims.d_model);

        let pre = self.edge.prefill(&ids)?;
        h1_history.extend_from_slice(&pre.h1);
        // token 1: full history (the prompt) travels fp32, synchronously
        counters.bytes_up += (quant::pack(&h1_history, Precision::F32).len() + 30) as u64;
        counters.cloud_requests += 1;
        let first = self.cloud.prefill(&pre.h1, prompt_len)?;
        counters.bytes_down += 21; // TokenResponse frame

        let mut tokens = vec![first.exit.token];
        counters.tokens_generated = 1;
        counters.tokens_cloud = 1;

        while !self.tokenizer.is_eos(*tokens.last().unwrap())
            && tokens.len() < max_new_tokens
            && prompt_len + tokens.len() < dims.max_seq
        {
            let pos = prompt_len + tokens.len() - 1;
            let s1 = self.edge.seg1(*tokens.last().unwrap(), pos)?;
            h1_history.extend_from_slice(&s1.h1);
            // the WHOLE history goes out again (no content manager)
            counters.bytes_up += (h1_history.len() * 4 + 30) as u64;
            counters.cloud_requests += 1;
            let out = self.cloud.decode(&s1.h1, pos)?;
            counters.bytes_down += 21; // TokenResponse frame
            counters.tokens_cloud += 1;
            counters.tokens_generated += 1;
            tokens.push(out.exit.token);
        }

        Ok(NaiveOutput { text: self.tokenizer.decode(&tokens), tokens, counters })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::cloud_only::CloudOnlyRunner;
    use crate::model::manifest::test_manifest;
    use crate::runtime::mock::{MockCloud, MockEdge, MockOracle};

    fn pair(seed: u64) -> (MockEdge, MockCloud) {
        let dims = test_manifest().model;
        let o = MockOracle::new(seed);
        (MockEdge::new(o, dims.clone()), MockCloud::new(o, dims))
    }

    #[test]
    fn tokens_match_cloud_only() {
        let (e, c) = pair(9);
        let mut naive = NaiveSplitRunner::new(e, c);
        let nv = naive.generate("the system", 10).unwrap();
        let (e2, c2) = pair(9);
        let mut cloud = CloudOnlyRunner::new(e2, c2);
        let cl = cloud.generate("the system", 10).unwrap();
        assert_eq!(nv.tokens, cl.tokens);
        assert_eq!(nv.text, cl.text);
    }

    #[test]
    fn hundred_percent_cloud_rate() {
        let (e, c) = pair(1);
        let out = NaiveSplitRunner::new(e, c).generate("abc", 12).unwrap();
        assert_eq!(out.counters.request_cloud_rate(), 1.0);
        assert_eq!(out.counters.cloud_requests, out.counters.tokens_generated);
    }

    #[test]
    fn transmitted_bytes_grow_quadratically() {
        let (e, c) = pair(2);
        let short = NaiveSplitRunner::new(e, c).generate("abcdefgh", 5).unwrap();
        let (e, c) = pair(2);
        let long = NaiveSplitRunner::new(e, c).generate("abcdefgh", 20).unwrap();
        let b_s = short.counters.bytes_up as f64;
        let b_l = long.counters.bytes_up as f64;
        // 4x the tokens must cost much more than 4x the bytes
        assert!(b_l / b_s > 4.0, "{b_s} -> {b_l}");
    }

    #[test]
    fn history_bytes_are_fp32() {
        let dims = test_manifest().model;
        let (e, c) = pair(3);
        let out = NaiveSplitRunner::new(e, c).generate("xy", 3).unwrap();
        // first request carries prompt_len=3 hiddens in fp32
        let d = dims.d_model;
        assert!(out.counters.bytes_up >= (3 * d * 4) as u64);
    }
}
