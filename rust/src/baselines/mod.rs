//! Baseline deployment strategies from the paper's evaluation (§5).
//!
//! These are *real-engine* implementations used for reference outputs and
//! for validating the DES accounting; the timing rows of Tables 2/4 are
//! produced by replaying the same logic analytically
//! ([`crate::harness::des::Strategy::CloudOnly`] / `NaiveSplit`).

pub mod cloud_only;
pub mod naive_split;
