//! Cloud-based LLM deployment (paper Fig 1a): the edge sends an API
//! request with the raw prompt; the *full* model runs in the cloud.
//!
//! With CE-CoLLM's partitioning, the full model is exactly
//! `layers[0..l_ee1)` (the edge seg-1 stack) followed by the cloud
//! partition `layers[l_ee1..N)` + final head — so this runner composes an
//! edge session and a cloud session, both *charged to the cloud*.  Its
//! greedy output is the reference string for every ROUGE-L column in
//! Table 2, and must equal CE-CoLLM's output at θ=1.0 (tested in
//! `rust/tests/`).

use anyhow::Result;

use crate::model::tokenizer::Tokenizer;
use crate::runtime::traits::{CloudEngine, EdgeEngine};

pub struct CloudOnlyRunner<E: EdgeEngine, C: CloudEngine> {
    seg1: E,
    cloud: C,
    pub tokenizer: Tokenizer,
}

#[derive(Debug, Clone)]
pub struct CloudOnlyOutput {
    pub text: String,
    pub tokens: Vec<i32>,
    /// Payload bytes for the API round trip (prompt up, text down).
    pub bytes_up: u64,
    pub bytes_down: u64,
}

impl<E: EdgeEngine, C: CloudEngine> CloudOnlyRunner<E, C> {
    pub fn new(seg1: E, cloud: C) -> Self {
        let tokenizer = Tokenizer::from_dims(seg1.dims());
        Self { seg1, cloud, tokenizer }
    }

    /// Full-model greedy generation, entirely "in the cloud".
    pub fn generate(&mut self, prompt: &str, max_new_tokens: usize) -> Result<CloudOnlyOutput> {
        let dims = self.seg1.dims().clone();
        let ids = self.tokenizer.encode(prompt);
        let prompt_len = ids.len();

        self.seg1.reset();
        self.cloud.reset();

        // full-model prefill: seg1 hiddens feed the cloud partition
        let pre = self.seg1.prefill(&ids)?;
        let first = self.cloud.prefill(&pre.h1, prompt_len)?;

        let mut tokens = vec![first.exit.token];
        while !self.tokenizer.is_eos(*tokens.last().unwrap())
            && tokens.len() < max_new_tokens
            && prompt_len + tokens.len() < dims.max_seq
        {
            let pos = prompt_len + tokens.len() - 1;
            let s1 = self.seg1.seg1(*tokens.last().unwrap(), pos)?;
            let out = self.cloud.decode(&s1.h1, pos)?;
            tokens.push(out.exit.token);
        }

        let text = self.tokenizer.decode(&tokens);
        Ok(CloudOnlyOutput {
            bytes_up: prompt.len() as u64 + 30,
            bytes_down: text.len() as u64 + 30,
            text,
            tokens,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::test_manifest;
    use crate::runtime::mock::{MockCloud, MockEdge, MockOracle};

    fn runner(seed: u64) -> CloudOnlyRunner<MockEdge, MockCloud> {
        let dims = test_manifest().model;
        let o = MockOracle::new(seed);
        CloudOnlyRunner::new(MockEdge::new(o, dims.clone()), MockCloud::new(o, dims))
    }

    #[test]
    fn generates_cloud_tokens_only() {
        let mut r = runner(3);
        let o = MockOracle::new(3);
        let out = r.generate("a question", 8).unwrap();
        assert_eq!(out.tokens.len(), 8);
        // every token is the oracle's cloud/final token at its position
        let plen = "a question".len() + 1;
        for (i, t) in out.tokens.iter().enumerate() {
            assert_eq!(*t, o.cloud_token(plen - 1 + i));
        }
    }

    #[test]
    fn stops_at_eos() {
        let dims = test_manifest().model;
        let mut o = MockOracle::new(1);
        let plen = "ab".len() + 1;
        o.eos_at = Some(plen - 1 + 3);
        let mut r =
            CloudOnlyRunner::new(MockEdge::new(o, dims.clone()), MockCloud::new(o, dims));
        let out = r.generate("ab", 64).unwrap();
        assert_eq!(out.tokens.len(), 4);
        assert_eq!(*out.tokens.last().unwrap(), 257);
    }

    #[test]
    fn api_bytes_are_text_sized() {
        let mut r = runner(2);
        let out = r.generate("hello there machine", 6).unwrap();
        assert_eq!(out.bytes_up, 19 + 30);
        assert_eq!(out.bytes_down, out.text.len() as u64 + 30);
        // tiny compared to even one fp16 hidden state per token
        assert!(out.bytes_up < 128);
    }
}
