//! Hidden-state payload quantization (paper §4.3).
//!
//! The edge transmits intermediate hidden states in half precision to cut
//! the dominant communication cost.  The paper validates that observed
//! activations (−6553.19 .. 2126.24) sit comfortably inside the f16 range
//! (±65504); we provide the same range check plus round-trip utilities and
//! accuracy statistics used by Table 3 and the §5.4 ablation.

use crate::util::f16;

/// Wire precision of a hidden-state payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    F16,
    F32,
}

impl Precision {
    pub fn bytes_per_elem(self) -> usize {
        match self {
            Precision::F16 => 2,
            Precision::F32 => 4,
        }
    }

    pub fn from_flag(half_precision: bool) -> Self {
        if half_precision { Precision::F16 } else { Precision::F32 }
    }
}

/// Pack an f32 slice into wire bytes (little-endian).
///
/// Writes into a pre-sized buffer through `chunks_exact_mut` (no per-
/// element growth checks, auto-vectorizable) — see EXPERIMENTS.md §Perf
/// for the before/after.
pub fn pack(values: &[f32], precision: Precision) -> Vec<u8> {
    match precision {
        Precision::F32 => {
            let mut out = vec![0u8; values.len() * 4];
            for (chunk, v) in out.chunks_exact_mut(4).zip(values) {
                chunk.copy_from_slice(&v.to_le_bytes());
            }
            out
        }
        Precision::F16 => {
            let mut out = vec![0u8; values.len() * 2];
            for (chunk, v) in out.chunks_exact_mut(2).zip(values) {
                chunk.copy_from_slice(&f16::f32_to_f16_bits(*v).to_le_bytes());
            }
            out
        }
    }
}

/// Unpack wire bytes back to f32.  Errors on length mismatch.
pub fn unpack(bytes: &[u8], precision: Precision) -> anyhow::Result<Vec<f32>> {
    let mut out = Vec::new();
    unpack_into(bytes, precision, &mut out)?;
    Ok(out)
}

/// [`unpack`] into a caller-owned buffer: the buffer is cleared and
/// refilled, reusing its allocation.  The per-token serve path unpacks
/// every uploaded hidden state; reusing one buffer per connection removes
/// that allocation from the hot loop (see the hotpath bench).
pub fn unpack_into(
    bytes: &[u8],
    precision: Precision,
    out: &mut Vec<f32>,
) -> anyhow::Result<()> {
    let esz = precision.bytes_per_elem();
    if bytes.len() % esz != 0 {
        anyhow::bail!("payload length {} not a multiple of {}", bytes.len(), esz);
    }
    let n = bytes.len() / esz;
    out.clear();
    out.reserve(n);
    match precision {
        Precision::F32 => {
            for c in bytes.chunks_exact(4) {
                out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
            }
        }
        Precision::F16 => {
            for c in bytes.chunks_exact(2) {
                out.push(f16::f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]])));
            }
        }
    }
    Ok(())
}

/// Statistics from quantizing a batch of activations — mirrors the paper's
/// feasibility analysis ("values ranged from −6553.19 to 2126.24, within
/// the representable range of float16").
#[derive(Debug, Clone, Default)]
pub struct QuantStats {
    pub min: f32,
    pub max: f32,
    pub max_abs_err: f32,
    pub mean_abs_err: f64,
    pub n: usize,
    pub out_of_range: usize,
}

/// f16 range limit.
pub const F16_MAX: f32 = 65504.0;

pub fn analyze(values: &[f32]) -> QuantStats {
    let mut s = QuantStats {
        min: f32::INFINITY,
        max: f32::NEG_INFINITY,
        ..Default::default()
    };
    let mut sum_err = 0f64;
    for &v in values {
        s.min = s.min.min(v);
        s.max = s.max.max(v);
        if v.abs() > F16_MAX {
            s.out_of_range += 1;
        }
        let err = (f16::quantize(v) - v).abs();
        s.max_abs_err = s.max_abs_err.max(err);
        sum_err += err as f64;
    }
    s.n = values.len();
    s.mean_abs_err = if s.n > 0 { sum_err / s.n as f64 } else { 0.0 };
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip_is_exact() {
        let v = vec![0.0, 1.5, -3.25, 1e-7, 6553.1875, -6553.1875];
        let b = pack(&v, Precision::F32);
        assert_eq!(b.len(), v.len() * 4);
        assert_eq!(unpack(&b, Precision::F32).unwrap(), v);
    }

    #[test]
    fn f16_roundtrip_small_relative_error() {
        let v: Vec<f32> = (0..1000).map(|i| (i as f32 - 500.0) * 0.37).collect();
        let back = unpack(&pack(&v, Precision::F16), Precision::F16).unwrap();
        for (a, b) in v.iter().zip(&back) {
            let rel = (a - b).abs() / a.abs().max(1.0);
            assert!(rel < 1e-3, "rel err {rel} for {a} -> {b}");
        }
    }

    #[test]
    fn f16_halves_the_bytes() {
        let v = vec![1.0f32; 128];
        assert_eq!(pack(&v, Precision::F16).len() * 2, pack(&v, Precision::F32).len());
    }

    #[test]
    fn paper_observed_range_fits_f16() {
        // the exact range the paper reports for hidden states
        let s = analyze(&[-6553.1875, 2126.2419]);
        assert_eq!(s.out_of_range, 0);
        assert!(s.max_abs_err / 6553.19 < 1e-3);
    }

    #[test]
    fn unpack_rejects_ragged_payload() {
        assert!(unpack(&[1, 2, 3], Precision::F16).is_err());
        assert!(unpack(&[1, 2, 3, 4, 5], Precision::F32).is_err());
    }

    #[test]
    fn unpack_into_reuses_the_buffer() {
        let v: Vec<f32> = (0..128).map(|i| i as f32 * 0.5).collect();
        let b = pack(&v, Precision::F16);
        let mut buf = Vec::new();
        unpack_into(&b, Precision::F16, &mut buf).unwrap();
        assert_eq!(buf, unpack(&b, Precision::F16).unwrap());
        let cap = buf.capacity();
        let ptr = buf.as_ptr();
        unpack_into(&b, Precision::F16, &mut buf).unwrap();
        assert_eq!(buf.capacity(), cap, "second unpack must not grow");
        assert_eq!(buf.as_ptr(), ptr, "second unpack must not reallocate");
        // a ragged payload errors before touching the buffer
        assert!(unpack_into(&[1, 2, 3], Precision::F16, &mut buf).is_err());
        assert_eq!(buf.len(), 128, "failed unpack must not corrupt the buffer");
    }

    #[test]
    fn analyze_flags_out_of_range() {
        let s = analyze(&[70000.0, -70000.0, 1.0]);
        assert_eq!(s.out_of_range, 2);
        assert_eq!(s.n, 3);
    }
}
