//! # CE-CoLLM — Cloud-Edge Collaborative LLM Inference (reproduction)
//!
//! Reproduction of *CE-CoLLM: Efficient and Adaptive Large Language Models
//! Through Cloud-Edge Collaboration* (Jin & Wu, 2024) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: an edge
//!   client with an early-exit decode loop and asynchronous parallel hidden
//!   state upload, a cloud server with a per-device content manager and
//!   single-token responses, wire protocol, WAN models, baselines
//!   (cloud-only / naïve split), metrics, evaluation, and the experiment
//!   harnesses that regenerate every table and figure in the paper.
//! * **L2 (python/compile, build time)** — an EE-LLM-style byte-level
//!   transformer segmented at the paper's exit points and AOT-lowered to
//!   HLO text artifacts.
//! * **L1 (python/compile/kernels, build time)** — Pallas kernels: flash
//!   prefill/decode attention and a fused exit head producing the token
//!   confidence in a single VMEM-resident pass.
//!
//! Python never runs on the request path: the artifacts in `artifacts/`
//! are loaded and executed through PJRT (`runtime` module).

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod harness;
pub mod metrics;
pub mod model;
pub mod net;
pub mod quant;
pub mod runtime;
pub mod trace;
pub mod util;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::config::{AblationFlags, DeploymentConfig, ExitPolicy};
    pub use crate::metrics::CostBreakdown;
    pub use crate::net::profiles::LinkProfile;
}
