//! Dependency-free substrates: the deployment environment is offline, so
//! JSON parsing, half-precision conversion, PRNG, and CLI parsing are
//! implemented here rather than pulled from crates.io.

pub mod bench;
pub mod cli;
pub mod f16;
pub mod json;
pub mod rng;
