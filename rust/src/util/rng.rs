//! Small deterministic PRNG (splitmix64 core) for workload generation and
//! property tests.  Not cryptographic; chosen for exact reproducibility of
//! every table row given a seed.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`.  Panics on `n == 0`.
    pub fn gen_range(&mut self, n: usize) -> usize {
        assert!(n > 0, "gen_range(0)");
        // Lemire's multiply-shift with rejection for unbiased sampling
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_u64(x, n);
            if lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn gen_f32(&mut self) -> f32 {
        self.gen_f64() as f32
    }

    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.gen_range(items.len())]
    }

    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.gen_range(i + 1);
            items.swap(i, j);
        }
    }
}

#[inline]
fn mul_u64(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::seed_from_u64(9);
        let mut b = Rng::seed_from_u64(9);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(10);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn gen_range_in_bounds_and_covers() {
        let mut r = Rng::seed_from_u64(1);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.gen_range(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn unit_floats_in_range_and_roughly_uniform() {
        let mut r = Rng::seed_from_u64(2);
        let mut sum = 0f64;
        for _ in 0..10_000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "astronomically unlikely identity");
    }

    #[test]
    #[should_panic(expected = "gen_range(0)")]
    fn gen_range_zero_panics() {
        Rng::seed_from_u64(0).gen_range(0);
    }
}
