//! Minimal recursive-descent JSON parser (RFC 8259 subset sufficient for
//! `manifest.json`): objects, arrays, strings with escapes, numbers,
//! booleans, null.  No external crates are available offline, and the
//! manifest is the only JSON the request path ever touches.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest parsing helper.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().filter(|n| n.fract() == 0.0).map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }
}

impl fmt::Display for Json {
    /// Compact serialization (used by the CLI to dump reports).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(
                                char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        } else {
                            out.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble multi-byte utf-8 sequences
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {"e": null}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert!(j.get("d").unwrap().get("e").unwrap().is_null());
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\nb\t\"q\" \\ A 😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\nb\t\"q\" \\ A 😀");
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo — ω\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo — ω");
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "\"abc", "{\"a\"}", "01x", "{\"a\":1,}", "tru", "[1 2]"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn typed_accessors() {
        let j = Json::parse(r#"{"n": 3, "f": 1.5, "neg": -2}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("f").unwrap().as_usize(), None);
        assert_eq!(j.get("neg").unwrap().as_usize(), None);
        assert_eq!(j.get("neg").unwrap().as_i64(), Some(-2));
        assert!(j.req("missing").is_err());
    }

    #[test]
    fn display_roundtrip() {
        let src = r#"{"a":[1,2.5,"x\n"],"b":{"c":true,"d":null}}"#;
        let j = Json::parse(src).unwrap();
        let printed = j.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), j);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "model": {"vocab_size": 384, "norm_eps": 1e-05},
          "artifacts": {"edge_prefill": {"file": "edge_prefill.hlo.txt",
            "inputs": [{"name": "tokens", "shape": [256], "dtype": "int32"}]}}
        }"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(
            j.req("model").unwrap().req("vocab_size").unwrap().as_usize(),
            Some(384)
        );
        let eps = j.get("model").unwrap().get("norm_eps").unwrap().as_f64().unwrap();
        assert!((eps - 1e-5).abs() < 1e-12);
    }
}
