//! Minimal benchmarking harness (the offline environment has no
//! criterion).  Warmup + timed iterations, reporting mean / p50 / p90 in
//! adaptive units; used by the `benches/` targets run via `cargo bench`.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p90_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10}/iter  p50 {:>10}  p90 {:>10}  ({} iters)",
            self.name,
            fmt_dur(self.mean_s),
            fmt_dur(self.p50_s),
            fmt_dur(self.p90_s),
            self.iters
        )
    }
}

pub fn fmt_dur(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Run `f` repeatedly for roughly `budget_s` seconds (after warmup) and
/// report per-iteration statistics.  `f`'s return value is black-boxed.
pub fn bench<T>(name: &str, budget_s: f64, mut f: impl FnMut() -> T) -> BenchResult {
    // warmup: one call, then estimate batch size
    let t0 = Instant::now();
    std::hint::black_box(f());
    let first = t0.elapsed().as_secs_f64().max(1e-9);

    let target_iters = ((budget_s / first) as usize).clamp(1, 100_000);
    let mut samples = Vec::with_capacity(target_iters);
    let bench_start = Instant::now();
    for _ in 0..target_iters {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed().as_secs_f64());
        if bench_start.elapsed().as_secs_f64() > budget_s * 2.0 {
            break;
        }
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let r = BenchResult {
        name: name.to_string(),
        iters: n,
        mean_s: mean,
        p50_s: samples[n / 2],
        p90_s: samples[(n * 9) / 10],
    };
    println!("{}", r.report());
    r
}

/// Throughput helper: report bytes/s for a payload-processing closure.
pub fn bench_throughput(
    name: &str,
    bytes_per_iter: usize,
    budget_s: f64,
    f: impl FnMut() -> Vec<u8>,
) -> BenchResult {
    let mut f = f;
    let r = bench(name, budget_s, || f());
    println!(
        "{:<44} {:>10.2} MB/s",
        format!("{name} (throughput)"),
        bytes_per_iter as f64 / r.mean_s / 1e6
    );
    r
}

/// Serialize results as a JSON array (hand-rolled — the offline build has
/// no serde).  CI uploads this as the `BENCH_hotpath.json` artifact so
/// the perf trajectory accumulates across commits.
pub fn to_json(results: &[BenchResult]) -> String {
    let mut s = String::from("[\n");
    for (i, r) in results.iter().enumerate() {
        if i > 0 {
            s.push_str(",\n");
        }
        s.push_str(&format!(
            "  {{\"name\": \"{}\", \"iters\": {}, \"mean_s\": {:e}, \"p50_s\": {:e}, \"p90_s\": {:e}}}",
            r.name.replace('\\', "\\\\").replace('"', "\\\""),
            r.iters,
            r.mean_s,
            r.p50_s,
            r.p90_s
        ));
    }
    s.push_str("\n]\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let r = bench("noop", 0.02, || 1 + 1);
        assert!(r.iters >= 1);
        assert!(r.mean_s >= 0.0 && r.p50_s <= r.p90_s + 1e-12);
    }

    #[test]
    fn json_output_is_well_formed() {
        let r = BenchResult {
            name: "a \"quoted\" name".into(),
            iters: 3,
            mean_s: 1.5e-6,
            p50_s: 0.0,
            p90_s: 2e-6,
        };
        let j = to_json(&[r]);
        assert!(j.starts_with("[\n") && j.ends_with("]\n"), "{j}");
        assert!(j.contains("\\\"quoted\\\""), "{j}");
        assert!(j.contains("\"iters\": 3"), "{j}");
        // parses as one object per result
        assert_eq!(j.matches("\"name\"").count(), 1);
    }

    #[test]
    fn fmt_dur_units() {
        assert!(fmt_dur(2.0).ends_with(" s"));
        assert!(fmt_dur(2e-3).ends_with(" ms"));
        assert!(fmt_dur(2e-6).ends_with(" µs"));
        assert!(fmt_dur(2e-9).ends_with(" ns"));
    }
}
