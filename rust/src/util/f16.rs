//! IEEE 754 binary16 conversion (no `half` crate in the offline env).
//!
//! Round-to-nearest-even on narrowing, full subnormal/Inf/NaN handling —
//! bit-exact with `numpy.float16` on every value the model transmits,
//! which is what makes the Table 3 "f16 == f32 accuracy" comparison
//! meaningful.

/// Convert an `f32` to binary16 bits (round-to-nearest-even).
pub fn f32_to_f16_bits(value: f32) -> u16 {
    let x = value.to_bits();
    let sign = ((x >> 16) & 0x8000) as u16;
    let exp = ((x >> 23) & 0xFF) as i32;
    let mant = x & 0x7F_FFFF;

    if exp == 0xFF {
        // Inf stays Inf; any NaN becomes a quiet NaN
        return if mant != 0 { sign | 0x7E00 } else { sign | 0x7C00 };
    }

    // unbiased exponent in f16 terms
    let e16 = exp - 127 + 15;
    if e16 >= 0x1F {
        return sign | 0x7C00; // overflow -> Inf
    }
    if e16 <= 0 {
        // subnormal or zero in f16
        if e16 < -10 {
            return sign; // too small -> signed zero
        }
        // implicit leading 1 joins the mantissa
        let m = mant | 0x80_0000;
        let shift = (14 - e16) as u32;
        let half_ulp = 1u32 << (shift - 1);
        let mut out = (m >> shift) as u16;
        // round to nearest even
        let rem = m & ((1 << shift) - 1);
        if rem > half_ulp || (rem == half_ulp && (out & 1) == 1) {
            out += 1;
        }
        return sign | out;
    }

    // normal number: keep 10 mantissa bits, round-to-nearest-even
    let mut out = sign | ((e16 as u16) << 10) | ((mant >> 13) as u16);
    let rem = mant & 0x1FFF;
    if rem > 0x1000 || (rem == 0x1000 && (out & 1) == 1) {
        out = out.wrapping_add(1); // may carry into the exponent: correct (2^k)
    }
    out
}

/// Convert binary16 bits to `f32` (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;
    let bits = match (exp, mant) {
        (0, 0) => sign,
        (0, m) => {
            // subnormal: renormalize
            let mut e = 127 - 15 - 10;
            let mut m = m;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (((e + 10 + 1) as u32) << 23) | ((m & 0x03FF) << 13)
        }
        (0x1F, 0) => sign | 0x7F80_0000,
        (0x1F, m) => sign | 0x7F80_0000 | (m << 13),
        (e, m) => sign | ((e + 127 - 15) << 23) | (m << 13),
    };
    f32::from_bits(bits)
}

/// One round trip through f16.
pub fn quantize(value: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(value))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        for v in [0.0f32, -0.0, 1.0, -1.0, 2.0, 0.5, 0.25, 65504.0, -65504.0, 1024.0] {
            assert_eq!(quantize(v), v, "{v}");
        }
    }

    #[test]
    fn sign_preserved_on_zero() {
        assert!(quantize(-0.0).is_sign_negative());
        assert!(quantize(0.0).is_sign_positive());
    }

    #[test]
    fn overflow_to_infinity() {
        assert_eq!(quantize(70000.0), f32::INFINITY);
        assert_eq!(quantize(-70000.0), f32::NEG_INFINITY);
        // largest finite f16 is 65504; halfway rounds to inf
        assert_eq!(quantize(65520.0), f32::INFINITY);
    }

    #[test]
    fn inf_nan_preserved() {
        assert_eq!(quantize(f32::INFINITY), f32::INFINITY);
        assert_eq!(quantize(f32::NEG_INFINITY), f32::NEG_INFINITY);
        assert!(quantize(f32::NAN).is_nan());
    }

    #[test]
    fn subnormals() {
        // smallest positive f16 subnormal = 2^-24
        let tiny = 2.0f32.powi(-24);
        assert_eq!(quantize(tiny), tiny);
        // below half of it underflows to zero
        assert_eq!(quantize(tiny / 4.0), 0.0);
        // 2^-25 is exactly half an ulp: round-to-even -> 0
        assert_eq!(quantize(2.0f32.powi(-25)), 0.0);
        // just above half an ulp rounds up to the smallest subnormal
        assert_eq!(quantize(2.0f32.powi(-25) * 1.5), tiny);
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and 1+2^-10:
        // even mantissa (1.0) wins
        let halfway = 1.0 + 2.0f32.powi(-11);
        assert_eq!(quantize(halfway), 1.0);
        // 1 + 3*2^-11 is halfway between 1+2^-10 and 1+2^-9: rounds up to even
        let halfway2 = 1.0 + 3.0 * 2.0f32.powi(-11);
        assert_eq!(quantize(halfway2), 1.0 + 2.0 * 2.0f32.powi(-10));
    }

    #[test]
    fn relative_error_bound_for_normals() {
        // f16 has 11 significand bits -> rel err <= 2^-11
        let mut x = 1e-3f32;
        while x < 6e4 {
            let q = quantize(x);
            let rel = (q - x).abs() / x;
            assert!(rel <= 2.0f32.powi(-11), "x={x} q={q} rel={rel}");
            x *= 1.37;
        }
    }

    #[test]
    fn paper_observed_range_fits() {
        for v in [-6553.1875f32, 2126.2419] {
            let q = quantize(v);
            assert!((q - v).abs() / v.abs() < 1e-3);
            assert!(q.is_finite());
        }
    }

    #[test]
    fn carry_into_exponent_on_mantissa_overflow() {
        // 2047.9999... pattern: mantissa all-ones rounds up to next power of two
        let v = f16_bits_to_f32(0x6BFF); // 4092
        let next = f16_bits_to_f32(0x6C00); // 4096
        let mid = (v + next) / 2.0 + 0.5; // just above halfway
        assert_eq!(quantize(mid), next);
    }
}
