//! Tiny command-line parser for the `ce-collm` binary and examples
//! (offline environment: no clap).  Supports `--flag`, `--key value`,
//! `--key=value`, and positional arguments.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, Option<String>>,
}

impl Args {
    pub fn parse_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Self {
        let mut out = Args::default();
        let mut iter = iter.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), Some(v.to_string()));
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.flags.insert(rest.to_string(), iter.next());
                } else {
                    out.flags.insert(rest.to_string(), None);
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|v| v.as_deref())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positional_and_flags() {
        let a = parse("table 2 --repeats 5 --verbose --out=x.md");
        assert_eq!(a.positional, vec!["table", "2"]);
        assert_eq!(a.get("repeats"), Some("5"));
        assert!(a.has("verbose"));
        assert_eq!(a.get("out"), Some("x.md"));
    }

    #[test]
    fn typed_defaults() {
        let a = parse("--n 7");
        assert_eq!(a.get_parse("n", 0usize), 7);
        assert_eq!(a.get_parse("missing", 3.5f64), 3.5);
        assert_eq!(a.get_parse("n", 0.0f64), 7.0);
        assert_eq!(a.get_or("missing", "d"), "d");
    }

    #[test]
    fn bare_flag_before_flag_not_greedy() {
        let a = parse("--verbose --n 2");
        assert!(a.has("verbose"));
        assert_eq!(a.get("verbose"), None);
        assert_eq!(a.get("n"), Some("2"));
    }
}
